"""falcon-mamba-7b — 64L d_model=4096 attention-free mamba1, ssm_state=16
[arXiv:2410.05355]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=65_024,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, chunk=64),
)
