"""recurrentgemma-9b — 38L d_model=4096 16H (MQA kv=1) d_ff=12288, RG-LRU +
local attention 1:2 [arXiv:2402.19427]"""
from repro.configs.base import HybridConfig, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12_288,
    vocab_size=256_000,
    head_dim=256,
    act="geglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    hybrid=HybridConfig(pattern=("rec", "rec", "attn"), lru_width=4096,
                        conv_width=4, window=2048, c=8.0),
)
