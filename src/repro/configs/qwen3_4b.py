"""qwen3-4b — 36L d_model=2560 32H (GQA kv=8) d_ff=9728, qk_norm
[hf:Qwen/Qwen3-8B family]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=9728,
    vocab_size=151_936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
