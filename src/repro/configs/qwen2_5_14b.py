"""qwen2.5-14b — 48L d_model=5120 40H (GQA kv=8) d_ff=13824, QKV bias
[hf:Qwen/Qwen2.5 family]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=13_824,
    vocab_size=152_064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)
