"""granite-moe-1b-a400m — 24L d_model=1024 16H (GQA kv=8) MoE 32e top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,                       # MoE expert intermediate size
    vocab_size=49_155,
    head_dim=64,
    rope_theta=10_000.0,
    tie_embeddings=True,
    moe=MoEConfig(num_experts=32, experts_per_token=8, d_ff_expert=512,
                  router_norm_topk=False),
)
