"""qwen2-vl-2b — 28L d_model=1536 12H (GQA kv=2) d_ff=8960, M-RoPE,
dynamic resolution (vision frontend stubbed) [arXiv:2409.12191]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151_936,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),    # temporal/height/width; sums to hd//2
    frontend="patch_stub",
    tie_embeddings=True,
)
