"""qwen2-7b — 28L d_model=3584 28H (GQA kv=4) d_ff=18944, QKV bias
[arXiv:2407.10671]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18_944,
    vocab_size=152_064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)
