"""qwen3-moe-30b-a3b — 48L d_model=2048 32H (GQA kv=4) MoE 128e top-8
[hf:Qwen/Qwen3-30B-A3B]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=768,                       # MoE expert intermediate size
    vocab_size=151_936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=128, experts_per_token=8, d_ff_expert=768,
                  router_norm_topk=True),
)
