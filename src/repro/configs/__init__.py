"""Architecture registry: ``get_config(arch_id)`` / ``ARCHS``."""
from __future__ import annotations

from repro.configs.base import (ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K,
                                TRAIN_4K, EncDecConfig, HybridConfig,
                                ModelConfig, MoEConfig, ShapeConfig, SSMConfig,
                                SwarmConfig, reduced, shape_applicable)
from repro.configs.falcon_mamba_7b import CONFIG as _mamba
from repro.configs.granite_moe_1b_a400m import CONFIG as _granite_moe
from repro.configs.qwen2_5_14b import CONFIG as _qwen25_14b
from repro.configs.qwen2_7b import CONFIG as _qwen2_7b
from repro.configs.qwen2_vl_2b import CONFIG as _qwen2_vl
from repro.configs.qwen3_1_7b import CONFIG as _qwen3_17
from repro.configs.qwen3_4b import CONFIG as _qwen3_4b
from repro.configs.qwen3_moe_30b_a3b import CONFIG as _qwen3_moe
from repro.configs.recurrentgemma_9b import CONFIG as _rgemma
from repro.configs.whisper_medium import CONFIG as _whisper

ARCHS = {
    c.name: c for c in (
        _qwen3_moe, _granite_moe, _qwen3_17, _qwen3_4b, _qwen2_7b,
        _qwen25_14b, _rgemma, _qwen2_vl, _whisper, _mamba,
    )
}

SHAPES = {s.name: s for s in ALL_SHAPES}


def get_config(arch_id: str) -> ModelConfig:
    try:
        return ARCHS[arch_id]
    except KeyError:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}") from None


def get_shape(shape_id: str) -> ShapeConfig:
    try:
        return SHAPES[shape_id]
    except KeyError:
        raise KeyError(f"unknown shape {shape_id!r}; known: {sorted(SHAPES)}") from None


__all__ = [
    "ARCHS", "SHAPES", "get_config", "get_shape", "reduced",
    "shape_applicable", "ModelConfig", "ShapeConfig", "SwarmConfig",
    "MoEConfig", "SSMConfig", "HybridConfig", "EncDecConfig",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K", "ALL_SHAPES",
]
