"""whisper-medium — enc-dec, 24L(+24L enc) d_model=1024 16H (MHA) d_ff=4096,
conv frontend stubbed [arXiv:2212.04356]"""
from repro.configs.base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=24,                  # decoder layers
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51_865,
    head_dim=64,
    norm="layernorm",
    act="gelu",
    learned_pos=True,
    qkv_bias=True,
    attn_out_bias=True,
    frontend="audio_stub",
    tie_embeddings=True,
    encdec=EncDecConfig(encoder_layers=24, source_positions=1500),
)
