"""qwen3-1.7b — 28L d_model=2048 16H (GQA kv=8) d_ff=6144, qk_norm
[hf:Qwen/Qwen3-8B family]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=6144,
    vocab_size=151_936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
