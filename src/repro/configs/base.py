"""Configuration system for the repro framework.

Two config kinds:
  * ModelConfig  — one per assigned architecture (exact public dims).
  * ShapeConfig  — the four assigned input-shape cells.
  * SwarmConfig  — the paper's simulation parameters (Table 2).

All configs are frozen dataclasses; `reduced()` derives the CPU smoke-test
variant of a ModelConfig (same family / same code paths, tiny dims).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    experts_per_token: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_norm_topk: bool = True     # qwen3-style renormalized top-k gate
    router_aux_loss: float = 0.0      # load-balance aux loss coefficient


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                  # 0 => ceil(d_model / 16)
    chunk: int = 64                   # selective-scan chunk length (train)
    # remat each chunk body: backward saves only the [B, d_in, N] carries
    # instead of the per-chunk [B, chunk, d_in, N] scan states (§Perf lever)
    chunk_remat: bool = False


@dataclass(frozen=True)
class HybridConfig:
    # RecurrentGemma/Griffin-style block pattern, repeated over depth.
    pattern: Tuple[str, ...] = ("rec", "rec", "attn")
    lru_width: int = 0                # 0 => d_model
    conv_width: int = 4
    window: int = 2048                # local-attention window
    # RG-LRU constant `c` (power applied to the recurrence gate).
    c: float = 8.0


@dataclass(frozen=True)
class EncDecConfig:
    encoder_layers: int = 0
    source_positions: int = 1500      # whisper-medium 30 s of audio frames
    max_target_positions: int = 32_768  # learned-pos table size (covers cells)
    # the conv frontend is a stub: input_specs() hands pre-computed frame
    # embeddings of shape [B, source_positions, d_model].


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 => d_model // num_heads
    qk_norm: bool = False             # qwen3 per-head RMS norm on q/k
    qkv_bias: bool = False            # qwen2 QKV bias
    attn_out_bias: bool = False
    rope_theta: float = 1_000_000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (sums to head_dim//2)
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    act: str = "swiglu"               # swiglu | geglu | gelu
    tie_embeddings: bool = False
    learned_pos: bool = False         # whisper: learned absolute positions
    frontend: str = "none"            # none | patch_stub | audio_stub
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    # Early-exit head layers (paper §4.3): indices of layer boundaries at which
    # a truncated inference may produce logits. 0 entries => [L//4, L//2].
    exit_layers: Tuple[int, ...] = ()
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # training-side knobs (hillclimb levers, see EXPERIMENTS.md §Perf)
    remat_policy: str = "nothing"     # nothing | dots | none
    attn_chunk: int = 1024            # q-chunk size for the chunked ref attention
    scan_layers: bool = True
    # cast large (>=1M-element) weight matrices to compute dtype *before*
    # use: the ZeRO-3 all-gathers then move bf16 instead of fp32 (2× less
    # ICI traffic); fp32 master copies stay in the optimizer.
    cast_weights_bf16: bool = False
    # compute lm-head logits + CE in sequence chunks of this size (0 = off):
    # avoids materializing the [B, S, vocab] fp32 logits tensor.
    loss_chunk: int = 0
    # serving (prefill/decode) weight layout: True = ZeRO-3 over the batch
    # axes (min memory, per-step all-gathers); False = weights replicated
    # across the data axis (inference has no optimizer state, so they fit —
    # and the per-step weight gathers disappear).  §Perf lever.
    serve_param_fsdp: bool = True
    # pure data parallelism: batch spans BOTH mesh axes, weights are
    # FSDP-sharded over both, nothing is tensor-parallel.  Exact for
    # attention-free per-channel architectures (mamba): the TP out_proj
    # all-reduces disappear and per-device token count drops by the model-
    # axis width.  §Perf lever (beyond-paper sharding scheme).
    pure_dp: bool = False

    # ---- derived ---------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        if self.num_heads == 0:
            return 0
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def q_groups(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def exit_layers_(self) -> Tuple[int, ...]:
        if self.exit_layers:
            return self.exit_layers
        L = self.num_layers
        return (max(L // 4, 1), max(L // 2, 2))

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_subquadratic(self) -> bool:
        """True if long-context decode (500k) is tractable: SSM state or
        bounded local-attention window instead of a full-length KV cache."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        d, hd = self.d_model, self.head_dim_
        Hq, Hkv = self.num_heads, self.num_kv_heads
        attn = d * (Hq * hd) + 2 * d * (Hkv * hd) + (Hq * hd) * d
        if self.qkv_bias:
            attn += (Hq + 2 * Hkv) * hd
        if self.act in ("swiglu", "geglu"):
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        per_layer = 0
        n_attn_layers = self.num_layers
        if self.family == "moe":
            m = self.moe
            moe_mlp = m.num_experts * 3 * d * m.d_ff_expert + d * m.num_experts
            per_layer = attn + moe_mlp + 2 * d
            total = self.num_layers * per_layer
        elif self.family == "ssm":
            s = self.ssm
            d_in = s.expand * d
            dt_rank = s.dt_rank or math.ceil(d / 16)
            blk = (d * 2 * d_in + d_in * s.d_conv
                   + d_in * (dt_rank + 2 * s.d_state) + dt_rank * d_in
                   + d_in * s.d_state + d_in  # A_log, D
                   + d_in * d + d)
            total = self.num_layers * blk
        elif self.family == "hybrid":
            h = self.hybrid
            w = h.lru_width or d
            rec = (2 * d * w + w * h.conv_width + 3 * w  # Λ, gates' diag params
                   + 2 * w * (w // 8)                     # block-diag input gates (a/x)
                   + w * d + 2 * d)
            att = attn + mlp + 2 * d
            n_att = sum(1 for i in range(self.num_layers)
                        if h.pattern[i % len(h.pattern)] == "attn")
            total = n_att * att + (self.num_layers - n_att) * rec
        elif self.family == "encdec":
            e = self.encdec
            enc = e.encoder_layers * (attn + mlp + 2 * d)
            dec = self.num_layers * (2 * attn + mlp + 3 * d)
            total = enc + dec
        else:  # dense / vlm
            total = self.num_layers * (attn + mlp + 2 * d)
        emb = self.vocab_size * d
        total += emb if self.tie_embeddings else 2 * emb
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        m = self.moe
        d = self.d_model
        dense_moe = self.num_layers * m.num_experts * 3 * d * m.d_ff_expert
        active_moe = self.num_layers * m.experts_per_token * 3 * d * m.d_ff_expert
        return int(self.param_count() - dense_moe + active_moe)


# ---------------------------------------------------------------------------
# Input shapes (assigned cells)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str           # train | prefill | decode
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeConfig("train_4k", "train", 4_096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524_288, 1)

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Assignment skip rules. Returns (runnable, reason-if-skipped)."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, ("long_500k requires sub-quadratic attention; "
                       f"{cfg.name} is pure full-attention (skip per brief)")
    return True, ""


# ---------------------------------------------------------------------------
# Smoke-test reduction
# ---------------------------------------------------------------------------


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests (same code paths)."""
    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=len(cfg.hybrid.pattern) + 2 if cfg.family == "hybrid" else 2,
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) or 1,
        d_ff=128,
        head_dim=16,
        vocab_size=256,
        attn_chunk=32,
        scan_layers=cfg.scan_layers,
    )
    if cfg.mrope_sections:
        kw["mrope_sections"] = (2, 3, 3)   # sums to head_dim//2 = 8
    if cfg.moe:
        # capacity_factor = E guarantees zero drops (worst case: every
        # assignment routes to one expert), making smoke tests exact.
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, experts_per_token=2, d_ff_expert=32,
            capacity_factor=4.0)
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=4, chunk=8)
    if cfg.hybrid:
        kw["hybrid"] = dataclasses.replace(cfg.hybrid, lru_width=64, window=16)
    if cfg.encdec:
        kw["encdec"] = dataclasses.replace(
            cfg.encdec, encoder_layers=2, source_positions=24)
    return dataclasses.replace(cfg, **kw)


# ---------------------------------------------------------------------------
# Swarm (paper Table 2)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SwarmConfig:
    num_workers: int = 30
    area_m: float = 20_000.0                 # 20×20 km
    placement_granularity: int = 15
    movement_radius_m: float = 1_000.0
    speed_mps: float = 75.0
    capability_mean: float = 400.0           # GFLOP/s, N(400,100)
    capability_std: float = 100.0
    energy_per_gflop_j: float = 0.02
    task_period_s: float = 0.060             # Markov mean inter-arrival
    # Markov-modulated (bursty) arrivals: per-node ON/OFF chain; long-run
    # mean inter-arrival stays task_period_s, bursts arrive at rate
    # 1/(period*duty) while ON ("event-triggered bursty loads", Fig. 1).
    burst_on_s: float = 2.0                  # mean burst duration
    burst_off_s: float = 6.0                 # mean quiet duration
    exit_points: Tuple[int, int, int] = (15, 30, 60)       # L1, L2, L_full
    exit_finalize_layers: int = 3
    exit_thresholds: Tuple[float, float] = (1.5, 2.5)      # τ_med, τ_high
    exit_accuracy: Tuple[float, float, float] = (0.6, 0.9, 0.95)
    tx_power_dbm: float = 30.0
    noise_dbm: float = -85.0
    snr_min_db: float = 3.0
    bandwidth_hz: float = 10e6
    sim_time_s: float = 100.0
    gamma: float = 0.02                      # distributed offload threshold
    decision_period_s: float = 0.200
    random_offload_p: float = 0.2
    random_acyclic_p: float = 0.1
    greedy_offload_p: float = 0.05
    ema_alpha: float = 0.3                   # smoothing α (Eq. 15)
    # --- simulator discretization (DESIGN.md §3) ---
    tick_s: float = 0.010
    queue_slots: int = 128
    altitude_m: float = 100.0                # two-ray antenna heights
    num_runs: int = 50
    early_exit_enabled: bool = False
    # --- scenario engine (DESIGN.md §3.4): string-keyed model selection ---
    # Every field below is static under jit, so sweeping scenarios is a pure
    # config change — no code edits, one executable per (cfg, n) pair.
    # mobility: circular|random_waypoint|gauss_markov|levy_flight
    mobility_model: str = "circular"
    # channel: two_ray|free_space|log_normal|log_normal_corr|rician|nakagami
    channel_model: str = "two_ray"
    fault_model: str = "none"                # none|markov
    # random-waypoint / Gauss-Markov / Lévy mobility parameters
    speed_min_mps: float = 25.0
    speed_max_mps: float = 100.0
    gm_alpha: float = 0.85                   # Gauss-Markov velocity memory
    gm_sigma_mps: float = 20.0               # Gauss-Markov velocity noise
    levy_alpha: float = 1.6                  # Pareto tail of Lévy hop length
    # free-space / log-normal / fading channel parameters
    carrier_hz: float = 2.4e9
    # log-distance exponent (1 m reference); at the 20 km mission scale,
    # 2.0 keeps a sparse multi-hop topology — exponents > 2.2 disconnect it
    pathloss_exp: float = 2.0
    shadowing_sigma_db: float = 6.0          # log-normal shadowing std
    rician_k_db: float = 6.0                 # Rician K-factor (LoS/NLoS dB)
    nakagami_m: float = 2.0                  # Nakagami shape (1 = Rayleigh)
    # Gudmundson decorrelation distance of the spatially-correlated
    # shadowing model (log_normal_corr): shadowing processes of two nodes
    # d metres apart correlate as exp(-d / shadow_corr_m)
    shadow_corr_m: float = 500.0
    # node fault/churn (markov): mean dwell times of the up/down chain
    fault_mean_up_s: float = 30.0
    fault_mean_down_s: float = 5.0
    # --- neighbor representation (DESIGN.md §11) ---
    # "dense" keeps the historical [N, N] adjacency/capacity hot path
    # (bit-compatible with every earlier PR); "sparse" switches the epoch
    # update to fixed-width [N, K] neighbor lists built by the spatial-hash
    # search in swarm/neighbors.py — per-epoch cost O(N·k) instead of
    # O(N²), exact vs dense whenever neighbor_k covers the true max degree
    # (truncated-degree approximation beyond that).
    neighbor_mode: str = "dense"             # dense|sparse
    neighbor_k: int = 16                     # neighbor-list width K
    # bucket-grid knobs (0 = auto-derived from N, K and the channel range):
    # candidate radius of the grid search in metres, and the fixed per-cell
    # candidate capacity of the sorted-grid buckets
    neighbor_range_m: float = 0.0
    neighbor_cell_cap: int = 0
    # task profile (illustrative detection CNN, DESIGN.md §3)
    task_layers: int = 60
    task_gflops_total: float = 12.0
    # --- per-task telemetry (repro.trace, DESIGN.md §10) ---
    # > 0 enables in-scan TaskRecord capture: one fixed-width record per
    # completed/dropped task, scattered by global seq into a buffer of this
    # many slots (records with seq >= capacity are counted as overflow, not
    # captured).  0 (default) is fully off — no trace state exists and
    # every metric is bit-identical to an untraced build.
    trace_capacity: int = 0
    # > 0 enables the second in-scan stream: one fixed-width HopRecord per
    # *delivered transfer* (seq/src/dst/t_depart/t_arrive/bits/
    # boundary_layer/stall_ticks), scattered by a dedicated hop sequence
    # counter assigned at transfer initiation.  Independent of
    # trace_capacity (either stream can be on alone); 0 (default) is fully
    # off with the same zero-cost guarantee.
    trace_hop_capacity: int = 0
    # > 0 enables the third in-scan stream, the swarm-state "flight
    # recorder" (DESIGN.md §12): every trace_state_every-th epoch captures
    # per-node gauges (phi / queue depth / cumulative energy / alive /
    # in-flight bits) plus system aggregates into epoch-indexed buffers of
    # ceil(n_epochs / every) slots.  Memory is O(E/stride · min(N, nodes));
    # 0 (default) is fully off with the same zero-cost guarantee as the
    # task/hop streams.
    trace_state_every: int = 0
    # optional node subsample for the state stream: record gauges only for
    # the first min(N, trace_state_nodes) nodes (deterministic prefix —
    # node identity is arbitrary under i.i.d. placement, so a prefix is an
    # unbiased panel).  System aggregates always span all N nodes.
    # 0 records every node.
    trace_state_nodes: int = 0
