"""Declarative scenario sweeps (DESIGN.md §8.1).

A :class:`SweepSpec` names a scenario grid — config-field axes × offloading
strategies × Monte-Carlo runs — and ``expand()`` unrolls it into concrete
:class:`SweepPoint`\\ s, one per grid cell.  Each point carries a fully
resolved static ``SwarmConfig``, so executing a point is exactly one
``(cfg, n)`` compile of the simulator regardless of backend; the
Monte-Carlo/seed axis inside a point is the *batched* axis the executors
vmap / shard / stream over (``fleet/executor.py``).

Axes come in two shapes:

  * **field axis** — the axis name is a ``SwarmConfig`` field and each value
    is assigned to it directly: ``{"gamma": (0.01, 0.02)}``;
  * **composite axis** — each value is a ``(label, overrides)`` pair where
    ``overrides`` is a dict of config fields, for grid dimensions that move
    several fields at once: ``{"scenario": (("rwp", {"mobility_model":
    "random_waypoint", "channel_model": "log_normal"}), ...)}``.

Unknown field names fail loudly at expansion time (same philosophy as the
scenario registries: a typo'd sweep dies before it compiles).  Note that
``SwarmConfig`` is *static* under jit by design — the grid expands into
per-point configs rather than a batched config pytree, because every
config field change retraces anyway; only the seed axis is batched.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
from typing import Any, Dict, Mapping, NamedTuple, Sequence, Tuple

from repro.configs.base import SwarmConfig

_CFG_FIELDS = {f.name for f in dataclasses.fields(SwarmConfig)}
# tuple-typed config fields (exit_points, …) — JSON lists convert back
_CFG_TUPLE_FIELDS = {f.name for f in dataclasses.fields(SwarmConfig)
                     if isinstance(getattr(SwarmConfig(), f.name), tuple)}


def _cfg_from_dict(d: Mapping[str, Any]) -> SwarmConfig:
    return SwarmConfig(**{k: tuple(v) if k in _CFG_TUPLE_FIELDS else v
                          for k, v in d.items()})


class SweepPoint(NamedTuple):
    """One grid cell: a static config + strategy, with its seed axis."""
    label: str                           # "gamma=0.02/strategy=Distributed"
    coords: Tuple[Tuple[str, Any], ...]  # ((axis, value-or-label), ...)
    cfg: SwarmConfig
    strategy: int
    n: int                               # swarm size (= cfg.num_workers)
    num_runs: int                        # Monte-Carlo axis length
    seed: int

    @property
    def values(self) -> Dict[str, Any]:
        return dict(self.coords)


def _strategy_name(s: int) -> str:
    from repro.swarm.simulator import STRATEGY_NAMES
    return STRATEGY_NAMES[s]


def _apply_axis(axis: str, value: Any) -> Tuple[Any, Dict[str, Any]]:
    """Returns (coordinate label/value, config overrides) for one cell."""
    if isinstance(value, tuple) and len(value) == 2 and isinstance(
            value[1], Mapping):
        label, overrides = value
        bad = set(overrides) - _CFG_FIELDS
        if bad:
            raise ValueError(
                f"sweep axis {axis!r} cell {label!r} overrides unknown "
                f"SwarmConfig fields {sorted(bad)}")
        return label, dict(overrides)
    if axis not in _CFG_FIELDS:
        raise ValueError(
            f"sweep axis {axis!r} is not a SwarmConfig field; either use a "
            "known field name or (label, overrides-dict) cell values")
    return value, {axis: value}


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """A scenario grid: axes × strategies × seeds, expanded lazily."""
    name: str
    base: SwarmConfig = SwarmConfig()
    # ordered ((axis, (cell, ...)), ...); see module docstring for cell forms
    axes: Tuple[Tuple[str, Tuple[Any, ...]], ...] = ()
    strategies: Tuple[int, ...] = (4,)   # DISTRIBUTED
    num_runs: int = 16
    seed: int = 0

    @classmethod
    def build(cls, name: str, base: SwarmConfig | None = None, *,
              axes: Mapping[str, Sequence[Any]] | None = None,
              strategies: Sequence[int] = (4,), num_runs: int = 16,
              seed: int = 0) -> "SweepSpec":
        """Normalizing constructor: accepts a mapping/sequences for axes."""
        base = SwarmConfig() if base is None else base
        ax = tuple((k, tuple(v)) for k, v in (axes or {}).items())
        return cls(name=name, base=base, axes=ax,
                   strategies=tuple(int(s) for s in strategies),
                   num_runs=int(num_runs), seed=int(seed))

    def expand(self) -> Tuple[SweepPoint, ...]:
        axis_names = [a for a, _ in self.axes]
        axis_cells = [cells for _, cells in self.axes]
        points = []
        for combo in itertools.product(*axis_cells) if axis_cells else [()]:
            coords, overrides = [], {}
            for axis, cell in zip(axis_names, combo, strict=True):
                coord, ov = _apply_axis(axis, cell)
                coords.append((axis, coord))
                overrides.update(ov)
            cfg = (dataclasses.replace(self.base, **overrides)
                   if overrides else self.base)
            for s in self.strategies:
                label = "/".join([f"{a}={c}" for a, c in coords]
                                 + [f"strategy={_strategy_name(s)}"])
                points.append(SweepPoint(
                    label=label, coords=tuple(coords), cfg=cfg,
                    strategy=int(s), n=cfg.num_workers,
                    num_runs=self.num_runs, seed=self.seed))
        return tuple(points)

    def __len__(self) -> int:
        n = len(self.strategies)
        for _, cells in self.axes:
            n *= len(cells)
        return n

    # ---- cross-process contract (fleet/dispatch.py) ----------------------

    def to_json(self) -> str:
        """Serialize the spec for dispatch workers (other processes/hosts).

        The JSON round-trips exactly: ``from_json(to_json())`` expands to
        the same points with the same digests, which is what lets a remote
        worker claim and compute points for a sweep it never constructed.
        """
        return json.dumps({
            "name": self.name,
            "base": dataclasses.asdict(self.base),
            "axes": [[a, list(cells)] for a, cells in self.axes],
            "strategies": list(self.strategies),
            "num_runs": self.num_runs,
            "seed": self.seed,
        }, sort_keys=True)

    @classmethod
    def from_json(cls, blob: str) -> "SweepSpec":
        doc = json.loads(blob)

        def cell(c):
            # composite cells serialize as [label, {overrides}]; everything
            # else is a plain config value (lists were tuples)
            if (isinstance(c, list) and len(c) == 2
                    and isinstance(c[1], dict)):
                # tuple-typed override values (exit_points, …) came through
                # JSON as lists; restore them or the rebuilt frozen config
                # is unhashable under jit's static cfg argument
                return (c[0], {k: tuple(v) if k in _CFG_TUPLE_FIELDS
                               and isinstance(v, list) else v
                               for k, v in c[1].items()})
            return tuple(c) if isinstance(c, list) else c

        return cls(
            name=doc["name"], base=_cfg_from_dict(doc["base"]),
            axes=tuple((a, tuple(cell(c) for c in cells))
                       for a, cells in doc["axes"]),
            strategies=tuple(int(s) for s in doc["strategies"]),
            num_runs=int(doc["num_runs"]), seed=int(doc["seed"]))
