"""`repro.fleet` — sharded Monte-Carlo sweep engine (DESIGN.md §8).

Declare a scenario grid as a :class:`SweepSpec`, execute it on any backend
(``vmap`` / ``sharded`` / ``streaming`` — bit-identical), cache/resume
through :class:`ResultStore`, aggregate with :mod:`repro.fleet.report`.
"""
from repro.fleet.executor import (BACKENDS, SweepInterrupted, execute,
                                  run_batch, run_point)
from repro.fleet.report import (build_report, ci95, latency_cdf,
                                load_bench_json, point_indices,
                                write_bench_json)
from repro.fleet.store import ResultStore, code_version, point_digest
from repro.fleet.sweep import SweepPoint, SweepSpec

__all__ = ["SweepSpec", "SweepPoint", "BACKENDS", "SweepInterrupted",
           "execute", "run_batch", "run_point",
           "ResultStore", "point_digest", "code_version",
           "build_report", "point_indices", "latency_cdf", "ci95",
           "load_bench_json", "write_bench_json"]
