"""`repro.fleet` — sharded Monte-Carlo sweep engine (DESIGN.md §8-9).

Declare a scenario grid as a :class:`SweepSpec`, execute it on any backend
(``vmap`` / ``sharded`` / ``streaming`` — bit-identical), cache/resume
through :class:`ResultStore`, aggregate with :mod:`repro.fleet.report`,
and scale the point axis over processes/hosts with
:mod:`repro.fleet.dispatch`.
"""
from repro.fleet.dispatch import (ProgressWriter, WorkerEnv, collect,
                                  dispatch, progress_summary, publish_spec,
                                  read_progress, render_progress, run_sweep,
                                  run_worker, spawn_workers, worker_env)
from repro.fleet.executor import (BACKENDS, SweepInterrupted, execute,
                                  run_batch, run_point)
from repro.fleet.report import (build_report, ci95, latency_cdf,
                                load_bench_json, point_indices,
                                write_bench_json)
from repro.fleet.store import ResultStore, code_version, point_digest
from repro.fleet.sweep import SweepPoint, SweepSpec

__all__ = ["SweepSpec", "SweepPoint", "BACKENDS", "SweepInterrupted",
           "execute", "run_batch", "run_point",
           "ResultStore", "point_digest", "code_version",
           "build_report", "point_indices", "latency_cdf", "ci95",
           "load_bench_json", "write_bench_json",
           "dispatch", "run_sweep", "run_worker", "spawn_workers",
           "collect", "publish_spec", "worker_env", "WorkerEnv",
           "ProgressWriter", "read_progress", "progress_summary",
           "render_progress"]
