"""Content-addressed sweep-result store with resumable checkpoints
(DESIGN.md §8.3).

A sweep point is addressed by the SHA-256 of everything that determines its
numbers: the full ``SwarmConfig``, strategy, swarm size, Monte-Carlo run
count, seed, and a git-describable code version.  Because the executor
backends are bit-identical (tested), the digest deliberately excludes the
backend — a result computed by the streaming path on one host is a valid
cache hit for a ``vmap`` re-run on another.

Layout under the store root::

    <root>/<digest[:2]>/<digest>/result.json    # final (atomic rename)
    <root>/<digest[:2]>/<digest>/partial/       # repro.checkpoint chunk dir

``result.json`` stores per-run float32 metrics as JSON floats; float32 →
float64 → decimal → float32 round-trips exactly, so a cache hit reproduces
the computed arrays bit-for-bit.  Partial progress from the streaming
backend goes through ``repro.checkpoint.ckpt`` (atomic ``step_<k>`` dirs):
a sweep killed mid-point resumes at the last completed chunk and, because
per-run results are bitwise stable, yields the same ``BENCH_fleet.json`` as
an uninterrupted run (tested in ``tests/test_fleet.py``).
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
import os
import shutil
import subprocess
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.checkpoint import ckpt
from repro.fleet.sweep import SweepPoint


def _git(args, cwd, text=True):
    out = subprocess.run(["git"] + args, cwd=cwd, capture_output=True,
                         text=text, timeout=30)
    if out.returncode != 0:
        raise RuntimeError(f"git {args[0]} failed: {out.stderr}")
    return out.stdout


def _dirty_digest(cwd: str) -> str:
    """Content hash of everything uncommitted: the tracked diff plus each
    untracked (non-ignored) file.  A bare ``--dirty`` suffix would alias
    *every* dirty tree to one cache version and serve stale results across
    uncommitted edits."""
    h = hashlib.sha256(_git(["diff", "HEAD"], cwd, text=False))
    for rel in _git(["ls-files", "--others", "--exclude-standard"],
                    cwd).splitlines():
        h.update(rel.encode())
        path = os.path.join(cwd, rel)
        if os.path.isfile(path):
            with open(path, "rb") as f:
                h.update(f.read())
    return h.hexdigest()[:12]


@functools.lru_cache(maxsize=1)
def code_version() -> str:
    """Git-describable code version for cache keys.

    ``REPRO_CODE_VERSION`` overrides (hermetic builds / tests); falls back
    to ``git describe --always --dirty`` at this file's repo — with the
    ``-dirty`` suffix refined by a content hash of the uncommitted changes,
    so editing the code always moves the cache key — then to ``"unknown"``
    outside a git checkout (deployments without git should pin
    ``REPRO_CODE_VERSION`` to a build id, or stale hits become possible).
    """
    env = os.environ.get("REPRO_CODE_VERSION")
    if env:
        return env
    cwd = os.path.dirname(os.path.abspath(__file__))
    try:
        desc = _git(["describe", "--always", "--dirty"], cwd).strip()
        if desc.endswith("-dirty"):
            desc += "." + _dirty_digest(cwd)
        return desc or "unknown"
    except (OSError, RuntimeError, subprocess.SubprocessError):
        return "unknown"


def _compact_trace(key: str, v) -> np.ndarray:
    """Trim trailing all-unwritten slots off a record buffer
    (``trace_records`` / ``trace_hops``).

    Slots are seq-indexed, so a buffer sized generously above the record
    count is mostly ``seq = -1`` sentinel rows; persisting them as JSON
    would bloat ``result.json`` by the (capacity / records) ratio.  Only
    slots past the last written seq of *any* run are dropped — per-run
    shape structure and every written record survive, so decode/export of
    a cache hit equals the freshly computed buffer.  Both schemas keep
    ``seq`` in column 0 (asserted), so one trim covers both streams.

    The flight-recorder buffers (``trace_state`` / ``trace_state_sys`` /
    ``trace_state_epochs``) are *epoch*-indexed with exact static size
    S = ceil(n_epochs / every) — no sentinel slack to trim — so they pass
    through here untouched (nested ``tolist`` in ``put`` round-trips any
    rank).
    """
    rec = np.asarray(v, np.float32)
    if (key not in ("trace_records", "trace_hops") or rec.ndim != 3
            or rec.shape[1] == 0):
        return rec
    from repro.trace import schema
    assert schema.SEQ == 0 and schema.HOP_SEQ == 0
    written = np.nonzero((rec[..., 0] >= 0).any(axis=0))[0]
    return rec[:, :int(written[-1]) + 1 if written.size else 0]


def point_digest(point: SweepPoint, version: Optional[str] = None) -> str:
    """Content address of a sweep point's result."""
    payload = {
        "cfg": dataclasses.asdict(point.cfg),
        "strategy": int(point.strategy),
        "n": int(point.n),
        "num_runs": int(point.num_runs),
        "seed": int(point.seed),
        "code_version": version if version is not None else code_version(),
    }
    blob = json.dumps(payload, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


class ResultStore:
    """Digest-keyed result cache + per-chunk resume state for one store root."""

    def __init__(self, root: str):
        self.root = root

    def _dir(self, digest: str) -> str:
        return os.path.join(self.root, digest[:2], digest)

    def _partial_dir(self, digest: str) -> str:
        return os.path.join(self._dir(digest), "partial")

    def _lease_path(self, digest: str) -> str:
        return os.path.join(self.root, "leases", digest + ".json")

    # ---- final results ---------------------------------------------------

    def has(self, digest: str) -> bool:
        return os.path.exists(os.path.join(self._dir(digest), "result.json"))

    def get(self, digest: str) -> Optional[Dict[str, np.ndarray]]:
        path = os.path.join(self._dir(digest), "result.json")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            doc = json.load(f)
        return {k: np.asarray(v, np.float32)
                for k, v in doc["metrics"].items()}

    def put(self, digest: str, metrics: Dict[str, np.ndarray],
            meta: Optional[Dict] = None) -> str:
        d = self._dir(digest)
        os.makedirs(d, exist_ok=True)
        # nested tolist() keeps array shapes (the trace record buffers are
        # [num_runs, capacity, fields]); for the historical 1-D metric
        # vectors the emitted JSON is byte-identical to the flat form
        doc = {
            "meta": meta or {},
            "metrics": {k: _compact_trace(k, v).tolist()
                        for k, v in metrics.items()},
        }
        tmp = os.path.join(d, "result.json.tmp")
        with open(tmp, "w") as f:
            json.dump(doc, f)
        os.replace(tmp, os.path.join(d, "result.json"))
        self.clear_partial(digest)
        return os.path.join(d, "result.json")

    # ---- streaming-resume chunk checkpoints ------------------------------

    def save_partial(self, digest: str, chunks_done: int,
                     accum: Dict[str, np.ndarray],
                     chunk_size: int) -> None:
        """Checkpoint the first ``chunks_done`` chunks' per-run metrics."""
        ckpt.save(self._partial_dir(digest), chunks_done, dict(accum),
                  keep=1, extra={"metrics": sorted(accum),
                                 "chunk_size": int(chunk_size)})

    def load_partial(self, digest: str, chunk_size: Optional[int] = None
                     ) -> Tuple[int, Optional[Dict[str, np.ndarray]]]:
        """Returns (chunks_done, accum) of the newest partial checkpoint,
        or (0, None) when there is nothing to resume.

        ``chunks_done`` only indexes runs together with the chunk size it
        was written under — with ``chunk_size`` given, a partial written
        under a *different* chunking is discarded (resuming it would skip
        or duplicate Monte-Carlo runs) and the sweep restarts cleanly.
        """
        d = self._partial_dir(digest)
        step = ckpt.latest_step(d)
        if step is None:
            return 0, None
        with open(os.path.join(d, f"step_{step:08d}",
                               "manifest.json")) as f:
            extra = json.load(f)["extra"]
        if chunk_size is not None and extra.get("chunk_size") != chunk_size:
            self.clear_partial(digest)
            return 0, None
        like = {k: 0 for k in extra["metrics"]}
        tree, _ = ckpt.restore(d, like, step=step)
        return step, {k: np.asarray(v) for k, v in tree.items()}

    def clear_partial(self, digest: str) -> None:
        shutil.rmtree(self._partial_dir(digest), ignore_errors=True)

    # ---- point leases (fleet/dispatch.py work-stealing) ------------------
    #
    # A lease is an advisory exclusive claim on a point, held by one worker
    # while it computes.  ``try_claim`` is an atomic create-exclusive of a
    # JSON lease file; a lease whose deadline passed is *stealable*: any
    # worker may remove it and re-claim, so points held by a killed worker
    # return to the pool after ``ttl_s`` (the fleet-level analogue of the
    # paper's fault-tolerant forwarding — stalled work resumes elsewhere).
    #
    # The unlink-then-create steal has a benign TOCTOU window (two stealers
    # may both end up computing the point): leases only need *liveness*,
    # not mutual exclusion, because execution is idempotent — results are
    # content-addressed and bit-identical across backends and workers, and
    # ``put`` publishes by atomic rename.  A double-claim costs wall time,
    # never correctness.

    def try_claim(self, digest: str, owner: str, ttl_s: float) -> bool:
        """Claim ``digest`` for ``owner`` until ``now + ttl_s``.

        Returns False when another worker holds an unexpired lease.  An
        expired lease is stolen (removed and re-claimed).
        """
        path = self._lease_path(digest)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        doc = json.dumps({"digest": digest, "owner": owner,
                          "deadline": time.time() + ttl_s})
        for _ in range(2):          # second pass: after stealing an expiry
            try:
                fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
            except FileExistsError:
                info = self.lease_info(digest)
                if info is not None and info["deadline"] > time.time():
                    return False    # live lease held elsewhere
                try:                # expired (or unreadable): steal
                    os.unlink(path)
                except FileNotFoundError:
                    pass            # a racing stealer got there first
                continue
            with os.fdopen(fd, "w") as f:
                f.write(doc)
            return True
        return False

    def renew_lease(self, digest: str, owner: str, ttl_s: float) -> bool:
        """Extend ``owner``'s lease; False if it was lost (stolen/expired)."""
        info = self.lease_info(digest)
        if info is None or info["owner"] != owner:
            return False
        path = self._lease_path(digest)
        tmp = path + f".{owner}.tmp"
        with open(tmp, "w") as f:
            json.dump({"digest": digest, "owner": owner,
                       "deadline": time.time() + ttl_s}, f)
        os.replace(tmp, path)
        return True

    def release_lease(self, digest: str, owner: Optional[str] = None
                      ) -> None:
        """Remove the lease; with ``owner`` given, only if still held by
        that owner — a worker whose lease was stolen must not unlink the
        stealer's fresh lease on its way out."""
        if owner is not None:
            info = self.lease_info(digest)
            if info is not None and info.get("owner") != owner:
                return
        try:
            os.unlink(self._lease_path(digest))
        except FileNotFoundError:
            pass

    def lease_info(self, digest: str) -> Optional[Dict]:
        """{"owner", "deadline"} of the current lease, or None."""
        try:
            with open(self._lease_path(digest)) as f:
                return json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return None
