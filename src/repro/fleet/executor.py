"""Execution backends for Monte-Carlo sweep points (DESIGN.md §8.2).

All three backends batch the *seed* axis of one :class:`SweepPoint` around
the single-simulation ``run_sim`` and are bit-identical on equal
``(cfg, strategy, n, num_runs, seed)`` — proven by tests — so the choice is
purely operational:

  * ``vmap``      — one fused executable over all runs on one device; the
                    default, and exactly the historical ``run_many`` path
                    (``swarm.run_many`` routes here, so the simulator and
                    the benchmarks share this batching code).
  * ``sharded``   — ``shard_map`` over a 1-D ``("mc",)`` device mesh (built
                    through ``repro.compat.shard_map``, same shim as
                    ``models/moe.py``): each device vmaps its slice of the
                    run axis.  Run count is padded up to the device count by
                    repeating the last key (padding is computed then
                    discarded — never over-split the key, key-prefix
                    stability does not hold across split widths).
  * ``streaming`` — a host loop over fixed-size chunks; inside a chunk
                    ``jax.lax.map`` runs simulations *serially* with the
                    chunk key buffer donated, so peak memory is one swarm
                    state + the per-run summary rows regardless of N or run
                    count (the N ≥ 1k regime).  With a store attached, each
                    completed chunk checkpoints, and a killed sweep resumes
                    at the last completed chunk.

Strategy ids stay *traced* scalars (one executable covers all five
strategies per cfg), configs stay static — identical compile economics to
the simulator itself.

Per-task and per-hop telemetry (DESIGN.md §10) ride through every backend
unchanged: a traced config (``trace_capacity > 0`` and/or
``trace_hop_capacity > 0``) adds ``trace_records`` / ``trace_overflow``
(and ``trace_hops`` / ``trace_hop_overflow``) leaves to the metric dict,
which vmap/shard_map batch over the run axis and the streaming loop
concatenates per chunk — so record buffers are bit-identical across
backends and survive the same chunk-level checkpoint resume as the
scalar metrics (tested in ``tests/test_trace.py`` /
``tests/test_hops.py``).
"""
from __future__ import annotations

import functools
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map
from repro.configs.base import SwarmConfig
from repro.fleet.store import ResultStore, code_version, point_digest
from repro.fleet.sweep import SweepPoint, SweepSpec
from repro.swarm.simulator import run_sim

BACKENDS = ("vmap", "sharded", "streaming")
DEFAULT_CHUNK = 8


class SweepInterrupted(RuntimeError):
    """Raised by the streaming backend when ``max_chunks`` is reached —
    a deterministic stand-in for preemption in resume tests; progress up to
    the interrupt is checkpointed in the store."""


def _pad_keys(keys: jax.Array, to: int) -> jax.Array:
    pad = to - keys.shape[0]
    if pad <= 0:
        return keys
    return jnp.concatenate(
        [keys, jnp.broadcast_to(keys[-1:], (pad,) + keys.shape[1:])], axis=0)


# ---------------------------------------------------------------------------
# backends (each: key -> dict of [num_runs] metric arrays)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg", "n", "num_runs"))
def _vmap_call(key, cfg: SwarmConfig, strategy, n: int, num_runs: int):
    keys = jax.random.split(key, num_runs)
    return jax.vmap(lambda k: run_sim(k, cfg, strategy, n))(keys)


@functools.partial(jax.jit, static_argnames=("cfg", "n", "mesh"))
def _sharded_call(keys, cfg: SwarmConfig, strategy, n: int, mesh):
    from jax.sharding import PartitionSpec as P
    return shard_map(
        lambda ks: jax.vmap(lambda k: run_sim(k, cfg, strategy, n))(ks),
        mesh=mesh, in_specs=P("mc"), out_specs=P("mc"))(keys)


@functools.lru_cache(maxsize=2)
def _stream_chunk_fn(donate: bool):
    def chunk(keys, cfg: SwarmConfig, strategy, n: int):
        return jax.lax.map(lambda k: run_sim(k, cfg, strategy, n), keys)
    return jax.jit(chunk, static_argnames=("cfg", "n"),
                   donate_argnums=(0,) if donate else ())


def _stream_chunk(keys, cfg: SwarmConfig, strategy, n: int):
    # donate the chunk key buffer where the runtime honors it (TPU/GPU —
    # the memory-bounded regime streaming exists for); CPU XLA declines
    # donation and would warn on every compile
    return _stream_chunk_fn(jax.default_backend() != "cpu")(
        keys, cfg, strategy, n)


def _run_sharded(key, cfg: SwarmConfig, strategy, n: int, num_runs: int):
    from jax.sharding import Mesh
    devs = np.asarray(jax.devices())
    mesh = Mesh(devs, ("mc",))
    padded = (num_runs + len(devs) - 1) // len(devs) * len(devs)
    keys = _pad_keys(jax.random.split(key, num_runs), padded)
    out = _sharded_call(keys, cfg, strategy, n, mesh)
    return jax.tree.map(lambda x: x[:num_runs], out)


def _run_streaming(key, cfg: SwarmConfig, strategy, n: int, num_runs: int,
                   chunk_size: int, store: Optional[ResultStore] = None,
                   digest: Optional[str] = None,
                   max_chunks: Optional[int] = None
                   ) -> Dict[str, np.ndarray]:
    chunk = max(1, min(chunk_size, num_runs))
    n_chunks = (num_runs + chunk - 1) // chunk
    keys = jax.random.split(key, num_runs)

    done, accum = 0, None
    if store is not None and digest is not None:
        done, accum = store.load_partial(digest, chunk_size=chunk)
        done = min(done, n_chunks)

    for c in range(done, n_chunks):
        if max_chunks is not None and c >= max_chunks:
            raise SweepInterrupted(
                f"stopped after {c}/{n_chunks} chunks (max_chunks)")
        ks = _pad_keys(keys[c * chunk:(c + 1) * chunk], chunk)
        out = _stream_chunk(ks, cfg, strategy, n)
        out = {k: np.asarray(v) for k, v in out.items()}
        if accum is None:
            accum = out
        else:
            accum = {k: np.concatenate([accum[k], out[k]]) for k in accum}
        if store is not None and digest is not None:
            store.save_partial(digest, c + 1, accum, chunk)

    return {k: v[:num_runs] for k, v in accum.items()}


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


def run_batch(key, cfg: SwarmConfig, strategy, n: int, num_runs: int, *,
              backend: str = "vmap", chunk_size: int = DEFAULT_CHUNK):
    """Run ``num_runs`` Monte-Carlo simulations of ``(cfg, strategy, n)``.

    Returns a dict of ``[num_runs]`` metric arrays (see ``summarize``),
    bit-identical across backends.  ``swarm.run_many`` is a thin wrapper
    over the ``vmap`` backend of this function.
    """
    if backend == "vmap":
        return _vmap_call(key, cfg, strategy, n, num_runs)
    if backend == "sharded":
        return _run_sharded(key, cfg, strategy, n, num_runs)
    if backend == "streaming":
        return {k: jnp.asarray(v) for k, v in _run_streaming(
            key, cfg, strategy, n, num_runs, chunk_size).items()}
    raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")


def run_point(point: SweepPoint, *, backend: str = "vmap",
              store: Optional[ResultStore] = None,
              chunk_size: int = DEFAULT_CHUNK,
              max_chunks: Optional[int] = None) -> Dict[str, np.ndarray]:
    """Execute one sweep point, consulting/filling ``store`` if given."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
    digest = point_digest(point) if store is not None else None
    if store is not None:
        hit = store.get(digest)
        if hit is not None:
            return hit
    key = jax.random.PRNGKey(point.seed)
    if backend == "streaming":
        metrics = _run_streaming(key, point.cfg, jnp.int32(point.strategy),
                                 point.n, point.num_runs, chunk_size,
                                 store=store, digest=digest,
                                 max_chunks=max_chunks)
    else:
        out = run_batch(key, point.cfg, jnp.int32(point.strategy), point.n,
                        point.num_runs, backend=backend)
        metrics = {k: np.asarray(v) for k, v in out.items()}
    if store is not None:
        store.put(digest, metrics, meta={
            "label": point.label, "backend": backend,
            "code_version": code_version()})
    return metrics


def execute(spec: SweepSpec, *, backend: str = "vmap",
            store: Optional[ResultStore] = None,
            chunk_size: int = DEFAULT_CHUNK,
            verbose: bool = False,
            progress=None) -> Dict[str, Dict[str, np.ndarray]]:
    """Expand and run a whole sweep; returns ``{point.label: metrics}``.

    Each point's wall time (including any cache hit) is recorded under the
    ``"_wall_s"`` pseudo-metric, matching the historical ``timed_sweep``
    convention the benchmark CSVs rely on.  ``progress`` is an optional
    ``ProgressWriter`` (``fleet/dispatch.py``): the single-process path
    then emits the same ``progress.jsonl`` rows as a dispatched run, so
    ``benchmarks/run.py --watch`` works either way.
    """
    points = spec.expand()
    if progress is not None:
        progress.emit(event="sweep_start", sweep=spec.name,
                      total=len(points), t=time.time())
    out = {}
    for pt in points:
        t0 = time.perf_counter()
        m = dict(run_point(pt, backend=backend, store=store,
                           chunk_size=chunk_size))
        m["_wall_s"] = time.perf_counter() - t0
        if verbose:
            print(f"[fleet:{spec.name}] {pt.label} "
                  f"({m['_wall_s']:.2f}s, backend={backend})")
        if progress is not None:
            progress.emit(event="point", label=pt.label,
                          digest=point_digest(pt) if store is not None
                          else None,
                          worker="local", num_runs=pt.num_runs,
                          wall_s=round(m["_wall_s"], 3), t=time.time())
        out[pt.label] = m
    return out
