"""Execution backends for Monte-Carlo sweep points (DESIGN.md §8.2).

All three backends batch the *seed* axis of one :class:`SweepPoint` around
the single-simulation ``run_sim`` and are bit-identical on equal
``(cfg, strategy, n, num_runs, seed)`` — proven by tests — so the choice is
purely operational:

  * ``vmap``      — one fused executable over all runs on one device; the
                    default, and exactly the historical ``run_many`` path
                    (``swarm.run_many`` routes here, so the simulator and
                    the benchmarks share this batching code).
  * ``sharded``   — ``shard_map`` over a 1-D ``("mc",)`` device mesh (built
                    through ``repro.compat.shard_map``, same shim as
                    ``models/moe.py``): each device vmaps its slice of the
                    run axis.  Run count is padded up to the device count by
                    repeating the last key (padding is computed then
                    discarded — never over-split the key, key-prefix
                    stability does not hold across split widths).
  * ``streaming`` — a host loop over fixed-size chunks; inside a chunk
                    ``jax.lax.map`` runs simulations *serially* with the
                    chunk key buffer donated, so peak memory is one swarm
                    state + the per-run summary rows regardless of N or run
                    count (the N ≥ 1k regime).  With a store attached, each
                    completed chunk checkpoints, and a killed sweep resumes
                    at the last completed chunk.

Strategy ids stay *traced* scalars (one executable covers all five
strategies per cfg), configs stay static — identical compile economics to
the simulator itself.

Per-task and per-hop telemetry (DESIGN.md §10) ride through every backend
unchanged: a traced config (``trace_capacity > 0`` and/or
``trace_hop_capacity > 0``) adds ``trace_records`` / ``trace_overflow``
(and ``trace_hops`` / ``trace_hop_overflow``) leaves to the metric dict,
which vmap/shard_map batch over the run axis and the streaming loop
concatenates per chunk — so record buffers are bit-identical across
backends and survive the same chunk-level checkpoint resume as the
scalar metrics (tested in ``tests/test_trace.py`` /
``tests/test_hops.py``).  The state stream (``trace_state_every > 0``,
DESIGN.md §12) is three more such leaves, nothing backend-specific.

Self-profiling (DESIGN.md §12): every backend builds its executable
ahead-of-time (``jax.jit(fn).lower(...).compile()`` — same jaxpr and HLO
as dispatching through ``jit``, so numerics are bit-identical; pinned by
``tests/test_state_trace.py``), which splits the first-call wall clock
into an honest *compile* span and an *execute* span.  ``run_point``
surfaces them as ``_compile_s`` / ``_execute_s`` pseudo-metrics (leading
underscore: skipped by reports, never stored) and they land in the
``profile`` section of BENCH_fleet.json via ``benchmarks/common.py``.
Executables are cached per (cfg, n, run-shape) — cache hits repeat the
original compile span, which is the cost a cold worker would pay.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map
from repro.configs.base import SwarmConfig
from repro.fleet.store import ResultStore, code_version, point_digest
from repro.fleet.sweep import SweepPoint, SweepSpec
from repro.swarm.simulator import run_sim

BACKENDS = ("vmap", "sharded", "streaming")
DEFAULT_CHUNK = 8


class SweepInterrupted(RuntimeError):
    """Raised by the streaming backend when ``max_chunks`` is reached —
    a deterministic stand-in for preemption in resume tests; progress up to
    the interrupt is checkpointed in the store."""


def _pad_keys(keys: jax.Array, to: int) -> jax.Array:
    pad = to - keys.shape[0]
    if pad <= 0:
        return keys
    return jnp.concatenate(
        [keys, jnp.broadcast_to(keys[-1:], (pad,) + keys.shape[1:])], axis=0)


# ---------------------------------------------------------------------------
# backends (each: key -> dict of [num_runs] metric arrays), built AOT so
# compile time and execute time are separable spans
# ---------------------------------------------------------------------------


def _key_struct() -> jax.ShapeDtypeStruct:
    k = jax.random.PRNGKey(0)
    return jax.ShapeDtypeStruct(k.shape, k.dtype)


_I32 = jax.ShapeDtypeStruct((), jnp.int32)


@functools.lru_cache(maxsize=None)
def _profiled_vmap(cfg: SwarmConfig, n: int, num_runs: int):
    """AOT executable for the vmap backend + its compile-span seconds."""
    def fn(key, strategy):
        keys = jax.random.split(key, num_runs)
        return jax.vmap(lambda k: run_sim(k, cfg, strategy, n))(keys)
    t0 = time.perf_counter()
    compiled = jax.jit(fn).lower(_key_struct(), _I32).compile()
    return compiled, time.perf_counter() - t0


@functools.lru_cache(maxsize=None)
def _profiled_sharded(cfg: SwarmConfig, n: int, padded: int, mesh):
    """AOT executable for the sharded backend (padded key batch in)."""
    from jax.sharding import PartitionSpec as P

    def fn(keys, strategy):
        return shard_map(
            lambda ks: jax.vmap(lambda k: run_sim(k, cfg, strategy, n))(ks),
            mesh=mesh, in_specs=P("mc"), out_specs=P("mc"))(keys)
    ks = _key_struct()
    keys_struct = jax.ShapeDtypeStruct((padded,) + ks.shape, ks.dtype)
    t0 = time.perf_counter()
    compiled = jax.jit(fn).lower(keys_struct, _I32).compile()
    return compiled, time.perf_counter() - t0


@functools.lru_cache(maxsize=None)
def _profiled_stream(cfg: SwarmConfig, n: int, chunk: int, donate: bool):
    """AOT executable for one streaming chunk (lax.map, serial runs).

    ``donate`` releases the chunk key buffer where the runtime honors it
    (TPU/GPU — the memory-bounded regime streaming exists for); CPU XLA
    declines donation and would warn on every compile.
    """
    def fn(keys, strategy):
        return jax.lax.map(lambda k: run_sim(k, cfg, strategy, n), keys)
    ks = _key_struct()
    keys_struct = jax.ShapeDtypeStruct((chunk,) + ks.shape, ks.dtype)
    t0 = time.perf_counter()
    compiled = jax.jit(fn, donate_argnums=(0,) if donate else ()).lower(
        keys_struct, _I32).compile()
    return compiled, time.perf_counter() - t0


def _block(out):
    return jax.block_until_ready(out)


def _run_sharded(key, cfg: SwarmConfig, strategy, n: int, num_runs: int,
                 spans: Optional[Dict] = None):
    from jax.sharding import Mesh
    devs = np.asarray(jax.devices())
    mesh = Mesh(devs, ("mc",))
    padded = (num_runs + len(devs) - 1) // len(devs) * len(devs)
    keys = _pad_keys(jax.random.split(key, num_runs), padded)
    compiled, compile_s = _profiled_sharded(cfg, n, padded, mesh)
    t0 = time.perf_counter()
    out = _block(compiled(keys, jnp.asarray(strategy, jnp.int32)))
    if spans is not None:
        spans["_compile_s"] = compile_s
        spans["_execute_s"] = time.perf_counter() - t0
    return jax.tree.map(lambda x: x[:num_runs], out)


def _sys_gauges(sys_buf) -> Dict[str, float]:
    """Final-sample system gauges of a ``trace_state_sys`` buffer, run-mean,
    rounded — the live swarm-health row for progress.jsonl."""
    from repro.trace import schema
    s = np.asarray(sys_buf, np.float64)
    if s.ndim == 2:
        s = s[None]
    g = dict(zip(schema.SYS_GAUGES, s[:, -1, :].mean(axis=0), strict=True))
    return {"queue_depth_mean": round(g["queue_depth_mean"], 3),
            "queue_depth_max": round(g["queue_depth_max"], 3),
            "phi_spread": round(g["phi_max"] - g["phi_min"], 3),
            "completion_rate": round(g["completed"]
                                     / max(g["generated"], 1.0), 4),
            "sim_t": round(g["t"], 3)}


def _run_streaming(key, cfg: SwarmConfig, strategy, n: int, num_runs: int,
                   chunk_size: int, store: Optional[ResultStore] = None,
                   digest: Optional[str] = None,
                   max_chunks: Optional[int] = None,
                   spans: Optional[Dict] = None,
                   progress=None, label: Optional[str] = None
                   ) -> Dict[str, np.ndarray]:
    chunk = max(1, min(chunk_size, num_runs))
    n_chunks = (num_runs + chunk - 1) // chunk
    keys = jax.random.split(key, num_runs)
    strategy = jnp.asarray(strategy, jnp.int32)
    compiled, compile_s = _profiled_stream(
        cfg, n, chunk, jax.default_backend() != "cpu")
    if spans is not None:
        spans["_compile_s"] = compile_s
        spans.setdefault("_execute_s", 0.0)

    done, accum = 0, None
    if store is not None and digest is not None:
        done, accum = store.load_partial(digest, chunk_size=chunk)
        done = min(done, n_chunks)

    for c in range(done, n_chunks):
        if max_chunks is not None and c >= max_chunks:
            raise SweepInterrupted(
                f"stopped after {c}/{n_chunks} chunks (max_chunks)")
        ks = _pad_keys(keys[c * chunk:(c + 1) * chunk], chunk)
        t0 = time.perf_counter()
        out = compiled(ks, strategy)
        out = {k: np.asarray(v) for k, v in out.items()}
        if spans is not None:
            spans["_execute_s"] += time.perf_counter() - t0
        if accum is None:
            accum = out
        else:
            accum = {k: np.concatenate([accum[k], out[k]]) for k in accum}
        if store is not None and digest is not None:
            store.save_partial(digest, c + 1, accum, chunk)
        if progress is not None:
            # live swarm health per completed chunk: the flight recorder's
            # final system gauges, when the state stream is on
            row = {"event": "chunk", "label": label, "chunk": c + 1,
                   "chunks": n_chunks, "t": time.time()}
            if "trace_state_sys" in out:
                row.update(_sys_gauges(out["trace_state_sys"]))
            progress.emit(**row)

    return {k: v[:num_runs] for k, v in accum.items()}


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


def run_batch(key, cfg: SwarmConfig, strategy, n: int, num_runs: int, *,
              backend: str = "vmap", chunk_size: int = DEFAULT_CHUNK,
              spans: Optional[Dict] = None):
    """Run ``num_runs`` Monte-Carlo simulations of ``(cfg, strategy, n)``.

    Returns a dict of ``[num_runs]`` metric arrays (see ``summarize``),
    bit-identical across backends.  ``swarm.run_many`` is a thin wrapper
    over the ``vmap`` backend of this function.  Passing a ``spans`` dict
    fills ``"_compile_s"`` / ``"_execute_s"`` wall-clock spans (the
    execute span blocks on the result).
    """
    if backend == "vmap":
        compiled, compile_s = _profiled_vmap(cfg, n, num_runs)
        t0 = time.perf_counter()
        out = compiled(key, jnp.asarray(strategy, jnp.int32))
        if spans is not None:
            _block(out)
            spans["_compile_s"] = compile_s
            spans["_execute_s"] = time.perf_counter() - t0
        return out
    if backend == "sharded":
        return _run_sharded(key, cfg, strategy, n, num_runs, spans=spans)
    if backend == "streaming":
        return {k: jnp.asarray(v) for k, v in _run_streaming(
            key, cfg, strategy, n, num_runs, chunk_size,
            spans=spans).items()}
    raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")


def run_point(point: SweepPoint, *, backend: str = "vmap",
              store: Optional[ResultStore] = None,
              chunk_size: int = DEFAULT_CHUNK,
              max_chunks: Optional[int] = None,
              progress=None,
              spans: Optional[Dict] = None) -> Dict[str, np.ndarray]:
    """Execute one sweep point, consulting/filling ``store`` if given.

    A caller-supplied ``spans`` dict receives ``"_compile_s"`` /
    ``"_execute_s"`` wall-clock spans when the point is actually computed
    (a store hit fills nothing — it cost neither), keeping the returned
    metrics identical between computed and cached paths.  ``progress``
    additionally receives per-chunk rows (streaming) and a per-point
    ``gauges`` row when the state stream is on.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
    digest = point_digest(point) if store is not None else None
    if store is not None:
        hit = store.get(digest)
        if hit is not None:
            return hit
    key = jax.random.PRNGKey(point.seed)
    if backend == "streaming":
        metrics = _run_streaming(key, point.cfg, jnp.int32(point.strategy),
                                 point.n, point.num_runs, chunk_size,
                                 store=store, digest=digest,
                                 max_chunks=max_chunks, spans=spans,
                                 progress=progress, label=point.label)
    else:
        out = run_batch(key, point.cfg, jnp.int32(point.strategy), point.n,
                        point.num_runs, backend=backend, spans=spans)
        metrics = {k: np.asarray(v) for k, v in out.items()}
    if store is not None:
        store.put(digest, metrics, meta={
            "label": point.label, "backend": backend,
            "code_version": code_version()})
    if progress is not None and "trace_state_sys" in metrics:
        progress.emit(event="gauges", label=point.label, t=time.time(),
                      **_sys_gauges(metrics["trace_state_sys"]))
    return metrics


def execute(spec: SweepSpec, *, backend: str = "vmap",
            store: Optional[ResultStore] = None,
            chunk_size: int = DEFAULT_CHUNK,
            verbose: bool = False,
            progress=None) -> Dict[str, Dict[str, np.ndarray]]:
    """Expand and run a whole sweep; returns ``{point.label: metrics}``.

    Each point's wall time (including any cache hit) is recorded under the
    ``"_wall_s"`` pseudo-metric, matching the historical ``timed_sweep``
    convention the benchmark CSVs rely on.  ``progress`` is an optional
    ``ProgressWriter`` (``fleet/dispatch.py``): the single-process path
    then emits the same ``progress.jsonl`` rows as a dispatched run, so
    ``benchmarks/run.py --watch`` works either way.
    """
    points = spec.expand()
    if progress is not None:
        progress.emit(event="sweep_start", sweep=spec.name,
                      total=len(points), t=time.time())
    out = {}
    for pt in points:
        t0 = time.perf_counter()
        spans: Dict[str, float] = {}
        m = dict(run_point(pt, backend=backend, store=store,
                           chunk_size=chunk_size, progress=progress,
                           spans=spans))
        m["_wall_s"] = time.perf_counter() - t0
        # computed points carry the AOT compile/execute split (a store hit
        # fills neither); reports skip underscore keys, so these are purely
        # for the profile section / progress surface
        m["_compile_s"] = spans.get("_compile_s")
        m["_execute_s"] = spans.get("_execute_s")
        if verbose:
            print(f"[fleet:{spec.name}] {pt.label} "
                  f"({m['_wall_s']:.2f}s, backend={backend})")
        if progress is not None:
            row = {"event": "point", "label": pt.label,
                   "digest": point_digest(pt) if store is not None
                   else None,
                   "worker": "local", "num_runs": pt.num_runs,
                   "wall_s": round(m["_wall_s"], 3),
                   "cached": spans.get("_execute_s") is None,
                   "t": time.time()}
            if m["_compile_s"] is not None:
                row["compile_s"] = round(m["_compile_s"], 3)
                row["execute_s"] = round(m["_execute_s"], 3)
            progress.emit(**row)
        out[pt.label] = m
    return out
