"""Sweep-result aggregation into the paper's performance indices
(DESIGN.md §8.4) and the ``BENCH_fleet.json`` emitter.

``point_indices`` turns one point's per-run metric arrays into the summary
the paper reports: mean ± 95 % CI per metric, the latency CDF quantiles
(Fig. 4a-style), Jain fairness and energy per task (J/task).
``write_bench_json`` merges a named section into ``BENCH_fleet.json``
atomically, so independent producers (figure sweeps, the φ microbench, CI
smoke runs) accumulate into one machine-readable perf-trajectory file.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Mapping, Optional, Sequence

import numpy as np

from repro.trace.aggregate import QS as LATENCY_QS
from repro.trace.aggregate import quantile_summary

BENCH_NAME = "BENCH_fleet.json"


def ci95(x) -> tuple:
    """(mean, 95 % CI half-width) of a 1-D sample (paper: 50 runs, 95 % CI)."""
    x = np.asarray(x, np.float64)
    m = x.mean()
    half = 1.96 * x.std(ddof=1) / np.sqrt(len(x)) if len(x) > 1 else 0.0
    return m, half


def latency_cdf(lat_s, qs: Sequence[float] = LATENCY_QS) -> Dict[str, float]:
    """Empirical latency quantiles (seconds) of a 1-D latency sample —
    the same grid/implementation as the task-level indices
    (``repro.trace.aggregate``), so the two can never drift apart."""
    return quantile_summary(lat_s, qs)


def point_indices(metrics: Mapping[str, np.ndarray],
                  per_task_latency_s=None,
                  tick_s: Optional[float] = None,
                  tx_power_dbm: Optional[float] = None,
                  cfg=None) -> Dict:
    """Paper performance indices for one sweep point's per-run metrics.

    ``metrics["avg_latency_s"]`` holds one *mean* latency per Monte-Carlo
    run, so its quantiles describe the distribution of run means — emitted
    as ``run_mean_latency_quantiles_s`` (an earlier revision mislabeled
    them ``latency_cdf_s``; Fig. 4a's CDF is per-*task*).  The true
    ``task_latency_cdf_s`` comes from the point's in-scan TaskRecords when
    it ran traced (``SwarmConfig.trace_capacity > 0``), or from an
    explicit pooled ``per_task_latency_s`` sample (which wins when both
    are present).  A point that also captured the hop stream
    (``trace_hop_capacity > 0``) additionally gains the hop-resolved
    indices (per-hop transfer-time/link-bits quantiles, queue-wait vs
    in-flight decomposition — ``tick_s`` converts stall ticks to wall
    time — and, with ``tx_power_dbm``, the airtime-J energy attribution
    per hop and per link; see ``repro.trace.aggregate.hop_indices``).

    ``cfg`` (the point's ``SwarmConfig``) additionally enables the
    critical-path attribution of a traced point: ``latency_segments`` —
    per-task compute / queue-wait / airtime / stall quantiles and shares
    whose per-task sums reconcile exactly with ``latency_s``
    (``repro.trace.critical``, DESIGN.md §14.4; the compute rate estimate
    is ``task_gflops_total / task_layers`` over ``capability_mean``).
    """
    out = {}
    for k, v in metrics.items():
        if k.startswith("_") or k.startswith("trace_"):
            continue     # wall-time / record buffers: not per-run scalars
        mean, half = ci95(v)
        out[k] = {"mean": float(mean), "ci95": float(half)}
    if "avg_latency_s" in metrics:
        out["run_mean_latency_quantiles_s"] = latency_cdf(
            metrics["avg_latency_s"])
    dec = hdec = None
    if "trace_records" in metrics:
        # per-task telemetry captured in-scan (repro.trace): the true
        # task-level indices, pooled over the point's Monte-Carlo runs
        from repro.trace import decode, trace_indices
        dec = decode(metrics["trace_records"],
                     metrics.get("trace_overflow"))
        out.update(trace_indices(dec))
    if "trace_hops" in metrics:
        from repro.trace import decode_hops, hop_indices
        hdec = decode_hops(metrics["trace_hops"],
                           metrics.get("trace_hop_overflow"))
        out.update(hop_indices(hdec, tick_s=tick_s,
                               tx_power_dbm=tx_power_dbm))
    if dec is not None and cfg is not None:
        from repro.trace.critical import segment_indices
        layers = max(int(getattr(cfg, "task_layers", 0)), 1)
        out["latency_segments"] = segment_indices(
            dec, hdec, tick_s=tick_s,
            gflops_per_layer=float(
                getattr(cfg, "task_gflops_total", 0.0)) / layers,
            capability_gflops=getattr(cfg, "capability_mean", None))
    if "trace_state" in metrics or "trace_state_sys" in metrics:
        # the flight recorder (trace_state_every > 0): φ-convergence,
        # queue-depth heatmap, energy-drain and imbalance indices
        from repro.trace import decode_state, state_indices
        out.update(state_indices(decode_state(
            metrics.get("trace_state"), metrics.get("trace_state_sys"),
            metrics.get("trace_state_epochs"))))
    if per_task_latency_s is not None and len(per_task_latency_s):
        out["task_latency_cdf_s"] = latency_cdf(per_task_latency_s)
    for k in ("jain_fairness", "energy_per_task_j"):
        if k in metrics:
            out[k]["min"] = float(np.min(metrics[k]))
            out[k]["max"] = float(np.max(metrics[k]))
    return out


def build_report(results: Mapping[str, Mapping[str, np.ndarray]],
                 meta: Optional[Dict] = None,
                 per_task_latency_s: Optional[Mapping] = None,
                 tick_s=None, tx_power_dbm=None, cfg=None) -> Dict:
    """``{point label: metrics}`` (executor output) → JSON-ready section.

    ``per_task_latency_s`` optionally maps point labels to pooled per-task
    latency samples (for the true Fig. 4a CDF); points without an entry
    just omit ``task_latency_cdf_s``.  ``tick_s`` feeds the hop stream's
    queue-wait/in-flight wall-time decomposition and ``tx_power_dbm`` its
    airtime-J energy attribution: each is either one float for the whole
    sweep or a ``{point label: value}`` mapping (both are ordinary config
    fields, so a sweep axis may vary them per point).  ``cfg`` — one
    ``SwarmConfig`` or a ``{point label: SwarmConfig}`` mapping — enables
    the per-point ``latency_segments`` critical-path attribution of
    traced points (DESIGN.md §14.4).  Output is deterministic in the
    inputs either way.
    """
    lat = per_task_latency_s or {}

    def per_label(v):
        return (v if isinstance(v, Mapping) or v is None
                else {label: v for label in results})

    tick = per_label(tick_s)
    txp = per_label(tx_power_dbm)
    cfgs = (cfg if isinstance(cfg, Mapping) or cfg is None
            else {label: cfg for label in results})
    return {
        "meta": dict(meta or {}),
        "points": {label: point_indices(
            m, lat.get(label), tick_s=(tick or {}).get(label),
            tx_power_dbm=(txp or {}).get(label),
            cfg=(cfgs or {}).get(label))
            for label, m in results.items()},
    }


def load_bench_json(path: str) -> Dict:
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        return json.load(f)


def write_bench_json(path: str, section: str, payload) -> str:
    """Merge ``payload`` under ``doc[section]`` and rewrite atomically.

    Re-running one producer never perturbs the other sections, and the
    output is deterministic in the inputs (no timestamps) — an interrupted-
    then-resumed sweep emits a byte-identical file to an uninterrupted one.
    """
    doc = load_bench_json(path)
    doc[section] = payload
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    os.replace(tmp, path)
    return path
