"""Multi-host sweep dispatch: fleet points across processes (DESIGN.md §9).

The executors (`fleet/executor.py`) scale one point's Monte-Carlo axis over
the devices of one process; this module scales the *point* axis of a whole
:class:`SweepSpec` over worker processes — locally via ``multiprocessing``
spawn, remotely via a rank/world-size env contract (one process per host,
the same shape ``jax.distributed`` expects).  Three design rules keep the
distributed run equivalent to a local one:

  * **The store is the only coordination channel.**  Workers share nothing
    but a :class:`ResultStore` root (a shared filesystem in the multi-host
    case).  Completed points are content-addressed results; in-flight
    points are advisory lease files; a streaming point killed mid-chunk
    resumes from its `repro.checkpoint` partial.  There is no dispatcher
    process to lose.
  * **Work-stealing with idempotent execution.**  Each worker first walks
    its round-robin shard of the expanded points (``points[rank::world]``),
    then steals any remaining point whose lease is missing or expired — so
    a killed worker's points re-enter the pool after ``lease_ttl_s``, the
    fleet-level analogue of the paper's fault-tolerant forwarding.  Leases
    only provide liveness, not mutual exclusion: execution is idempotent
    (results are bit-identical and published by atomic rename), so a
    double-claim costs wall time, never correctness.
  * **Deterministic gather.**  ``collect`` reads results back in expansion
    order from the store, so the report — and the resulting
    ``BENCH_fleet.json`` — is byte-identical to a single-process run no
    matter how points were interleaved across workers (tested in
    ``tests/test_dispatch.py``).

Progress surface: every completed point appends one JSON line to a shared
``progress.jsonl`` (O_APPEND single-write, safe across processes); the
``sweep_start`` row carries the point total, so ``benchmarks/run.py
--watch`` can render completed/total, points/min and ETA while a sweep is
running anywhere on the fleet.  Points computed under the state stream
(``trace_state_every > 0``) additionally append live swarm-health rows —
``event: "gauges"`` per completed point and ``event: "chunk"`` per
completed streaming chunk, both carrying the flight recorder's final
system gauges (mean/max queue depth, φ spread, completion rate;
``benchmarks/loadtest.py`` streams its SLO gauges — p50/p99 latency,
goodput, drop rate — onto the same rows, DESIGN.md §14.3) — and
computed point rows carry the executor's ``compile_s`` / ``execute_s``
spans, which ``benchmarks/common.fleet_sweep`` folds into the BENCH
``profile`` section.

Env contract (remote mode — set per host, then run
``python -m repro.fleet.dispatch`` on each)::

    REPRO_FLEET_HOSTS=h0,h1,h2   # optional roster; len() defaults the world
    REPRO_FLEET_WORLD_SIZE=3     # explicit world size (overrides roster)
    REPRO_FLEET_RANK=1           # this process's rank in [0, world)
    REPRO_FLEET_COORD=h0:9876    # optional jax.distributed coordinator
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import multiprocessing
import os
import socket
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from repro.fleet.executor import BACKENDS, DEFAULT_CHUNK, run_point
from repro.fleet.store import ResultStore, point_digest
from repro.fleet.sweep import SweepSpec

ENV_RANK = "REPRO_FLEET_RANK"
ENV_WORLD = "REPRO_FLEET_WORLD_SIZE"
ENV_HOSTS = "REPRO_FLEET_HOSTS"
ENV_COORD = "REPRO_FLEET_COORD"

DEFAULT_LEASE_TTL_S = 30.0   # reclaim delay after a worker dies; live
                             # workers heartbeat-renew at ttl/2, so slow
                             # points never expire just by being slow
_POLL_S = 0.2                # wait between scans while peers hold leases


# ---------------------------------------------------------------------------
# env contract
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WorkerEnv:
    rank: int
    world: int
    coordinator: Optional[str] = None


def worker_env(environ=None) -> WorkerEnv:
    """Parse the ``REPRO_FLEET_*`` contract; defaults to a world of one."""
    env = os.environ if environ is None else environ
    hosts = [h for h in env.get(ENV_HOSTS, "").split(",") if h]
    world = int(env.get(ENV_WORLD, len(hosts) or 1))
    rank = int(env.get(ENV_RANK, 0))
    if world < 1 or not 0 <= rank < world:
        raise ValueError(
            f"bad fleet env: rank={rank} world={world} "
            f"(need 0 <= {ENV_RANK} < {ENV_WORLD})")
    return WorkerEnv(rank=rank, world=world,
                     coordinator=env.get(ENV_COORD) or None)


def maybe_init_distributed(env: WorkerEnv) -> bool:
    """``jax.distributed.initialize`` from the env contract, when asked.

    Point sharding itself needs no JAX-level coordination (the store is the
    only channel); this exists so a worker's *intra-point* sharded backend
    can span the fleet's devices when a coordinator address is provided.
    """
    if env.coordinator is None or env.world <= 1:
        return False
    import jax
    jax.distributed.initialize(coordinator_address=env.coordinator,
                               num_processes=env.world,
                               process_id=env.rank)
    return True


# ---------------------------------------------------------------------------
# progress surface
# ---------------------------------------------------------------------------


class ProgressWriter:
    """Append-only JSONL progress rows, multi-process safe.

    Each row is one ``write()`` of a single line to an O_APPEND stream —
    atomic for short lines on POSIX — so any number of local or remote
    workers may share one file without interleaving partial lines.

    A ``sweep_start`` row *truncates* the file first: the file always holds
    the latest sweep, so it never grows without bound across benchmark runs
    and ``--watch`` re-parses stay cheap.  (The dispatcher writes
    ``sweep_start`` before workers write rows; a straggler row from a prior
    sweep erased by the truncation is re-surfaced by the cached-row scan in
    ``run_worker``.)
    """

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)

    def emit(self, **row) -> None:
        mode = "w" if row.get("event") == "sweep_start" else "a"
        with open(self.path, mode) as f:
            f.write(json.dumps(row, sort_keys=True) + "\n")


def read_progress(path: str) -> List[Dict]:
    if not os.path.exists(path):
        return []
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError:
                continue     # torn tail line of a live writer: skip
    return rows


def progress_summary(rows: List[Dict]) -> Optional[Dict]:
    """Completed/total, points/min and ETA for the *latest* sweep in rows."""
    start_idx = None
    for i, r in enumerate(rows):
        if r.get("event") == "sweep_start":
            start_idx = i
    if start_idx is None:
        return None
    start = rows[start_idx]
    done = {}
    for r in rows[start_idx + 1:]:
        if r.get("event") == "point":
            # digest may be emitted as null (storeless execute rows):
            # fall back to the label, never collapse onto one None key
            done[r.get("digest") or r.get("label")] = r
    completed, total = len(done), int(start.get("total", 0))
    ts = [r["t"] for r in done.values() if "t" in r]
    elapsed = (max(ts) - start["t"]) if ts and "t" in start else 0.0
    rate = completed / (elapsed / 60.0) if elapsed > 0 else 0.0
    eta = (total - completed) / (rate / 60.0) if rate > 0 else None
    gauges = None
    for r in rows[start_idx + 1:]:
        # live swarm health: the latest gauges/chunk row of this sweep
        # (present only when points run with the state stream on)
        if "queue_depth_mean" in r:
            gauges = {k: r[k] for k in
                      ("queue_depth_mean", "queue_depth_max", "phi_spread",
                       "completion_rate", "sim_t",
                       # SLO gauges emitted by benchmarks/loadtest.py
                       "p50_latency_s", "p99_latency_s", "goodput_rps",
                       "drop_rate") if k in r}
    return {"sweep": start.get("sweep", "?"), "completed": completed,
            "total": total, "points_per_min": rate, "eta_s": eta,
            "gauges": gauges}


def render_progress(summary: Optional[Dict]) -> str:
    if summary is None:
        return "no sweep in progress file yet"
    eta = ("--" if summary["eta_s"] is None
           else f"{summary['eta_s']:.0f}s")
    line = (f"[{summary['sweep']}] {summary['completed']}/{summary['total']} "
            f"points · {summary['points_per_min']:.1f} points/min · "
            f"ETA {eta}")
    g = summary.get("gauges")
    if g:
        line += (f" · q̄ {g.get('queue_depth_mean', 0):.1f}"
                 f"/max {g.get('queue_depth_max', 0):.0f}")
        if "phi_spread" in g:
            line += f" · φΔ {g['phi_spread']:.2f}"
        line += f" · done {100.0 * g.get('completion_rate', 0):.0f}%"
        if g.get("p99_latency_s") is not None:
            line += (f" · p50/p99 {(g.get('p50_latency_s') or 0):.3f}/"
                     f"{g['p99_latency_s']:.3f}s")
        if "goodput_rps" in g:
            line += f" · {g['goodput_rps']:.0f} rps"
        if g.get("drop_rate"):
            line += f" · drop {100.0 * g['drop_rate']:.1f}%"
    return line


# ---------------------------------------------------------------------------
# worker loop
# ---------------------------------------------------------------------------


def _worker_id(rank: int) -> str:
    return f"{socket.gethostname()}:{os.getpid()}:r{rank}"


def claim_order(num_points: int, rank: int, world: int) -> List[int]:
    """Round-robin shard first, then everyone else's points (steal order)."""
    own = list(range(rank, num_points, world))
    rest = [i for i in range(num_points) if i % world != rank % world]
    return own + rest


def _renew_loop(store: ResultStore, digest: str, owner: str,
                ttl_s: float, stop: threading.Event) -> None:
    while not stop.wait(max(ttl_s / 2.0, 0.05)):
        store.renew_lease(digest, owner, ttl_s)


def run_worker(spec: SweepSpec, store: ResultStore, *, rank: int = 0,
               world: int = 1, backend: str = "vmap",
               chunk_size: int = DEFAULT_CHUNK,
               lease_ttl_s: float = DEFAULT_LEASE_TTL_S,
               progress: Optional[ProgressWriter] = None,
               max_points: Optional[int] = None,
               poll_s: float = _POLL_S) -> int:
    """One worker's claim-and-compute loop; returns points computed here.

    Blocks until *every* point of ``spec`` has a result in ``store`` (some
    computed here, some by peers), so a caller returning from this function
    may immediately ``collect``.  ``max_points`` makes the worker exit
    early after computing that many points — the dispatch-level analogue of
    the streaming backend's ``max_chunks`` (a deterministic stand-in for a
    killed worker in resume tests).
    """
    points = spec.expand()
    digests = [point_digest(p) for p in points]
    me = _worker_id(rank)
    computed = 0
    emitted = set()    # digests this worker has written a progress row for

    def emit(i, wall, cached, spans=None):
        emitted.add(digests[i])
        if progress is not None:
            row = {"event": "point", "label": points[i].label,
                   "digest": digests[i], "worker": me,
                   "num_runs": points[i].num_runs,
                   "wall_s": round(wall, 3), "cached": cached,
                   "t": time.time()}
            if spans and spans.get("_compile_s") is not None:
                row["compile_s"] = round(spans["_compile_s"], 3)
                row["execute_s"] = round(spans["_execute_s"], 3)
            progress.emit(**row)

    while True:
        progressed = False
        for i in claim_order(len(points), rank, world):
            if max_points is not None and computed >= max_points:
                return computed
            dig = digests[i]
            if store.has(dig):
                # already in the store (cache hit / peer / earlier run):
                # still surface it once, or a resumed dispatch's progress
                # file would never reach the sweep_start total
                if dig not in emitted:
                    emit(i, 0.0, cached=True)
                continue
            if not store.try_claim(dig, me, lease_ttl_s):
                continue     # live lease elsewhere; revisit next scan
            # heartbeat: renew the lease while the point computes, so only
            # a *dead* worker's lease ever expires into a steal — a slow
            # point never exceeds its TTL just by being slow
            stop = threading.Event()
            renewer = threading.Thread(
                target=_renew_loop,
                args=(store, dig, me, lease_ttl_s, stop), daemon=True)
            renewer.start()
            try:
                if store.has(dig):
                    continue     # completed between has() and claim
                t0 = time.perf_counter()
                spans: Dict[str, float] = {}
                run_point(points[i], backend=backend, store=store,
                          chunk_size=chunk_size, progress=progress,
                          spans=spans)
                wall = time.perf_counter() - t0
            finally:
                stop.set()
                renewer.join()
                store.release_lease(dig, owner=me)
            computed += 1
            progressed = True
            emit(i, wall, cached=False, spans=spans)
        if all(store.has(d) for d in digests):
            return computed
        if not progressed:
            time.sleep(poll_s)   # peers hold live leases: wait, then rescan
                                 # (a dead peer's lease expires into steals)


# ---------------------------------------------------------------------------
# local multi-process dispatch
# ---------------------------------------------------------------------------


def _worker_entry(spec_json: str, store_root: str, rank: int, world: int,
                  backend: str, chunk_size: int, lease_ttl_s: float,
                  progress_path: Optional[str],
                  max_points: Optional[int]) -> None:
    """Spawn target (module-level for picklability under 'spawn')."""
    spec = SweepSpec.from_json(spec_json)
    store = ResultStore(store_root)
    progress = ProgressWriter(progress_path) if progress_path else None
    run_worker(spec, store, rank=rank, world=world, backend=backend,
               chunk_size=chunk_size, lease_ttl_s=lease_ttl_s,
               progress=progress, max_points=max_points)


def spawn_workers(spec: SweepSpec, store_root: str, world: int, *,
                  backend: str = "vmap", chunk_size: int = DEFAULT_CHUNK,
                  lease_ttl_s: float = DEFAULT_LEASE_TTL_S,
                  progress_path: Optional[str] = None,
                  max_points: Optional[int] = None) -> List:
    """Start ``world`` spawned worker processes over a shared store root.

    'spawn' (not fork) so every worker initializes its own JAX runtime —
    forking a process with a live XLA client deadlocks.
    """
    ctx = multiprocessing.get_context("spawn")
    procs = [
        ctx.Process(target=_worker_entry,
                    args=(spec.to_json(), store_root, r, world, backend,
                          chunk_size, lease_ttl_s, progress_path,
                          max_points),
                    name=f"fleet-worker-r{r}")
        for r in range(world)]
    for p in procs:
        p.start()
    return procs


def collect(spec: SweepSpec, store: ResultStore
            ) -> Dict[str, Dict[str, np.ndarray]]:
    """Deterministic gather: every point of ``spec``, in expansion order.

    Reading back from the store (rather than returning in completion
    order) is what makes the multi-worker report byte-identical to a
    single-process run.  Raises if any point is missing — redispatch to
    resume; completed points are cache hits, partial streaming points
    resume at their last chunk.
    """
    out = {}
    missing = []
    for pt in spec.expand():
        m = store.get(point_digest(pt))
        if m is None:
            missing.append(pt.label)
        else:
            out[pt.label] = m
    if missing:
        raise RuntimeError(
            f"sweep {spec.name!r}: {len(missing)} point(s) missing from "
            f"store (first: {missing[0]!r}); redispatch to resume")
    return out


def dispatch(spec: SweepSpec, store: ResultStore, *, workers: int = 2,
             backend: str = "vmap", chunk_size: int = DEFAULT_CHUNK,
             lease_ttl_s: float = DEFAULT_LEASE_TTL_S,
             progress_path: Optional[str] = None,
             max_points_per_worker: Optional[int] = None
             ) -> Dict[str, Dict[str, np.ndarray]]:
    """Run ``spec`` across ``workers`` local processes and collect.

    ``workers <= 1`` runs the claim loop in-process (same lease/progress
    protocol, no spawn cost).  Workers that die are survivable: as long as
    one worker lives, expired leases are stolen and the sweep completes;
    if all die, ``collect`` raises and a re-``dispatch`` resumes from the
    store.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
    progress = ProgressWriter(progress_path) if progress_path else None
    if progress is not None:
        progress.emit(event="sweep_start", sweep=spec.name,
                      total=len(spec.expand()), t=time.time())
    if workers <= 1:
        run_worker(spec, store, rank=0, world=1, backend=backend,
                   chunk_size=chunk_size, lease_ttl_s=lease_ttl_s,
                   progress=progress, max_points=max_points_per_worker)
    else:
        procs = spawn_workers(spec, store.root, workers, backend=backend,
                              chunk_size=chunk_size, lease_ttl_s=lease_ttl_s,
                              progress_path=progress_path,
                              max_points=max_points_per_worker)
        for p in procs:
            p.join()
        failed = [(p.name, p.exitcode) for p in procs if p.exitcode != 0]
        try:
            return collect(spec, store)
        except RuntimeError as e:
            if failed:
                # an incomplete sweep with dead workers: surface the exit
                # codes, or 'redispatch to resume' hides a systematic
                # child crash (bad spec, device init failure under spawn)
                raise RuntimeError(
                    f"{e}; worker processes exited non-zero: {failed} "
                    "(see their stderr for the underlying error)") from e
            raise
    return collect(spec, store)


def run_sweep(spec: SweepSpec, store: ResultStore, *,
              workers: Optional[int] = None, backend: str = "vmap",
              chunk_size: int = DEFAULT_CHUNK,
              lease_ttl_s: float = DEFAULT_LEASE_TTL_S,
              progress_path: Optional[str] = None
              ) -> Optional[Dict[str, Dict[str, np.ndarray]]]:
    """Entry point covering both dispatch modes.

    With the ``REPRO_FLEET_*`` env contract set (one process per host),
    this process becomes that rank's worker against the shared store; every
    rank blocks until the sweep completes, then rank 0 collects and returns
    (other ranks return ``None``).  Otherwise it is a local multi-process
    ``dispatch`` with ``workers`` processes (default 1).
    """
    env = worker_env()
    if env.world > 1:
        maybe_init_distributed(env)
        progress = ProgressWriter(progress_path) if progress_path else None
        if env.rank == 0 and progress is not None:
            progress.emit(event="sweep_start", sweep=spec.name,
                          total=len(spec.expand()), t=time.time())
        run_worker(spec, store, rank=env.rank, world=env.world,
                   backend=backend, chunk_size=chunk_size,
                   lease_ttl_s=lease_ttl_s, progress=progress)
        return collect(spec, store) if env.rank == 0 else None
    return dispatch(spec, store, workers=workers or 1, backend=backend,
                    chunk_size=chunk_size, lease_ttl_s=lease_ttl_s,
                    progress_path=progress_path)


# ---------------------------------------------------------------------------
# spec publication + CLI
# ---------------------------------------------------------------------------


def publish_spec(spec: SweepSpec, store: ResultStore) -> str:
    """Write the spec JSON into the store so remote workers can find it by
    name: ``python -m repro.fleet.dispatch --spec <name> --store <root>``."""
    d = os.path.join(store.root, "sweeps")
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, spec.name + ".json")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(spec.to_json())
    os.replace(tmp, path)
    return path


def _load_spec(ref: str, store: ResultStore) -> SweepSpec:
    path = ref if os.path.exists(ref) else os.path.join(
        store.root, "sweeps", ref + ".json")
    if not os.path.exists(path):
        raise FileNotFoundError(
            f"spec {ref!r}: not a file and not published under "
            f"{os.path.join(store.root, 'sweeps')}")
    with open(path) as f:
        return SweepSpec.from_json(f.read())


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m repro.fleet.dispatch",
        description="Run a published SweepSpec as one fleet worker (env "
                    "contract) or a local worker pool (--workers).")
    ap.add_argument("--spec", required=True,
                    help="path to a SweepSpec JSON, or a name published "
                         "via publish_spec under <store>/sweeps/")
    ap.add_argument("--store", required=True, help="shared store root")
    ap.add_argument("--workers", type=int, default=0,
                    help="local worker processes; 0 = follow the "
                         "REPRO_FLEET_* env contract in-process")
    ap.add_argument("--backend", default="vmap", choices=BACKENDS)
    ap.add_argument("--chunk-size", type=int, default=DEFAULT_CHUNK)
    ap.add_argument("--lease-ttl", type=float, default=DEFAULT_LEASE_TTL_S)
    ap.add_argument("--progress", default=None,
                    help="progress.jsonl path (benchmarks/run.py --watch)")
    args = ap.parse_args(argv)

    store = ResultStore(args.store)
    spec = _load_spec(args.spec, store)
    res = run_sweep(spec, store, workers=args.workers or None,
                    backend=args.backend, chunk_size=args.chunk_size,
                    lease_ttl_s=args.lease_ttl,
                    progress_path=args.progress)
    if res is not None:
        print(f"[fleet.dispatch] sweep {spec.name!r}: "
              f"{len(res)} points complete in {store.root}")


if __name__ == "__main__":
    main()
