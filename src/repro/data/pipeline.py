"""Deterministic synthetic token pipeline.

Produces host-sharded LM batches (tokens + next-token labels) from a seeded
markov-ish token generator — no external datasets in this offline container,
but the interface mirrors a real loader: per-host sharding by
(host_id, num_hosts), stateless indexing by step (restart-safe: resuming at
step k regenerates the identical batch — checkpoint/restart tests rely on
this), and an optional background prefetcher.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator

import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0


def _batch_np(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Stateless batch for `step` (full global batch, then host slice)."""
    assert cfg.global_batch % cfg.num_hosts == 0
    per_host = cfg.global_batch // cfg.num_hosts
    rng = np.random.default_rng(np.uint64(cfg.seed * 1_000_003 + step))
    # structured synthetic stream: mixture of a few markov chains so the
    # model has something learnable (loss decreases in the train example)
    B, S = cfg.global_batch, cfg.seq_len + 1
    base = rng.integers(0, cfg.vocab_size, (B, 1), dtype=np.int64)
    drift = rng.integers(1, 7, (B, S), dtype=np.int64).cumsum(axis=1)
    toks = (base + drift) % cfg.vocab_size
    lo = cfg.host_id * per_host
    toks = toks[lo:lo + per_host]
    return {"tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32)}


def batch_at(cfg: DataConfig, step: int) -> Dict[str, jnp.ndarray]:
    return {k: jnp.asarray(v) for k, v in _batch_np(cfg, step).items()}


class Prefetcher:
    """Background thread producing batches ahead of the train loop."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, depth: int = 2):
        self.cfg = cfg
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        s = self._step
        while not self._stop.is_set():
            try:
                self._q.put((s, _batch_np(self.cfg, s)), timeout=0.2)
                s += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, b = self._q.get()
        return step, {k: jnp.asarray(v) for k, v in b.items()}

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
