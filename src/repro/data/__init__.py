from repro.data.pipeline import DataConfig, Prefetcher, batch_at

__all__ = ["DataConfig", "batch_at", "Prefetcher"]
