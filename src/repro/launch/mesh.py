"""Production mesh construction + PartitionSpec template resolution.

Importing this module never touches jax device state (the dry-run sets
``XLA_FLAGS`` before any jax import; see dryrun.py).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) == need:
        return jax.make_mesh(shape, axes)
    if len(devs) < need:
        raise RuntimeError(
            f"need {need} devices for mesh {shape}, have {len(devs)} — the "
            "dry-run must set XLA_FLAGS=--xla_force_host_platform_device_"
            "count=512 before importing jax")
    return jax.make_mesh(shape, axes, devices=devs[:need])


def batch_axes_of(mesh) -> Tuple[str, ...]:
    if mesh is not None and "pod" in mesh.axis_names:
        return ("pod", "data")
    return ("data",)


def resolve_spec(spec: P, mesh) -> P:
    """Map template axes onto the concrete mesh: on multi-pod meshes every
    'data' entry becomes ('pod', 'data') — FSDP/batch span both axes."""
    if "pod" not in mesh.axis_names:
        return spec
    out = []
    for e in spec:
        if e == "data":
            out.append(("pod", "data"))
        elif isinstance(e, (tuple, list)):
            ee = []
            for x in e:
                ee.extend(("pod", "data") if x == "data" else (x,))
            out.append(tuple(ee))
        else:
            out.append(e)
    return P(*out)


def resolve_specs(tree, mesh):
    return jax.tree.map(lambda s: resolve_spec(s, mesh), tree,
                        is_leaf=lambda x: isinstance(x, P))


def sanitize_spec(spec: P, shape: Tuple[int, ...], mesh) -> P:
    """jit-boundary shardings must divide evenly; drop axis entries that
    don't (e.g. vocab 49155 over 16, batch 1 over 'data', 28 heads over 16).
    Internal with_sharding_constraint hints stay uneven-capable — this is
    only for in/out shardings."""
    spec = resolve_spec(spec, mesh)
    out = []
    for i, e in enumerate(spec):
        if e is None or i >= len(shape):
            out.append(e)
            continue
        axes = e if isinstance(e, (tuple, list)) else (e,)
        p = 1
        for a in axes:
            p *= mesh.shape[a]
        out.append(e if shape[i] % p == 0 else None)
    return P(*out)


def shardings(tree_of_specs, mesh, shapes_tree=None):
    """NamedShardings from spec templates; with `shapes_tree` (matching tree
    of ShapeDtypeStructs/arrays) the specs are divisibility-sanitized."""
    if shapes_tree is None:
        return jax.tree.map(
            lambda s: NamedSharding(mesh, resolve_spec(s, mesh)),
            tree_of_specs, is_leaf=lambda x: isinstance(x, P))
    return jax.tree.map(
        lambda st, s: NamedSharding(mesh, sanitize_spec(s, st.shape, mesh)),
        shapes_tree, tree_of_specs)
