"""Training launcher: pjit train loop with checkpoint/restart + straggler
policy.  CPU-sized by default (reduced arch) — the mesh/sharding code path
is identical to the production one (same step builder as the dry-run).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config, reduced
from repro.data import DataConfig, batch_at
from repro.launch.step import init_train_state, make_train_step
from repro.models import build_model
from repro.optim import OptConfig
from repro.runtime import DriverConfig, run_with_restarts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (needs a real pod)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = reduced(cfg)
    model = build_model(cfg)
    opt_cfg = OptConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)

    train_step = jax.jit(make_train_step(model, opt_cfg),
                         donate_argnums=(0,))

    def init_state():
        return init_train_state(model, jax.random.PRNGKey(0))

    t0 = time.time()

    def on_metrics(step, metrics):
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({(time.time() - t0):.1f}s)", flush=True)

    drv = DriverConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                       max_steps=args.steps)
    state = run_with_restarts(
        drv, init_state=init_state, train_step=train_step,
        batch_fn=lambda step: batch_at(dcfg, step), on_metrics=on_metrics)
    print("done; final step", int(state.opt.step))


if __name__ == "__main__":
    main()
