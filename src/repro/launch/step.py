"""Train/serve step builders shared by the dry-run, train.py and serve.py."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import build_model, input_partition_specs, input_structs
from repro.models.registry import Model
from repro.optim import OptConfig, OptState, apply_updates, init_opt, opt_specs


class TrainState(NamedTuple):
    params: Any
    opt: OptState


def make_train_step(model: Model, opt_cfg: OptConfig):
    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(state.params, batch)
        params, opt, om = apply_updates(state.params, grads, state.opt,
                                        opt_cfg)
        return TrainState(params, opt), {**metrics, **om}

    return train_step


def init_train_state(model: Model, key) -> TrainState:
    params = model.init(key)
    return TrainState(params, init_opt(params))


def train_state_specs(model: Model) -> TrainState:
    ps = model.specs()
    return TrainState(ps, opt_specs(ps))


# ---------------------------------------------------------------------------
# serve steps
# ---------------------------------------------------------------------------


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, caches, batch):
        return model.decode_step(params, caches, batch)
    return decode_step


# ---------------------------------------------------------------------------
# dry-run cell assembly (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------


def _with_shardings(struct_tree, spec_tree, mesh):
    from repro.launch.mesh import sanitize_spec
    from jax.sharding import NamedSharding

    def one(st, sp):
        return jax.ShapeDtypeStruct(
            st.shape, st.dtype,
            sharding=NamedSharding(mesh, sanitize_spec(sp, st.shape, mesh)))

    return jax.tree.map(one, struct_tree, spec_tree,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def cell_structs(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """Returns (fn, example_structs_tuple, out_shardings) for the cell.

    train  : train_step(state, batch)
    prefill: prefill(params, batch)
    decode : decode_step(params, caches, batch)
    """
    from repro.launch.mesh import shardings

    model = build_model(cfg, mesh=mesh)
    key = jax.random.PRNGKey(0)
    param_structs = jax.eval_shape(model.init, key)
    pspecs = model.specs()
    batch_axes = ("data",)
    if cfg.pure_dp:
        # pure data parallelism (attention-free archs): batch spans both
        # axes, weights FSDP over both, nothing tensor-parallel.
        batch_axes = ("data", "model")

        def to_dp(sp):
            ent = []
            seen_data = False
            for e in sp:
                if e == "data" and not seen_data:
                    ent.append(("data", "model"))
                    seen_data = True
                elif e in ("data", "model"):
                    ent.append(None)
                else:
                    ent.append(e)
            return P(*ent)

        pspecs = jax.tree.map(to_dp, pspecs,
                              is_leaf=lambda x: isinstance(x, P))
    if shape.kind != "train" and not cfg.serve_param_fsdp:
        # inference weight layout: replicate across the batch axes (no
        # optimizer state to hold, no per-step ZeRO-3 weight gathers)
        def drop_data(sp):
            ent = []
            for e in sp:
                if e == "data":
                    ent.append(None)
                elif isinstance(e, (tuple, list)):
                    kept = tuple(x for x in e if x != "data")
                    ent.append(kept if kept else None)
                else:
                    ent.append(e)
            return P(*ent)
        pspecs = jax.tree.map(drop_data, pspecs,
                              is_leaf=lambda x: isinstance(x, P))
    params_sh = _with_shardings(param_structs, pspecs, mesh)

    binp = input_structs(cfg, shape)
    bspec = input_partition_specs(cfg, shape, batch_axes=batch_axes)
    batch_sh = _with_shardings(binp, bspec, mesh)

    if shape.kind == "train":
        opt_structs = jax.eval_shape(
            lambda p: init_opt(p), param_structs)
        ospecs = opt_specs(pspecs)
        state_sh = TrainState(params_sh,
                              _with_shardings(opt_structs, ospecs, mesh))
        step = make_train_step(model, OptConfig())
        out_sharding = (shardings(TrainState(pspecs, ospecs), mesh,
                                  TrainState(param_structs, opt_structs)),
                        None)
        return step, (state_sh, batch_sh), out_sharding, model

    if shape.kind == "prefill":
        step = make_prefill_step(model)
        cspecs = model.cache_specs()
        _, cache_structs = jax.eval_shape(step, param_structs, binp)
        out_sharding = (None, shardings(cspecs, mesh, cache_structs))
        return step, (params_sh, batch_sh), out_sharding, model

    # decode: one new token against a cache of seq_len
    # (local-attention ring buffers and SSM states are bounded; the generic
    # families allocate [L, B, S, Hkv, hd])
    cache_structs = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    cspecs = model.cache_specs()
    caches_sh = _with_shardings(cache_structs, cspecs, mesh)
    step = make_decode_step(model)
    out_sharding = (None, shardings(cspecs, mesh, cache_structs))
    return step, (params_sh, caches_sh, batch_sh), out_sharding, model
