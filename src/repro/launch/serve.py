"""Serving launcher: φ-partitioned split-computing inference over
heterogeneous executors (the paper's protocol driving a real LM).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --requests 32 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.splitcompute import SplitServeEngine, plan_stages


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--executors", type=int, default=4)
    ap.add_argument("--burst", type=int, default=0,
                    help="submit this many extra requests at once to trigger "
                         "the congestion-aware early exit")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # heterogeneous executors (paper Table 2: N(400, 100) GFLOP/s)
    rng = np.random.default_rng(0)
    F = np.maximum(rng.normal(400, 100, args.executors), 50.0)
    plan = plan_stages(cfg, F)
    print("capabilities:", np.round(F, 1).tolist())
    print("φ:", np.round(plan.phi, 1).tolist())
    print("stage boundaries:", plan.boundaries, "executors:", plan.executors)

    eng = SplitServeEngine(cfg, params, plan)
    key = jax.random.PRNGKey(1)
    t0 = time.perf_counter()
    # submit/step share the engine's deterministic epoch clock, so the
    # reported latency is in epoch time (requests × steps), reproducible
    # run-to-run; wall time below is only for throughput
    for _ in range(args.requests):
        key, k = jax.random.split(key)
        toks = jax.random.randint(k, (args.batch, args.seq), 0,
                                  cfg.vocab_size)
        eng.submit({"tokens": toks})
        eng.step()
    for _ in range(args.burst):
        key, k = jax.random.split(key)
        toks = jax.random.randint(k, (args.batch, args.seq), 0,
                                  cfg.vocab_size)
        eng.submit({"tokens": toks})
    stats = eng.drain()
    dt = time.perf_counter() - t0
    print(f"served {stats.completed} sequences in {dt:.2f}s "
          f"({stats.completed / dt:.1f} seq/s), avg latency "
          f"{stats.avg_latency * 1e3:.1f} epoch-ms, "
          f"{len(eng.results)} result tensors stashed")
    print("exit label counts (0=full,1=medium,2=high):", stats.exit_counts)


if __name__ == "__main__":
    main()
