"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell and
extract roofline terms from the compiled artifacts.  (Deliverables e/g.)

Two passes per cell:
  * census  — the production step (scan-over-layers, full depth)
              lowered + compiled; proves sharding coherence and yields
              ``memory_analysis()`` (the real per-device footprint).
  * costing — XLA's HLO cost analysis counts a while-loop body once, so
              FLOP/byte/collective numbers come from *unrolled* compiles at
              two reduced depths (full width/batch/seq), linearly
              extrapolated to full depth: cost(d) = a + b·d.  Inner
              q-chunk/ssm-chunk loops are unrolled too (exact accounting).
              Single-pod only (the roofline table's mesh).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Writes one JSON per cell under benchmarks/artifacts/dryrun/<mesh>/.
"""
# The dry-run (and ONLY the dry-run) needs 512 placeholder devices; jax
# locks the device count on first init, so this precedes every other import.
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from repro.configs import (ARCHS, SHAPES, get_config, get_shape,             # noqa: E402
                           shape_applicable)
from repro.launch.mesh import make_production_mesh                           # noqa: E402
from repro.launch.step import cell_structs                                   # noqa: E402

# --- TPU v5e hardware model (per brief) ------------------------------------
PEAK_FLOPS = 197e12         # bf16 FLOP/s per chip
HBM_BW = 819e9              # B/s per chip
LINK_BW = 50e9              # B/s per ICI link

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), os.pardir, os.pardir,
                            os.pardir, "benchmarks", "artifacts", "dryrun")

_SHAPE_RE = re.compile(r"(f32|f16|bf16|f64|s32|s8|u32|u8|s64|pred|u64|s16|u16)"
                       r"\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s+(\(?[^)=]*?\)?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1}

# per-device link-traffic factor ≈ factor × output_bytes (ring algorithms);
# reduce-scatter additionally scales by the group size (input = n × output).
_TRAFFIC_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0,
                   "reduce-scatter": 1.0, "all-to-all": 1.0,
                   "collective-permute": 1.0}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtp, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtp]
    return total


def collective_bytes(hlo_text: str):
    """Per-device collective link-traffic estimate + op census from the
    post-SPMD HLO (output shapes × ring factors)."""
    per_op = {}
    count = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:   # async pair: count the -start only
            continue
        out_bytes = _shape_bytes(m.group(1))
        op = m.group(2)
        factor = _TRAFFIC_FACTOR[op]
        if op == "reduce-scatter":
            g = _GROUPS_RE.search(line)
            if g:
                factor = max(int(g.group(2)) - 1, 1)
        per_op[op] = per_op.get(op, 0.0) + factor * out_bytes
        count[op] = count.get(op, 0) + 1
    return per_op, count


def model_flops(cfg, shape) -> float:
    """6·N_active·tokens (train) / 2·N_active·tokens (inference)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch       # decode: 1 token/seq


def _compile_cell(cfg, shape, mesh):
    """lower + compile one step; returns (compiled, t_lower, t_compile)."""
    t0 = time.time()
    with mesh:
        fn, structs, out_sh, _ = cell_structs(cfg, shape, mesh)
        donate = (0,) if shape.kind == "train" else (
            (1,) if shape.kind == "decode" else ())
        jitted = jax.jit(fn, out_shardings=out_sh, donate_argnums=donate)
        lowered = jitted.lower(*structs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    return compiled, t_lower, t_compile


def _depth_plan(cfg):
    """(d1, d2, units_full) for the cost extrapolation; depths in layers,
    units in extrapolation steps (superblocks for hybrid — the 38-layer
    config's 2-layer tail is covered by the fractional 38/3 unit count)."""
    if cfg.family == "hybrid":
        n = len(cfg.hybrid.pattern)
        return n, 2 * n, cfg.num_layers / n
    # encdec scales encoder and decoder depth together (24/24 config)
    return 2, 4, float(cfg.num_layers)


def _at_depth(cfg, depth, shape):
    """Depth-reduced unrolled config for costing.  Inner chunk loops are
    unrolled too (exact accounting), so their chunk sizes are raised to
    bound the unroll factor at <=16 iterations — totals are unchanged
    (the chunked ops are linear in S)."""
    kw = {"num_layers": depth, "scan_layers": False,
          "attn_chunk": max(cfg.attn_chunk, shape.seq_len // 16)}
    if cfg.family == "encdec":
        kw["encdec"] = dataclasses.replace(cfg.encdec, encoder_layers=depth)
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, chunk=max(cfg.ssm.chunk, shape.seq_len // 16))
    if cfg.loss_chunk:
        kw["loss_chunk"] = max(cfg.loss_chunk, shape.seq_len // 16)
    return dataclasses.replace(cfg, **kw)


def _cost_once(cfg, shape, mesh):
    compiled, _, _ = _compile_cell(cfg, shape, mesh)
    ca = compiled.cost_analysis() or {}
    coll, coll_n = collective_bytes(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": coll, "coll_n": coll_n}


def _extrapolate(c1, c2, d1, d2, units_full, unit):
    """cost(d) = a + b·d (d in layers), report at units_full·unit layers."""
    def lin(v1, v2):
        b = (v2 - v1) / (d2 - d1)
        a = v1 - b * d1
        return a + b * units_full * unit

    out = {"flops": lin(c1["flops"], c2["flops"]),
           "bytes": lin(c1["bytes"], c2["bytes"])}
    ops = set(c1["coll"]) | set(c2["coll"])
    out["coll"] = {op: max(lin(c1["coll"].get(op, 0.0),
                               c2["coll"].get(op, 0.0)), 0.0) for op in ops}
    out["coll_n"] = {op: int(round(max(
        lin(c1["coll_n"].get(op, 0), c2["coll_n"].get(op, 0)), 0)))
        for op in set(c1["coll_n"]) | set(c2["coll_n"])}
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             force: bool = False, cfg_override=None, tag: str = ""):
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, f"{arch}__{shape_name}{tag}.json")
    if os.path.exists(out_path) and not force:
        print(f"[skip-cached] {arch} × {shape_name} × {mesh_kind}")
        return json.load(open(out_path))

    cfg = cfg_override or get_config(arch)
    shape = get_shape(shape_name)
    ok, reason = shape_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "kind": shape.kind}
    if not ok:
        rec.update({"status": "SKIP", "reason": reason})
        json.dump(rec, open(out_path, "w"), indent=1)
        print(f"[SKIP] {arch} × {shape_name}: {reason}")
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    try:
        # ---- census: production (scanned) step, full depth --------------
        compiled, t_lower, t_compile = _compile_cell(
            dataclasses.replace(cfg, scan_layers=True), shape, mesh)
        ma = compiled.memory_analysis()
        mem = {}
        if ma is not None:
            mem = {"argument_bytes": ma.argument_size_in_bytes,
                   "output_bytes": ma.output_size_in_bytes,
                   "temp_bytes": ma.temp_size_in_bytes,
                   "alias_bytes": ma.alias_size_in_bytes,
                   "peak_estimate_bytes": (ma.argument_size_in_bytes
                                           + ma.output_size_in_bytes
                                           + ma.temp_size_in_bytes
                                           - ma.alias_size_in_bytes)}
        rec.update({"status": "OK", "chips": chips,
                    "lower_s": round(t_lower, 2),
                    "compile_s": round(t_compile, 2), "memory": mem})
        del compiled

        # ---- costing: depth-extrapolated unrolled compiles --------------
        if mesh_kind == "single":
            d1, d2, units_full = _depth_plan(cfg)
            c1 = _cost_once(_at_depth(cfg, d1, shape), shape, mesh)
            c2 = _cost_once(_at_depth(cfg, d2, shape), shape, mesh)
            full = _extrapolate(c1, c2, d1, d2,
                                units_full, cfg.num_layers / units_full)
            flops_dev, bytes_dev = full["flops"], full["bytes"]
            coll_dev = float(sum(full["coll"].values()))
            mf = model_flops(cfg, shape)
            t_compute = flops_dev / PEAK_FLOPS
            t_memory = bytes_dev / HBM_BW
            t_coll = coll_dev / LINK_BW
            dominant = max((("compute", t_compute), ("memory", t_memory),
                            ("collective", t_coll)),
                           key=lambda kv: kv[1])[0]
            rec.update({
                "flops_per_device": flops_dev,
                "hlo_flops_global": flops_dev * chips,
                "bytes_per_device": bytes_dev,
                "collective_bytes_per_device": coll_dev,
                "collective_by_op": full["coll"],
                "collective_op_counts": full["coll_n"],
                "model_flops": mf,
                "useful_flop_ratio": mf / max(flops_dev * chips, 1.0),
                "roofline": {"compute_s": t_compute, "memory_s": t_memory,
                             "collective_s": t_coll, "dominant": dominant,
                             "bound_step_s": max(t_compute, t_memory,
                                                 t_coll)},
            })
            print(f"[OK] {arch} × {shape_name} × {mesh_kind}: "
                  f"compile={t_compile:.1f}s dom={dominant} "
                  f"comp={t_compute*1e3:.2f}ms mem={t_memory*1e3:.2f}ms "
                  f"coll={t_coll*1e3:.2f}ms "
                  f"useful={rec['useful_flop_ratio']:.2f}", flush=True)
        else:
            print(f"[OK] {arch} × {shape_name} × {mesh_kind}: "
                  f"compile={t_compile:.1f}s (census only)", flush=True)
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec.update({"status": "FAIL", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
        print(f"[FAIL] {arch} × {shape_name} × {mesh_kind}: {e}", flush=True)
    json.dump(rec, open(out_path, "w"), indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=os.path.normpath(ARTIFACT_DIR))
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = sorted(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = sorted(SHAPES) if (args.all or not args.shape) else [args.shape]

    n_fail = 0
    for mk in meshes:
        for a in archs:
            for s in shapes:
                rec = run_cell(a, s, mk, os.path.join(args.out, mk),
                               force=args.force)
                n_fail += rec.get("status") == "FAIL"
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
