"""TaskRecord + HopRecord layouts (DESIGN.md §10.1, §10.5).

One task (or one hop) = one fixed-width float32 row.  A packed row
(rather than a struct-of-arrays dict) keeps the in-scan buffer a single
carry leaf that every executor backend batches/concatenates/checkpoints
without special cases, and makes the record vocabulary trivially
shareable with the serving stack (``splitcompute.ServeStats`` builds the
same rows on host).

TaskRecord fields (float32; integral fields are exact up to 2^24, far
above any realistic seq/node/layer count):

  ==============  =========================================================
  ``seq``         global task sequence number at the task's *last* enqueue
                  (``queues.py`` re-seqs on every hop; ``created_t`` still
                  spans the whole lifetime).  < 0 marks an unwritten slot.
  ``src``         node that generated the task (serve: entry stage)
  ``dst``         node that completed/dropped it (serve: exit stage)
  ``created_t``   generation time, simulation seconds
  ``completed_t`` completion/drop time, simulation seconds
  ``exit_label``  0 full / 1 medium / 2 high congestion exit, 3 = dropped
  ``layers``      layers executed at completion (0 for drops)
  ``hops``        |visited set| — distinct nodes that forwarded the task
  ``energy_j``    compute + transfer energy attributed to the task
  ``tx_time_s``   total time the task spent in flight between nodes
  ==============  =========================================================

HopRecord fields — one row per *delivered transfer* (the second in-scan
stream, ``SwarmConfig.trace_hop_capacity``); a task relocated over k
links leaves k rows, so hop-resolved timelines and per-link decomposition
come from stored traces instead of the net src→dst summary:

  ==================  =====================================================
  ``seq``             global hop sequence number, assigned at
                      ``transfer.initiate`` (in-flight hops at sim end
                      never deliver, so their slots stay unwritten —
                      never counted as overflow).  < 0 marks unwritten.
  ``src``             origin node of this hop (the sender)
  ``dst``             node the payload was delivered into
  ``t_depart``        transfer initiation time, simulation seconds
  ``t_arrive``        delivery time, simulation seconds
  ``bits``            boundary activation bits shipped over the link
  ``boundary_layer``  layer boundary the task was snapped to (§3.1)
  ``stall_ticks``     ticks the transfer was pending but not progressing:
                      endpoint-down fault stalls plus fully-arrived ticks
                      spent waiting out receiver contention (queue-wait);
                      in-flight airtime = (t_arrive − t_depart) −
                      stall_ticks · tick_s
  ==================  =====================================================

State stream (the flight recorder, ``SwarmConfig.trace_state_every``;
DESIGN.md §12) — unlike the two event streams above it is *epoch-indexed*:
sample s holds a snapshot taken at the end of epoch ``s * every``, so the
buffers have statically-known shape [S, M, NUM_STATE_GAUGES] /
[S, NUM_SYS_GAUGES] with S = ceil(n_epochs / every) and M = min(N, nodes).
Every slot is written exactly once (no seq counter, no overflow concept).

STATE_GAUGES — per-node columns of one snapshot row:

  ===============  ========================================================
  ``phi``          diffusive aggregated-GFLOPS metric φ_i
  ``queue_depth``  active tasks queued at the node (instantaneous)
  ``e_comp_j``     cumulative compute energy spent by the node, J
  ``e_tx_j``       cumulative transmit (airtime) energy spent, J
  ``alive``        1.0 while the fault process holds the node up
  ``tx_bits``      bits still in flight on the node's outgoing transfer
  ===============  ========================================================

SYS_GAUGES — whole-swarm aggregates (always over all N nodes, independent
of the node subsample):

  ====================  ===================================================
  ``t``                 simulation time at the snapshot, seconds
  ``tasks_in_flight``   queued tasks + active transfers
  ``transfers_active``  transfers currently in flight
  ``completed``         cumulative completed tasks
  ``dropped``           cumulative dropped tasks
  ``generated``         cumulative generated tasks
  ``queue_depth_mean``  mean queue depth over nodes
  ``queue_depth_max``   max queue depth over nodes
  ``queue_jain``        Jain fairness over instantaneous queue depths
  ``phi_mean/min/max``  φ distribution summary (spread = max − min)
  ``energy_j``          cumulative swarm energy (compute + transfer), J
  ====================  ===================================================
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

FIELDS = ("seq", "src", "dst", "created_t", "completed_t", "exit_label",
          "layers", "hops", "energy_j", "tx_time_s")
(SEQ, SRC, DST, CREATED_T, COMPLETED_T, EXIT_LABEL, LAYERS, HOPS, ENERGY_J,
 TX_TIME_S) = range(len(FIELDS))
NUM_FIELDS = len(FIELDS)

# exit_label values beyond the paper's 0/1/2 congestion ladder
DROPPED = 3

INT_FIELDS = ("seq", "src", "dst", "exit_label", "layers", "hops")


def pack(seq, src, dst, created_t, completed_t, exit_label, layers, hops,
         energy_j, tx_time_s) -> jnp.ndarray:
    """Stack per-task field vectors into ``[..., NUM_FIELDS]`` f32 rows."""
    cols = (seq, src, dst, created_t, completed_t, exit_label, layers, hops,
            energy_j, tx_time_s)
    return jnp.stack([jnp.asarray(c, jnp.float32) for c in
                      jnp.broadcast_arrays(*cols)], axis=-1)


def pack_np(seq, src, dst, created_t, completed_t, exit_label, layers, hops,
            energy_j=0.0, tx_time_s=0.0) -> np.ndarray:
    """Host-side single-record row (serving stack).

    float64: host records never ride in a device carry, so there is no
    reason to round the caller's clock domain through float32.
    """
    return np.asarray([seq, src, dst, created_t, completed_t, exit_label,
                       layers, hops, energy_j, tx_time_s], np.float64)


def empty_buffer(capacity: int) -> jnp.ndarray:
    """Unwritten ``[capacity, NUM_FIELDS]`` buffer (seq = -1 everywhere)."""
    return jnp.full((capacity, NUM_FIELDS), -1.0, jnp.float32)


# ---------------------------------------------------------------------------
# HopRecord (the per-transfer stream; same conventions as TaskRecord)
# ---------------------------------------------------------------------------

HOP_FIELDS = ("seq", "src", "dst", "t_depart", "t_arrive", "bits",
              "boundary_layer", "stall_ticks")
(HOP_SEQ, HOP_SRC, HOP_DST, HOP_T_DEPART, HOP_T_ARRIVE, HOP_BITS,
 HOP_BOUNDARY_LAYER, HOP_STALL_TICKS) = range(len(HOP_FIELDS))
NUM_HOP_FIELDS = len(HOP_FIELDS)

HOP_INT_FIELDS = ("seq", "src", "dst", "boundary_layer", "stall_ticks")


def pack_hop(seq, src, dst, t_depart, t_arrive, bits, boundary_layer,
             stall_ticks) -> jnp.ndarray:
    """Stack per-hop field vectors into ``[..., NUM_HOP_FIELDS]`` f32 rows."""
    cols = (seq, src, dst, t_depart, t_arrive, bits, boundary_layer,
            stall_ticks)
    return jnp.stack([jnp.asarray(c, jnp.float32) for c in
                      jnp.broadcast_arrays(*cols)], axis=-1)


def empty_hop_buffer(capacity: int) -> jnp.ndarray:
    """Unwritten ``[capacity, NUM_HOP_FIELDS]`` buffer (seq = -1)."""
    return jnp.full((capacity, NUM_HOP_FIELDS), -1.0, jnp.float32)


# ---------------------------------------------------------------------------
# State stream (the flight recorder; epoch-indexed, see module docstring)
# ---------------------------------------------------------------------------

STATE_GAUGES = ("phi", "queue_depth", "e_comp_j", "e_tx_j", "alive",
                "tx_bits")
(ST_PHI, ST_QUEUE_DEPTH, ST_E_COMP_J, ST_E_TX_J, ST_ALIVE,
 ST_TX_BITS) = range(len(STATE_GAUGES))
NUM_STATE_GAUGES = len(STATE_GAUGES)

SYS_GAUGES = ("t", "tasks_in_flight", "transfers_active", "completed",
              "dropped", "generated", "queue_depth_mean", "queue_depth_max",
              "queue_jain", "phi_mean", "phi_min", "phi_max", "energy_j")
(SYS_T, SYS_TASKS_IN_FLIGHT, SYS_TRANSFERS_ACTIVE, SYS_COMPLETED,
 SYS_DROPPED, SYS_GENERATED, SYS_QUEUE_DEPTH_MEAN, SYS_QUEUE_DEPTH_MAX,
 SYS_QUEUE_JAIN, SYS_PHI_MEAN, SYS_PHI_MIN, SYS_PHI_MAX,
 SYS_ENERGY_J) = range(len(SYS_GAUGES))
NUM_SYS_GAUGES = len(SYS_GAUGES)


def pack_state_sys_np(t, tasks_in_flight, transfers_active, completed,
                      dropped, generated, queue_depth_mean, queue_depth_max,
                      queue_jain, phi_mean=0.0, phi_min=0.0, phi_max=0.0,
                      energy_j=0.0) -> np.ndarray:
    """Host-side single system-gauge row (serving stack; f64 like pack_np)."""
    return np.asarray([t, tasks_in_flight, transfers_active, completed,
                       dropped, generated, queue_depth_mean, queue_depth_max,
                       queue_jain, phi_mean, phi_min, phi_max, energy_j],
                      np.float64)
