"""TaskRecord layout (DESIGN.md §10.1).

One task = one fixed-width float32 row.  A packed row (rather than a
struct-of-arrays dict) keeps the in-scan buffer a single carry leaf that
every executor backend batches/concatenates/checkpoints without special
cases, and makes the record vocabulary trivially shareable with the
serving stack (``splitcompute.ServeStats`` builds the same rows on host).

Fields (float32; integral fields are exact up to 2^24, far above any
realistic seq/node/layer count):

  ==============  =========================================================
  ``seq``         global task sequence number at the task's *last* enqueue
                  (``queues.py`` re-seqs on every hop; ``created_t`` still
                  spans the whole lifetime).  < 0 marks an unwritten slot.
  ``src``         node that generated the task (serve: entry stage)
  ``dst``         node that completed/dropped it (serve: exit stage)
  ``created_t``   generation time, simulation seconds
  ``completed_t`` completion/drop time, simulation seconds
  ``exit_label``  0 full / 1 medium / 2 high congestion exit, 3 = dropped
  ``layers``      layers executed at completion (0 for drops)
  ``hops``        |visited set| — distinct nodes that forwarded the task
  ``energy_j``    compute + transfer energy attributed to the task
  ``tx_time_s``   total time the task spent in flight between nodes
  ==============  =========================================================
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

FIELDS = ("seq", "src", "dst", "created_t", "completed_t", "exit_label",
          "layers", "hops", "energy_j", "tx_time_s")
(SEQ, SRC, DST, CREATED_T, COMPLETED_T, EXIT_LABEL, LAYERS, HOPS, ENERGY_J,
 TX_TIME_S) = range(len(FIELDS))
NUM_FIELDS = len(FIELDS)

# exit_label values beyond the paper's 0/1/2 congestion ladder
DROPPED = 3

INT_FIELDS = ("seq", "src", "dst", "exit_label", "layers", "hops")


def pack(seq, src, dst, created_t, completed_t, exit_label, layers, hops,
         energy_j, tx_time_s) -> jnp.ndarray:
    """Stack per-task field vectors into ``[..., NUM_FIELDS]`` f32 rows."""
    cols = (seq, src, dst, created_t, completed_t, exit_label, layers, hops,
            energy_j, tx_time_s)
    return jnp.stack([jnp.asarray(c, jnp.float32) for c in
                      jnp.broadcast_arrays(*cols)], axis=-1)


def pack_np(seq, src, dst, created_t, completed_t, exit_label, layers, hops,
            energy_j=0.0, tx_time_s=0.0) -> np.ndarray:
    """Host-side single-record row (serving stack).

    float64: host records never ride in a device carry, so there is no
    reason to round the caller's clock domain through float32.
    """
    return np.asarray([seq, src, dst, created_t, completed_t, exit_label,
                       layers, hops, energy_j, tx_time_s], np.float64)


def empty_buffer(capacity: int) -> jnp.ndarray:
    """Unwritten ``[capacity, NUM_FIELDS]`` buffer (seq = -1 everywhere)."""
    return jnp.full((capacity, NUM_FIELDS), -1.0, jnp.float32)
