"""Task- and hop-level aggregates (DESIGN.md §10.3, §10.5) — the paper's
evaluation currency: per-task latency distributions, Jain fairness over
task latencies, hop/exit histograms and energy per task, plus the
hop-resolved transfer decomposition (per-hop transfer time, per-link
bits, queue-wait vs in-flight), all computed from decoded records rather
than run means.

Both index builders emit a *stable key set*: an all-drop (or hop-free)
trace produces the same JSON keys as a populated one, with empty
histograms and ``None`` quantiles — so BENCH diffs across sweep points
stay comparable no matter what each point's tasks did.

Kept free of ``repro.fleet`` imports so ``fleet.report`` can call in
without a cycle; the quantile grid matches ``report.LATENCY_QS``.
"""
from __future__ import annotations

from typing import Dict, Mapping, Optional, Sequence

import numpy as np

QS = (0.05, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99)


def quantile_summary(x, qs: Sequence[float] = QS) -> Optional[Dict[str, float]]:
    """``{"p05": ..., "p50": ..., ...}`` of a 1-D sample; ``None`` when the
    sample is empty (a stable null beats a key that comes and goes)."""
    x = np.asarray(x, np.float64)
    if x.size == 0:
        return None
    return {f"p{int(q * 100):02d}": float(np.quantile(x, q)) for q in qs}


def jain_fairness(x) -> float:
    """Jain index (Σx)² / (n Σx²) of a 1-D sample."""
    x = np.asarray(x, np.float64)
    if x.size == 0:
        return 0.0
    return float(x.sum() ** 2 / (x.size * np.square(x).sum() + 1e-12))


def int_histogram(col) -> Dict[str, int]:
    """Value → count histogram of an integral column, string-keyed for
    JSON (the one histogram implementation every surface shares)."""
    vals, counts = np.unique(np.asarray(col, np.int64), return_counts=True)
    return {str(int(v)): int(c) for v, c in zip(vals, counts)}


def hop_histogram(dec: Mapping) -> Dict[str, int]:
    """Completed-task counts by number of forwarding hops."""
    return int_histogram(dec["hops"][~dec["is_dropped"]])


def exit_label_histogram(dec: Mapping) -> Dict[str, int]:
    """Task counts by exit label (0 full / 1 med / 2 high / 3 dropped)."""
    return int_histogram(dec["exit_label"])


def trace_indices(dec: Mapping) -> Dict:
    """Decoded TaskRecords → the JSON-ready task-level report section.

    Deterministic in the records, with a *stable schema*: an all-drop
    trace emits the same keys as a populated one (empty histograms, null
    quantiles), so the key set never varies across sweep points.
    """
    done = ~dec["is_dropped"]
    lat = dec["latency_s"][done]
    return {
        "task_count": int(done.sum()),
        "dropped_count": int(dec["is_dropped"].sum()),
        "trace_overflow": int(dec["overflow"]),
        "exit_label_histogram": exit_label_histogram(dec),
        "hop_histogram": hop_histogram(dec),
        "task_latency_cdf_s": quantile_summary(lat),
        "task_latency_jain": jain_fairness(lat) if lat.size else None,
        "energy_per_task_j_quantiles": quantile_summary(
            dec["energy_j"][done]),
        "tx_time_s_mean": (float(dec["tx_time_s"][done].mean())
                           if lat.size else None),
    }


def link_bits(hdec: Mapping) -> Dict[str, float]:
    """Total bits shipped per directed link, keyed ``"src->dst"``.

    Vectorized (a pooled point can hold millions of hop rows): groupby on
    the combined (src, dst) key via ``np.unique`` + weighted bincount.
    """
    src = np.asarray(hdec["src"], np.int64)
    dst = np.asarray(hdec["dst"], np.int64)
    if src.size == 0:
        return {}
    n = int(max(src.max(), dst.max())) + 1
    uniq, inv = np.unique(src * n + dst, return_inverse=True)
    sums = np.bincount(inv, weights=np.asarray(hdec["bits"], np.float64))
    return {f"{int(k // n)}->{int(k % n)}": float(s)
            for k, s in zip(uniq, sums)}


def hop_indices(hdec: Mapping, tick_s: Optional[float] = None) -> Dict:
    """Decoded HopRecords → the JSON-ready hop-resolved report section.

    ``tick_s`` converts ``stall_ticks`` into the queue-wait vs in-flight
    wall-time decomposition; without it the stall accounting stays in
    ticks and the seconds-valued entries are ``None`` (keys stable either
    way).  ``hop_count`` counts *delivered* hops — transfers still in
    flight at sim end never wrote a record and are not overflow.
    """
    t = hdec["transfer_time_s"]
    stall = hdec["stall_ticks"]
    lb = link_bits(hdec)
    out: Dict = {
        "hop_count": int(t.size),
        "hop_overflow": int(hdec["overflow"]),
        "hop_transfer_time_s_quantiles": quantile_summary(t),
        "hop_bits_quantiles": quantile_summary(hdec["bits"]),
        "link_count": len(lb),
        "link_bits_quantiles": quantile_summary(list(lb.values())),
        "hop_stall_ticks_quantiles": quantile_summary(stall),
        "stalled_hop_count": int((stall > 0).sum()),
        "hop_boundary_layer_histogram": int_histogram(
            hdec["boundary_layer"]),
        "hop_queue_wait_s_quantiles": None,
        "hop_in_flight_s_quantiles": None,
    }
    if tick_s is not None and t.size:
        wait = stall.astype(np.float64) * float(tick_s)
        out["hop_queue_wait_s_quantiles"] = quantile_summary(wait)
        out["hop_in_flight_s_quantiles"] = quantile_summary(t - wait)
    return out
