"""Task- and hop-level aggregates (DESIGN.md §10.3, §10.5) — the paper's
evaluation currency: per-task latency distributions, Jain fairness over
task latencies, hop/exit histograms and energy per task, plus the
hop-resolved transfer decomposition (per-hop transfer time, per-link
bits, queue-wait vs in-flight), all computed from decoded records rather
than run means.

Both index builders emit a *stable key set*: an all-drop (or hop-free)
trace produces the same JSON keys as a populated one, with empty
histograms and ``None`` quantiles — so BENCH diffs across sweep points
stay comparable no matter what each point's tasks did.

Kept free of ``repro.fleet`` imports so ``fleet.report`` can call in
without a cycle; the quantile grid matches ``report.LATENCY_QS``.
"""
from __future__ import annotations

from typing import Dict, Mapping, Optional, Sequence

import numpy as np

QS = (0.05, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99)

# φ-convergence threshold: epochs_to_eps is the first sampled epoch where
# the run-mean relative residual RMS(φ_t − φ_final)/RMS(φ_final) ≤ this
PHI_EPS = 0.05
# queue-depth heatmaps are downsampled to at most this many epoch rows
# before landing in BENCH (indent=1 JSON puts every number on its own
# line); the kept epochs are reported explicitly, never silently
HEATMAP_MAX_EPOCHS = 128


def quantile_summary(x, qs: Sequence[float] = QS) -> Optional[Dict[str, float]]:
    """``{"p05": ..., "p50": ..., ...}`` of a 1-D sample; ``None`` when the
    sample is empty (a stable null beats a key that comes and goes)."""
    x = np.asarray(x, np.float64)
    if x.size == 0:
        return None
    return {f"p{int(q * 100):02d}": float(np.quantile(x, q)) for q in qs}


def jain_fairness(x) -> float:
    """Jain index (Σx)² / (n Σx²) of a 1-D sample."""
    x = np.asarray(x, np.float64)
    if x.size == 0:
        return 0.0
    return float(x.sum() ** 2 / (x.size * np.square(x).sum() + 1e-12))


def int_histogram(col) -> Dict[str, int]:
    """Value → count histogram of an integral column, string-keyed for
    JSON (the one histogram implementation every surface shares)."""
    vals, counts = np.unique(np.asarray(col, np.int64), return_counts=True)
    return {str(int(v)): int(c) for v, c in zip(vals, counts, strict=True)}


def hop_histogram(dec: Mapping) -> Dict[str, int]:
    """Completed-task counts by number of forwarding hops."""
    return int_histogram(dec["hops"][~dec["is_dropped"]])


def exit_label_histogram(dec: Mapping) -> Dict[str, int]:
    """Task counts by exit label (0 full / 1 med / 2 high / 3 dropped)."""
    return int_histogram(dec["exit_label"])


def trace_indices(dec: Mapping) -> Dict:
    """Decoded TaskRecords → the JSON-ready task-level report section.

    Deterministic in the records, with a *stable schema*: an all-drop
    trace emits the same keys as a populated one (empty histograms, null
    quantiles), so the key set never varies across sweep points.
    """
    done = ~dec["is_dropped"]
    lat = dec["latency_s"][done]
    return {
        "task_count": int(done.sum()),
        "dropped_count": int(dec["is_dropped"].sum()),
        "trace_overflow": int(dec["overflow"]),
        "exit_label_histogram": exit_label_histogram(dec),
        "hop_histogram": hop_histogram(dec),
        "task_latency_cdf_s": quantile_summary(lat),
        "task_latency_jain": jain_fairness(lat) if lat.size else None,
        "energy_per_task_j_quantiles": quantile_summary(
            dec["energy_j"][done]),
        "tx_time_s_mean": (float(dec["tx_time_s"][done].mean())
                           if lat.size else None),
    }


def _round_list(x, nd: int = 6):
    return [round(float(v), nd) for v in np.asarray(x, np.float64).ravel()]


def state_indices(sdec: Mapping) -> Dict:
    """Decoded state stream → the JSON-ready flight-recorder section.

    Stable key set, like the task/hop builders: node-gauge indices are
    ``None`` when the decode lacks per-node buffers, system indices are
    ``None`` when it lacks sys columns (the serve engine emits either
    subset), and a fully-populated simulated point fills everything —
    φ-convergence curve + epochs-to-ε, queue-depth heatmap (run mean,
    ≤ :data:`HEATMAP_MAX_EPOCHS` epoch rows, kept epochs listed
    explicitly), energy-drain trajectory, and the peak/steady-state
    Jain imbalance of instantaneous queue depths.
    """
    epochs = np.asarray(sdec["epoch"], np.int64)
    S = int(epochs.size)
    out: Dict = {
        "state_sample_count": S,
        "state_runs": int(sdec.get("num_runs", 1)),
        "state_epochs": [int(e) for e in epochs],
        "state_nodes": None,
        "phi_eps": PHI_EPS,
        "phi_residual_curve": None,
        "phi_epochs_to_eps": None,
        "phi_spread_final": None,
        "queue_depth_heatmap": None,
        "queue_depth_heatmap_epochs": None,
        "queue_depth_mean_curve": None,
        "queue_depth_max_curve": None,
        "queue_jain_curve": None,
        "queue_jain_min": None,
        "queue_jain_final": None,
        "energy_drain_j_curve": None,
        "tasks_in_flight_curve": None,
        "completion_rate_final": None,
    }
    if "phi" in sdec and S:
        phi = np.asarray(sdec["phi"], np.float64)          # [R, S, M]
        out["state_nodes"] = int(phi.shape[2])
        # ‖φ_t − φ_∞‖: RMS over nodes of the residual vs the final sample,
        # averaged over runs (φ_∞ ≈ the last recorded sample of each run)
        resid = np.sqrt(np.mean((phi - phi[:, -1:, :]) ** 2, axis=2))
        curve = resid.mean(axis=0)                         # [S]
        out["phi_residual_curve"] = _round_list(curve)
        denom = np.sqrt(np.mean(phi[:, -1:, :] ** 2, axis=2)) + 1e-12
        rel = (resid / denom).mean(axis=0)
        hit = np.nonzero(rel <= PHI_EPS)[0]
        out["phi_epochs_to_eps"] = (int(epochs[hit[0]]) if hit.size
                                    else None)
        depth = np.asarray(sdec["queue_depth"], np.float64)  # [R, S, M]
        heat = depth.mean(axis=0)                            # [S, M]
        keep = np.unique(np.linspace(0, S - 1,
                                     min(S, HEATMAP_MAX_EPOCHS)).astype(int))
        out["queue_depth_heatmap"] = [_round_list(heat[i], 3) for i in keep]
        out["queue_depth_heatmap_epochs"] = [int(epochs[i]) for i in keep]
    if "queue_depth_mean" in sdec and S:
        qmean = np.asarray(sdec["queue_depth_mean"], np.float64)
        qmax = np.asarray(sdec["queue_depth_max"], np.float64)
        jain = np.asarray(sdec["queue_jain"], np.float64)
        out["queue_depth_mean_curve"] = _round_list(qmean.mean(axis=0), 3)
        out["queue_depth_max_curve"] = _round_list(qmax.mean(axis=0), 3)
        out["queue_jain_curve"] = _round_list(jain.mean(axis=0))
        out["queue_jain_min"] = round(float(jain.mean(axis=0).min()), 6)
        out["queue_jain_final"] = round(float(jain[:, -1].mean()), 6)
        out["energy_drain_j_curve"] = _round_list(
            np.asarray(sdec["energy_j"], np.float64).mean(axis=0))
        out["tasks_in_flight_curve"] = _round_list(
            np.asarray(sdec["tasks_in_flight"], np.float64).mean(axis=0), 3)
        done = np.asarray(sdec["completed"], np.float64)[:, -1]
        gen = np.asarray(sdec["generated"], np.float64)[:, -1]
        out["completion_rate_final"] = round(
            float((done / np.maximum(gen, 1.0)).mean()), 6)
        out["phi_spread_final"] = round(float(
            (np.asarray(sdec["phi_max"], np.float64)[:, -1]
             - np.asarray(sdec["phi_min"], np.float64)[:, -1]).mean()), 6)
    elif "phi" in sdec and S:
        phi = np.asarray(sdec["phi"], np.float64)
        out["phi_spread_final"] = round(float(
            (phi[:, -1, :].max(axis=1) - phi[:, -1, :].min(axis=1)).mean()),
            6)
    return out


def _link_sums(hdec: Mapping, weights) -> Dict[str, float]:
    """Sum ``weights`` per directed link, keyed ``"src->dst"``.

    Vectorized (a pooled point can hold millions of hop rows): groupby on
    the combined (src, dst) key via ``np.unique`` + weighted bincount.
    """
    src = np.asarray(hdec["src"], np.int64)
    dst = np.asarray(hdec["dst"], np.int64)
    if src.size == 0:
        return {}
    n = int(max(src.max(), dst.max())) + 1
    uniq, inv = np.unique(src * n + dst, return_inverse=True)
    sums = np.bincount(inv, weights=np.asarray(weights, np.float64))
    return {f"{int(k // n)}->{int(k % n)}": float(s)
            for k, s in zip(uniq, sums, strict=True)}


def link_bits(hdec: Mapping) -> Dict[str, float]:
    """Total bits shipped per directed link, keyed ``"src->dst"``."""
    return _link_sums(hdec, hdec["bits"])


def hop_airtime_s(hdec: Mapping, tick_s: float) -> np.ndarray:
    """Per-hop radio airtime: wall transfer time minus the stalled ticks
    (fault stalls + post-arrival contention waits), i.e. the ticks the
    sender's radio actually transmitted."""
    return (np.asarray(hdec["transfer_time_s"], np.float64)
            - np.asarray(hdec["stall_ticks"], np.float64) * float(tick_s))


def hop_energy_j(hdec: Mapping, tick_s: float,
                 tx_power_dbm: float) -> np.ndarray:
    """Per-hop transmit energy: airtime × linear transmit power.

    This is the HopRecord-side attribution of the simulator's ``e_tx``
    accumulator (which adds ``tx_w · tick`` per flying tick): when every
    transfer delivers before sim end, the sum over hops equals ``e_tx``
    exactly — the join the per-hop energy test pins.
    """
    tx_w = 10.0 ** (float(tx_power_dbm) / 10.0) * 1e-3
    return hop_airtime_s(hdec, tick_s) * tx_w


def link_energy_j(hdec: Mapping, tick_s: float,
                  tx_power_dbm: float) -> Dict[str, float]:
    """Total transmit joules per directed link, keyed ``"src->dst"`` —
    the airtime-J-per-link map the energy-budget analyses consume."""
    return _link_sums(hdec, hop_energy_j(hdec, tick_s, tx_power_dbm))


def hop_indices(hdec: Mapping, tick_s: Optional[float] = None,
                tx_power_dbm: Optional[float] = None) -> Dict:
    """Decoded HopRecords → the JSON-ready hop-resolved report section.

    ``tick_s`` converts ``stall_ticks`` into the queue-wait vs in-flight
    wall-time decomposition; ``tx_power_dbm`` additionally joins the hop
    stream with the transmit power into the per-hop / per-link airtime
    energy attribution (hop energy = (transfer time − stall ticks·tick) ×
    linear tx power).  Without them the corresponding entries are ``None``
    (keys stable either way).  ``hop_count`` counts *delivered* hops —
    transfers still in flight at sim end never wrote a record and are not
    overflow.
    """
    t = hdec["transfer_time_s"]
    stall = hdec["stall_ticks"]
    lb = link_bits(hdec)
    out: Dict = {
        "hop_count": int(t.size),
        "hop_overflow": int(hdec["overflow"]),
        "hop_transfer_time_s_quantiles": quantile_summary(t),
        "hop_bits_quantiles": quantile_summary(hdec["bits"]),
        "link_count": len(lb),
        "link_bits_quantiles": quantile_summary(list(lb.values())),
        "hop_stall_ticks_quantiles": quantile_summary(stall),
        "stalled_hop_count": int((stall > 0).sum()),
        "hop_boundary_layer_histogram": int_histogram(
            hdec["boundary_layer"]),
        "hop_queue_wait_s_quantiles": None,
        "hop_in_flight_s_quantiles": None,
        "hop_energy_j_quantiles": None,
        "link_energy_j_quantiles": None,
        "tx_airtime_total_s": None,
        "tx_energy_total_j": None,
    }
    if tick_s is not None and t.size:
        wait = stall.astype(np.float64) * float(tick_s)
        out["hop_queue_wait_s_quantiles"] = quantile_summary(wait)
        out["hop_in_flight_s_quantiles"] = quantile_summary(t - wait)
        out["tx_airtime_total_s"] = float(hop_airtime_s(hdec, tick_s).sum())
        if tx_power_dbm is not None:
            e = hop_energy_j(hdec, tick_s, tx_power_dbm)
            le = link_energy_j(hdec, tick_s, tx_power_dbm)
            out["hop_energy_j_quantiles"] = quantile_summary(e)
            out["link_energy_j_quantiles"] = quantile_summary(
                list(le.values()))
            out["tx_energy_total_j"] = float(e.sum())
    return out
