"""Task-level aggregates (DESIGN.md §10.3) — the paper's evaluation
currency: per-task latency distributions, Jain fairness over task
latencies, hop/exit histograms and energy per task, all computed from
decoded TaskRecords rather than run means.

Kept free of ``repro.fleet`` imports so ``fleet.report`` can call in
without a cycle; the quantile grid matches ``report.LATENCY_QS``.
"""
from __future__ import annotations

from typing import Dict, Mapping, Sequence

import numpy as np

QS = (0.05, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99)


def quantile_summary(x, qs: Sequence[float] = QS) -> Dict[str, float]:
    """``{"p05": ..., "p50": ..., ...}`` of a 1-D sample."""
    x = np.asarray(x, np.float64)
    return {f"p{int(q * 100):02d}": float(np.quantile(x, q)) for q in qs}


def jain_fairness(x) -> float:
    """Jain index (Σx)² / (n Σx²) of a 1-D sample."""
    x = np.asarray(x, np.float64)
    if x.size == 0:
        return 0.0
    return float(x.sum() ** 2 / (x.size * np.square(x).sum() + 1e-12))


def _histogram(col) -> Dict[str, int]:
    vals, counts = np.unique(np.asarray(col, np.int64), return_counts=True)
    return {str(int(v)): int(c) for v, c in zip(vals, counts)}


def hop_histogram(dec: Mapping) -> Dict[str, int]:
    """Completed-task counts by number of forwarding hops."""
    return _histogram(dec["hops"][~dec["is_dropped"]])


def exit_label_histogram(dec: Mapping) -> Dict[str, int]:
    """Task counts by exit label (0 full / 1 med / 2 high / 3 dropped)."""
    return _histogram(dec["exit_label"])


def trace_indices(dec: Mapping) -> Dict:
    """Decoded records → the JSON-ready task-level section of a report.

    Deterministic in the records; empty-completion traces degrade to the
    counters alone (no quantiles of an empty sample).
    """
    done = ~dec["is_dropped"]
    lat = dec["latency_s"][done]
    out: Dict = {
        "task_count": int(done.sum()),
        "dropped_count": int(dec["is_dropped"].sum()),
        "trace_overflow": int(dec["overflow"]),
        "exit_label_histogram": exit_label_histogram(dec),
    }
    if lat.size:
        out["task_latency_cdf_s"] = quantile_summary(lat)
        out["task_latency_jain"] = jain_fairness(lat)
        out["hop_histogram"] = hop_histogram(dec)
        out["energy_per_task_j_quantiles"] = quantile_summary(
            dec["energy_j"][done])
        out["tx_time_s_mean"] = float(dec["tx_time_s"][done].mean())
    return out
