"""Host-side TaskRecord/HopRecord decoding (DESIGN.md §10.3).

``decode`` (tasks) and ``decode_hops`` mask the unwritten slots out of
one or many record buffers (any leading batch shape — a single run's
``[C, F]`` buffer, a sweep point's ``[num_runs, C, F]`` stack) and split
the packed rows back into named numpy columns.  Row order is run-major
then seq-ascending (slot index == seq), so the output is deterministic
in the inputs.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.trace import schema


def _decode(records, overflow, fields, int_fields, seq_idx
            ) -> Dict[str, np.ndarray]:
    rec = np.asarray(records, np.float64).reshape(-1, len(fields))
    rec = rec[rec[:, seq_idx] >= 0.0]
    out: Dict[str, np.ndarray] = {}
    for i, name in enumerate(fields):
        col = rec[:, i]
        out[name] = (col.astype(np.int64) if name in int_fields else col)
    out["overflow"] = np.int64(0 if overflow is None
                               else np.sum(np.asarray(overflow)))
    return out


def decode(records, overflow=None) -> Dict[str, np.ndarray]:
    """TaskRecord buffer(s) → dict of per-task numpy columns.

    Integral fields come back as int64, times/energies as float64, plus
    two derived columns: ``latency_s`` (completed − created) and
    ``is_dropped``.  ``overflow`` (scalar or per-run array) is summed into
    the ``"overflow"`` entry (0-d int64) when given.
    """
    out = _decode(records, overflow, schema.FIELDS, schema.INT_FIELDS,
                  schema.SEQ)
    out["latency_s"] = out["completed_t"] - out["created_t"]
    out["is_dropped"] = out["exit_label"] == schema.DROPPED
    return out


def decode_hops(records, overflow=None) -> Dict[str, np.ndarray]:
    """HopRecord buffer(s) → dict of per-hop numpy columns.

    Adds the derived ``transfer_time_s`` column (``t_arrive − t_depart``,
    the hop's full initiate→delivery latency including stalls); convert
    ``stall_ticks`` to seconds with the run's ``tick_s`` when a wall-time
    decomposition is needed (``aggregate.hop_indices`` does).
    """
    out = _decode(records, overflow, schema.HOP_FIELDS,
                  schema.HOP_INT_FIELDS, schema.HOP_SEQ)
    out["transfer_time_s"] = out["t_arrive"] - out["t_depart"]
    return out


def decode_state(state=None, sys=None, epochs=None) -> Dict[str, np.ndarray]:
    """State-stream buffer(s) → dict of epoch-indexed numpy series.

    Accepts any subset of the three flight-recorder buffers (a simulated
    point carries all three; the serve engine emits sys-only or
    state+sys without epochs):

      * ``state``  — ``[S, M, NUM_STATE_GAUGES]`` or ``[R, S, M, G]``
      * ``sys``    — ``[S, NUM_SYS_GAUGES]`` or ``[R, S, SYS]``
      * ``epochs`` — ``[S]`` or ``[R, S]`` slot→epoch map (−1 = unwritten;
        identical across runs, so only row 0 is consulted)

    Returns ``{"epoch": [S'] int64, "num_runs": int}`` plus one
    ``[R, S', M]`` float64 series per :data:`schema.STATE_GAUGES` name and
    one ``[R, S']`` series per :data:`schema.SYS_GAUGES` name (the two
    vocabularies don't collide, so the dict is flat).  Unwritten slots
    (scan ended before the slot's epoch) are masked out of every series.
    """
    out: Dict[str, np.ndarray] = {}
    S = None
    if state is not None:
        st = np.asarray(state, np.float64)
        if st.ndim == 3:
            st = st[None]
        S = st.shape[1]
    if sys is not None:
        sy = np.asarray(sys, np.float64)
        if sy.ndim == 2:
            sy = sy[None]
        S = sy.shape[1] if S is None else S
    if S is None:
        raise ValueError("decode_state needs at least one buffer")
    if epochs is not None:
        ep = np.asarray(epochs, np.float64).reshape(-1, S)[0]
        valid = ep >= 0.0
        out["epoch"] = ep[valid].astype(np.int64)
    else:
        valid = np.ones((S,), bool)
        out["epoch"] = np.arange(S, dtype=np.int64)
    if state is not None:
        for i, name in enumerate(schema.STATE_GAUGES):
            # index the gauge axis first: combining the boolean epoch mask
            # and the gauge index in one subscript would be non-adjacent
            # advanced indexing, which transposes the result dims to the
            # front ([S', R, M] instead of [R, S', M])
            out[name] = st[..., i][:, valid, :]
        out["num_runs"] = int(st.shape[0])
    if sys is not None:
        for i, name in enumerate(schema.SYS_GAUGES):
            out[name] = sy[:, valid, i]
        out["num_runs"] = int(sy.shape[0])
    return out


def split_runs(records, overflow=None, hops: bool = False):
    """``[num_runs, C, F]`` stack → list of per-run decoded dicts."""
    rec = np.asarray(records)
    if rec.ndim == 2:
        rec = rec[None]
    ovf = (np.zeros((rec.shape[0],)) if overflow is None
           else np.asarray(overflow).reshape(rec.shape[0]))
    fn = decode_hops if hops else decode
    return [fn(r, o) for r, o in zip(rec, ovf, strict=True)]
