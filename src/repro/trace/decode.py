"""Host-side TaskRecord decoding (DESIGN.md §10.3).

``decode`` masks the unwritten slots out of one or many record buffers
(any leading batch shape — a single run's ``[C, F]`` buffer, a sweep
point's ``[num_runs, C, F]`` stack) and splits the packed rows back into
named numpy columns.  Row order is run-major then seq-ascending (slot
index == seq), so the output is deterministic in the inputs.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.trace import schema


def decode(records, overflow=None) -> Dict[str, np.ndarray]:
    """Record buffer(s) → dict of per-task numpy columns.

    Integral fields come back as int64, times/energies as float64, plus
    two derived columns: ``latency_s`` (completed − created) and
    ``is_dropped``.  ``overflow`` (scalar or per-run array) is summed into
    the ``"overflow"`` entry (0-d int64) when given.
    """
    rec = np.asarray(records, np.float64).reshape(-1, schema.NUM_FIELDS)
    rec = rec[rec[:, schema.SEQ] >= 0.0]
    out: Dict[str, np.ndarray] = {}
    for i, name in enumerate(schema.FIELDS):
        col = rec[:, i]
        out[name] = (col.astype(np.int64) if name in schema.INT_FIELDS
                     else col)
    out["latency_s"] = out["completed_t"] - out["created_t"]
    out["is_dropped"] = out["exit_label"] == schema.DROPPED
    out["overflow"] = np.int64(0 if overflow is None
                               else np.sum(np.asarray(overflow)))
    return out


def split_runs(records, overflow=None):
    """``[num_runs, C, F]`` stack → list of per-run decoded dicts."""
    rec = np.asarray(records)
    if rec.ndim == 2:
        rec = rec[None]
    ovf = (np.zeros((rec.shape[0],)) if overflow is None
           else np.asarray(overflow).reshape(rec.shape[0]))
    return [decode(r, o) for r, o in zip(rec, ovf)]
