"""Host-side TaskRecord/HopRecord decoding (DESIGN.md §10.3).

``decode`` (tasks) and ``decode_hops`` mask the unwritten slots out of
one or many record buffers (any leading batch shape — a single run's
``[C, F]`` buffer, a sweep point's ``[num_runs, C, F]`` stack) and split
the packed rows back into named numpy columns.  Row order is run-major
then seq-ascending (slot index == seq), so the output is deterministic
in the inputs.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.trace import schema


def _decode(records, overflow, fields, int_fields, seq_idx
            ) -> Dict[str, np.ndarray]:
    rec = np.asarray(records, np.float64).reshape(-1, len(fields))
    rec = rec[rec[:, seq_idx] >= 0.0]
    out: Dict[str, np.ndarray] = {}
    for i, name in enumerate(fields):
        col = rec[:, i]
        out[name] = (col.astype(np.int64) if name in int_fields else col)
    out["overflow"] = np.int64(0 if overflow is None
                               else np.sum(np.asarray(overflow)))
    return out


def decode(records, overflow=None) -> Dict[str, np.ndarray]:
    """TaskRecord buffer(s) → dict of per-task numpy columns.

    Integral fields come back as int64, times/energies as float64, plus
    two derived columns: ``latency_s`` (completed − created) and
    ``is_dropped``.  ``overflow`` (scalar or per-run array) is summed into
    the ``"overflow"`` entry (0-d int64) when given.
    """
    out = _decode(records, overflow, schema.FIELDS, schema.INT_FIELDS,
                  schema.SEQ)
    out["latency_s"] = out["completed_t"] - out["created_t"]
    out["is_dropped"] = out["exit_label"] == schema.DROPPED
    return out


def decode_hops(records, overflow=None) -> Dict[str, np.ndarray]:
    """HopRecord buffer(s) → dict of per-hop numpy columns.

    Adds the derived ``transfer_time_s`` column (``t_arrive − t_depart``,
    the hop's full initiate→delivery latency including stalls); convert
    ``stall_ticks`` to seconds with the run's ``tick_s`` when a wall-time
    decomposition is needed (``aggregate.hop_indices`` does).
    """
    out = _decode(records, overflow, schema.HOP_FIELDS,
                  schema.HOP_INT_FIELDS, schema.HOP_SEQ)
    out["transfer_time_s"] = out["t_arrive"] - out["t_depart"]
    return out


def split_runs(records, overflow=None, hops: bool = False):
    """``[num_runs, C, F]`` stack → list of per-run decoded dicts."""
    rec = np.asarray(records)
    if rec.ndim == 2:
        rec = rec[None]
    ovf = (np.zeros((rec.shape[0],)) if overflow is None
           else np.asarray(overflow).reshape(rec.shape[0]))
    fn = decode_hops if hops else decode
    return [fn(r, o) for r, o in zip(rec, ovf)]
