"""Chrome-trace / Perfetto timeline export (DESIGN.md §10.4).

One decoded run → the Trace Event JSON format both ``chrome://tracing``
and https://ui.perfetto.dev load directly:

  * one complete (``"X"``) slice per completed task on its completion
    node's track, spanning creation → completion (µs timebase);
  * one instant (``"i"``) event per dropped task at its drop time;
  * **without hop records**: a flow arrow (``"s"`` → ``"f"``) from the
    generating node's track to the completion node's for every task that
    was forwarded at least once — the net src→dst relocation, with the
    hop count and total in-flight time in ``args``;
  * **with hop records** (``decode_hops`` output passed as ``hops``):
    the net arrow is replaced by the true per-hop timeline — per
    delivered hop an in-flight ``"hop"`` slice on the *sender's* track
    (its single outgoing radio is busy exactly then), a ``"queue"``
    slice on the visited *receiving* node's track for the queue-wait
    tail (stall ticks: receiver contention / fault stalls), and one flow
    arrow per hop from departure to delivery.

  * **with the state stream** (``decode_state`` output passed as
    ``state``): Perfetto **counter tracks** (``"C"`` events) next to the
    slices — per recorded node a φ lane, a queue-depth lane and a
    cumulative-energy lane (``e_comp_j``/``e_tx_j`` stack), plus
    swarm-level counters (queue depth mean/max, tasks
    in-flight/completed/dropped, φ mean/min/max, total energy, queue
    Jain) from the system gauges.

Everything is stamped from record fields only — no wall clock — so the
export is deterministic in the records.
"""
from __future__ import annotations

import json
from typing import Dict, List, Mapping, Optional

from repro.trace import schema

_US = 1e6     # trace event timestamps are microseconds


def _base(dec: Mapping, i: int, ph: str) -> Dict:
    return {"ph": ph, "pid": 0, "tid": int(dec["dst"][i])}


def hop_trace_events(hops: Mapping, tick_s: Optional[float] = None
                     ) -> List[Dict]:
    """Decoded single-run HopRecords → per-hop Trace Event list.

    ``tick_s`` sizes the queue-wait slice (``stall_ticks`` is in ticks);
    without it stall ticks still ride in ``args`` but no queue slice is
    drawn (its wall-time extent would be unknown).
    """
    events: List[Dict] = []
    for i in range(len(hops["seq"])):
        seq = int(hops["seq"][i])
        src, dst = int(hops["src"][i]), int(hops["dst"][i])
        t0, t1 = float(hops["t_depart"][i]), float(hops["t_arrive"][i])
        stall = int(hops["stall_ticks"][i])
        args = {"seq": seq, "src": src, "dst": dst,
                "bits": float(hops["bits"][i]),
                "boundary_layer": int(hops["boundary_layer"][i]),
                "stall_ticks": stall}
        wait_s = stall * tick_s if tick_s is not None else None
        if wait_s is not None:
            args["queue_wait_s"] = wait_s
            args["in_flight_s"] = (t1 - t0) - wait_s
        # the sender's radio is busy only while bits are on the air: with
        # tick_s known the slice is the in-flight interval and the stall
        # tail renders as its own queue slice below; without it, the full
        # span (the wait's wall-time extent is unknown)
        fly_s = (t1 - t0) - wait_s if wait_s is not None else (t1 - t0)
        events.append({"ph": "X", "pid": 0, "tid": src,
                       "name": f"hop {src}→{dst}", "cat": "hop",
                       "ts": t0 * _US, "dur": fly_s * _US,
                       "args": args})
        if wait_s is not None and stall > 0:
            # queue-wait at the visited receiving node, adjacent to the
            # in-flight slice (mid-flight fault stalls are approximated
            # into the same tail — the record stores a total, not phases)
            events.append({"ph": "X", "pid": 0, "tid": dst,
                           "name": "queue-wait", "cat": "queue",
                           "ts": (t1 - wait_s) * _US, "dur": wait_s * _US,
                           "args": args})
        events.append({"ph": "s", "pid": 0, "tid": src, "id": seq,
                       "cat": "transfer", "name": "xfer", "ts": t0 * _US,
                       "args": args})
        events.append({"ph": "f", "pid": 0, "tid": dst, "bp": "e",
                       "id": seq, "cat": "transfer", "name": "xfer",
                       "ts": t1 * _US})
    return events


def state_counter_events(state: Mapping, run: int = 0) -> List[Dict]:
    """Decoded state stream → Perfetto counter-track (``"C"``) events.

    One φ / queue-depth / energy counter lane per recorded node (its own
    pid so the lanes group under a "swarm state" process, clear of the
    slice tracks) and swarm-level lanes from the system gauges.  ``run``
    picks the Monte-Carlo run to render (counters are per-run series; the
    aggregate surfaces live in ``state_indices``, not the timeline).
    """
    ts_s = (state["t"][run] if "t" in state
            else state["epoch"].astype(float))
    events: List[Dict] = [
        {"ph": "M", "pid": 1, "name": "process_name",
         "args": {"name": "swarm state"}}]
    if "phi" in state:
        phi = state["phi"][run]                       # [S, M]
        depth = state["queue_depth"][run]
        e_comp = state["e_comp_j"][run]
        e_tx = state["e_tx_j"][run]
        for m in range(phi.shape[1]):
            for s in range(phi.shape[0]):
                ts = float(ts_s[s]) * _US
                events.append({"ph": "C", "pid": 1, "name": f"uav {m} phi",
                               "ts": ts,
                               "args": {"phi": float(phi[s, m])}})
                events.append({"ph": "C", "pid": 1,
                               "name": f"uav {m} queue", "ts": ts,
                               "args": {"depth": float(depth[s, m])}})
                events.append({"ph": "C", "pid": 1,
                               "name": f"uav {m} energy_j", "ts": ts,
                               "args": {"e_comp_j": float(e_comp[s, m]),
                                        "e_tx_j": float(e_tx[s, m])}})
    if "queue_depth_mean" in state:
        series = (
            ("swarm queue depth", {"mean": state["queue_depth_mean"],
                                   "max": state["queue_depth_max"]}),
            ("swarm tasks", {"in_flight": state["tasks_in_flight"],
                             "completed": state["completed"],
                             "dropped": state["dropped"]}),
            ("swarm phi", {"mean": state["phi_mean"],
                           "min": state["phi_min"],
                           "max": state["phi_max"]}),
            ("swarm energy_j", {"total": state["energy_j"]}),
            ("swarm queue jain", {"jain": state["queue_jain"]}),
        )
        for s in range(len(state["epoch"])):
            ts = float(ts_s[s]) * _US
            for name, cols in series:
                events.append({"ph": "C", "pid": 1, "name": name, "ts": ts,
                               "args": {k: float(v[run][s])
                                        for k, v in cols.items()}})
    return events


def chrome_trace_events(dec: Mapping, hops: Optional[Mapping] = None,
                        tick_s: Optional[float] = None,
                        state: Optional[Mapping] = None) -> List[Dict]:
    """Decoded single-run records → Trace Event list (chronological).

    With ``hops`` (a ``decode_hops`` dict for the same run) the per-task
    net src→dst arrows are replaced by true per-hop slices + one flow
    arrow per hop (see module docstring).
    """
    tracks = sorted({*map(int, dec["src"]), *map(int, dec["dst"]),
                     *(map(int, hops["src"]) if hops is not None else ()),
                     *(map(int, hops["dst"]) if hops is not None else ())})
    events: List[Dict] = [
        {"ph": "M", "pid": 0, "name": "process_name",
         "args": {"name": "swarm"}}]
    events += [{"ph": "M", "pid": 0, "tid": t, "name": "thread_name",
                "args": {"name": f"uav {t}"}} for t in tracks]
    order = sorted(range(len(dec["seq"])),
                   key=lambda i: (float(dec["created_t"][i]),
                                  int(dec["seq"][i])))
    for i in order:
        seq = int(dec["seq"][i])
        args = {"seq": seq, "src": int(dec["src"][i]),
                "hops": int(dec["hops"][i]),
                "exit_label": int(dec["exit_label"][i]),
                "layers": int(dec["layers"][i]),
                "energy_j": float(dec["energy_j"][i]),
                "tx_time_s": float(dec["tx_time_s"][i])}
        if dec["is_dropped"][i]:
            events.append({**_base(dec, i, "i"), "s": "t",
                           "name": f"drop {seq}", "cat": "drop",
                           "ts": dec["completed_t"][i] * _US,
                           "args": args})
            continue
        events.append({**_base(dec, i, "X"), "name": f"task {seq}",
                       "cat": "task", "ts": dec["created_t"][i] * _US,
                       "dur": dec["latency_s"][i] * _US, "args": args})
        if hops is None and dec["hops"][i] > 0:
            # no hop stream: fall back to the net relocation arrow
            events.append({"ph": "s", "pid": 0, "tid": int(dec["src"][i]),
                           "id": seq, "cat": "transfer", "name": "xfer",
                           "ts": dec["created_t"][i] * _US, "args": args})
            events.append({**_base(dec, i, "f"), "bp": "e", "id": seq,
                           "cat": "transfer", "name": "xfer",
                           "ts": dec["completed_t"][i] * _US})
    if hops is not None:
        events += hop_trace_events(hops, tick_s)
    if state is not None:
        events += state_counter_events(state)
    return events


def write_chrome_trace(path: str, dec: Mapping,
                       hops: Optional[Mapping] = None,
                       tick_s: Optional[float] = None,
                       state: Optional[Mapping] = None) -> str:
    """Write ``{"traceEvents": [...]}`` JSON; returns ``path``."""
    doc = {"traceEvents": chrome_trace_events(dec, hops, tick_s, state),
           "displayTimeUnit": "ms",
           "otherData": {"schema": list(schema.FIELDS),
                         "hop_schema": list(schema.HOP_FIELDS)}}
    if state is not None:
        doc["otherData"]["state_schema"] = list(schema.STATE_GAUGES)
        doc["otherData"]["state_sys_schema"] = list(schema.SYS_GAUGES)
    with open(path, "w") as f:
        json.dump(doc, f)
    return path
