"""Chrome-trace / Perfetto timeline export (DESIGN.md §10.4).

One decoded run → the Trace Event JSON format both ``chrome://tracing``
and https://ui.perfetto.dev load directly:

  * one complete (``"X"``) slice per completed task on its completion
    node's track, spanning creation → completion (µs timebase);
  * one instant (``"i"``) event per dropped task at its drop time;
  * a flow arrow (``"s"`` → ``"f"``) from the generating node's track to
    the completion node's for every task that was forwarded at least once
    — per-hop timestamps are not in the TaskRecord (one record per task,
    not per hop), so the arrow renders the net src→dst relocation, with
    the hop count and total in-flight time in ``args``.

Everything is stamped from TaskRecord fields only — no wall clock — so
the export is deterministic in the records.
"""
from __future__ import annotations

import json
from typing import Dict, List, Mapping

from repro.trace import schema

_US = 1e6     # trace event timestamps are microseconds


def _base(dec: Mapping, i: int, ph: str) -> Dict:
    return {"ph": ph, "pid": 0, "tid": int(dec["dst"][i])}


def chrome_trace_events(dec: Mapping) -> List[Dict]:
    """Decoded single-run records → Trace Event list (chronological)."""
    tracks = sorted({*map(int, dec["src"]), *map(int, dec["dst"])})
    events: List[Dict] = [
        {"ph": "M", "pid": 0, "name": "process_name",
         "args": {"name": "swarm"}}]
    events += [{"ph": "M", "pid": 0, "tid": t, "name": "thread_name",
                "args": {"name": f"uav {t}"}} for t in tracks]
    order = sorted(range(len(dec["seq"])),
                   key=lambda i: (float(dec["created_t"][i]),
                                  int(dec["seq"][i])))
    for i in order:
        seq = int(dec["seq"][i])
        args = {"seq": seq, "src": int(dec["src"][i]),
                "hops": int(dec["hops"][i]),
                "exit_label": int(dec["exit_label"][i]),
                "layers": int(dec["layers"][i]),
                "energy_j": float(dec["energy_j"][i]),
                "tx_time_s": float(dec["tx_time_s"][i])}
        if dec["is_dropped"][i]:
            events.append({**_base(dec, i, "i"), "s": "t",
                           "name": f"drop {seq}", "cat": "drop",
                           "ts": dec["completed_t"][i] * _US,
                           "args": args})
            continue
        events.append({**_base(dec, i, "X"), "name": f"task {seq}",
                       "cat": "task", "ts": dec["created_t"][i] * _US,
                       "dur": dec["latency_s"][i] * _US, "args": args})
        if dec["hops"][i] > 0:      # net relocation arrow src → dst
            events.append({"ph": "s", "pid": 0, "tid": int(dec["src"][i]),
                           "id": seq, "cat": "transfer", "name": "xfer",
                           "ts": dec["created_t"][i] * _US, "args": args})
            events.append({**_base(dec, i, "f"), "bp": "e", "id": seq,
                           "cat": "transfer", "name": "xfer",
                           "ts": dec["completed_t"][i] * _US})
    return events


def write_chrome_trace(path: str, dec: Mapping) -> str:
    """Write ``{"traceEvents": [...]}`` JSON; returns ``path``."""
    doc = {"traceEvents": chrome_trace_events(dec),
           "displayTimeUnit": "ms",
           "otherData": {"schema": list(schema.FIELDS)}}
    with open(path, "w") as f:
        json.dump(doc, f)
    return path
