"""In-scan TaskRecord + HopRecord capture (DESIGN.md §10.2, §10.5).

A fixed-capacity record buffer rides in the simulator's scan carry; every
task completion (and queue-full drop) scatters one :mod:`schema` row into
it, keyed by the task's global sequence number from ``swarm/queues.py``.
Because each seq finishes exactly once, slot ``seq`` is written at most
once — the scatter is order-independent, so records are bit-identical
across ``vmap`` / ``shard_map`` / ``lax.map`` executor backends.  Records
whose seq exceeds the capacity are *dropped from capture* (out-of-bounds
scatter with ``mode="drop"``) and counted in a saturating overflow
counter: the buffer never wraps, decode is unambiguous, and
``trace_overflow`` tells you exactly how many task records were lost —
size ``SwarmConfig.trace_capacity`` above the expected task count to
capture everything.  No host callbacks anywhere: the whole path jits.

Attribution state carried alongside the queues (all trace-only — absent
when ``trace_capacity == 0``):

  * ``q_src`` / ``q_energy`` / ``q_txtime`` — per queue slot: generating
    node, energy attributed so far (compute J + transfer J), cumulative
    time in flight;
  * ``tx_src`` / ``tx_energy`` / ``tx_txtime`` — the same, for the
    in-flight outgoing transfer of each node.

The hop stream (``SwarmConfig.trace_hop_capacity``) is the same design a
level down: one row per *delivered transfer*, keyed by a dedicated hop
sequence counter assigned at ``transfer.initiate`` — each hop delivers at
most once, so the scatter is again order-independent.  It is gated
independently of the task stream (either can be on without the other)
and carries its own per-node in-flight attribution (``hop_seq`` /
``hop_bits`` / ``hop_layer`` / ``hop_stall``), all absent at the default
capacity 0.

The state stream (``SwarmConfig.trace_state_every``; DESIGN.md §12) is
simpler than either event stream because it is *epoch-indexed*: sample s
belongs to epoch ``s * every``, so slot ``epoch // every`` is written
exactly once, by exactly one epoch (non-sampled epochs target the
out-of-bounds slot S and are dropped by the scatter mode).  There is no
sequence counter, no overflow, and no ordering dependence — backend
bit-parity is free.  The per-node transmit-energy gauge reads the
simulator's own ``e_tx`` accumulator directly: energy accrues per sender
(``transfer.progress``) and is only summed to swarm level in summarize.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import SwarmConfig
from repro.trace import schema


def enabled(cfg: SwarmConfig) -> bool:
    return cfg.trace_capacity > 0


def init_trace(cfg: SwarmConfig, n: int) -> dict:
    """Trace-state entries for ``init_state`` — ``{}`` when tracing is off,
    so the untraced state pytree is unchanged field-for-field."""
    if not enabled(cfg):
        return {}
    Q = cfg.queue_slots
    return {
        "trace_records": schema.empty_buffer(cfg.trace_capacity),
        "trace_overflow": jnp.int32(0),
        "q_src": jnp.zeros((n, Q), jnp.int32),
        "q_energy": jnp.zeros((n, Q), jnp.float32),
        "q_txtime": jnp.zeros((n, Q), jnp.float32),
        "tx_src": jnp.zeros((n,), jnp.int32),
        "tx_energy": jnp.zeros((n,), jnp.float32),
        "tx_txtime": jnp.zeros((n,), jnp.float32),
    }


def hops_enabled(cfg: SwarmConfig) -> bool:
    return cfg.trace_hop_capacity > 0


def init_hops(cfg: SwarmConfig, n: int) -> dict:
    """Hop-stream state entries for ``init_state`` — ``{}`` when hop
    capture is off, so the state pytree is unchanged field-for-field."""
    if not hops_enabled(cfg):
        return {}
    return {
        "trace_hops": schema.empty_hop_buffer(cfg.trace_hop_capacity),
        "trace_hop_overflow": jnp.int32(0),
        "hop_counter": jnp.int32(0),
        # in-flight hop attribution, one slot per node (single outgoing
        # transfer per node, §3.2): the hop's seq, the bits staged at
        # initiate (tx_bits decrements in flight), the boundary layer the
        # task was snapped to, and the stall ticks accumulated so far
        "hop_seq": jnp.zeros((n,), jnp.int32),
        "hop_bits": jnp.zeros((n,), jnp.float32),
        "hop_layer": jnp.zeros((n,), jnp.int32),
        "hop_stall": jnp.zeros((n,), jnp.int32),
    }


def state_enabled(cfg: SwarmConfig) -> bool:
    return cfg.trace_state_every > 0


def num_state_samples(cfg: SwarmConfig) -> int:
    """Static slot count S = ceil(n_epochs / every) of the state buffers."""
    n_epochs = int(round(cfg.sim_time_s / cfg.decision_period_s))
    return (n_epochs + cfg.trace_state_every - 1) // cfg.trace_state_every


def state_nodes(cfg: SwarmConfig, n: int) -> int:
    """Recorded node-panel width M = min(N, trace_state_nodes or N)."""
    return min(n, cfg.trace_state_nodes or n)


def init_state_stream(cfg: SwarmConfig, n: int) -> dict:
    """State-stream entries for ``init_state`` — ``{}`` when off, so the
    untraced state pytree is unchanged field-for-field."""
    if not state_enabled(cfg):
        return {}
    S = num_state_samples(cfg)
    M = state_nodes(cfg, n)
    return {
        "trace_state": jnp.zeros((S, M, schema.NUM_STATE_GAUGES),
                                 jnp.float32),
        "trace_state_sys": jnp.zeros((S, schema.NUM_SYS_GAUGES),
                                     jnp.float32),
        # epoch index of each written slot; -1 marks never-written (only
        # possible if the scan ends before the slot's epoch)
        "trace_state_epochs": jnp.full((S,), -1.0, jnp.float32),
    }


def write_state(st, epoch_idx, t_end, cfg: SwarmConfig):
    """Snapshot node gauges + system aggregates at the end of an epoch.

    Called every epoch; epochs with ``epoch_idx % every != 0`` scatter to
    the out-of-bounds slot S and are dropped.  ``t_end`` is the simulation
    time at the end of the epoch.
    """
    S = st["trace_state"].shape[0]
    M = st["trace_state"].shape[1]
    every = cfg.trace_state_every
    sampled = (epoch_idx % every) == 0
    slot = jnp.where(sampled, epoch_idx // every, S)

    qdepth = jnp.sum(st["q_active"], axis=1).astype(jnp.float32)
    e_comp = st["proc_gflops"] * cfg.energy_per_gflop_j
    inflight_bits = jnp.where(st["tx_active"],
                              jnp.maximum(st["tx_bits"], 0.0), 0.0)
    node_rows = jnp.stack(
        [st["phi"][:M], qdepth[:M], e_comp[:M], st["e_tx"][:M],
         st["alive"][:M].astype(jnp.float32), inflight_bits[:M]], axis=-1)

    q = qdepth
    jain = (jnp.sum(q) ** 2) / (q.shape[0] * jnp.sum(q * q) + 1e-12)
    tx_act = jnp.sum(st["tx_active"].astype(jnp.float32))
    sys_row = jnp.stack(
        [t_end, jnp.sum(q) + tx_act, tx_act,
         st["done_count"].astype(jnp.float32),
         st["drop_count"].astype(jnp.float32),
         st["gen_count"].astype(jnp.float32),
         jnp.mean(q), jnp.max(q), jain,
         jnp.mean(st["phi"]), jnp.min(st["phi"]), jnp.max(st["phi"]),
         jnp.sum(st["e_comp"] + st["e_tx"])]).astype(jnp.float32)

    st = dict(st)
    # oob: drop is load-bearing — non-capture epochs target slot==capacity
    # on purpose, so the scatter is the stride filter itself (J003)
    st["trace_state"] = st["trace_state"].at[slot].set(
        node_rows, mode="drop")
    st["trace_state_sys"] = st["trace_state_sys"].at[slot].set(
        sys_row, mode="drop")
    # oob: same deliberate slot==capacity drop as above (J003)
    st["trace_state_epochs"] = st["trace_state_epochs"].at[slot].set(
        epoch_idx.astype(jnp.float32), mode="drop")
    return st


def _scatter_records(st, key_records, key_overflow, mask, seq, rows):
    """Shared scatter-by-seq + saturating-overflow core of both streams.

    Lanes with ``~mask`` (and captured-but-overflowed seqs) target slot
    ``capacity`` — out of bounds, dropped by the scatter mode — so the
    kept rows are deterministic regardless of lane order.
    """
    cap = st[key_records].shape[0]
    slot = jnp.where(mask, seq, cap)
    st = dict(st)
    # oob: drop is load-bearing — unmasked lanes and overflowed seqs
    # target slot==capacity so they vanish deterministically (J003)
    st[key_records] = st[key_records].at[slot].set(rows, mode="drop")
    # saturate at int32 max instead of wrapping (clamp the increment to
    # the remaining headroom — int32-only, no x64 dependence)
    inc = jnp.sum(mask & (seq >= cap)).astype(jnp.int32)
    room = jnp.int32(jnp.iinfo(jnp.int32).max) - st[key_overflow]
    st[key_overflow] = st[key_overflow] + jnp.minimum(inc, room)
    return st


def write_records(st, mask, *, seq, src, dst, created_t, completed_t,
                  exit_label, layers, hops, energy_j, tx_time_s):
    """Scatter one TaskRecord per ``mask`` lane into slot ``seq``."""
    rows = schema.pack(seq, src, dst, created_t, completed_t, exit_label,
                       layers, hops, energy_j, tx_time_s)
    return _scatter_records(st, "trace_records", "trace_overflow", mask,
                            seq, rows)


def write_hop_records(st, mask, *, seq, src, dst, t_depart, t_arrive, bits,
                      boundary_layer, stall_ticks):
    """Scatter one HopRecord per ``mask`` lane into slot ``seq``."""
    rows = schema.pack_hop(seq, src, dst, t_depart, t_arrive, bits,
                           boundary_layer, stall_ticks)
    return _scatter_records(st, "trace_hops", "trace_hop_overflow", mask,
                            seq, rows)


def traced_push(st, mask, cum, created, visited, *, src, energy, txtime,
                t_now, cfg: SwarmConfig):
    """``queues.push`` plus attribution carry and drop records.

    Tasks that find no free slot are dropped by ``push`` (counted in
    ``drop_count``); under tracing they additionally consume a seq — the
    record keyspace covers every task that ever *finished*, completed or
    not — and scatter a ``DROPPED`` record stamped at ``t_now``.
    """
    from repro.swarm.queues import push      # deferred: queues ↔ trace

    n = st["q_active"].shape[0]
    has_free = ~jnp.all(st["q_active"], axis=1)
    dropped = mask & ~has_free
    st = push(st, mask, cum, created, visited,
              extras={"src": src, "energy": energy, "txtime": txtime})
    # seqs for the drops, after push consumed the accepted tasks' seqs
    # (i32-pinned reductions: numpy-style widening under x64 would drift
    # the seq-counter carry dtype — swarmlint J002)
    drop_seq = st["seq_counter"] + jnp.cumsum(
        dropped.astype(jnp.int32), dtype=jnp.int32) - 1
    st = dict(st)
    st["seq_counter"] = st["seq_counter"] + jnp.sum(
        dropped.astype(jnp.int32), dtype=jnp.int32)
    return write_records(
        st, dropped, seq=drop_seq, src=src, dst=jnp.arange(n),
        created_t=created, completed_t=t_now,
        exit_label=jnp.int32(schema.DROPPED), layers=jnp.int32(0),
        hops=jnp.sum(visited, axis=-1), energy_j=energy,
        tx_time_s=txtime)
