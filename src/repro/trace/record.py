"""In-scan TaskRecord capture (DESIGN.md §10.2).

A fixed-capacity record buffer rides in the simulator's scan carry; every
task completion (and queue-full drop) scatters one :mod:`schema` row into
it, keyed by the task's global sequence number from ``swarm/queues.py``.
Because each seq finishes exactly once, slot ``seq`` is written at most
once — the scatter is order-independent, so records are bit-identical
across ``vmap`` / ``shard_map`` / ``lax.map`` executor backends.  Records
whose seq exceeds the capacity are *dropped from capture* (out-of-bounds
scatter with ``mode="drop"``) and counted in a saturating overflow
counter: the buffer never wraps, decode is unambiguous, and
``trace_overflow`` tells you exactly how many task records were lost —
size ``SwarmConfig.trace_capacity`` above the expected task count to
capture everything.  No host callbacks anywhere: the whole path jits.

Attribution state carried alongside the queues (all trace-only — absent
when ``trace_capacity == 0``):

  * ``q_src`` / ``q_energy`` / ``q_txtime`` — per queue slot: generating
    node, energy attributed so far (compute J + transfer J), cumulative
    time in flight;
  * ``tx_src`` / ``tx_energy`` / ``tx_txtime`` — the same, for the
    in-flight outgoing transfer of each node.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import SwarmConfig
from repro.trace import schema


def enabled(cfg: SwarmConfig) -> bool:
    return cfg.trace_capacity > 0


def init_trace(cfg: SwarmConfig, n: int) -> dict:
    """Trace-state entries for ``init_state`` — ``{}`` when tracing is off,
    so the untraced state pytree is unchanged field-for-field."""
    if not enabled(cfg):
        return {}
    Q = cfg.queue_slots
    return {
        "trace_records": schema.empty_buffer(cfg.trace_capacity),
        "trace_overflow": jnp.int32(0),
        "q_src": jnp.zeros((n, Q), jnp.int32),
        "q_energy": jnp.zeros((n, Q), jnp.float32),
        "q_txtime": jnp.zeros((n, Q), jnp.float32),
        "tx_src": jnp.zeros((n,), jnp.int32),
        "tx_energy": jnp.zeros((n,), jnp.float32),
        "tx_txtime": jnp.zeros((n,), jnp.float32),
    }


def write_records(st, mask, *, seq, src, dst, created_t, completed_t,
                  exit_label, layers, hops, energy_j, tx_time_s):
    """Scatter one record per ``mask`` lane into the buffer at slot ``seq``.

    Lanes with ``~mask`` (and captured-but-overflowed seqs) target slot
    ``capacity`` — out of bounds, dropped by the scatter mode — so the
    kept rows are deterministic regardless of lane order.
    """
    cap = st["trace_records"].shape[0]
    rows = schema.pack(seq, src, dst, created_t, completed_t, exit_label,
                       layers, hops, energy_j, tx_time_s)
    slot = jnp.where(mask, seq, cap)
    st = dict(st)
    st["trace_records"] = st["trace_records"].at[slot].set(rows,
                                                           mode="drop")
    # saturate at int32 max instead of wrapping (clamp the increment to
    # the remaining headroom — int32-only, no x64 dependence)
    inc = jnp.sum(mask & (seq >= cap)).astype(jnp.int32)
    room = jnp.int32(jnp.iinfo(jnp.int32).max) - st["trace_overflow"]
    st["trace_overflow"] = st["trace_overflow"] + jnp.minimum(inc, room)
    return st


def traced_push(st, mask, cum, created, visited, *, src, energy, txtime,
                t_now, cfg: SwarmConfig):
    """``queues.push`` plus attribution carry and drop records.

    Tasks that find no free slot are dropped by ``push`` (counted in
    ``drop_count``); under tracing they additionally consume a seq — the
    record keyspace covers every task that ever *finished*, completed or
    not — and scatter a ``DROPPED`` record stamped at ``t_now``.
    """
    from repro.swarm.queues import push      # deferred: queues ↔ trace

    n = st["q_active"].shape[0]
    has_free = ~jnp.all(st["q_active"], axis=1)
    dropped = mask & ~has_free
    st = push(st, mask, cum, created, visited,
              extras={"src": src, "energy": energy, "txtime": txtime})
    # seqs for the drops, after push consumed the accepted tasks' seqs
    drop_seq = st["seq_counter"] + jnp.cumsum(dropped.astype(jnp.int32)) - 1
    st = dict(st)
    st["seq_counter"] = st["seq_counter"] + jnp.sum(
        dropped.astype(jnp.int32))
    return write_records(
        st, dropped, seq=drop_seq, src=src, dst=jnp.arange(n),
        created_t=created, completed_t=t_now,
        exit_label=jnp.int32(schema.DROPPED), layers=jnp.int32(0),
        hops=jnp.sum(visited, axis=-1), energy_j=energy,
        tx_time_s=txtime)
