"""Critical-path attribution (DESIGN.md §14.4): decompose each task's
end-to-end latency into **compute / queue-wait / airtime / fault-stall**
segments from the existing TaskRecord + HopRecord streams, so a latency
regression names the segment that moved instead of just the total.

The decomposition is *exact by construction* — the four segments of every
task sum to its recorded ``latency_s`` bit-for-bit:

  * in-flight time is the TaskRecord's ``tx_time_s`` (clipped into
    ``[0, latency]``), split into **airtime** and **stall** by the hop
    stream's global stall fraction (Σ stall_ticks·tick / Σ transfer time
    — HopRecords carry stalls per hop but re-seq per enqueue, so the
    task join is by fraction, not by row);
  * on-node time (latency − in-flight) is split into **compute** —
    the physics estimate ``layers · gflops_per_layer / capability``,
    clamped to the on-node budget — and **queue-wait**, the remainder.

Without a hop stream the stall segment is 0 (all in-flight time is
airtime); without a compute-rate estimate the compute segment absorbs the
whole on-node budget (queue-wait 0) — both degradations keep the sum
exact and the key set stable.

Kept free of ``repro.fleet`` imports (``fleet.report`` calls in) and of
any executor/simulator imports (``splitcompute.ServeStats`` imports
:data:`SEGMENTS` for its streaming segment histograms).
"""
from __future__ import annotations

from typing import Dict, Mapping, Optional

import numpy as np

from repro.trace.aggregate import quantile_summary

# the four latency segments, in report order; per task they sum exactly
# to latency_s (the invariant tests/test_critical.py pins)
SEGMENTS = ("compute_s", "queue_wait_s", "airtime_s", "stall_s")


def hop_stall_fraction(hdec: Mapping, tick_s: float) -> float:
    """Fraction of total hop transfer time spent stalled (fault stalls +
    receiver-contention waits), from the decoded hop stream.

    This is the stream-wide ratio — HopRecord seqs are re-assigned at
    every enqueue, so per-task hop joins are not well-defined; the global
    fraction is the unbiased split of each task's ``tx_time_s``.
    """
    t = np.asarray(hdec["transfer_time_s"], np.float64)
    if t.size == 0:
        return 0.0
    stall = np.asarray(hdec["stall_ticks"], np.float64) * float(tick_s)
    denom = float(t.sum())
    if denom <= 0.0:
        return 0.0
    return float(np.clip(stall.sum() / denom, 0.0, 1.0))


def decompose(dec: Mapping, hdec: Optional[Mapping] = None, *,
              tick_s: Optional[float] = None,
              gflops_per_layer: Optional[float] = None,
              capability_gflops: Optional[float] = None
              ) -> Dict[str, np.ndarray]:
    """Decoded TaskRecords → per-task segment arrays (completed tasks
    only), plus the matching ``latency_s`` column.

    Returns ``{"latency_s", "compute_s", "queue_wait_s", "airtime_s",
    "stall_s"}``; every row satisfies ``latency == Σ segments`` exactly
    (the remainders are computed by subtraction, never re-derived).
    """
    done = ~np.asarray(dec["is_dropped"], bool)
    lat = np.asarray(dec["latency_s"], np.float64)[done]
    lat = np.maximum(lat, 0.0)
    tx = np.clip(np.asarray(dec["tx_time_s"], np.float64)[done], 0.0, lat)

    frac = (hop_stall_fraction(hdec, tick_s)
            if hdec is not None and tick_s is not None else 0.0)
    stall = tx * frac
    airtime = tx - stall

    on_node = lat - tx
    if gflops_per_layer is not None and capability_gflops:
        layers = np.asarray(dec["layers"], np.float64)[done]
        est = layers * float(gflops_per_layer) / float(capability_gflops)
        compute = np.minimum(est, on_node)
    else:
        compute = on_node
    queue_wait = on_node - compute

    return {"latency_s": lat, "compute_s": compute,
            "queue_wait_s": queue_wait, "airtime_s": airtime,
            "stall_s": stall}


def segment_indices(dec: Mapping, hdec: Optional[Mapping] = None, *,
                    tick_s: Optional[float] = None,
                    gflops_per_layer: Optional[float] = None,
                    capability_gflops: Optional[float] = None) -> Dict:
    """Per-segment quantile summaries + mean shares, JSON-ready.

    Stable key set: an all-drop trace emits the same keys with ``None``
    quantiles and zero shares.  ``reconcile_max_err_s`` is the largest
    per-task |latency − Σ segments| — 0.0 up to float rounding, the
    acceptance invariant BENCH carries explicitly.
    """
    seg = decompose(dec, hdec, tick_s=tick_s,
                    gflops_per_layer=gflops_per_layer,
                    capability_gflops=capability_gflops)
    lat = seg["latency_s"]
    total = float(lat.sum())
    out: Dict = {"task_count": int(lat.size)}
    resid = lat.copy()
    for name in SEGMENTS:
        x = seg[name]
        resid = resid - x
        out[f"{name}_quantiles"] = quantile_summary(x)
        out[f"{name}_share"] = (float(x.sum() / total) if total > 0.0
                                else 0.0)
    out["reconcile_max_err_s"] = (float(np.abs(resid).max())
                                  if lat.size else 0.0)
    return out


def attribute(baseline: Mapping, current: Mapping,
              quantile: str = "p50") -> Optional[Dict]:
    """Name the segment that moved between two :func:`segment_indices`
    payloads — the perf-gate attribution step (DESIGN.md §14.5).

    Compares each segment's ``quantile`` entry and returns the largest
    absolute increase as ``{"segment", "baseline_s", "current_s",
    "delta_s", "ratio"}`` (``ratio`` None when the baseline is 0), or
    ``None`` when no segment is comparable or none regressed.
    """
    worst = None
    for name in SEGMENTS:
        b = (baseline.get(f"{name}_quantiles") or {}).get(quantile)
        c = (current.get(f"{name}_quantiles") or {}).get(quantile)
        if b is None or c is None:
            continue
        delta = float(c) - float(b)
        if worst is None or delta > worst["delta_s"]:
            worst = {"segment": name, "baseline_s": float(b),
                     "current_s": float(c), "delta_s": delta,
                     "ratio": (float(c) / float(b) if b > 0.0 else None)}
    if worst is None or worst["delta_s"] <= 0.0:
        return None
    return worst
