"""`repro.trace` — per-task and per-hop telemetry (DESIGN.md §10).

The simulator only accumulates scalar sums; this package captures one
fixed-width :mod:`~repro.trace.schema` TaskRecord per completed (and
dropped) task — and, as a second stream, one HopRecord per delivered
transfer — *inside* the jitted scan (:mod:`~repro.trace.record` — no
host callbacks, vmap/shard_map/lax.map-safe), decodes the buffers on the
host (:mod:`~repro.trace.decode`), aggregates them into the paper's
task- and hop-level indices — latency CDF, Jain fairness over task
latencies, hop and exit histograms, energy per task, per-hop transfer
time and per-link bits with the queue-wait vs in-flight decomposition
(:mod:`~repro.trace.aggregate`) — and exports a Chrome-trace/Perfetto
timeline with true per-hop slices and flow arrows
(:mod:`~repro.trace.export`).

A third stream, the epoch-indexed swarm-state **flight recorder**
(``SwarmConfig.trace_state_every > 0``; DESIGN.md §12), snapshots
per-node gauges (φ, queue depth, cumulative energy, alive, in-flight
bits) plus system aggregates every N-th epoch; ``decode_state`` /
``state_indices`` turn it into φ-convergence curves, queue-depth
heatmaps, energy-drain trajectories and imbalance indices, and
``state_counter_events`` renders Perfetto counter tracks.

:mod:`~repro.trace.critical` decomposes each traced task's end-to-end
latency into compute / queue-wait / airtime / fault-stall segments that
sum back exactly (DESIGN.md §14.4) — ``segment_indices`` feeds the BENCH
``latency_segments`` payload and ``attribute`` names the segment that
moved in a perf-gate regression.

Enabled by ``SwarmConfig.trace_capacity > 0`` (tasks),
``SwarmConfig.trace_hop_capacity > 0`` (hops) and
``SwarmConfig.trace_state_every > 0`` (state), independently; with the
defaults 0 no trace state exists anywhere and the simulator is
bit-identical to an untraced build.
"""
from repro.trace import schema
from repro.trace.aggregate import (exit_label_histogram, hop_airtime_s,
                                   hop_energy_j, hop_histogram, hop_indices,
                                   int_histogram, jain_fairness, link_bits,
                                   link_energy_j, quantile_summary,
                                   state_indices, trace_indices)
from repro.trace.critical import (SEGMENTS, attribute, decompose,
                                  hop_stall_fraction, segment_indices)
from repro.trace.decode import decode, decode_hops, decode_state, split_runs
from repro.trace.export import (chrome_trace_events, hop_trace_events,
                                state_counter_events, write_chrome_trace)
from repro.trace.record import (init_hops, init_state_stream, init_trace,
                                state_enabled, traced_push,
                                write_hop_records, write_records,
                                write_state)

__all__ = ["schema", "decode", "decode_hops", "decode_state", "split_runs",
           "trace_indices", "hop_indices", "state_indices", "link_bits",
           "hop_airtime_s", "hop_energy_j", "link_energy_j",
           "quantile_summary", "jain_fairness",
           "hop_histogram", "exit_label_histogram", "int_histogram",
           "chrome_trace_events", "hop_trace_events",
           "state_counter_events", "write_chrome_trace",
           "init_trace", "init_hops", "init_state_stream", "state_enabled",
           "traced_push", "write_records", "write_hop_records",
           "write_state",
           "SEGMENTS", "decompose", "segment_indices", "attribute",
           "hop_stall_fraction"]
