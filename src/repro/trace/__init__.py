"""`repro.trace` — per-task telemetry (DESIGN.md §10).

The simulator only accumulates scalar sums; this package captures one
fixed-width :mod:`~repro.trace.schema` TaskRecord per completed (and
dropped) task *inside* the jitted scan (:mod:`~repro.trace.record` — no
host callbacks, vmap/shard_map/lax.map-safe), decodes the buffers on the
host (:mod:`~repro.trace.decode`), aggregates them into the paper's
task-level indices — latency CDF, Jain fairness over task latencies, hop
and exit histograms, energy per task (:mod:`~repro.trace.aggregate`) —
and exports a Chrome-trace/Perfetto timeline (:mod:`~repro.trace.export`).

Enabled by ``SwarmConfig.trace_capacity > 0``; with the default 0 no
trace state exists anywhere and the simulator is bit-identical to an
untraced build.
"""
from repro.trace import schema
from repro.trace.aggregate import (exit_label_histogram, hop_histogram,
                                   jain_fairness, quantile_summary,
                                   trace_indices)
from repro.trace.decode import decode, split_runs
from repro.trace.export import chrome_trace_events, write_chrome_trace
from repro.trace.record import init_trace, traced_push, write_records

__all__ = ["schema", "decode", "split_runs",
           "trace_indices", "quantile_summary", "jain_fairness",
           "hop_histogram", "exit_label_histogram",
           "chrome_trace_events", "write_chrome_trace",
           "init_trace", "traced_push", "write_records"]
