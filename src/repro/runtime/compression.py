"""int8 gradient compression with error feedback (distributed-optimization
trick for the DP all-reduce; 4× volume reduction vs fp32, 2× vs bf16).

Per-leaf symmetric quantization: q = round(g / s), s = max|g| / 127.
The residual (g - dequant(q)) is carried to the next step (error feedback,
Seide et al. 2014 / Karimireddy et al. 2019) so compression noise averages
out instead of biasing the descent direction.  Tested for convergence
parity in tests/test_runtime.py.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    residual: Any   # like grads (fp32)


def init_compression(params) -> CompressionState:
    return CompressionState(
        jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads(grads, state: CompressionState
                   ) -> Tuple[Any, CompressionState]:
    """Returns (dequantized grads as would survive the int8 all-reduce,
    updated residual state).  The all-reduce itself is XLA's (psum of the
    dequantized tensors is numerically identical on CPU; on a real fleet
    the int8 payload is what crosses the network)."""
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = quantize(gf)
        deq = dequantize(q, s)
        return deq, gf - deq

    out = jax.tree.map(one, grads, state.residual)
    deq = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    return deq, CompressionState(res)
