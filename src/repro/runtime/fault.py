"""Fault-tolerant training driver: checkpoint/restart, failure injection,
straggler mitigation.

The driver treats a train step as a unit of work that can die at any
moment (preemption, hardware fault).  Recovery = restore latest checkpoint
+ stateless data pipeline indexed by step ⇒ bit-identical resume (tested).

Straggler policy (the paper's congestion-aware early exit, lifted to the
step level): each step has a deadline = `straggler_factor` × EMA(step
time).  A step that exceeds it is counted and the policy reacts the way
the paper's Eq. 16 reacts to queue growth — by shedding optional work
(here: skipping the metrics host-sync, the analogue of a truncated exit)
rather than stalling the fleet.  On a real fleet the same hook is where
within-step timeout collectives / backup workers would attach.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

from repro.checkpoint import latest_step, restore, save


@dataclasses.dataclass
class DriverConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    max_steps: int = 200
    straggler_factor: float = 3.0
    # failure injection for tests: raise at this step, once
    fail_at_step: Optional[int] = None


class StepStats:
    def __init__(self):
        self.ema = None
        self.stragglers = 0
        self.steps = 0

    def update(self, dt: float, factor: float) -> bool:
        straggler = self.ema is not None and dt > factor * self.ema
        self.ema = dt if self.ema is None else 0.9 * self.ema + 0.1 * dt
        self.stragglers += int(straggler)
        self.steps += 1
        return straggler


class FailureInjected(RuntimeError):
    pass


def run_training(cfg: DriverConfig, *, init_state: Callable[[], Any],
                 train_step: Callable[[Any, int], Any],
                 batch_fn: Callable[[int], Dict],
                 on_metrics: Optional[Callable[[int, Dict], None]] = None,
                 _failed_once: Dict = None) -> Any:
    """Run (or resume) training to cfg.max_steps with checkpoint/restart.

    `train_step(state, batch) -> (state, metrics)` must be jit'd by the
    caller; `init_state()` builds step-0 state.  Returns final state.
    """
    _failed_once = _failed_once if _failed_once is not None else {}
    start = latest_step(cfg.ckpt_dir)
    if start is None:
        state = init_state()
        start = 0
    else:
        state, _ = restore(cfg.ckpt_dir, init_state())
    stats = StepStats()

    step = start
    while step < cfg.max_steps:
        if (cfg.fail_at_step is not None and step == cfg.fail_at_step
                and not _failed_once.get("done")):
            _failed_once["done"] = True
            raise FailureInjected(f"injected failure at step {step}")
        t0 = time.perf_counter()
        batch = batch_fn(step)
        state, metrics = train_step(state, batch)
        straggler = stats.update(time.perf_counter() - t0,
                                 cfg.straggler_factor)
        step += 1
        if on_metrics is not None and not straggler:
            # straggler steps shed the host sync (early-exit analogue)
            on_metrics(step, metrics)
        if step % cfg.ckpt_every == 0 or step == cfg.max_steps:
            save(cfg.ckpt_dir, step, state, keep=cfg.keep)
    return state


def run_with_restarts(cfg: DriverConfig, *, max_restarts: int = 3,
                      **kw) -> Any:
    """Supervisor loop: restart from the latest checkpoint on failure."""
    failed = {}
    for attempt in range(max_restarts + 1):
        try:
            return run_training(cfg, _failed_once=failed, **kw)
        except FailureInjected:
            if attempt == max_restarts:
                raise
            continue
    raise RuntimeError("unreachable")
