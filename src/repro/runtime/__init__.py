from repro.runtime.compression import (CompressionState, compress_grads,
                                       dequantize, init_compression, quantize)
from repro.runtime.fault import (DriverConfig, FailureInjected, StepStats,
                                 run_training, run_with_restarts)

__all__ = ["CompressionState", "init_compression", "compress_grads",
           "quantize", "dequantize", "DriverConfig", "run_training",
           "run_with_restarts", "FailureInjected", "StepStats"]
