from repro.splitcompute.partitioner import (StagePlan, plan_stages,
                                            split_points)
from repro.splitcompute.planner import (PipelineCost, layer_profile,
                                        plan_and_refine, plan_cost,
                                        refine_plan)
from repro.splitcompute.serve_engine import ServeStats, SplitServeEngine

__all__ = ["StagePlan", "plan_stages", "split_points", "SplitServeEngine",
           "ServeStats", "PipelineCost", "plan_cost", "refine_plan",
           "plan_and_refine", "layer_profile"]
