"""Batched-request serve engine over a φ-partitioned model.

This is the end-to-end integration of the paper's protocol with real model
execution: a reduced LM is split at vertical split points into stages
(``plan_stages``), each stage is bound to a simulated heterogeneous
executor, and requests flow stage→stage exactly like partial inferences
flow UAV→UAV in the swarm.  The congestion-aware early exit (Eq. 14-16)
monitors each executor's queue and truncates inference at the model's exit
layers under load, trading accuracy (deeper logits) for latency — the LM
analogue of the paper's accuracy levels.

Everything is functional JAX underneath (stage_apply slices the stacked
layer tree), so the same engine drives the TPU mesh in production and the
CPU demo in examples/serve_swarm.py.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.early_exit import (CongestionState, congestion_update,
                                   exit_label)
from repro.models.common import slice_layers
from repro.models.transformer import embed_in, head_out, run_layers
from repro.obs import hist as obs_hist
from repro.splitcompute.partitioner import StagePlan
from repro.trace import schema
from repro.trace.critical import SEGMENTS


class ServeStats:
    """Deterministic serving telemetry on the shared TaskRecord vocabulary
    (``repro.trace.schema``, DESIGN.md §10.1): one record row per served
    sample — request id as ``seq``, entry stage as ``src``, completing
    stage as ``dst``, stages traversed as ``hops`` — so sim and serve
    aggregate/export through the same ``repro.trace`` pipeline.  All
    timestamps come from the caller's clock domain (``submit``/``step``
    ``t_now``), never from wall time; the historical counter surface
    (``completed`` / ``latency_sum`` / ``exit_counts`` / ``avg_latency``)
    is derived from the records.

    Streaming SLO surface (DESIGN.md §14.1): every ``record()`` also fills
    a log-bucketed latency histogram plus per-segment histograms
    (compute / queue-wait / airtime / stall), so p50/p99/p999 stay O(1)
    in memory however many requests flow through — the record rows can be
    bounded (``max_records``) without losing the quantile story.
    """

    def __init__(self, max_records: Optional[int] = None,
                 latency_hist: Optional[obs_hist.HistSpec] = None):
        # counters are maintained incrementally (O(1) access however long
        # the serve loop runs); the rows are the exportable telemetry and
        # can be bounded like the sim side's trace_capacity — beyond
        # ``max_records`` the counters keep counting, rows overflow
        self._rows: List[np.ndarray] = []
        self.max_records = max_records
        self.record_overflow = 0
        self._completed = 0
        self._latency_sum = 0.0
        self._exit_counts: Dict[int, int] = {0: 0, 1: 0, 2: 0}
        # flight-recorder stream (sim's trace_state analogue): one system
        # gauge row + one per-stage gauge row per sampled epoch, on the
        # shared SYS_GAUGES / STATE_GAUGES vocabulary
        self._state_rows: List[np.ndarray] = []
        self._stage_rows: List[np.ndarray] = []
        self._dropped = 0
        self._generated = 0
        self._generated_rows = 0
        # streaming histograms: end-to-end latency + the critical-path
        # segment decomposition (same spec everywhere ⇒ mergeable)
        self.hist_spec = latency_hist or obs_hist.DEFAULT_LATENCY_HIST
        self.latency_counts = obs_hist.empty_np(self.hist_spec)
        self.segment_counts: Dict[str, np.ndarray] = {
            s: obs_hist.empty_np(self.hist_spec) for s in SEGMENTS}
        # exact per-segment second totals: latency_sum == Σ segment_sums
        # whenever every record carried service_s (the reconciliation
        # invariant slo_indices reports)
        self.segment_sums: Dict[str, float] = {s: 0.0 for s in SEGMENTS}
        # deterministic time-to-first-exit anchors (caller clock domain)
        self.first_submit_t: Optional[float] = None
        self.first_exit_t: Optional[float] = None

    def record_state(self, *, t, queue_depths, in_flight=None,
                     completed=None, dropped=None, generated=None,
                     load=None) -> None:
        """Append one flight-recorder sample (sim's ``write_state``
        analogue) on the shared gauge vocabulary.

        ``queue_depths`` is the per-stage depth snapshot; ``load``
        optionally carries the per-stage congestion metric D (Eqs. 14-15)
        into the ``phi`` gauge lane — the serve side's diffusive-metric
        stand-in, so the same decode/aggregate/export pipeline renders
        both.  Counters default from the incremental record() totals.
        """
        q = np.asarray(queue_depths, np.float64)
        completed = self._completed if completed is None else completed
        dropped = self._dropped if dropped is None else dropped
        generated = self._generated if generated is None else generated
        jain = (q.sum() ** 2) / (len(q) * (q * q).sum() + 1e-12)
        self._state_rows.append(schema.pack_state_sys_np(
            t, q.sum() if in_flight is None else in_flight,
            0.0, completed, dropped, generated,
            q.mean() if len(q) else 0.0, q.max() if len(q) else 0.0, jain,
            *( (float(np.mean(load)), float(np.min(load)),
                float(np.max(load))) if load is not None else (0, 0, 0) )))
        phi = (np.asarray(load, np.float64) if load is not None
               else np.zeros_like(q))
        rows = np.zeros((len(q), schema.NUM_STATE_GAUGES), np.float64)
        rows[:, schema.ST_PHI] = phi
        rows[:, schema.ST_QUEUE_DEPTH] = q
        rows[:, schema.ST_ALIVE] = 1.0
        self._stage_rows.append(rows)

    @property
    def state_records(self) -> np.ndarray:
        """``[samples, NUM_SYS_GAUGES]`` system gauge rows
        (``trace.decode_state(sys=...)``-able)."""
        if not self._state_rows:
            return np.zeros((0, schema.NUM_SYS_GAUGES), np.float64)
        return np.stack(self._state_rows)

    @property
    def stage_state(self) -> np.ndarray:
        """``[samples, n_stages, NUM_STATE_GAUGES]`` per-stage gauge rows
        (``trace.decode_state(state=...)``-able)."""
        if not self._stage_rows:
            return np.zeros((0, 0, schema.NUM_STATE_GAUGES), np.float64)
        return np.stack(self._stage_rows)

    def note_submit(self, t: float, rows: int = 1) -> None:
        """Stamp an admission: first-submit anchor + row-level counter
        (``_generated`` keeps its historical submit-count semantics)."""
        if self.first_submit_t is None:
            self.first_submit_t = float(t)
        self._generated_rows += rows

    def record(self, *, seq, src, dst, created_t, completed_t, exit_label,
               layers, hops, count=1, service_s=None) -> None:
        """Append ``count`` identical sample records (one per batch row).

        ``service_s`` is the caller's estimate of pure execution time for
        the request (stages run × epoch dt on the serve path); clamped to
        the recorded latency it becomes the compute segment, the rest
        queue-wait — the serve side of the DESIGN.md §14.4 decomposition
        (no radio ⇒ airtime/stall stay zero).
        """
        self._completed += count
        lat = float(completed_t - created_t)
        self._latency_sum += lat * count
        if self.first_exit_t is None:
            self.first_exit_t = float(completed_t)
        obs_hist.fill_np(self.hist_spec, self.latency_counts, [lat],
                         [count])
        if service_s is not None:
            comp = min(float(service_s), max(lat, 0.0))
            wait = max(lat, 0.0) - comp
            obs_hist.fill_np(self.hist_spec,
                             self.segment_counts["compute_s"],
                             [comp], [count])
            obs_hist.fill_np(self.hist_spec,
                             self.segment_counts["queue_wait_s"],
                             [wait], [count])
            self.segment_sums["compute_s"] += comp * count
            self.segment_sums["queue_wait_s"] += wait * count
        lbl = int(exit_label)
        self._exit_counts[lbl] = self._exit_counts.get(lbl, 0) + count
        kept = count
        if self.max_records is not None:
            kept = max(0, min(count, self.max_records - len(self._rows)))
            self.record_overflow += count - kept
        if kept:
            row = schema.pack_np(seq, src, dst, created_t, completed_t,
                                 exit_label, layers, hops)
            self._rows.extend([row] * kept)

    def drop(self, *, seq, src, t_now, count=1) -> None:
        """Record an admission-control drop: ``count`` DROPPED rows at
        ``t_now`` (created == completed — the request never entered), on
        the same vocabulary the sim uses for its drops."""
        self._dropped += count
        kept = count
        if self.max_records is not None:
            kept = max(0, min(count, self.max_records - len(self._rows)))
            self.record_overflow += count - kept
        if kept:
            row = schema.pack_np(seq, src, src, t_now, t_now,
                                 schema.DROPPED, 0, 0)
            self._rows.extend([row] * kept)

    @property
    def records(self) -> np.ndarray:
        """``[completed, NUM_FIELDS]`` TaskRecord rows (trace.decode-able)."""
        if not self._rows:
            return np.zeros((0, schema.NUM_FIELDS), np.float64)
        return np.stack(self._rows)

    @property
    def completed(self) -> int:
        return self._completed

    @property
    def latency_sum(self) -> float:
        return self._latency_sum

    @property
    def exit_counts(self) -> Dict[int, int]:
        return dict(self._exit_counts)

    @property
    def dropped(self) -> int:
        return self._dropped

    @property
    def generated(self) -> int:
        return self._generated

    @property
    def generated_rows(self) -> int:
        return self._generated_rows

    @property
    def avg_latency(self) -> float:
        """Mean completion latency; ``nan`` (not a fake 0) before the
        first completion — well-defined and unmistakable downstream."""
        if self._completed == 0:
            return float("nan")
        return self._latency_sum / self._completed

    @property
    def time_to_first_exit(self) -> float:
        """First completion time minus first submit time, both in the
        caller's clock domain — deterministic by construction; ``nan``
        until both anchors exist."""
        if self.first_submit_t is None or self.first_exit_t is None:
            return float("nan")
        return self.first_exit_t - self.first_submit_t

    def latency_quantiles(self, qs=obs_hist.SLO_QS) -> Dict:
        """Streaming p50/p99/p999 summary of the latency histogram."""
        return obs_hist.summary(self.hist_spec, self.latency_counts, qs)

    def __repr__(self):
        return (f"ServeStats(completed={self.completed}, "
                f"avg_latency={self.avg_latency:.4f}, "
                f"exit_counts={self.exit_counts})")


class SplitServeEngine:
    """Decoder-only families (dense/moe/vlm): stages = layer ranges."""

    def __init__(self, cfg: ModelConfig, params, plan: StagePlan, *,
                 tau_med=1.0, tau_high=3.0, alpha=0.3, max_results=64,
                 max_queue: Optional[int] = None, state_every: int = 1,
                 max_records: Optional[int] = None,
                 latency_hist: Optional[obs_hist.HistSpec] = None):
        assert cfg.family in ("dense", "moe", "vlm")
        self.cfg = cfg
        self.params = params
        self.plan = plan
        self.n_stages = len(plan.executors)
        # per-stage sliced params (static split-point extraction)
        self.stage_params = [
            slice_layers(params["layers"], plan.boundaries[i],
                         plan.boundaries[i + 1])
            for i in range(self.n_stages)]
        # early-exit bookkeeping per executor
        self.cong = CongestionState(jnp.zeros((self.n_stages,)),
                                    jnp.zeros((self.n_stages,)))
        self.tau = (tau_med, tau_high)
        self.alpha = alpha
        self.queues = [deque() for _ in range(self.n_stages)]
        # admission control: a bounded entry queue (sim's queue_slots
        # analogue) — submits beyond max_queue are dropped-and-recorded,
        # so an overloaded open-loop experiment reports drop rate instead
        # of growing without bound.  None (default) keeps the historical
        # unbounded behavior.
        self.max_queue = max_queue
        # flight-recorder stride (sim's trace_state_every analogue):
        # sample the state stream every state_every-th epoch
        self.state_every = max(int(state_every), 1)
        self._epoch = 0
        self.stats = ServeStats(max_records=max_records,
                                latency_hist=latency_hist)
        # completion stash, request_id -> logits, for callers that poll
        # after the fact; the primary hand-off is step()'s return value,
        # so the stash is small by default (each entry pins a full
        # [batch, seq, vocab] buffer) — oldest evicted, 0 disables
        self.results: Dict[int, jax.Array] = {}
        self.max_results = max_results
        self.clock = 0.0          # internal epoch clock (t_now fallback)
        self._next_id = 0
        self._stage_fns = [self._make_stage_fn(i)
                           for i in range(self.n_stages)]
        self._head_fn = jax.jit(
            lambda h: head_out(self.params, self.cfg, h))

    def _make_stage_fn(self, i):
        sp = self.stage_params[i]

        @jax.jit
        def fn(h, positions):
            h2, _, _ = run_layers(sp, self.cfg, h, positions, mode="train")
            return h2

        return fn

    # -- exit boundaries in *stage* space -----------------------------------
    def _exit_stage(self, label: int) -> int:
        """How many stages to run for a congestion label (Eq. 16 analogue):
        full / exit at L//2 / exit at L//4."""
        L = self.cfg.num_layers
        exit_layers = {0: L, 1: max(self.cfg.exit_layers_[1], 1),
                       2: max(self.cfg.exit_layers_[0], 1)}[label]
        # run stages until the boundary covers exit_layers
        for s in range(self.n_stages):
            if self.plan.boundaries[s + 1] >= exit_layers:
                return s + 1
        return self.n_stages

    def submit(self, batch: Dict, t_now: Optional[float] = None) -> int:
        """Enqueue one request batch; returns its request id.

        ``t_now`` stamps arrival in the *caller's* clock domain (simulated
        or wall) — latency is measured against the same domain's ``t_now``
        passed to ``step``.  Omitted, it defaults to the engine's internal
        epoch clock, keeping ``ServeStats`` fully deterministic.

        Returns ``None`` when admission control (``max_queue``) rejects
        the batch; the rejection is recorded as a DROPPED row.
        """
        h, positions = embed_in(self.params, self.cfg, batch)
        return self._enqueue(h, positions, t_now, rows=int(h.shape[0]))

    def _enqueue(self, h, positions, t_now: Optional[float],
                 rows: int = 1) -> Optional[int]:
        """Admission + queue push shared by submit() and subclasses that
        skip the embedding (synthetic load)."""
        t0 = self.clock if t_now is None else t_now
        rid = self._next_id
        self._next_id += 1
        self.stats._generated += 1
        self.stats.note_submit(t0, rows)
        if self.max_queue is not None and \
                len(self.queues[0]) >= self.max_queue:
            self.stats.drop(seq=rid, src=0, t_now=t0, count=rows)
            return None
        self.queues[0].append({
            "id": rid, "h": h, "positions": positions,
            "t0": t0, "stage": 0})
        return rid

    def step(self, dt: float = 0.05, t_now: Optional[float] = None
             ) -> List[Tuple[int, jax.Array]]:
        """One scheduling epoch: per-executor congestion update (Eqs. 14-15),
        exit decision (Eq. 16), then each executor advances one request —
        and only requests that were queued when the epoch began.

        Queue lengths are snapshotted up front: a request forwarded to
        stage ``s+1`` this epoch is *not* popped again by the same loop
        (it used to be, when it landed at the head of an empty queue — one
        request could traverse the whole pipeline in a single epoch, so
        queues never built depth past stage 0 and the early exit could
        never fire downstream).

        ``t_now`` is the epoch's completion timestamp in the caller's clock
        domain (same domain as ``submit``); omitted, the internal epoch
        clock advances by ``dt``.  Returns the requests completed this
        epoch as ``(request_id, logits)`` pairs, also stashed in
        ``self.results``.
        """
        if t_now is None:
            self.clock += dt
            t_now = self.clock
        else:
            self.clock = t_now
        self._epoch += 1
        labels = self._congestion_labels([len(q) for q in self.queues], dt)

        # epoch snapshot: each executor serves at most one request that was
        # already queued at epoch start
        depth = [len(q) for q in self.queues]
        completed: List[Tuple[int, jax.Array]] = []
        for s in range(self.n_stages):
            if depth[s] == 0:
                continue
            req = self.queues[s].popleft()
            h = self._stage_fns[s](req["h"], req["positions"])
            nxt = s + 1
            lbl = int(labels[s])
            stop_at = self._exit_stage(lbl)
            if nxt >= stop_at or nxt >= self.n_stages:
                logits = self._head_fn(h)
                size = h.shape[0]
                self.stats.record(
                    seq=req["id"], src=0, dst=s, created_t=req["t0"],
                    completed_t=t_now, exit_label=lbl,
                    layers=int(self.plan.boundaries[s + 1]), hops=s,
                    count=size, service_s=(s + 1) * dt)
                if self.max_results:
                    self.results[req["id"]] = logits
                    while len(self.results) > self.max_results:
                        self.results.pop(next(iter(self.results)))
                completed.append((req["id"], logits))
            else:
                req["h"] = h
                req["stage"] = nxt
                self.queues[nxt].append(req)
        # flight-recorder sample: post-step depths + the congestion metric
        # D in the phi lane (the serve side's diffusive-metric stand-in)
        if self._epoch % self.state_every == 0:
            self.stats.record_state(
                t=t_now, queue_depths=[len(q) for q in self.queues],
                load=np.asarray(self.cong.D))
        return completed

    def _congestion_labels(self, qlens: List[int], dt: float) -> np.ndarray:
        """Per-executor congestion update (Eqs. 14-15) + exit decision
        (Eq. 16) for one epoch; subclasses may override with an equivalent
        host-side mirror (the synthetic load engine does)."""
        qlen = jnp.asarray([float(x) for x in qlens])
        self.cong = congestion_update(self.cong, qlen, dt, self.alpha)
        return np.asarray(exit_label(self.cong.D, *self.tau))

    def drain(self, max_steps=1000, dt: float = 0.05):
        for _ in range(max_steps):
            if not any(self.queues):
                break
            self.step(dt)
        return self.stats
