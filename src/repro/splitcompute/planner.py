"""Stage-plan cost model + local-search refinement.

The φ-proportional partitioner (partitioner.py) is the paper-faithful
placement rule — fully distributed, one-hop information.  This module adds
what a *deployed* serving system layers on top: an explicit cost model
(per-stage compute time on the assigned executor + boundary-activation
transfer time over the link, exactly the d_tx term of Eq. 10 made concrete)
and a boundary local-search that refines the φ seed when global information
is available (e.g. within one TPU pod, where "global" is cheap).

Pipeline metrics for a plan:
  stage_time[i]  = layers_flops[i] / F[exec_i] + act_bytes[b_i] / bw[i-1, i]
  latency        = Σ stage_time            (one request walks every stage)
  throughput     = 1 / max stage_time      (steady-state, one in flight per
                                            stage — the paper's "one
                                            transfer at a time" constraint)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.splitcompute.partitioner import StagePlan, plan_stages, split_points


@dataclasses.dataclass(frozen=True)
class PipelineCost:
    stage_times_s: Tuple[float, ...]
    latency_s: float
    throughput_rps: float


def layer_profile(cfg: ModelConfig, seq_len: int, batch: int
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """(per-layer GFLOPs, boundary activation bytes) for a request batch.

    Analytic: dense layer ≈ 6·params_layer FLOPs/token at train, 2· at
    serve; boundary tensor = [batch, seq, d_model] in compute dtype
    (+ recurrent state for hybrid/ssm — the paper's 'state ships with the
    activation' cost).
    """
    toks = seq_len * batch
    per_layer_params = (cfg.active_param_count()
                        - 2 * cfg.vocab_size * cfg.d_model
                        * (1 if not cfg.tie_embeddings else 0.5)
                        ) / cfg.num_layers
    gflops = np.full(cfg.num_layers, 2.0 * per_layer_params * toks / 1e9)
    act = batch * seq_len * cfg.d_model * 2.0          # bf16 residual stream
    extra = 0.0
    if cfg.family == "ssm":
        extra = batch * cfg.ssm.expand * cfg.d_model * cfg.ssm.d_state * 4.0
    elif cfg.family == "hybrid":
        w = cfg.hybrid.lru_width or cfg.d_model
        extra = batch * (w * 4.0 + cfg.hybrid.window * cfg.num_kv_heads
                         * cfg.head_dim_ * 2.0 * 2)
    act_bytes = np.full(cfg.num_layers + 1, act + extra)
    return gflops, act_bytes


def plan_cost(plan: StagePlan, gflops: np.ndarray, act_bytes: np.ndarray,
              F: Sequence[float], bw_bps: np.ndarray) -> PipelineCost:
    """Evaluate a plan against executor capabilities + link bandwidths."""
    times = []
    b = plan.boundaries
    for i, ex in enumerate(plan.executors):
        comp = float(gflops[b[i]:b[i + 1]].sum()) / F[ex]
        tx = 0.0
        if i > 0:
            prev = plan.executors[i - 1]
            tx = float(act_bytes[b[i]]) * 8.0 / float(bw_bps[prev, ex])
        times.append(comp + tx)
    lat = float(sum(times))
    thr = 1.0 / max(times) if times else 0.0
    return PipelineCost(tuple(times), lat, thr)


def refine_plan(cfg: ModelConfig, plan: StagePlan, gflops, act_bytes,
                F: Sequence[float], bw_bps, *, iters: int = 64,
                objective: str = "throughput") -> Tuple[StagePlan,
                                                        PipelineCost]:
    """Greedy boundary local search from the φ seed: move one boundary one
    legal split point at a time while the objective improves."""
    legal = sorted(set(split_points(cfg)))

    def score(p):
        c = plan_cost(p, gflops, act_bytes, F, bw_bps)
        return (c.throughput_rps if objective == "throughput"
                else -c.latency_s), c

    best, best_cost = plan, score(plan)[1]
    best_s = score(plan)[0]
    for _ in range(iters):
        improved = False
        bl = list(best.boundaries)
        for j in range(1, len(bl) - 1):
            for cand in legal:
                if not (bl[j - 1] < cand < bl[j + 1]) or cand == bl[j]:
                    continue
                nb = tuple(bl[:j] + [cand] + bl[j + 1:])
                p2 = StagePlan(nb, best.executors, best.phi)
                s2, c2 = score(p2)
                if s2 > best_s + 1e-12:
                    best, best_s, best_cost = p2, s2, c2
                    bl = list(nb)
                    improved = True
        if not improved:
            break
    return best, best_cost


def plan_and_refine(cfg: ModelConfig, F: Sequence[float],
                    bw_bps: Optional[np.ndarray] = None, *,
                    seq_len: int = 128, batch: int = 4,
                    objective: str = "throughput"):
    """End to end: φ seed (paper rule) → cost model → refined plan.

    Returns (seed_plan, seed_cost, refined_plan, refined_cost).
    """
    n = len(F)
    if bw_bps is None:
        bw_bps = np.full((n, n), 1e9)        # 1 Gb/s default links
    gflops, act_bytes = layer_profile(cfg, seq_len, batch)
    d_tx = (act_bytes.mean() * 8.0 / bw_bps) / max(gflops.mean(), 1e-9)
    seed = plan_stages(cfg, F, d_tx)
    seed_cost = plan_cost(seed, gflops, act_bytes, F, bw_bps)
    refined, refined_cost = refine_plan(cfg, seed, gflops, act_bytes, F,
                                        bw_bps, objective=objective)
    return seed, seed_cost, refined, refined_cost
