"""φ-driven model partitioning — the paper's technique as a stage placer.

The paper's "vertical split points" are layer boundaries where exactly one
activation tensor crosses (Fig. 1 lower panel).  For the assigned LM
architectures those boundaries are the residual stream between layers
(MoE/attention internals are multi-tensor and therefore unsplittable,
exactly like the paper's multi-branch blocks).

``plan_stages`` assigns contiguous layer ranges to heterogeneous executors
in proportion to their *aggregated computation capability* φ (Eq. 10) —
i.e. the same diffusive metric that routes tasks in the swarm also places
pipeline stages on a heterogeneous mesh, with link delay folded in via the
d_tx term.  This is the TPU-native reading of "offload the remaining
layers to the best neighbor" (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.diffusive import phi_fixpoint


@dataclasses.dataclass(frozen=True)
class StagePlan:
    boundaries: Tuple[int, ...]   # len = n_stages+1; stage i = [b[i], b[i+1])
    executors: Tuple[int, ...]    # executor id per stage
    phi: Tuple[float, ...]        # aggregated capability per executor


def split_points(cfg: ModelConfig) -> List[int]:
    """Legal vertical split boundaries (layer indices 1..L-1).

    hybrid: superblock granularity (recurrent state + window cache travel
    with the activation, so we only cut between superblocks); others: every
    layer boundary.
    """
    if cfg.family == "hybrid":
        n = len(cfg.hybrid.pattern)
        return list(range(n, cfg.num_layers - cfg.num_layers % n, n))
    return list(range(1, cfg.num_layers))


def plan_stages(cfg: ModelConfig, F: Sequence[float],
                link_delay_s_per_gflop: Sequence[Sequence[float]] = None,
                n_stages: int = None) -> StagePlan:
    """Partition cfg.num_layers layers over executors proportionally to φ.

    F: raw capability per executor (GFLOP/s-like units).  link_delay:
    [n, n] matrix (s/GFLOP) for the φ diffusion; default = uniform small.
    """
    n = len(F)
    n_stages = n_stages or n
    F = jnp.asarray(F, jnp.float32)
    if link_delay_s_per_gflop is None:
        d_tx = jnp.full((n, n), 1e-4, jnp.float32)
    else:
        d_tx = jnp.asarray(link_delay_s_per_gflop, jnp.float32)
    adj = ~jnp.eye(n, dtype=bool)   # fully-connected executor graph
    phi, _ = phi_fixpoint(F, adj, d_tx, iters=16)
    phi_np = np.asarray(phi)

    # proportional allocation of layers to the n_stages strongest executors
    order = np.argsort(-phi_np)[:n_stages]
    weights = phi_np[order] / phi_np[order].sum()
    L = cfg.num_layers
    legal = set(split_points(cfg)) | {0, L}
    raw = np.round(np.cumsum(weights) * L).astype(int)
    raw[-1] = L
    bounds = [0]
    for b in raw:
        # snap to the nearest legal split point >= previous bound
        cand = min((p for p in legal if p >= bounds[-1]),
                   key=lambda p, b=b: abs(p - int(b)), default=L)
        cand = min((p for p in legal), key=lambda p, b=b: (abs(p - int(b))
                                                           if p > bounds[-1]
                                                           else 10**9))
        bounds.append(max(cand, bounds[-1]))
    bounds[-1] = L
    # dedupe while preserving monotonicity
    dedup = [0]
    for b in bounds[1:]:
        if b > dedup[-1]:
            dedup.append(b)
    if dedup[-1] != L:
        dedup.append(L)
    execs = tuple(int(order[i]) for i in range(len(dedup) - 1))
    return StagePlan(tuple(dedup), execs, tuple(float(x) for x in phi_np))
