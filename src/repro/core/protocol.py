"""Algorithm 1 — the per-epoch decision logic, as one pure JAX function.

``decision_epoch`` is the protocol core used by (a) the swarm simulator's
Distributed strategy and (b) the split-compute stage placer.  It consumes
only one-hop-visible state (adjacency, neighbor φ/U) — the vectorized form
computes all nodes' decisions at once but never reads beyond M_i(t).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.decision import TransferDecision, transfer_decision
from repro.core.diffusive import phi_update
from repro.core.early_exit import (CongestionState, congestion_update,
                                   exit_boundary_layers, exit_label)


class ProtocolState(NamedTuple):
    phi: jax.Array               # [N] aggregated computation capability
    congestion: CongestionState  # (prev_T, D) per node


class EpochDecision(NamedTuple):
    decision: TransferDecision   # utilization / target / transfer per node
    exit_layers: jax.Array       # [N] layers to execute this epoch (Eq. 16)
    exit_lbl: jax.Array          # [N] 0=full 1=medium 2=high congestion
    state: ProtocolState


def init_protocol(F: jax.Array) -> ProtocolState:
    n = F.shape[0]
    return ProtocolState(
        phi=F,
        congestion=CongestionState(jnp.zeros((n,), jnp.float32),
                                   jnp.zeros((n,), jnp.float32)))


def decision_epoch(state: ProtocolState, *, F, adj, d_tx, queued_gflops,
                   gamma: float, dt: float, alpha: float,
                   tau_med: float, tau_high: float,
                   exit_points: Tuple[int, int, int],
                   finalize_layers: int,
                   early_exit_enabled: bool = True) -> EpochDecision:
    """One decision epoch at every node (Alg. 1 lines 2-11), vectorized.

    F [N] GFLOP/s, adj [N,N] bool, d_tx [N,N] s/GFLOP, queued_gflops [N].
    """
    # line 2: update aggregated capability (Eq. 10)
    phi = phi_update(state.phi, F, adj, d_tx)
    # lines 3-5: utilization, least-utilized neighbor, offload predicate
    dec = transfer_decision(queued_gflops, phi, adj, gamma)
    # lines 10-11: congestion indicator + exit label
    cong = congestion_update(state.congestion, queued_gflops, dt, alpha)
    if early_exit_enabled:
        lbl = exit_label(cong.D, tau_med, tau_high)
    else:
        lbl = jnp.zeros_like(cong.D, dtype=jnp.int32)
    layers = exit_boundary_layers(lbl, exit_points, finalize_layers)
    return EpochDecision(dec, layers, lbl, ProtocolState(phi, cong))
