"""Aggregated computation capability — the paper's diffusive metric (Eq. 10).

    1/φ_i(t+1) = 1/(|M_i(t)|+1) · ( 1/F_i + max_{k∈M_i(t)} ( d^tx_{i,k}(t) + 1/φ_k(t) ) )

φ is an effective processing rate (GFLOP/s) under even one-hop load
sharing; the max term is the slowest collaborator.  Fully distributed in the
protocol sense (one-hop state only); vectorized here as a dense masked
max-plus row reduction over the [N, N] adjacency (DESIGN.md §3) — the Pallas
``diffusive_phi`` kernel implements the same contraction with VMEM tiling.

All functions are pure jnp: they vmap over Monte-Carlo runs and scan over
decision epochs inside the swarm simulator.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG = -1e30


def neighbor_mask(snr_db: jax.Array, snr_min_db: float) -> jax.Array:
    """Eq. 9: M_i(t) = { j != i : SNR_ij >= SNR_min }.  snr_db [N, N]."""
    n = snr_db.shape[-1]
    eye = jnp.eye(n, dtype=bool)
    return (snr_db >= snr_min_db) & ~eye


def phi_update(phi: jax.Array, F: jax.Array, adj: jax.Array,
               d_tx: jax.Array) -> jax.Array:
    """One synchronous iteration of Eq. 10.

    phi [N] current aggregated capability (GFLOP/s), F [N] local capability,
    adj [N, N] boolean one-hop adjacency, d_tx [N, N] per-unit-workload
    transfer delay (s/GFLOP).  Returns phi' [N].

    Isolated nodes (|M_i| = 0) fall back to φ_i = F_i.
    """
    inv_phi = 1.0 / phi                                     # [N] s/GFLOP
    # worst collaborator: max_k ( d_tx[i,k] + 1/phi_k ) over neighbors
    cand = jnp.where(adj, d_tx + inv_phi[None, :], NEG)     # [N, N]
    worst = jnp.max(cand, axis=1)                           # [N]
    deg = jnp.sum(adj, axis=1)                              # [N]
    inv_new = (1.0 / F + worst) / (deg + 1.0)
    phi_new = 1.0 / inv_new
    return jnp.where(deg > 0, phi_new, F)


def phi_update_op(phi: jax.Array, F: jax.Array, adj: jax.Array,
                  d_tx: jax.Array) -> jax.Array:
    """Backend-dispatched ``phi_update`` (the simulator hot path).

    Routes the [N, N] masked max-plus reduction through
    ``kernels.ops.diffusive_phi`` — the tiled Pallas kernel on TPU (or in
    interpret mode under ``REPRO_FORCE_INTERPRET=1``), the jnp reference
    elsewhere.  Accepts [N] or batched [R, N] operands; the isolated-node
    fallback (φ_i = F_i exactly) is applied here so results match
    ``phi_update`` to float32 rounding.
    """
    from repro.kernels import ops  # deferred: keep core import-light

    inv_phi = 1.0 / phi
    dtx_m = jnp.where(adj, d_tx, NEG)
    if inv_phi.ndim == 1:
        inv_new = ops.diffusive_phi(inv_phi[None], F[None], dtx_m[None])[0]
    else:
        inv_new = ops.diffusive_phi(inv_phi, F, dtx_m)
    deg = jnp.sum(adj, axis=-1)
    return jnp.where(deg > 0, 1.0 / inv_new, F)


def phi_update_sparse(phi: jax.Array, F: jax.Array, adj_e: jax.Array,
                      nbr: jax.Array, d_tx_e: jax.Array) -> jax.Array:
    """Eq. 10 over fixed-width neighbor lists (DESIGN.md §11).

    phi [N], F [N], adj_e [N, K] validity/adjacency of the gathered edges,
    nbr [N, K] neighbor ids, d_tx_e [N, K] per-unit-workload delay on the
    gathered edges.  Bit-identical to ``phi_update`` whenever the lists
    cover every dense neighbor (same candidates, same arithmetic; max is
    order-independent).
    """
    inv_phi = 1.0 / phi
    cand = jnp.where(adj_e, d_tx_e + inv_phi[nbr], NEG)     # [N, K]
    worst = jnp.max(cand, axis=-1)
    deg = jnp.sum(adj_e, axis=-1)
    inv_new = (1.0 / F + worst) / (deg + 1.0)
    return jnp.where(deg > 0, 1.0 / inv_new, F)


def phi_update_op_sparse(phi: jax.Array, F: jax.Array, adj_e: jax.Array,
                         nbr: jax.Array, d_tx_e: jax.Array) -> jax.Array:
    """Backend-dispatched ``phi_update_sparse`` (the O(N·k) hot path).

    Routes the gather-max reduction through
    ``kernels.ops.diffusive_phi_sparse``; accepts [N]/[N,K] or batched
    [R,N]/[R,N,K] operands.  The isolated-node fallback is applied here,
    mirroring ``phi_update_op``.
    """
    from repro.kernels import ops  # deferred: keep core import-light

    inv_phi = 1.0 / phi
    dtx_m = jnp.where(adj_e, d_tx_e, NEG)
    if inv_phi.ndim == 1:
        inv_new = ops.diffusive_phi_sparse(inv_phi[None], F[None],
                                           dtx_m[None], nbr[None])[0]
    else:
        inv_new = ops.diffusive_phi_sparse(inv_phi, F, dtx_m, nbr)
    deg = jnp.sum(adj_e, axis=-1)
    return jnp.where(deg > 0, 1.0 / inv_new, F)


def phi_fixpoint(F: jax.Array, adj: jax.Array, d_tx: jax.Array,
                 iters: int = 16, phi0: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Iterate Eq. 10 to (near) fixpoint; returns (phi, residual_history).

    The paper argues geometric convergence (the 1/(|M|+1) factor contracts
    residuals >= 2x per round for |M| >= 1); `residual_history` lets tests
    verify that claim.
    """
    phi = F if phi0 is None else phi0

    def body(phi, _):
        nxt = phi_update(phi, F, adj, d_tx)
        res = jnp.max(jnp.abs(1.0 / nxt - 1.0 / phi))
        return nxt, res

    phi, residuals = jax.lax.scan(body, phi, None, length=iters)
    return phi, residuals


def phi_bounds_ok(phi: jax.Array, F: jax.Array, adj: jax.Array) -> jax.Array:
    """Invariant from the paper's convergence argument: 0 < φ_i <= F_i +
    Σ_{k∈M_i} F_k (nonzero tx delay strictly reduces collaborative rate)."""
    upper = F + adj @ F
    return jnp.all((phi > 0) & (phi <= upper * (1 + 1e-5)))
