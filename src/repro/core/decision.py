"""Task-transfer decision (paper Eqs. 11-13).

    U_i(t)   = T_i(t) / φ_i(t)                         (utilization, Eq. 11)
    k*       = argmin_{k ∈ M_i(t)} U_k(t)              (Eq. 12)
    transfer ⇔ U_i - U_{k*} > γ                        (Eq. 13)

γ is the hysteresis threshold that prevents oscillatory offloading.
Vectorized over all nodes at once.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

BIG = 1e30


class TransferDecision(NamedTuple):
    utilization: jax.Array   # [N]  U_i
    target: jax.Array        # [N]  k* (argmin-utilization neighbor; -1 if none)
    transfer: jax.Array      # [N]  bool, Eq. 13 predicate


def utilization(queued_gflops: jax.Array, phi: jax.Array) -> jax.Array:
    """Eq. 11. queued_gflops T_i >= 0, phi > 0."""
    return queued_gflops / jnp.maximum(phi, 1e-9)


def transfer_decision(queued_gflops: jax.Array, phi: jax.Array,
                      adj: jax.Array, gamma: float) -> TransferDecision:
    """Eqs. 11-13 for every node simultaneously.

    queued_gflops [N], phi [N], adj [N, N] bool.  A node with no neighbors
    never transfers (target = -1).
    """
    U = utilization(queued_gflops, phi)                   # [N]
    cand = jnp.where(adj, U[None, :], BIG)                # [N, N]
    # index dtype pinned: argmin yields i64 under x64, and the strategy
    # switch requires every branch to return the same target dtype (J002)
    k_star = jnp.argmin(cand, axis=1).astype(jnp.int32)   # [N]
    U_star = jnp.min(cand, axis=1)                        # [N]
    has_nbr = jnp.any(adj, axis=1)
    do = has_nbr & ((U - U_star) > gamma)                 # Eq. 13
    return TransferDecision(U, jnp.where(has_nbr, k_star, -1), do)


def transfer_decision_sparse(queued_gflops: jax.Array, phi: jax.Array,
                             adj_e: jax.Array, nbr: jax.Array,
                             gamma: float) -> TransferDecision:
    """Eqs. 11-13 over fixed-width neighbor lists (DESIGN.md §11).

    adj_e [N, K] bool, nbr [N, K] int32.  The argmin runs over the K axis
    and maps back through the list; because the lists are canonically
    sorted ascending by node id, utilization ties resolve to the lowest
    node id — the same winner as the dense argmin.
    """
    U = utilization(queued_gflops, phi)                   # [N]
    rows = jnp.arange(U.shape[0])
    cand = jnp.where(adj_e, U[nbr], BIG)                  # [N, K]
    slot = jnp.argmin(cand, axis=1)                       # [N]
    k_star = nbr[rows, slot]
    U_star = jnp.min(cand, axis=1)
    has_nbr = jnp.any(adj_e, axis=1)
    do = has_nbr & ((U - U_star) > gamma)                 # Eq. 13
    return TransferDecision(U, jnp.where(has_nbr, k_star, -1), do)
