"""The paper's primary contribution: diffusive aggregated-computation-
capability metric (Eq. 10), utilization-threshold task transfer (Eqs. 11-13)
and congestion-aware early exit (Eqs. 14-16), composed in ``decision_epoch``
(Alg. 1)."""
from repro.core.decision import (TransferDecision, transfer_decision,
                                 utilization)
from repro.core.diffusive import (neighbor_mask, phi_bounds_ok, phi_fixpoint,
                                  phi_update)
from repro.core.early_exit import (CongestionState, congestion_update,
                                   exit_accuracy, exit_boundary_layers,
                                   exit_label, init_congestion)
from repro.core.protocol import (EpochDecision, ProtocolState, decision_epoch,
                                 init_protocol)

__all__ = [
    "phi_update", "phi_fixpoint", "phi_bounds_ok", "neighbor_mask",
    "utilization", "transfer_decision", "TransferDecision",
    "CongestionState", "init_congestion", "congestion_update", "exit_label",
    "exit_boundary_layers", "exit_accuracy",
    "ProtocolState", "EpochDecision", "init_protocol", "decision_epoch",
]
