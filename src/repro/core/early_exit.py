"""Congestion-aware early exit (paper Eqs. 14-16 + Fig. 2).

    ΔT_i = (T_i(t) - T_i(t-1)) / Δt                    (Eq. 14)
    D_i  ← D_i + α (ΔT_i - D_i)                        (Eq. 15, EMA)
    ξ_i  = L_full | L1 | L2  by τ_med / τ_high          (Eq. 16)

After a truncated exit (L1/L2) the task still runs `finalize_layers` extra
layers to produce its output (paper: +3).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CongestionState(NamedTuple):
    prev_T: jax.Array    # [N] previous outstanding GFLOPs
    D: jax.Array         # [N] smoothed derivative


def init_congestion(n: int) -> CongestionState:
    return CongestionState(jnp.zeros((n,), jnp.float32),
                           jnp.zeros((n,), jnp.float32))


def congestion_update(state: CongestionState, T: jax.Array, dt: float,
                      alpha: float) -> CongestionState:
    """Eqs. 14-15."""
    dT = (T - state.prev_T) / dt
    D = state.D + alpha * (dT - state.D)
    return CongestionState(T, D)


def exit_label(D: jax.Array, tau_med: float, tau_high: float) -> jax.Array:
    """Eq. 16 → {0: L_full, 1: L1 (medium), 2: L2 (high)} per node."""
    # i32 pin: python-int leaves widen to i64 under x64 and the label
    # feeds i32 scan-carry fields (swarmlint J002)
    return jnp.where(D > tau_high, 2,
                     jnp.where(D > tau_med, 1, 0)).astype(jnp.int32)


def exit_boundary_layers(label: jax.Array, exit_points: Tuple[int, int, int],
                         finalize_layers: int) -> jax.Array:
    """Total layers executed for a label: full L, or exit point + finalize.

    ``exit_points = (L1, L2, L_full)`` in the paper's Table 2 ordering,
    with truncation depth decreasing as congestion rises (defaults
    L1=15, L2=30, L_full=60, finalize=3):

        label 0 (no congestion)     → L_full      = 60 layers
        label 1 (medium congestion) → L2 + 3      = 33 layers
        label 2 (high congestion)   → L1 + 3      = 18 layers

    Each truncated exit is capped at ``L_full`` so finalize layers can
    never push past the full network.
    """
    L1, L2, L_full = exit_points
    med = min(L2 + finalize_layers, L_full)     # python ints: J002-safe
    high = min(L1 + finalize_layers, L_full)
    return jnp.where(label == 2, high,
                     jnp.where(label == 1, med, L_full)).astype(jnp.int32)


def exit_accuracy(label: jax.Array, accuracy_levels: Tuple[float, float, float]
                  ) -> jax.Array:
    """Table 2: [0.6, 0.9, 0.95] for [high-congestion, medium, full]."""
    acc_high, acc_med, acc_full = accuracy_levels
    # pinned f32: python-scalar leaves are weak f64 under x64 and would
    # promote the accuracy accumulator's scan carry (swarmlint J002)
    return jnp.where(label == 2, acc_high,
                     jnp.where(label == 1, acc_med,
                               acc_full)).astype(jnp.float32)
