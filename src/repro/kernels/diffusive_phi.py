"""Pallas TPU kernel for the diffusive φ update (paper Eq. 10).

The update is a masked max-plus row reduction over the [N, N] link-delay
matrix — at swarm scale (N in the thousands, R Monte-Carlo runs, every
200 ms epoch) this is the protocol's compute hot spot.  Tiling: the delay
matrix streams through VMEM in (BN, BN) tiles; the running row-max and the
degree count live in VMEM scratch across the column grid dimension (TPU
grids execute sequentially, so scratch persists over the reduction dim);
the final combine with 1/F and the degree normalization happens on the last
column tile.

Grid: (R, N/BN, N/BN) — Monte-Carlo batch × row tiles × column tiles.

``diffusive_phi_sparse`` is the O(N·K) neighbor-list variant (DESIGN.md
§11): the delay/index operands are fixed-width [N, K] gather lists, the
full 1/φ row rides in VMEM once per run (N fp32 — 256 KB even at
N = 65,536), and each (BN, BK) tile gathers its neighbors' 1/φ in-kernel.
The reduction runs over the K grid dimension with the same row-max +
degree scratch; invalid slots carry the NEG sentinel and lose the max
exactly like dense off-link columns, so sparse output is bit-identical to
dense whenever K covers the true degree.

Grid: (R, N/BN, K/BK) — Monte-Carlo batch × row tiles × neighbor tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30
BN = 128  # tile edge (VPU lane-aligned)


def _kernel(inv_phi_ref, f_ref, dtx_ref, out_ref, acc_ref, deg_ref):
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, NEG)
        deg_ref[...] = jnp.zeros_like(deg_ref)

    dtx = dtx_ref[0]                             # [BN, BN]; -inf off-link
    cand = dtx + inv_phi_ref[0][None, :]         # + 1/φ_k
    acc_ref[...] = jnp.maximum(acc_ref[...], jnp.max(cand, axis=1))
    deg_ref[...] = deg_ref[...] + jnp.sum(
        (dtx > NEG / 2).astype(jnp.float32), axis=1)

    @pl.when(j == nj - 1)
    def _finalize():
        f = f_ref[0]
        deg = deg_ref[...]
        inv_new = (1.0 / f + acc_ref[...]) / (deg + 1.0)
        out_ref[0] = jnp.where(deg > 0, inv_new, 1.0 / f)


@functools.partial(jax.jit, static_argnames=("interpret",))
def diffusive_phi(inv_phi, F, d_tx_masked, *, interpret=False):
    """inv_phi [R, N] (s/GFLOP), F [R, N], d_tx_masked [R, N, N] (-inf
    off-link) -> inv_phi' [R, N].  Pads N to a BN multiple internally;
    padding columns are off-link so they never win the max."""
    R, N = inv_phi.shape
    Np = (N + BN - 1) // BN * BN
    pad = Np - N
    if pad:
        inv_phi = jnp.pad(inv_phi, ((0, 0), (0, pad)), constant_values=1.0)
        F = jnp.pad(F, ((0, 0), (0, pad)), constant_values=1.0)
        d_tx_masked = jnp.pad(d_tx_masked, ((0, 0), (0, pad), (0, pad)),
                              constant_values=NEG)
    grid = (R, Np // BN, Np // BN)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BN), lambda r, i, j: (r, j)),       # 1/φ (cols)
            pl.BlockSpec((1, BN), lambda r, i, j: (r, i)),       # F   (rows)
            pl.BlockSpec((1, BN, BN), lambda r, i, j: (r, i, j)),
        ],
        out_specs=pl.BlockSpec((1, BN), lambda r, i, j: (r, i)),
        out_shape=jax.ShapeDtypeStruct((R, Np), inv_phi.dtype),
        scratch_shapes=[pltpu.VMEM((BN,), jnp.float32),
                        pltpu.VMEM((BN,), jnp.float32)],
        interpret=interpret,
    )(inv_phi, F, d_tx_masked)
    return out[:, :N]


BK = 128  # neighbor-tile width (lane-aligned); K pads up to a BK multiple


def _kernel_sparse(inv_phi_ref, f_ref, dtx_ref, nbr_ref, out_ref,
                   acc_ref, deg_ref):
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, NEG)
        deg_ref[...] = jnp.zeros_like(deg_ref)

    dtx = dtx_ref[0]                             # [BN, BK]; NEG on invalid
    row = inv_phi_ref[0]                         # [Np] — the full 1/φ row
    cand = dtx + row[nbr_ref[0]]                 # gather 1/φ_k per slot
    acc_ref[...] = jnp.maximum(acc_ref[...], jnp.max(cand, axis=1))
    deg_ref[...] = deg_ref[...] + jnp.sum(
        (dtx > NEG / 2).astype(jnp.float32), axis=1)

    @pl.when(j == nj - 1)
    def _finalize():
        f = f_ref[0]
        deg = deg_ref[...]
        inv_new = (1.0 / f + acc_ref[...]) / (deg + 1.0)
        out_ref[0] = jnp.where(deg > 0, inv_new, 1.0 / f)


@functools.partial(jax.jit, static_argnames=("interpret",))
def diffusive_phi_sparse(inv_phi, F, d_tx_masked, nbr, *, interpret=False):
    """inv_phi [R, N] (s/GFLOP), F [R, N], d_tx_masked [R, N, K] (NEG on
    invalid/off-link slots), nbr [R, N, K] int32 -> inv_phi' [R, N].

    Pads N to a BN multiple and K to a BK multiple internally; pad slots
    carry the NEG sentinel (and index 0) so they never win the max or
    count toward the degree.
    """
    R, N, K = d_tx_masked.shape
    Np = (N + BN - 1) // BN * BN
    Kp = (K + BK - 1) // BK * BK
    if Np - N:
        inv_phi = jnp.pad(inv_phi, ((0, 0), (0, Np - N)), constant_values=1.0)
        F = jnp.pad(F, ((0, 0), (0, Np - N)), constant_values=1.0)
        d_tx_masked = jnp.pad(d_tx_masked, ((0, 0), (0, Np - N), (0, 0)),
                              constant_values=NEG)
        nbr = jnp.pad(nbr, ((0, 0), (0, Np - N), (0, 0)))
    if Kp - K:
        d_tx_masked = jnp.pad(d_tx_masked, ((0, 0), (0, 0), (0, Kp - K)),
                              constant_values=NEG)
        nbr = jnp.pad(nbr, ((0, 0), (0, 0), (0, Kp - K)))
    grid = (R, Np // BN, Kp // BK)
    out = pl.pallas_call(
        _kernel_sparse,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Np), lambda r, i, j: (r, 0)),       # full 1/φ
            pl.BlockSpec((1, BN), lambda r, i, j: (r, i)),       # F (rows)
            pl.BlockSpec((1, BN, BK), lambda r, i, j: (r, i, j)),
            pl.BlockSpec((1, BN, BK), lambda r, i, j: (r, i, j)),
        ],
        out_specs=pl.BlockSpec((1, BN), lambda r, i, j: (r, i)),
        out_shape=jax.ShapeDtypeStruct((R, Np), inv_phi.dtype),
        scratch_shapes=[pltpu.VMEM((BN,), jnp.float32),
                        pltpu.VMEM((BN,), jnp.float32)],
        interpret=interpret,
    )(inv_phi, F, d_tx_masked, nbr)
    return out[:, :N]
