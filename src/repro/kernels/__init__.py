"""Pallas TPU kernels (pl.pallas_call + BlockSpec VMEM tiling) for the
framework's compute hot spots, with ``ops.py`` dispatch and ``ref.py``
pure-jnp oracles.  See DESIGN.md §6 for the tiling rationale."""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
