"""Pallas TPU fused RMSNorm: one pass over row tiles, fp32 accumulation.

Grid: (rows/BR,); block [BR, d] resident in VMEM (d ≤ 8192 ⇒ ≤ 4 MB fp32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BR = 256


def _kernel(x_ref, s_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "br", "interpret"))
def rmsnorm(x, scale, *, eps=1e-6, br=DEFAULT_BR, interpret=False):
    """x [..., d]; scale [d] -> same shape/dtype as x."""
    shape = x.shape
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br_ = min(br, rows)
    if rows % br_ != 0:
        br_ = 1
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(rows // br_,),
        in_specs=[pl.BlockSpec((br_, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br_, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out.reshape(shape)
