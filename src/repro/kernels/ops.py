"""jit'd dispatch layer: Pallas kernels on TPU, jnp references elsewhere.

The model code calls these entry points; on this CPU-only container they
route to ``ref.py`` (which the dry-run lowers), on a real TPU backend they
route to the Pallas kernels.  ``REPRO_FORCE_INTERPRET=1`` forces the Pallas
path in interpret mode (used by the kernel integration tests).
"""
from __future__ import annotations

import os

import jax

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _pl_decode
from repro.kernels.diffusive_phi import diffusive_phi as _pl_phi
from repro.kernels.diffusive_phi import \
    diffusive_phi_sparse as _pl_phi_sparse
from repro.kernels.flash_attention import flash_attention as _pl_flash
from repro.kernels.mamba_scan import mamba_scan as _pl_mamba
from repro.kernels.rglru_scan import rglru_scan as _pl_rglru
from repro.kernels.rmsnorm import rmsnorm as _pl_rmsnorm


def _mode() -> str:
    if os.environ.get("REPRO_FORCE_INTERPRET") == "1":
        return "interpret"
    if jax.default_backend() == "tpu":
        return "tpu"
    return "ref"


def flash_attention(q, k, v, *, causal=True, window=0):
    m = _mode()
    if m == "ref":
        return ref.flash_attention(q, k, v, causal=causal, window=window)
    return _pl_flash(q, k, v, causal=causal, window=window,
                     interpret=(m == "interpret"))


def decode_attention(q, k, v, pos, *, window=0):
    m = _mode()
    if m == "ref":
        return ref.decode_attention(q, k, v, pos, window=window)
    return _pl_decode(q, k, v, pos, window=window,
                      interpret=(m == "interpret"))


def diffusive_phi(inv_phi, F, d_tx_masked):
    m = _mode()
    if m == "ref":
        return ref.diffusive_phi(inv_phi, F, d_tx_masked)
    return _pl_phi(inv_phi, F, d_tx_masked, interpret=(m == "interpret"))


def diffusive_phi_sparse(inv_phi, F, d_tx_masked, nbr):
    m = _mode()
    if m == "ref":
        return ref.diffusive_phi_sparse(inv_phi, F, d_tx_masked, nbr)
    return _pl_phi_sparse(inv_phi, F, d_tx_masked, nbr,
                          interpret=(m == "interpret"))


def rglru_scan(a, b):
    m = _mode()
    if m == "ref":
        return ref.rglru_scan(a, b)
    return _pl_rglru(a, b, interpret=(m == "interpret"))


def mamba_scan(a, b, C):
    m = _mode()
    if m == "ref":
        return ref.mamba_scan(a, b, C)
    return _pl_mamba(a, b, C, interpret=(m == "interpret"))


def rmsnorm(x, scale, eps=1e-6):
    m = _mode()
    if m == "ref":
        return ref.rmsnorm(x, scale, eps)
    return _pl_rmsnorm(x, scale, eps=eps, interpret=(m == "interpret"))
