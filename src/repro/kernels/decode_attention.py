"""Pallas TPU flash-decode: one new query against a long KV cache.

Grid (B, Hkv, Sk/BK): the KV sequence streams through VMEM in (BK, hd)
tiles while the G = Hq/Hkv query heads for this kv-head stay resident
([G, hd], G ≤ 32 → a few KB).  Online softmax accumulators in VMEM scratch
across the (sequential) key grid dimension.  Position masking supports the
paper-relevant cases: plain causal (k ≤ pos), sliding window, and the
hybrid model's ring-buffer caches (negative positions = unwritten slots).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30
DEFAULT_BK = 512


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, window, bk):
    kb = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # [G, hd]
    k = k_ref[0, 0].astype(jnp.float32)          # [BK, hd]
    v = v_ref[0, 0].astype(jnp.float32)          # [BK, hd]
    pos = pos_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)[0]
    keep = kpos <= pos
    if window > 0:
        keep &= (pos - kpos) < window
    s = jnp.where(keep[None, :], s, NEG)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(kb == nk - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "bk", "interpret"))
def decode_attention(q, k, v, pos, *, window=0, bk=DEFAULT_BK,
                     interpret=False):
    """q [B,Hq,hd]; k/v [B,S,Hkv,hd]; pos [] int32 -> [B,Hq,hd]."""
    B, Hq, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    bk = min(bk, S)
    assert S % bk == 0, (S, bk)
    scale = 1.0 / math.sqrt(hd)

    qt = q.reshape(B, Hkv, G, hd)
    kt = jnp.swapaxes(k, 1, 2)                   # [B, Hkv, S, hd]
    vt = jnp.swapaxes(v, 1, 2)
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))

    grid = (B, Hkv, S // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, window=window, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, j: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((G,), jnp.float32),
                        pltpu.VMEM((G,), jnp.float32),
                        pltpu.VMEM((G, hd), jnp.float32)],
        interpret=interpret,
    )(pos_arr, qt, kt, vt)
    return out.reshape(B, Hq, hd)
