"""Pallas TPU kernel for the Mamba-1 selective scan.

State h [D, N] evolves as h_t = a_t ⊙ h_{t-1} + b_t with per-step readout
y_t = Σ_n h_t[:, n] · C_t[n].  The channel dimension D tiles over the grid
(per-channel independence); the sequence is blocked with the [BD, N] state
carried in VMEM scratch across sequence tiles.  Within a tile, an
associative scan over the BS steps runs in fp32, then the readout contracts
the small state dim (N = 16) — y never materializes [S, D, N] in HBM, which
is the whole point (the naive form claims ~34 GB at train_4k).

Grid: (B, D/BD, S/BS); a/b blocks [BS, BD, N], C block [BS, N].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BD = 128
DEFAULT_BS = 64


def _kernel(a_ref, b_ref, c_ref, y_ref, carry_ref):
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    a = a_ref[0].astype(jnp.float32)            # [BS, BD, N]
    b = b_ref[0].astype(jnp.float32)
    c = c_ref[0].astype(jnp.float32)            # [BS, N]
    b = b.at[0].add(a[0] * carry_ref[...])

    def comb(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, ay * bx + by

    _, h = jax.lax.associative_scan(comb, (a, b), axis=0)   # [BS, BD, N]
    y_ref[0] = jnp.einsum("sdn,sn->sd", h, c).astype(y_ref.dtype)
    carry_ref[...] = h[-1]


@functools.partial(jax.jit, static_argnames=("bd", "bs", "interpret"))
def mamba_scan(a, b, C, *, bd=DEFAULT_BD, bs=DEFAULT_BS, interpret=False):
    """a, b [B, S, D, N]; C [B, S, N] -> y [B, S, D]."""
    B, S, D, N = a.shape
    bd = min(bd, D)
    bs = min(bs, S)
    assert D % bd == 0 and S % bs == 0, (D, bd, S, bs)
    grid = (B, D // bd, S // bs)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, bd, N), lambda bb, d, s: (bb, s, d, 0)),
            pl.BlockSpec((1, bs, bd, N), lambda bb, d, s: (bb, s, d, 0)),
            pl.BlockSpec((1, bs, N), lambda bb, d, s: (bb, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, bd), lambda bb, d, s: (bb, s, d)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), a.dtype),
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(a, b, C)
