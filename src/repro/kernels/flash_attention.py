"""Pallas TPU flash attention (GQA, causal / sliding-window), forward.

Canonical online-softmax formulation: grid (B, Hq, Sq/BQ, Sk/BK); the key
dimension is the innermost (sequential) reduction axis, with running
max / sum-exp / output accumulators in VMEM scratch.  GQA is handled in the
index map (kv head = q head // group).  Q and KV tiles are (BQ, hd) and
(BK, hd) with hd padded-free (heads dims are 64..256, MXU-aligned at 128
where it matters for the contraction dims).

Causal/window masking is positional (broadcasted iota); fully-masked tiles
still stream (simple + correct; block-skip via the index map is a TPU
latency optimization left to the grid construction below for causal: the
key grid is truncated per q-block through the mask, not skipped — noted in
DESIGN.md §6).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30
DEFAULT_BQ = 128
DEFAULT_BK = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, causal, window, bq, bk):
    kb = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)           # [BQ, hd]
    k = k_ref[0, 0].astype(jnp.float32)           # [BK, hd]
    v = v_ref[0, 0].astype(jnp.float32)           # [BK, hd]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qpos = pl.program_id(2) * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0)
    kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    keep = jnp.ones((bq, bk), bool)
    if causal:
        keep &= qpos >= kpos
    if window > 0:
        keep &= (qpos - kpos) < window
    s = jnp.where(keep, s, NEG)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(kb == nk - 1)
    def _finalize():
        # rows with zero mass (fully masked) output 0
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, bq=DEFAULT_BQ,
                    bk=DEFAULT_BK, interpret=False):
    """q [B,Sq,Hq,hd]; k/v [B,Sk,Hkv,hd] -> [B,Sq,Hq,hd]."""
    B, Sq, Hq, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    scale = 1.0 / math.sqrt(hd)

    # layout: [B, H, S, hd] so the S tiles are contiguous per head
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)

    grid = (B, Hq, Sq // bq, Sk // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          bq=bq, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq, hd), jnp.float32)],
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.swapaxes(out, 1, 2)
