"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the semantics ground truth: each kernel's test sweeps shapes and
dtypes and asserts allclose against these.  They are also the CPU execution
path (Pallas requires the TPU backend; the multi-pod dry-run lowers these).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG = -1e30


# ---------------------------------------------------------------------------
# diffusive φ update (paper Eq. 10)
# ---------------------------------------------------------------------------


def diffusive_phi(inv_phi, F, d_tx_masked):
    """inv_phi [.., N] (s/GFLOP), F [.., N], d_tx_masked [.., N, N] with
    off-link entries = -inf-ish.  Returns inv_phi' [.., N]."""
    cand = d_tx_masked + inv_phi[..., None, :]
    worst = jnp.max(cand, axis=-1)
    deg = jnp.sum(d_tx_masked > NEG / 2, axis=-1).astype(inv_phi.dtype)
    inv_new = (1.0 / F + worst) / (deg + 1.0)
    return jnp.where(deg > 0, inv_new, 1.0 / F)


def diffusive_phi_sparse(inv_phi, F, d_tx_masked, nbr):
    """Neighbor-list form of Eq. 10: inv_phi [R, N], F [R, N],
    d_tx_masked [R, N, K] (-inf-ish on invalid/off-link slots),
    nbr [R, N, K] int32 neighbor ids (0 on invalid slots, masked by the
    delay sentinel).  Returns inv_phi' [R, N].

    Same arithmetic as ``diffusive_phi`` over the gathered candidates, so
    the result is bit-identical to the dense oracle whenever the lists
    cover every dense neighbor (max is order-independent and the masked
    slots lose exactly like dense off-link columns).
    """
    p = jax.vmap(lambda v, idx: v[idx])(inv_phi, nbr)       # [R, N, K]
    cand = d_tx_masked + p
    worst = jnp.max(cand, axis=-1)
    deg = jnp.sum(d_tx_masked > NEG / 2, axis=-1).astype(inv_phi.dtype)
    inv_new = (1.0 / F + worst) / (deg + 1.0)
    return jnp.where(deg > 0, inv_new, 1.0 / F)


# ---------------------------------------------------------------------------
# flash attention (GQA, causal/window), prefill/train
# ---------------------------------------------------------------------------


def flash_attention(q, k, v, *, causal=True, window=0):
    """q [B,Sq,Hq,hd], k/v [B,Sk,Hkv,hd] -> [B,Sq,Hq,hd] (fp32 softmax)."""
    B, Sq, Hq, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    s = s / math.sqrt(hd)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    keep = jnp.ones((Sq, Sk), bool)
    if causal:
        keep &= qpos >= kpos
    if window and window > 0:
        keep &= (qpos - kpos) < window
    s = jnp.where(keep[None, None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
    return o.reshape(B, Sq, Hq, hd)


# ---------------------------------------------------------------------------
# decode attention (one query vs KV cache)
# ---------------------------------------------------------------------------


def decode_attention(q, k, v, pos, *, window=0):
    """q [B,Hq,hd]; k/v [B,S,Hkv,hd]; pos scalar int (attend k_idx <= pos)."""
    B, Hq, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k).astype(jnp.float32)
    s = s / math.sqrt(hd)
    kpos = jnp.arange(S)
    keep = kpos <= pos
    if window and window > 0:
        keep &= (pos - kpos) < window
    s = jnp.where(keep[None, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v)
    return o.reshape(B, Hq, hd)


# ---------------------------------------------------------------------------
# RG-LRU linear recurrence
# ---------------------------------------------------------------------------


def rglru_scan(a, b, h0=None):
    """h_t = a_t h_{t-1} + b_t.  a, b [B,S,W] fp32.  Returns h [B,S,W]."""
    if h0 is None:
        h0 = jnp.zeros((a.shape[0], a.shape[2]), a.dtype)

    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    _, hs = jax.lax.scan(step, h0, (jnp.moveaxis(a, 1, 0),
                                    jnp.moveaxis(b, 1, 0)))
    return jnp.moveaxis(hs, 0, 1)


# ---------------------------------------------------------------------------
# Mamba selective scan
# ---------------------------------------------------------------------------


def mamba_scan(a, b, C, h0=None):
    """a,b [B,S,D,N]; C [B,S,N] -> y [B,S,D] = C_t·h_t, sequential oracle."""
    B, S, D, N = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, D, N), a.dtype)

    def step(h, xs):
        a_t, b_t, c_t = xs
        h = a_t * h + b_t
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    _, ys = jax.lax.scan(step, h0, (jnp.moveaxis(a, 1, 0),
                                    jnp.moveaxis(b, 1, 0),
                                    jnp.moveaxis(C, 1, 0)))
    return jnp.moveaxis(ys, 0, 1)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)
