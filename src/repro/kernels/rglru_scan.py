"""Pallas TPU kernel for the RG-LRU linear recurrence h_t = a_t·h_{t-1}+b_t.

The recurrence is per-channel, so the width dimension tiles freely
(BW lanes); the sequence dimension is blocked (BS) with the running state
carried in VMEM scratch across sequence tiles (grid dim 2 is sequential).
Inside a tile the recurrence runs as an O(log BS) associative scan over
fp32 registers — the classic work-inefficient-but-parallel form the VPU
prefers over a serial loop.

Grid: (B, W/BW, S/BS).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BW = 128
DEFAULT_BS = 256


def _kernel(a_ref, b_ref, h_ref, carry_ref):
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    a = a_ref[0].astype(jnp.float32)            # [BS, BW]
    b = b_ref[0].astype(jnp.float32)
    b = b.at[0].add(a[0] * carry_ref[...])

    def comb(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, ay * bx + by

    _, h = jax.lax.associative_scan(comb, (a, b), axis=0)
    h_ref[0] = h.astype(h_ref.dtype)
    carry_ref[...] = h[-1]


@functools.partial(jax.jit, static_argnames=("bw", "bs", "interpret"))
def rglru_scan(a, b, *, bw=DEFAULT_BW, bs=DEFAULT_BS, interpret=False):
    """a, b [B, S, W] -> h [B, S, W]  (h_0 = b_0; zero initial state)."""
    B, S, W = a.shape
    bw = min(bw, W)
    bs = min(bs, S)
    assert W % bw == 0 and S % bs == 0, (W, bw, S, bs)
    grid = (B, W // bw, S // bs)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, bw), lambda bb, w, s: (bb, s, w)),
            pl.BlockSpec((1, bs, bw), lambda bb, w, s: (bb, s, w)),
        ],
        out_specs=pl.BlockSpec((1, bs, bw), lambda bb, w, s: (bb, s, w)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), a.dtype),
        scratch_shapes=[pltpu.VMEM((bw,), jnp.float32)],
        interpret=interpret,
    )(a, b)
