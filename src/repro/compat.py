"""Version-compat shims for jax API drift."""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    """``jax.shard_map`` appeared as a top-level API (with the ``check_rep``
    flag renamed ``check_vma``) after 0.4.x; older releases only have
    ``jax.experimental.shard_map.shard_map``."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
