"""Mobility models for the scenario engine (DESIGN.md §3.4).

Three models, all exposing the same epoch-stepped interface consumed by
``swarm/scenario.py``'s registry:

    init(key, cfg, n)            -> state pytree
    step(state, key, cfg, t0)    -> (state', pos [N, 2])

``step`` is called once per decision epoch (Δt = ``cfg.decision_period_s``)
with the epoch start time ``t0``; stateless models (circular) evaluate a
closed form at ``t0`` and ignore the key, so the default scenario's
trajectories are bit-identical to the pre-engine simulator.

* **circular** (paper §5): centers on a granularity-g grid over the mission
  area; each UAV orbits its center at ``speed_mps``.
* **random_waypoint**: uniform waypoint in the area, travel at a per-leg
  speed ~ U[speed_min, speed_max], re-draw on arrival.
* **gauss_markov**: velocity AR(1) with memory ``gm_alpha`` around a random
  per-node mean heading; reflecting area boundaries.
* **levy_flight**: heavy-tailed (Pareto) hop lengths with uniform headings —
  the search-flight pattern UAV surveillance missions exhibit; reflecting
  area boundaries like random_waypoint.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SwarmConfig


# ---------------------------------------------------------------------------
# circular orbits (paper §5 — the original model, closed form in t)
# ---------------------------------------------------------------------------


def init_mobility(key, cfg: SwarmConfig, n: int):
    """Returns dict(center [N,2], phase0 [N], omega [N])."""
    kc, kp, kj = jax.random.split(key, 3)
    g = cfg.placement_granularity
    cell = cfg.area_m / g
    idx = jax.random.randint(kc, (n, 2), 0, g)
    jitter = jax.random.uniform(kj, (n, 2), jnp.float32, 0.25, 0.75)
    center = (idx.astype(jnp.float32) + jitter) * cell
    phase0 = jax.random.uniform(kp, (n,), jnp.float32, 0.0, 2.0 * jnp.pi)
    # f32 pin: default-dtype full is f64 under x64 and would drift the
    # mobility-state scan carry (swarmlint J002)
    omega = jnp.full((n,), cfg.speed_mps / cfg.movement_radius_m,
                     jnp.float32)
    return {"center": center, "phase0": phase0, "omega": omega}


def positions_at(mob, cfg: SwarmConfig, t: jax.Array) -> jax.Array:
    """[N, 2] positions at simulation time t (seconds)."""
    ang = mob["phase0"] + mob["omega"] * t
    off = jnp.stack([jnp.cos(ang), jnp.sin(ang)], axis=-1)
    return mob["center"] + cfg.movement_radius_m * off


def step_circular(state, key, cfg: SwarmConfig, t0):
    del key  # deterministic given the init draw
    return state, positions_at(state, cfg, t0)


# ---------------------------------------------------------------------------
# random waypoint
# ---------------------------------------------------------------------------


def init_random_waypoint(key, cfg: SwarmConfig, n: int):
    kp, kw, ks = jax.random.split(key, 3)
    pos = jax.random.uniform(kp, (n, 2), jnp.float32, 0.0, cfg.area_m)
    wp = jax.random.uniform(kw, (n, 2), jnp.float32, 0.0, cfg.area_m)
    speed = jax.random.uniform(ks, (n,), jnp.float32,
                               cfg.speed_min_mps, cfg.speed_max_mps)
    return {"pos": pos, "wp": wp, "speed": speed}


def step_random_waypoint(state, key, cfg: SwarmConfig, t0):
    n = state["pos"].shape[0]
    # epoch-start contract: the first epoch (t0 = 0) observes the init
    # placement; later epochs advance one decision period
    dt = jnp.where(t0 > 0.0, cfg.decision_period_s, 0.0)
    vec = state["wp"] - state["pos"]
    dist = jnp.sqrt(jnp.sum(jnp.square(vec), axis=-1) + 1e-12)
    hop = state["speed"] * dt
    reached = dist <= hop
    pos = jnp.where(reached[:, None], state["wp"],
                    state["pos"] + vec / dist[:, None] * hop[:, None])
    kw, ks = jax.random.split(key)
    wp = jnp.where(reached[:, None],
                   jax.random.uniform(kw, (n, 2), jnp.float32,
                                      0.0, cfg.area_m),
                   state["wp"])
    speed = jnp.where(reached,
                      jax.random.uniform(ks, (n,), jnp.float32,
                                         cfg.speed_min_mps,
                                         cfg.speed_max_mps),
                      state["speed"])
    return {"pos": pos, "wp": wp, "speed": speed}, pos


# ---------------------------------------------------------------------------
# Lévy flight
# ---------------------------------------------------------------------------


def init_levy_flight(key, cfg: SwarmConfig, n: int):
    pos = jax.random.uniform(key, (n, 2), jnp.float32, 0.0, cfg.area_m)
    return {"pos": pos}


def step_levy_flight(state, key, cfg: SwarmConfig, t0):
    """One epoch of a bounded Lévy flight.

    Hop length per epoch is Pareto-tailed: L = L_min · u^(-1/α) with
    α = ``levy_alpha`` (1 < α < 3 gives the characteristic many-small-hops /
    rare-long-relocations mix), truncated so one epoch never exceeds
    ``speed_max_mps`` — the same physical speed cap random_waypoint obeys.
    Heading is uniform per epoch; boundary hits reflect back into the arena.
    """
    n = state["pos"].shape[0]
    dt = cfg.decision_period_s
    kl, kh = jax.random.split(key)
    l_min = cfg.speed_min_mps * dt
    l_max = cfg.speed_max_mps * dt
    u = jax.random.uniform(kl, (n,), jnp.float32, 1e-6, 1.0)
    hop = jnp.minimum(l_min * jnp.power(u, -1.0 / cfg.levy_alpha), l_max)
    theta = jax.random.uniform(kh, (n,), jnp.float32, 0.0, 2.0 * jnp.pi)
    step = hop[:, None] * jnp.stack([jnp.cos(theta), jnp.sin(theta)],
                                    axis=-1)
    # epoch-start contract: the first epoch (t0 = 0) observes init placement
    pos = state["pos"] + jnp.where(t0 > 0.0, 1.0, 0.0) * step
    A = cfg.area_m
    pos = jnp.clip(jnp.where(pos < 0.0, -pos,
                             jnp.where(pos > A, 2.0 * A - pos, pos)),
                   0.0, A)
    return {"pos": pos}, pos


# ---------------------------------------------------------------------------
# Gauss-Markov
# ---------------------------------------------------------------------------


def init_gauss_markov(key, cfg: SwarmConfig, n: int):
    kp, kh = jax.random.split(key)
    pos = jax.random.uniform(kp, (n, 2), jnp.float32, 0.0, cfg.area_m)
    theta = jax.random.uniform(kh, (n,), jnp.float32, 0.0, 2.0 * jnp.pi)
    mean_speed = 0.5 * (cfg.speed_min_mps + cfg.speed_max_mps)
    mean_vel = mean_speed * jnp.stack([jnp.cos(theta), jnp.sin(theta)],
                                      axis=-1)
    return {"pos": pos, "vel": mean_vel, "mean_vel": mean_vel}


def step_gauss_markov(state, key, cfg: SwarmConfig, t0):
    dt = cfg.decision_period_s
    a = cfg.gm_alpha
    w = jax.random.normal(key, state["vel"].shape, jnp.float32)
    vel = (a * state["vel"] + (1.0 - a) * state["mean_vel"]
           + cfg.gm_sigma_mps * (1.0 - a * a) ** 0.5 * w)
    # epoch-start contract: no advance (and no AR velocity step) at t0 = 0
    vel = jnp.where(t0 > 0.0, vel, state["vel"])
    pos = state["pos"] + vel * jnp.where(t0 > 0.0, dt, 0.0)
    # reflect off the mission-area boundary: flip the offending component of
    # BOTH vel and mean_vel — otherwise the AR(1) pull toward the original
    # mean heading pins wall-facing nodes to the boundary
    A = cfg.area_m
    out_lo, out_hi = pos < 0.0, pos > A
    pos = jnp.clip(jnp.where(out_lo, -pos, jnp.where(out_hi, 2.0 * A - pos,
                                                     pos)), 0.0, A)
    bounce = out_lo | out_hi
    vel = jnp.where(bounce, -vel, vel)
    mean_vel = jnp.where(bounce, -state["mean_vel"], state["mean_vel"])
    return {"pos": pos, "vel": vel, "mean_vel": mean_vel}, pos
