"""Circular-trajectory mobility (paper §5): centers placed on a
granularity-g grid over the mission area; each UAV orbits its center with
radius `movement_radius_m` at `speed_mps`."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SwarmConfig


def init_mobility(key, cfg: SwarmConfig, n: int):
    """Returns dict(center [N,2], phase0 [N], omega [N])."""
    kc, kp, kj = jax.random.split(key, 3)
    g = cfg.placement_granularity
    cell = cfg.area_m / g
    idx = jax.random.randint(kc, (n, 2), 0, g)
    jitter = jax.random.uniform(kj, (n, 2), jnp.float32, 0.25, 0.75)
    center = (idx.astype(jnp.float32) + jitter) * cell
    phase0 = jax.random.uniform(kp, (n,), jnp.float32, 0.0, 2.0 * jnp.pi)
    omega = jnp.full((n,), cfg.speed_mps / cfg.movement_radius_m)
    return {"center": center, "phase0": phase0, "omega": omega}


def positions_at(mob, cfg: SwarmConfig, t: jax.Array) -> jax.Array:
    """[N, 2] positions at simulation time t (seconds)."""
    ang = mob["phase0"] + mob["omega"] * t
    off = jnp.stack([jnp.cos(ang), jnp.sin(ang)], axis=-1)
    return mob["center"] + cfg.movement_radius_m * off
