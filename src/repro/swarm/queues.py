"""Struct-of-arrays task-queue ops (DESIGN.md §3.2).

Each node owns ``Q = cfg.queue_slots`` slots; a task is (active, cum_gflops,
created_t, seq, visited-set).  FIFO order is by global sequence number, so
``head_slot`` is an argmin over active seqs — all ops are fixed-shape
scatter/gathers that jit and vmap cleanly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.swarm.tasks import TaskProfile

INT_MAX = jnp.iinfo(jnp.int32).max


def head_slot(st):
    """FIFO head per node: (head_slot_idx [N], has_task [N])."""
    seqv = jnp.where(st["q_active"], st["q_seq"], INT_MAX)
    head = jnp.argmin(seqv, axis=1)
    has = jnp.any(st["q_active"], axis=1)
    return head, has


def queued_gflops(st, profile: TaskProfile) -> jax.Array:
    """Remaining GFLOPs per node across all queued tasks (load metric T)."""
    rem = jnp.maximum(profile.total_gflops - st["q_cum"], 0.0)
    return jnp.sum(jnp.where(st["q_active"], rem, 0.0), axis=1)


def push(st, mask, cum, created, visited, extras=None):
    """Insert one task per node where mask; drops (with count) if full.

    ``extras`` scatters additional per-task columns into ``q_<name>``
    arrays alongside the core fields (the trace layer's attribution state,
    ``repro.trace.record``); ``None`` leaves the state untouched beyond
    the core fields — the untraced path is byte-for-byte the historical
    one.
    """
    n, Q = st["q_active"].shape
    free = jnp.argmin(st["q_active"], axis=1)              # first False slot
    has_free = ~jnp.all(st["q_active"], axis=1)
    ok = mask & has_free
    rows = jnp.arange(n)
    # dtype pins: integer cumsum/sum follow numpy and widen to i64 under
    # x64, which would drift the i32 seq fields' carry (swarmlint J002)
    seq = (st["seq_counter"]
           + jnp.cumsum(ok.astype(jnp.int32), dtype=jnp.int32) - 1)
    st = dict(st)
    for name, val in (extras or {}).items():
        k = f"q_{name}"
        # oob: `free` is an argmin over the slot axis, always in [0, Q);
        # drop mode is the .at[] default here, never exercised (J003)
        st[k] = st[k].at[rows, free].set(
            jnp.where(ok, jnp.asarray(val, st[k].dtype),
                      st[k][rows, free]))
    # oob: same in-range `free` slot for every core-field scatter below
    st["q_active"] = st["q_active"].at[rows, free].set(
        jnp.where(ok, True, st["q_active"][rows, free]))
    st["q_cum"] = st["q_cum"].at[rows, free].set(
        jnp.where(ok, cum, st["q_cum"][rows, free]))
    # oob: in-range `free` (argmin), see above
    st["q_created"] = st["q_created"].at[rows, free].set(
        jnp.where(ok, created, st["q_created"][rows, free]))
    st["q_seq"] = st["q_seq"].at[rows, free].set(
        jnp.where(ok, seq, st["q_seq"][rows, free]))
    # oob: in-range `free` (argmin), see above
    st["q_visited"] = st["q_visited"].at[rows, free].set(
        jnp.where(ok[:, None], visited, st["q_visited"][rows, free]))
    st["seq_counter"] = st["seq_counter"] + jnp.sum(
        ok.astype(jnp.int32), dtype=jnp.int32)
    # i32 count: exact under any reduction order, so the in-scan sum
    # cannot drift across executor backends (swarmlint J001, §8.2)
    st["drop_count"] = st["drop_count"] + jnp.sum(mask & ~has_free,
                                                  dtype=jnp.int32)
    return st


def pop_head(st, mask):
    """Deactivate the FIFO head where mask."""
    head, _ = head_slot(st)
    rows = jnp.arange(st["q_active"].shape[0])
    st = dict(st)
    # oob: `head` is an argmin over the slot axis, always in [0, Q) (J003)
    st["q_active"] = st["q_active"].at[rows, head].set(
        jnp.where(mask, False, st["q_active"][rows, head]))
    return st
