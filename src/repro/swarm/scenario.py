"""Pluggable scenario engine (DESIGN.md §3.4).

The simulator is parameterized over three orthogonal environment axes, each
selected by a string field on ``SwarmConfig`` through a registry here:

  * **mobility** (``cfg.mobility_model``)  — where the UAVs are each epoch,
  * **channel**  (``cfg.channel_model``)   — pathloss → SNR → adjacency,
  * **fault**    (``cfg.fault_model``)     — epoch-level node up/down churn.

Because the config is static under jit, a scenario sweep is a pure config
change: ``run_many`` compiles one executable per (cfg, n) pair and every
benchmark/example can iterate scenarios without touching simulator code.
Third-party models register with the ``register_*`` decorators; lookups
raise with the list of known keys so a typo'd config fails loudly at trace
time, not with a shape error mid-scan.

The fault injector mirrors ``runtime/fault.py``'s failure-injection idiom
at swarm scale: a two-state Markov chain per node (mean dwell times
``fault_mean_up_s`` / ``fault_mean_down_s``) produces an epoch-level alive
mask that is threaded through adjacency (down nodes have no links), compute
budgets and task arrivals.  Queued work on a down node survives the outage
— conservation invariants hold under churn.
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import SwarmConfig
from repro.swarm import channel as _channel
from repro.swarm import mobility as _mobility


class MobilityModel(NamedTuple):
    init: Callable   # (key, cfg, n) -> state pytree
    step: Callable   # (state, key, cfg, t0) -> (state', pos [N, 2])


class FaultModel(NamedTuple):
    init: Callable   # (key, cfg, n) -> alive [N] bool
    step: Callable   # (alive, key, cfg) -> alive' [N] bool


# channel models are bare pathloss callables: (key, dist [N,N], cfg) -> dB;
# edge-channel models are their sparse twins (key, dist [N,K], src [N,K],
# dst [N,K], cfg) -> dB for the neighbor-list path (DESIGN.md §11)
MOBILITY_MODELS: Dict[str, MobilityModel] = {}
CHANNEL_MODELS: Dict[str, Callable] = {}
CHANNEL_EDGE_MODELS: Dict[str, Callable] = {}
FAULT_MODELS: Dict[str, FaultModel] = {}


def _register(registry: Dict, kind: str, name: str, value):
    if name in registry:
        raise ValueError(f"duplicate {kind} model {name!r}")
    registry[name] = value
    return value


def register_mobility(name: str, init: Callable, step: Callable):
    return _register(MOBILITY_MODELS, "mobility", name,
                     MobilityModel(init, step))


def register_channel(name: str, pathloss_fn: Callable):
    return _register(CHANNEL_MODELS, "channel", name, pathloss_fn)


def register_channel_edges(name: str, pathloss_edges_fn: Callable):
    return _register(CHANNEL_EDGE_MODELS, "edge channel", name,
                     pathloss_edges_fn)


def register_fault(name: str, init: Callable, step: Callable):
    return _register(FAULT_MODELS, "fault", name, FaultModel(init, step))


def _lookup(registry: Dict, kind: str, name: str):
    try:
        return registry[name]
    except KeyError:
        raise KeyError(
            f"unknown {kind} model {name!r}; registered: "
            f"{sorted(registry)}") from None


def get_mobility(cfg: SwarmConfig) -> MobilityModel:
    return _lookup(MOBILITY_MODELS, "mobility", cfg.mobility_model)


def get_channel(cfg: SwarmConfig) -> Callable:
    return _lookup(CHANNEL_MODELS, "channel", cfg.channel_model)


def get_fault(cfg: SwarmConfig) -> FaultModel:
    return _lookup(FAULT_MODELS, "fault", cfg.fault_model)


def get_channel_edges(cfg: SwarmConfig) -> Callable:
    """Per-edge pathloss model for ``neighbor_mode="sparse"``.  Channels
    without a sparse implementation (``log_normal_corr`` needs the full
    node-field Cholesky) fail loudly here rather than silently falling
    back to dense."""
    if cfg.channel_model not in CHANNEL_EDGE_MODELS:
        raise KeyError(
            f"channel model {cfg.channel_model!r} has no per-edge (sparse) "
            f"implementation; registered: {sorted(CHANNEL_EDGE_MODELS)} — "
            f"use neighbor_mode='dense' or register_channel_edges()")
    return CHANNEL_EDGE_MODELS[cfg.channel_model]


# ---------------------------------------------------------------------------
# fault/churn models
# ---------------------------------------------------------------------------


def _fault_none_init(key, cfg: SwarmConfig, n: int):
    del key
    return jnp.ones((n,), bool)


def _fault_none_step(alive, key, cfg: SwarmConfig):
    del key
    return alive


def _fault_markov_init(key, cfg: SwarmConfig, n: int):
    # start at the chain's stationary distribution so short runs see churn
    p_down = cfg.fault_mean_down_s / (cfg.fault_mean_up_s
                                      + cfg.fault_mean_down_s)
    return ~jax.random.bernoulli(key, p_down, (n,))


def _fault_markov_step(alive, key, cfg: SwarmConfig):
    dt = cfg.decision_period_s
    p_fail = 1.0 - jnp.exp(-dt / cfg.fault_mean_up_s)
    p_recover = 1.0 - jnp.exp(-dt / cfg.fault_mean_down_s)
    u = jax.random.uniform(key, alive.shape)
    return jnp.where(alive, u >= p_fail, u < p_recover)


def mask_adjacency(adj: jax.Array, alive: jax.Array) -> jax.Array:
    """Down nodes have no links in either direction."""
    return adj & alive[:, None] & alive[None, :]


# ---------------------------------------------------------------------------
# workload: Markov-modulated (bursty) arrivals — part of the scenario
# ---------------------------------------------------------------------------


def burst_arrivals(burst_on, key, cfg: SwarmConfig):
    """One tick of the per-node ON/OFF arrival chain (Fig. 1 workload).

    Long-run mean inter-arrival stays ``task_period_s``; while ON, tasks
    arrive at rate 1/(period·duty).  Returns (burst_on', arrive [N] bool).
    """
    tick = cfg.tick_s
    k_sw, k_ar = jax.random.split(key)
    duty = cfg.burst_on_s / (cfg.burst_on_s + cfg.burst_off_s)
    p_on_off = 1.0 - jnp.exp(-tick / cfg.burst_on_s)
    p_off_on = 1.0 - jnp.exp(-tick / cfg.burst_off_s)
    flip = jax.random.uniform(k_sw, burst_on.shape)
    burst_on = jnp.where(burst_on, flip >= p_on_off, flip < p_off_on)
    p_arr = 1.0 - jnp.exp(-tick / (cfg.task_period_s * duty))
    arrive = jax.random.bernoulli(k_ar, p_arr, burst_on.shape) & burst_on
    return burst_on, arrive


# ---------------------------------------------------------------------------
# built-in registrations
# ---------------------------------------------------------------------------

register_mobility("circular", _mobility.init_mobility,
                  _mobility.step_circular)
register_mobility("random_waypoint", _mobility.init_random_waypoint,
                  _mobility.step_random_waypoint)
register_mobility("gauss_markov", _mobility.init_gauss_markov,
                  _mobility.step_gauss_markov)
register_mobility("levy_flight", _mobility.init_levy_flight,
                  _mobility.step_levy_flight)

register_channel("two_ray", _channel.two_ray)
register_channel("free_space", _channel.free_space)
register_channel("log_normal", _channel.log_normal)
register_channel("log_normal_corr", _channel.log_normal_corr)
register_channel("rician", _channel.rician)
register_channel("nakagami", _channel.nakagami)

# sparse per-edge twins (no log_normal_corr: see get_channel_edges)
register_channel_edges("two_ray", _channel.two_ray_edges)
register_channel_edges("free_space", _channel.free_space_edges)
register_channel_edges("log_normal", _channel.log_normal_edges)
register_channel_edges("rician", _channel.rician_edges)
register_channel_edges("nakagami", _channel.nakagami_edges)

register_fault("none", _fault_none_init, _fault_none_step)
register_fault("markov", _fault_markov_init, _fault_markov_step)
