"""Single-outgoing-transfer machinery (paper §3.2, DESIGN.md §3.3).

Each node carries at most one in-flight outgoing task transfer.  An epoch
decision *initiates* a transfer (pop the FIFO head, snap its progress back
to the last layer boundary per §3.1, ship the boundary activation bits);
fine ticks *progress* it at the epoch-frozen link capacity and *deliver* it
into the destination queue — one delivery per receiver per tick, lowest
origin index winning contention.

Accounting note: a transfer whose payload has fully arrived
(``tx_bits <= 0``) but that lost receiver contention stays ``tx_active``
until it wins a delivery slot.  Those waiting ticks are *queue-wait*, not
airtime — the radio is done — so bit decrement and transmit-energy accrual
freeze once ``tx_bits <= 0`` (they used to keep running, over-counting
``e_tx`` and the task's ``tx_energy`` for every contended delivery).
Under hop capture the waiting ticks are counted in ``hop_stall`` instead,
alongside endpoint-down fault stalls.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SwarmConfig
from repro.swarm.queues import INT_MAX, head_slot, pop_head, push
from repro.swarm.tasks import (TaskProfile, boundary_bits, layer_of,
                               snap_to_boundary)
from repro.trace import record as trace_record


def initiate(st, elig, tgt, t0, profile: TaskProfile):
    """Start transfers where ``elig``: pop the head task, discard partial-
    layer progress and stage the boundary activation for shipping."""
    rows = jnp.arange(st["F"].shape[0])
    head, _ = head_slot(st)
    cum_h = st["q_cum"][rows, head]
    cum_snap = snap_to_boundary(profile, cum_h)
    bits = boundary_bits(profile, cum_h)
    st = dict(st)
    if "tx_src" in st:       # trace attribution rides along (DESIGN §10.2)
        for f in ("src", "energy", "txtime"):
            st[f"tx_{f}"] = jnp.where(elig, st[f"q_{f}"][rows, head],
                                      st[f"tx_{f}"])
    if "hop_seq" in st:      # hop stream: assign seqs at initiation (§10.5)
        # i32-pinned reductions: numpy-style widening to i64 under x64
        # would drift the hop-seq carry dtype (swarmlint J002)
        hseq = st["hop_counter"] + jnp.cumsum(
            elig.astype(jnp.int32), dtype=jnp.int32) - 1
        st["hop_seq"] = jnp.where(elig, hseq, st["hop_seq"])
        st["hop_counter"] = st["hop_counter"] + jnp.sum(
            elig.astype(jnp.int32), dtype=jnp.int32)
        st["hop_bits"] = jnp.where(elig, bits, st["hop_bits"])
        st["hop_layer"] = jnp.where(
            elig, jnp.clip(layer_of(profile, cum_h), 0,
                           profile.cum_gflops.shape[0] - 1),
            st["hop_layer"])
        st["hop_stall"] = jnp.where(elig, 0, st["hop_stall"])
    st["tx_dst"] = jnp.where(elig, tgt, st["tx_dst"])
    st["tx_bits"] = jnp.where(elig, bits, st["tx_bits"])
    st["tx_cum"] = jnp.where(elig, cum_snap, st["tx_cum"])
    st["tx_created"] = jnp.where(elig, st["q_created"][rows, head],
                                 st["tx_created"])
    st["tx_visited"] = jnp.where(elig[:, None],
                                 st["q_visited"][rows, head],
                                 st["tx_visited"])
    st["tx_start"] = jnp.where(elig, t0, st["tx_start"])
    # i32 count: exact under any reduction order, so the in-scan sum
    # cannot drift across executor backends (swarmlint J001, §8.2)
    st["tx_count"] = st["tx_count"] + jnp.sum(elig, dtype=jnp.int32)
    st["tx_active"] = st["tx_active"] | elig
    return pop_head(st, elig)


def progress(st, cap, alive, cfg: SwarmConfig, t_now):
    """One tick of transfer progress + delivery.

    ``cap`` is the epoch-frozen capacity: the [N,N] matrix on the dense
    path (indexed per node at its transfer destination), or an [N] rate
    vector on the sparse neighbor-list path, where the simulator already
    resolved each node's (i, tx_dst_i) link via ``channel.edge_rate`` —
    valid because tx_dst only changes at epoch decisions, never mid-tick.
    ``alive`` is the epoch fault mask — a transfer whose endpoint is down
    stalls (bits conserved) and resumes when the node recovers.
    """
    n = st["F"].shape[0]
    # i32 pin: the origin ranks scatter into i32 contention fields, and
    # default arange/full are i64 under x64 (swarmlint J002)
    rows = jnp.arange(n, dtype=jnp.int32)
    tick = cfg.tick_s
    rate = cap if cap.ndim == 1 else cap[rows, st["tx_dst"]]  # bit/s
    live = alive & alive[st["tx_dst"]]
    active = st["tx_active"] & live
    # a fully-arrived payload is off the air: no further bit decrement or
    # transmit-energy accrual while it waits out receiver contention
    pre_arrived = st["tx_bits"] <= 0.0
    flying = active & ~pre_arrived
    tx_w = 10.0 ** (cfg.tx_power_dbm / 10.0) * 1e-3
    st = dict(st)
    if "hop_stall" in st:    # pending but not progressing: fault stall or
        st["hop_stall"] = st["hop_stall"] + (   # post-arrival queue-wait
            st["tx_active"] & (~live | pre_arrived)).astype(jnp.int32)
    st["tx_bits"] = jnp.where(flying, st["tx_bits"] - rate * tick,
                              st["tx_bits"])
    st["e_tx"] = st["e_tx"] + jnp.where(flying, tx_w * tick, 0.0)
    if "tx_energy" in st:    # attribute the airtime joules to the task
        st["tx_energy"] = st["tx_energy"] + jnp.where(flying,
                                                      tx_w * tick, 0.0)
    arrived = active & (st["tx_bits"] <= 0.0)
    # receiver contention: lowest-index origin wins per destination
    origin_rank = jnp.where(arrived, rows, INT_MAX)
    # oob: tx_dst holds node ids from the decision stage, always in
    # [0, N); drop mode is the .at[] default, never exercised (J003)
    winner = jnp.full((n,), INT_MAX, jnp.int32).at[st["tx_dst"]].min(
        jnp.where(arrived, origin_rank, INT_MAX))
    deliver = arrived & (winner[st["tx_dst"]] == rows)

    # oob: in-range tx_dst, see winner scatter above (J003)
    dst_mask = jnp.zeros((n,), bool).at[st["tx_dst"]].max(deliver)
    # scatter in-flight fields to destination rows
    # oob: in-range tx_dst, see winner scatter above (J003)
    inv = jnp.full((n,), 0, jnp.int32).at[st["tx_dst"]].max(
        jnp.where(deliver, rows, 0))                        # origin per dst
    cum_d = st["tx_cum"][inv]
    created_d = st["tx_created"][inv]
    visited_d = st["tx_visited"][inv] | jax.nn.one_hot(
        inv, n, dtype=bool)                                 # mark origin
    if trace_record.hops_enabled(cfg):
        st = trace_record.write_hop_records(
            st, deliver, seq=st["hop_seq"], src=rows, dst=st["tx_dst"],
            t_depart=st["tx_start"], t_arrive=t_now, bits=st["hop_bits"],
            boundary_layer=st["hop_layer"], stall_ticks=st["hop_stall"])
    if trace_record.enabled(cfg):
        st = trace_record.traced_push(
            st, dst_mask, cum_d, created_d, visited_d,
            src=st["tx_src"][inv], energy=st["tx_energy"][inv],
            txtime=st["tx_txtime"][inv] + jnp.where(
                dst_mask, t_now - st["tx_start"][inv], 0.0),
            t_now=t_now, cfg=cfg)
    else:
        st = push(st, dst_mask, cum_d, created_d, visited_d)
    st["tx_active"] = st["tx_active"] & ~deliver
    # i32 count (see tx_count in initiate); tx_time_sum below stays a
    # float accumulator and is baselined under J001 with its rationale
    st["tx_delivered"] = st["tx_delivered"] + jnp.sum(deliver,
                                                      dtype=jnp.int32)
    st["tx_time_sum"] = st["tx_time_sum"] + jnp.sum(
        jnp.where(deliver, t_now - st["tx_start"], 0.0))
    return st
