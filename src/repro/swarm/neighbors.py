"""Fixed-width neighbor lists via spatial-hash bucket search (DESIGN.md §11).

The diffusive protocol (Eq. 10) is strictly one-hop-local, yet the dense
hot path materializes [N, N] distance/gain/capacity matrices every epoch.
This module builds the sparse alternative: per-node top-k nearest-neighbor
index lists ``nbr [N, K]`` (+ validity mask) from positions, in O(N) per
epoch at fixed K:

  1. hash every node into a ``G × G`` grid of cells (cell edge ≈ the
     channel's communication range, capped by a density heuristic so the
     candidate set stays ~K-sized even when the radio range spans the
     whole mission area);
  2. sort node ids by cell id once — ``searchsorted`` then yields each
     cell's contiguous [start, end) slice, i.e. a bucket table without any
     variable-width structure;
  3. every node gathers a fixed window of ``cap`` candidates from each of
     its 9 surrounding cells (out-of-grid offsets masked, never wrapped,
     so no candidate appears twice) and keeps the K nearest by squared
     distance (``lax.top_k``).

All shapes are static under jit (grid size, cell capacity and K are
derived from the config in Python), so the builder scans/vmaps exactly
like the rest of the simulator.  Exactness: if every true neighbor lies
within one cell edge (cell ≥ comm range), no cell overflows ``cap``, and
K ≥ the true max degree, the K-nearest lists contain *exactly* the dense
adjacency's neighbor sets — the regime the sparse-vs-dense parity tests
pin.  Beyond it (huge N, K ≪ degree) the lists are the K nearest
candidates: the truncated-degree approximation DESIGN.md §11 discusses.

Lists are canonicalized to ascending node id (invalid slots pushed to the
end) so downstream argmin/argmax tie-breaks match the dense path's
lowest-index-wins convention bit-for-bit.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SwarmConfig

# grid resolution cap: G² cells must stay cheap to searchsorted over
MAX_GRID = 256


def comm_range_m(cfg: SwarmConfig) -> float:
    """Distance at which the selected channel's *deterministic* pathloss
    baseline crosses ``snr_min_db`` (the Eq. 9 adjacency threshold).

    Stochastic models get a fade margin on top (3σ shadowing, ~10 dB for
    the unit-mean fading envelopes) so candidates that only connect on a
    lucky draw still enter the search window.  Unknown (user-registered)
    channels fall back to the mission-area diagonal — conservative; set
    ``cfg.neighbor_range_m`` to override.
    """
    if cfg.neighbor_range_m > 0.0:
        return cfg.neighbor_range_m
    diag = cfg.area_m * math.sqrt(2.0)
    budget = cfg.tx_power_dbm - cfg.noise_dbm - cfg.snr_min_db
    name = cfg.channel_model
    if name == "two_ray":
        r = 10.0 ** ((budget
                      + 20.0 * math.log10(cfg.altitude_m * cfg.altitude_m))
                     / 40.0)
    elif name in ("free_space", "log_normal", "log_normal_corr", "rician",
                  "nakagami"):
        fspl1 = 20.0 * math.log10(cfg.carrier_hz) - 147.55
        n_exp = 2.0 if name == "free_space" else cfg.pathloss_exp
        margin = 0.0
        if name in ("log_normal", "log_normal_corr"):
            margin = 3.0 * cfg.shadowing_sigma_db
        elif name in ("rician", "nakagami"):
            margin = 10.0
        r = 10.0 ** ((budget - fspl1 + margin) / (10.0 * n_exp))
    else:
        r = diag
    return min(r, diag)


def grid_geometry(cfg: SwarmConfig, n: int, k: int) -> Tuple[int, float, int]:
    """Static (G, cell_m, cell_cap) of the bucket grid for an N-node swarm.

    The cell edge is the smaller of the channel range (exact coverage when
    it fits) and a density heuristic sized so the 3×3 search window holds
    a few K's worth of candidates (the complete-graph regime, where range
    covers the whole area and exact coverage would degenerate to O(N²)).
    All three outputs are Python scalars — static under jit.
    """
    r = comm_range_m(cfg)
    density_cell = 0.75 * cfg.area_m * math.sqrt(max(k, 1) / max(n, 1))
    target = max(min(r, density_cell), cfg.area_m / MAX_GRID)
    # floor, not ceil: the realized cell = area/G must stay >= target, so
    # that when the range is the binding constraint (cell >= r) the 3x3
    # window provably covers every in-range neighbor
    G = max(int(cfg.area_m / target), 1)
    cell = cfg.area_m / G
    if cfg.neighbor_cell_cap > 0:
        cap = cfg.neighbor_cell_cap
    elif n <= 1024:
        cap = n          # small swarms: exact, 9n candidates are cheap
    else:
        lam = n / float(G * G)       # mean cell occupancy
        cap = max(2 * k, int(math.ceil(4.0 * lam)) + 8)
    return G, cell, min(cap, n)


def neighbor_lists(pos: jax.Array, cfg: SwarmConfig, k: int | None = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """pos [N, 2] → (nbr [N, K] int32 ascending by id, valid [N, K] bool).

    Valid slots hold the K nearest distinct nodes within the candidate
    radius; invalid slots carry index 0 and are masked everywhere
    downstream (the NEG off-link convention of the φ kernels).
    """
    n = pos.shape[0]
    k = cfg.neighbor_k if k is None else k
    k = max(1, min(k, n - 1)) if n > 1 else 1
    G, cell, cap = grid_geometry(cfg, n, k)
    r = comm_range_m(cfg)

    ix = jnp.clip((pos[:, 0] / cell).astype(jnp.int32), 0, G - 1)
    iy = jnp.clip((pos[:, 1] / cell).astype(jnp.int32), 0, G - 1)
    cid = ix * G + iy
    order = jnp.argsort(cid)                       # node ids sorted by cell
    scid = cid[order]
    cells = jnp.arange(G * G, dtype=cid.dtype)
    starts = jnp.searchsorted(scid, cells)
    ends = jnp.searchsorted(scid, cells, side="right")

    window = jnp.arange(cap)
    cand_parts, ok_parts = [], []
    for dx in (-1, 0, 1):
        for dy in (-1, 0, 1):
            cx, cy = ix + dx, iy + dy
            in_grid = (cx >= 0) & (cx < G) & (cy >= 0) & (cy < G)
            c = jnp.clip(cx, 0, G - 1) * G + jnp.clip(cy, 0, G - 1)
            s, e = starts[c], ends[c]              # [N] bucket slices
            slot = s[:, None] + window[None, :]    # [N, cap]
            ok = in_grid[:, None] & (slot < e[:, None])
            cand_parts.append(order[jnp.clip(slot, 0, n - 1)])
            ok_parts.append(ok)
    cand = jnp.concatenate(cand_parts, axis=1)     # [N, 9·cap]
    ok = jnp.concatenate(ok_parts, axis=1)

    d2 = jnp.sum(jnp.square(pos[:, None, :] - pos[cand]), axis=-1)
    ok &= cand != jnp.arange(n)[:, None]           # never your own neighbor
    ok &= d2 <= jnp.float32(r * r)                 # candidate-radius cut
    score = jnp.where(ok, d2, jnp.inf)
    neg_d2, sel = jax.lax.top_k(-score, k)         # k smallest distances
    # oob: `sel` comes from top_k over the candidate axis, always
    # in-range; fill mode is take_along_axis's default (J003)
    nbr = jnp.take_along_axis(cand, sel, axis=1)
    valid = neg_d2 > -jnp.inf
    # canonical ascending-id order (invalid slots last): argmin/argmax
    # tie-breaks over the K axis then match dense lowest-index-wins
    key = jnp.where(valid, nbr, n)
    perm = jnp.argsort(key, axis=1)
    # oob: `perm` is an argsort permutation, in-range by construction
    nbr = jnp.take_along_axis(nbr, perm, axis=1)
    valid = jnp.take_along_axis(valid, perm, axis=1)
    return jnp.where(valid, nbr, 0).astype(jnp.int32), valid


def mask_neighbors(valid: jax.Array, nbr: jax.Array, alive: jax.Array
                   ) -> jax.Array:
    """Sparse twin of ``scenario.mask_adjacency``: down nodes have no links
    in either direction.  valid/nbr [N, K], alive [N] → [N, K]."""
    return valid & alive[:, None] & alive[nbr]
