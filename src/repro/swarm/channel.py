"""Communication models (paper §3.2 + DESIGN.md §3.4): pathloss → SNR
(Eq. 4) → Shannon capacity (Eq. 3) → one-hop adjacency (Eq. 9).

The pathloss stage is pluggable.  Every model exposes

    pathloss_db(key, dist_m [N,N], cfg) -> [N,N] dB

(the key feeds stochastic models — log-normal shadowing redraws per epoch;
deterministic models ignore it) and is selected by name through
``swarm/scenario.py``'s channel registry.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import SwarmConfig


def pairwise_distance(pos: jax.Array) -> jax.Array:
    """pos [N, 2] metres -> [N, N] distances (diag = 0)."""
    d = pos[:, None, :] - pos[None, :, :]
    return jnp.sqrt(jnp.sum(jnp.square(d), axis=-1) + 1e-9)


# ---------------------------------------------------------------------------
# pathloss models
# ---------------------------------------------------------------------------


def two_ray_pathloss_db(dist_m: jax.Array, h_tx: float, h_rx: float
                        ) -> jax.Array:
    """Two-ray ground-reflection model (Rappaport §4.6), far-field form:
    PL(dB) = 40 log10(d) - 20 log10(h_t·h_r)."""
    d = jnp.maximum(dist_m, 1.0)
    # constant term pinned f32: jnp.log10(python float) is a *strong* f64
    # under x64 and would promote the whole pathloss chain (swarmlint
    # J002).  Pinning — not a host-side math.log10 — keeps the constant
    # bit-identical to the historical f32 computation; the sparse/dense
    # capacity parity tests are sensitive to a 1-ulp shift here.
    return 40.0 * jnp.log10(d) - 20.0 * jnp.log10(jnp.float32(h_tx * h_rx))


def two_ray(key, dist_m: jax.Array, cfg: SwarmConfig) -> jax.Array:
    del key
    return two_ray_pathloss_db(dist_m, cfg.altitude_m, cfg.altitude_m)


def _fspl_1m_db(cfg: SwarmConfig) -> jax.Array:
    """Friis free-space loss at the 1 m reference distance:
    20 log10(f) - 147.55 (c = 3e8, isotropic antennas).  f32-pinned so it
    never sets the chain dtype under x64 (swarmlint J002) while staying
    bit-identical to the historical f32 computation."""
    return 20.0 * jnp.log10(jnp.float32(cfg.carrier_hz)) - 147.55


def free_space(key, dist_m: jax.Array, cfg: SwarmConfig) -> jax.Array:
    """Friis free-space pathloss:
    FSPL(dB) = 20 log10(d) + 20 log10(f) - 147.55."""
    del key
    d = jnp.maximum(dist_m, 1.0)
    return 20.0 * jnp.log10(d) + _fspl_1m_db(cfg)


def _log_distance_db(dist_m: jax.Array, cfg: SwarmConfig) -> jax.Array:
    """Log-distance pathloss baseline shared by the stochastic models:
    PL(dB) = FSPL(1 m) + 10·n·log10(d)."""
    d = jnp.maximum(dist_m, 1.0)
    return _fspl_1m_db(cfg) + 10.0 * cfg.pathloss_exp * jnp.log10(d)


def _mirror_gain(g: jax.Array) -> jax.Array:
    """Symmetrize a per-link power-gain draw: upper triangle mirrored, unit
    gain on the diagonal (the diagonal is masked out of adjacency anyway,
    but keeping it deterministic preserves the key-invariant-diagonal
    contract the shadowing tests rely on)."""
    n = g.shape[-1]
    u = jnp.triu(g, 1)
    return u + jnp.swapaxes(u, -1, -2) + jnp.eye(n, dtype=g.dtype)


def log_normal(key, dist_m: jax.Array, cfg: SwarmConfig) -> jax.Array:
    """Log-distance pathloss with log-normal shadowing:
    PL(dB) = FSPL(1 m) + 10·n·log10(d) + X,  X ~ N(0, σ²) symmetric per
    link (drawn on the upper triangle, mirrored)."""
    base = _log_distance_db(dist_m, cfg)
    n = dist_m.shape[-1]
    z = jax.random.normal(key, (n, n), jnp.float32) * cfg.shadowing_sigma_db
    upper = jnp.triu(z, 1)
    return base + upper + upper.T


def log_normal_corr(key, dist_m: jax.Array, cfg: SwarmConfig) -> jax.Array:
    """Log-distance pathloss with *spatially correlated* log-normal
    shadowing (Gudmundson '91): each node carries a shadowing process that
    decorrelates exponentially over distance, so nearby UAVs see similar
    obstruction — the realistic failure mode where a whole cluster loses
    links together, which iid ``log_normal`` can never produce.

    Node field z ~ N(0, Σ) with Σ_ik = exp(-d_ik / ``shadow_corr_m``)
    (sampled via Cholesky of the jittered covariance); the link value is
    the endpoint sum X_ij = σ (z_i + z_j) / √(2 (1 + ρ_ij)), normalized so
    every off-diagonal link keeps the exact marginal N(0, σ²) of the iid
    model.  Symmetric per link by construction (the endpoint sum *is* the
    mirrored upper triangle), deterministic (zero) on the diagonal,
    redrawn each epoch.  ``shadow_corr_m → 0`` leaves only the shared-
    endpoint correlation of 1/2; large values shadow the swarm as one.
    """
    base = _log_distance_db(dist_m, cfg)
    n = dist_m.shape[-1]
    rho = jnp.exp(-dist_m / jnp.maximum(cfg.shadow_corr_m, 1e-6))
    chol = jnp.linalg.cholesky(rho + 1e-4 * jnp.eye(n, dtype=rho.dtype))
    z = chol @ jax.random.normal(key, (n,), jnp.float32)
    x = (z[:, None] + z[None, :]) / jnp.sqrt(2.0 * (1.0 + rho))
    return base + cfg.shadowing_sigma_db * x * (1.0 - jnp.eye(
        n, dtype=x.dtype))   # dtype-pinned eye: default is f64 under x64


def rician(key, dist_m: jax.Array, cfg: SwarmConfig) -> jax.Array:
    """Log-distance pathloss under Rician small-scale fading (strong LoS —
    the typical UAV-to-UAV air corridor).

    Per-link complex channel h = √(K/(K+1)) + √(1/(K+1))·CN(0, 1) with
    linear K-factor from ``rician_k_db``; E[|h|²] = 1, so the fading only
    redistributes SNR around the log-distance baseline:
    PL(dB) = base - 10·log10(|h|²), symmetric per link (upper triangle
    mirrored), redrawn each epoch.
    """
    base = _log_distance_db(dist_m, cfg)
    n = dist_m.shape[-1]
    K = 10.0 ** (cfg.rician_k_db / 10.0)       # python: weak, J002-safe
    kx, ky = jax.random.split(key)
    s = math.sqrt(1.0 / (2.0 * (K + 1.0)))
    x = math.sqrt(K / (K + 1.0)) + s * jax.random.normal(kx, (n, n),
                                                         jnp.float32)
    y = s * jax.random.normal(ky, (n, n), jnp.float32)
    g = _mirror_gain(x * x + y * y)
    return base - 10.0 * jnp.log10(jnp.maximum(g, 1e-12))


def nakagami(key, dist_m: jax.Array, cfg: SwarmConfig) -> jax.Array:
    """Log-distance pathloss under Nakagami-m fading (generalized envelope:
    m = 1 is Rayleigh, m → ∞ approaches the deterministic baseline).

    The power gain is Gamma(m, 1/m) (unit mean); PL(dB) = base -
    10·log10(g), symmetric per link, redrawn each epoch.
    """
    base = _log_distance_db(dist_m, cfg)
    n = dist_m.shape[-1]
    m = jnp.float32(cfg.nakagami_m)
    g = _mirror_gain(jax.random.gamma(key, m, (n, n), jnp.float32) / m)
    return base - 10.0 * jnp.log10(jnp.maximum(g, 1e-12))


# ---------------------------------------------------------------------------
# per-edge pathloss (sparse neighbor-list path, DESIGN.md §11)
#
# Every model exposes  pathloss_edges_db(key, dist [N,K], src [N,K],
# dst [N,K], cfg) -> [N,K] dB  and is selected through the
# ``scenario.CHANNEL_EDGE_MODELS`` registry.  Deterministic models are the
# exact dense formulas applied elementwise (bit-identical per pair);
# stochastic models replace the dense [N,N] matrix draw with per-edge
# draws keyed on the *unordered* node pair — symmetric by construction and
# identically distributed to the dense marginals, but a different PRNG
# stream, so sparse-vs-dense parity is exact only for the deterministic
# channels.  ``log_normal_corr`` (node-field Cholesky) has no sparse
# counterpart and is deliberately absent from the registry.
# ---------------------------------------------------------------------------


def _edge_normal(key, src, dst, draws: int = 1) -> jax.Array:
    """Per-edge standard normals, symmetric in (src, dst): the edge key is
    the epoch key folded with (min id, max id) — double fold_in rather than
    a flat ``min·N + max`` edge id, which overflows int32 at N = 65,536.
    src/dst [N, K] -> [N, K] (draws=1) or [N, K, draws]."""
    lo = jnp.minimum(src, dst)
    hi = jnp.maximum(src, dst)

    def draw(l, h):
        k = jax.random.fold_in(jax.random.fold_in(key, l), h)
        return jax.random.normal(k, (draws,), jnp.float32)

    z = jax.vmap(jax.vmap(draw))(lo, hi)
    return z[..., 0] if draws == 1 else z


def _edge_gamma(key, src, dst, m) -> jax.Array:
    """Per-edge Gamma(m, 1/m) (unit mean), symmetric in (src, dst)."""
    lo = jnp.minimum(src, dst)
    hi = jnp.maximum(src, dst)

    def draw(l, h):
        k = jax.random.fold_in(jax.random.fold_in(key, l), h)
        return jax.random.gamma(k, m, (), jnp.float32) / m

    return jax.vmap(jax.vmap(draw))(lo, hi)


def two_ray_edges(key, dist_m, src, dst, cfg: SwarmConfig) -> jax.Array:
    del key, src, dst
    return two_ray_pathloss_db(dist_m, cfg.altitude_m, cfg.altitude_m)


def free_space_edges(key, dist_m, src, dst, cfg: SwarmConfig) -> jax.Array:
    del src, dst
    return free_space(key, dist_m, cfg)


def log_normal_edges(key, dist_m, src, dst, cfg: SwarmConfig) -> jax.Array:
    base = _log_distance_db(dist_m, cfg)
    return base + _edge_normal(key, src, dst) * cfg.shadowing_sigma_db


def rician_edges(key, dist_m, src, dst, cfg: SwarmConfig) -> jax.Array:
    base = _log_distance_db(dist_m, cfg)
    K = 10.0 ** (cfg.rician_k_db / 10.0)       # python: weak, J002-safe
    s = math.sqrt(1.0 / (2.0 * (K + 1.0)))
    z = _edge_normal(key, src, dst, draws=2)
    x = math.sqrt(K / (K + 1.0)) + s * z[..., 0]
    y = s * z[..., 1]
    g = x * x + y * y
    return base - 10.0 * jnp.log10(jnp.maximum(g, 1e-12))


def nakagami_edges(key, dist_m, src, dst, cfg: SwarmConfig) -> jax.Array:
    base = _log_distance_db(dist_m, cfg)
    g = _edge_gamma(key, src, dst, jnp.float32(cfg.nakagami_m))
    return base - 10.0 * jnp.log10(jnp.maximum(g, 1e-12))


# ---------------------------------------------------------------------------
# SNR / capacity / adjacency
# ---------------------------------------------------------------------------


def snr_from_pathloss_db(pl_db: jax.Array, cfg: SwarmConfig) -> jax.Array:
    """Eq. 4: SNR_ij = P_i - L(i,j) - N0   (all dB/dBm)."""
    return cfg.tx_power_dbm - pl_db - cfg.noise_dbm


def snr_db(dist_m: jax.Array, cfg: SwarmConfig) -> jax.Array:
    """Eq. 4 under the default two-ray model."""
    return snr_from_pathloss_db(two_ray(None, dist_m, cfg), cfg)


def capacity_bps(snr: jax.Array, cfg: SwarmConfig) -> jax.Array:
    """Eq. 3: C = B log2(1 + 10^(SNR/10))."""
    return cfg.bandwidth_hz * jnp.log2(1.0 + jnp.power(10.0, snr / 10.0))


def link_state(pos: jax.Array, cfg: SwarmConfig, key=None, pathloss_fn=None):
    """Returns (adj [N,N] bool, capacity [N,N] bit/s) at the given positions.

    ``pathloss_fn`` defaults to the two-ray model (the paper baseline);
    ``key`` feeds stochastic pathloss models.  adj masks the diagonal and
    sub-threshold links (Eq. 9); capacity is clamped to a tiny positive
    floor off-link so downstream divisions are safe (those entries are
    never selected through adj).
    """
    if pathloss_fn is None:
        pathloss_fn = two_ray
    dist = pairwise_distance(pos)
    snr = snr_from_pathloss_db(pathloss_fn(key, dist, cfg), cfg)
    n = pos.shape[0]
    eye = jnp.eye(n, dtype=bool)
    adj = (snr >= cfg.snr_min_db) & ~eye
    cap = jnp.where(adj, capacity_bps(snr, cfg), 1.0)
    return adj, cap


def _edge_distance(pos: jax.Array, src: jax.Array, dst: jax.Array
                   ) -> jax.Array:
    """Distances of the gathered (src, dst) pairs — the same ``+1e-9``
    guard as ``pairwise_distance`` so shared pairs are bit-identical."""
    d = pos[src] - pos[dst]
    return jnp.sqrt(jnp.sum(jnp.square(d), axis=-1) + 1e-9)


def link_state_sparse(pos: jax.Array, nbr: jax.Array, valid: jax.Array,
                      cfg: SwarmConfig, key=None, pathloss_fn=None):
    """Neighbor-list twin of ``link_state``: pathloss/SNR/capacity computed
    only on the gathered [N, K] pairs.

    ``pathloss_fn`` is a per-edge model (``*_edges`` above, selected via
    ``scenario.get_channel_edges``).  Returns (adj [N,K] bool, capacity
    [N,K] bit/s) with the same conventions as the dense path: adj folds in
    the validity mask (which already excludes self), capacity floors at
    1.0 off-link.
    """
    if pathloss_fn is None:
        pathloss_fn = two_ray_edges
    n, k = nbr.shape
    src = jnp.broadcast_to(jnp.arange(n)[:, None], (n, k))
    dist = _edge_distance(pos, src, nbr)
    snr = snr_from_pathloss_db(pathloss_fn(key, dist, src, nbr, cfg), cfg)
    adj = valid & (snr >= cfg.snr_min_db)
    cap = jnp.where(adj, capacity_bps(snr, cfg), 1.0)
    return adj, cap


def edge_rate(pos: jax.Array, dst: jax.Array, cfg: SwarmConfig, key=None,
              pathloss_fn=None) -> jax.Array:
    """Per-node link rate toward ``dst`` [N] — the sparse replacement for
    the dense ``cap[rows, tx_dst]`` lookup in transfer progress.

    Same epoch ``key`` and per-edge model as ``link_state_sparse``, so a
    stochastic draw for the pair (i, dst_i) is exactly the draw the
    decision stage saw; same 1.0 floor where the link is below threshold
    or points at self (a stale destination behaves like the dense path's
    floored capacity entry — the transfer stalls until the link returns).
    """
    if pathloss_fn is None:
        pathloss_fn = two_ray_edges
    n = pos.shape[0]
    rows = jnp.arange(n)
    dist = _edge_distance(pos, rows, dst)[:, None]
    snr = snr_from_pathloss_db(
        pathloss_fn(key, dist, rows[:, None], dst[:, None], cfg), cfg)[:, 0]
    ok = (snr >= cfg.snr_min_db) & (dst != rows)
    return jnp.where(ok, capacity_bps(snr, cfg), 1.0)
