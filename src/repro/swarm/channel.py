"""Communication model (paper §3.2): two-ray ground reflection pathloss →
SNR (Eq. 4) → Shannon capacity (Eq. 3) → one-hop adjacency (Eq. 9)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SwarmConfig


def pairwise_distance(pos: jax.Array) -> jax.Array:
    """pos [N, 2] metres -> [N, N] distances (diag = 0)."""
    d = pos[:, None, :] - pos[None, :, :]
    return jnp.sqrt(jnp.sum(jnp.square(d), axis=-1) + 1e-9)


def two_ray_pathloss_db(dist_m: jax.Array, h_tx: float, h_rx: float
                        ) -> jax.Array:
    """Two-ray ground-reflection model (Rappaport §4.6), far-field form:
    PL(dB) = 40 log10(d) - 20 log10(h_t·h_r)."""
    d = jnp.maximum(dist_m, 1.0)
    return 40.0 * jnp.log10(d) - 20.0 * jnp.log10(h_tx * h_rx)


def snr_db(dist_m: jax.Array, cfg: SwarmConfig) -> jax.Array:
    """Eq. 4: SNR_ij = P_i - L(i,j) - N0   (all dB/dBm)."""
    pl = two_ray_pathloss_db(dist_m, cfg.altitude_m, cfg.altitude_m)
    return cfg.tx_power_dbm - pl - cfg.noise_dbm


def capacity_bps(snr: jax.Array, cfg: SwarmConfig) -> jax.Array:
    """Eq. 3: C = B log2(1 + 10^(SNR/10))."""
    return cfg.bandwidth_hz * jnp.log2(1.0 + jnp.power(10.0, snr / 10.0))


def link_state(pos: jax.Array, cfg: SwarmConfig):
    """Returns (adj [N,N] bool, capacity [N,N] bit/s) at the given positions.

    adj masks the diagonal and sub-threshold links (Eq. 9); capacity is
    clamped to a tiny positive floor off-link so downstream divisions are
    safe (those entries are never selected through adj).
    """
    dist = pairwise_distance(pos)
    snr = snr_db(dist, cfg)
    n = pos.shape[0]
    eye = jnp.eye(n, dtype=bool)
    adj = (snr >= cfg.snr_min_db) & ~eye
    cap = jnp.where(adj, capacity_bps(snr, cfg), 1.0)
    return adj, cap
