"""Communication models (paper §3.2 + DESIGN.md §3.4): pathloss → SNR
(Eq. 4) → Shannon capacity (Eq. 3) → one-hop adjacency (Eq. 9).

The pathloss stage is pluggable.  Every model exposes

    pathloss_db(key, dist_m [N,N], cfg) -> [N,N] dB

(the key feeds stochastic models — log-normal shadowing redraws per epoch;
deterministic models ignore it) and is selected by name through
``swarm/scenario.py``'s channel registry.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SwarmConfig


def pairwise_distance(pos: jax.Array) -> jax.Array:
    """pos [N, 2] metres -> [N, N] distances (diag = 0)."""
    d = pos[:, None, :] - pos[None, :, :]
    return jnp.sqrt(jnp.sum(jnp.square(d), axis=-1) + 1e-9)


# ---------------------------------------------------------------------------
# pathloss models
# ---------------------------------------------------------------------------


def two_ray_pathloss_db(dist_m: jax.Array, h_tx: float, h_rx: float
                        ) -> jax.Array:
    """Two-ray ground-reflection model (Rappaport §4.6), far-field form:
    PL(dB) = 40 log10(d) - 20 log10(h_t·h_r)."""
    d = jnp.maximum(dist_m, 1.0)
    return 40.0 * jnp.log10(d) - 20.0 * jnp.log10(h_tx * h_rx)


def two_ray(key, dist_m: jax.Array, cfg: SwarmConfig) -> jax.Array:
    del key
    return two_ray_pathloss_db(dist_m, cfg.altitude_m, cfg.altitude_m)


def _fspl_1m_db(cfg: SwarmConfig) -> jax.Array:
    """Friis free-space loss at the 1 m reference distance:
    20 log10(f) - 147.55 (c = 3e8, isotropic antennas)."""
    return 20.0 * jnp.log10(cfg.carrier_hz) - 147.55


def free_space(key, dist_m: jax.Array, cfg: SwarmConfig) -> jax.Array:
    """Friis free-space pathloss:
    FSPL(dB) = 20 log10(d) + 20 log10(f) - 147.55."""
    del key
    d = jnp.maximum(dist_m, 1.0)
    return 20.0 * jnp.log10(d) + _fspl_1m_db(cfg)


def _log_distance_db(dist_m: jax.Array, cfg: SwarmConfig) -> jax.Array:
    """Log-distance pathloss baseline shared by the stochastic models:
    PL(dB) = FSPL(1 m) + 10·n·log10(d)."""
    d = jnp.maximum(dist_m, 1.0)
    return _fspl_1m_db(cfg) + 10.0 * cfg.pathloss_exp * jnp.log10(d)


def _mirror_gain(g: jax.Array) -> jax.Array:
    """Symmetrize a per-link power-gain draw: upper triangle mirrored, unit
    gain on the diagonal (the diagonal is masked out of adjacency anyway,
    but keeping it deterministic preserves the key-invariant-diagonal
    contract the shadowing tests rely on)."""
    n = g.shape[-1]
    u = jnp.triu(g, 1)
    return u + jnp.swapaxes(u, -1, -2) + jnp.eye(n, dtype=g.dtype)


def log_normal(key, dist_m: jax.Array, cfg: SwarmConfig) -> jax.Array:
    """Log-distance pathloss with log-normal shadowing:
    PL(dB) = FSPL(1 m) + 10·n·log10(d) + X,  X ~ N(0, σ²) symmetric per
    link (drawn on the upper triangle, mirrored)."""
    base = _log_distance_db(dist_m, cfg)
    n = dist_m.shape[-1]
    z = jax.random.normal(key, (n, n), jnp.float32) * cfg.shadowing_sigma_db
    upper = jnp.triu(z, 1)
    return base + upper + upper.T


def log_normal_corr(key, dist_m: jax.Array, cfg: SwarmConfig) -> jax.Array:
    """Log-distance pathloss with *spatially correlated* log-normal
    shadowing (Gudmundson '91): each node carries a shadowing process that
    decorrelates exponentially over distance, so nearby UAVs see similar
    obstruction — the realistic failure mode where a whole cluster loses
    links together, which iid ``log_normal`` can never produce.

    Node field z ~ N(0, Σ) with Σ_ik = exp(-d_ik / ``shadow_corr_m``)
    (sampled via Cholesky of the jittered covariance); the link value is
    the endpoint sum X_ij = σ (z_i + z_j) / √(2 (1 + ρ_ij)), normalized so
    every off-diagonal link keeps the exact marginal N(0, σ²) of the iid
    model.  Symmetric per link by construction (the endpoint sum *is* the
    mirrored upper triangle), deterministic (zero) on the diagonal,
    redrawn each epoch.  ``shadow_corr_m → 0`` leaves only the shared-
    endpoint correlation of 1/2; large values shadow the swarm as one.
    """
    base = _log_distance_db(dist_m, cfg)
    n = dist_m.shape[-1]
    rho = jnp.exp(-dist_m / jnp.maximum(cfg.shadow_corr_m, 1e-6))
    chol = jnp.linalg.cholesky(rho + 1e-4 * jnp.eye(n, dtype=rho.dtype))
    z = chol @ jax.random.normal(key, (n,), jnp.float32)
    x = (z[:, None] + z[None, :]) / jnp.sqrt(2.0 * (1.0 + rho))
    return base + cfg.shadowing_sigma_db * x * (1.0 - jnp.eye(n))


def rician(key, dist_m: jax.Array, cfg: SwarmConfig) -> jax.Array:
    """Log-distance pathloss under Rician small-scale fading (strong LoS —
    the typical UAV-to-UAV air corridor).

    Per-link complex channel h = √(K/(K+1)) + √(1/(K+1))·CN(0, 1) with
    linear K-factor from ``rician_k_db``; E[|h|²] = 1, so the fading only
    redistributes SNR around the log-distance baseline:
    PL(dB) = base - 10·log10(|h|²), symmetric per link (upper triangle
    mirrored), redrawn each epoch.
    """
    base = _log_distance_db(dist_m, cfg)
    n = dist_m.shape[-1]
    K = jnp.power(10.0, cfg.rician_k_db / 10.0)
    kx, ky = jax.random.split(key)
    s = jnp.sqrt(1.0 / (2.0 * (K + 1.0)))
    x = jnp.sqrt(K / (K + 1.0)) + s * jax.random.normal(kx, (n, n),
                                                        jnp.float32)
    y = s * jax.random.normal(ky, (n, n), jnp.float32)
    g = _mirror_gain(x * x + y * y)
    return base - 10.0 * jnp.log10(jnp.maximum(g, 1e-12))


def nakagami(key, dist_m: jax.Array, cfg: SwarmConfig) -> jax.Array:
    """Log-distance pathloss under Nakagami-m fading (generalized envelope:
    m = 1 is Rayleigh, m → ∞ approaches the deterministic baseline).

    The power gain is Gamma(m, 1/m) (unit mean); PL(dB) = base -
    10·log10(g), symmetric per link, redrawn each epoch.
    """
    base = _log_distance_db(dist_m, cfg)
    n = dist_m.shape[-1]
    m = jnp.float32(cfg.nakagami_m)
    g = _mirror_gain(jax.random.gamma(key, m, (n, n), jnp.float32) / m)
    return base - 10.0 * jnp.log10(jnp.maximum(g, 1e-12))


# ---------------------------------------------------------------------------
# SNR / capacity / adjacency
# ---------------------------------------------------------------------------


def snr_from_pathloss_db(pl_db: jax.Array, cfg: SwarmConfig) -> jax.Array:
    """Eq. 4: SNR_ij = P_i - L(i,j) - N0   (all dB/dBm)."""
    return cfg.tx_power_dbm - pl_db - cfg.noise_dbm


def snr_db(dist_m: jax.Array, cfg: SwarmConfig) -> jax.Array:
    """Eq. 4 under the default two-ray model."""
    return snr_from_pathloss_db(two_ray(None, dist_m, cfg), cfg)


def capacity_bps(snr: jax.Array, cfg: SwarmConfig) -> jax.Array:
    """Eq. 3: C = B log2(1 + 10^(SNR/10))."""
    return cfg.bandwidth_hz * jnp.log2(1.0 + jnp.power(10.0, snr / 10.0))


def link_state(pos: jax.Array, cfg: SwarmConfig, key=None, pathloss_fn=None):
    """Returns (adj [N,N] bool, capacity [N,N] bit/s) at the given positions.

    ``pathloss_fn`` defaults to the two-ray model (the paper baseline);
    ``key`` feeds stochastic pathloss models.  adj masks the diagonal and
    sub-threshold links (Eq. 9); capacity is clamped to a tiny positive
    floor off-link so downstream divisions are safe (those entries are
    never selected through adj).
    """
    if pathloss_fn is None:
        pathloss_fn = two_ray
    dist = pairwise_distance(pos)
    snr = snr_from_pathloss_db(pathloss_fn(key, dist, cfg), cfg)
    n = pos.shape[0]
    eye = jnp.eye(n, dtype=bool)
    adj = (snr >= cfg.snr_min_db) & ~eye
    cap = jnp.where(adj, capacity_bps(snr, cfg), 1.0)
    return adj, cap
