"""Communication models (paper §3.2 + DESIGN.md §3.4): pathloss → SNR
(Eq. 4) → Shannon capacity (Eq. 3) → one-hop adjacency (Eq. 9).

The pathloss stage is pluggable.  Every model exposes

    pathloss_db(key, dist_m [N,N], cfg) -> [N,N] dB

(the key feeds stochastic models — log-normal shadowing redraws per epoch;
deterministic models ignore it) and is selected by name through
``swarm/scenario.py``'s channel registry.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SwarmConfig


def pairwise_distance(pos: jax.Array) -> jax.Array:
    """pos [N, 2] metres -> [N, N] distances (diag = 0)."""
    d = pos[:, None, :] - pos[None, :, :]
    return jnp.sqrt(jnp.sum(jnp.square(d), axis=-1) + 1e-9)


# ---------------------------------------------------------------------------
# pathloss models
# ---------------------------------------------------------------------------


def two_ray_pathloss_db(dist_m: jax.Array, h_tx: float, h_rx: float
                        ) -> jax.Array:
    """Two-ray ground-reflection model (Rappaport §4.6), far-field form:
    PL(dB) = 40 log10(d) - 20 log10(h_t·h_r)."""
    d = jnp.maximum(dist_m, 1.0)
    return 40.0 * jnp.log10(d) - 20.0 * jnp.log10(h_tx * h_rx)


def two_ray(key, dist_m: jax.Array, cfg: SwarmConfig) -> jax.Array:
    del key
    return two_ray_pathloss_db(dist_m, cfg.altitude_m, cfg.altitude_m)


def _fspl_1m_db(cfg: SwarmConfig) -> jax.Array:
    """Friis free-space loss at the 1 m reference distance:
    20 log10(f) - 147.55 (c = 3e8, isotropic antennas)."""
    return 20.0 * jnp.log10(cfg.carrier_hz) - 147.55


def free_space(key, dist_m: jax.Array, cfg: SwarmConfig) -> jax.Array:
    """Friis free-space pathloss:
    FSPL(dB) = 20 log10(d) + 20 log10(f) - 147.55."""
    del key
    d = jnp.maximum(dist_m, 1.0)
    return 20.0 * jnp.log10(d) + _fspl_1m_db(cfg)


def log_normal(key, dist_m: jax.Array, cfg: SwarmConfig) -> jax.Array:
    """Log-distance pathloss with log-normal shadowing:
    PL(dB) = FSPL(1 m) + 10·n·log10(d) + X,  X ~ N(0, σ²) symmetric per
    link (drawn on the upper triangle, mirrored)."""
    d = jnp.maximum(dist_m, 1.0)
    base = _fspl_1m_db(cfg) + 10.0 * cfg.pathloss_exp * jnp.log10(d)
    n = dist_m.shape[-1]
    z = jax.random.normal(key, (n, n), jnp.float32) * cfg.shadowing_sigma_db
    upper = jnp.triu(z, 1)
    return base + upper + upper.T


# ---------------------------------------------------------------------------
# SNR / capacity / adjacency
# ---------------------------------------------------------------------------


def snr_from_pathloss_db(pl_db: jax.Array, cfg: SwarmConfig) -> jax.Array:
    """Eq. 4: SNR_ij = P_i - L(i,j) - N0   (all dB/dBm)."""
    return cfg.tx_power_dbm - pl_db - cfg.noise_dbm


def snr_db(dist_m: jax.Array, cfg: SwarmConfig) -> jax.Array:
    """Eq. 4 under the default two-ray model."""
    return snr_from_pathloss_db(two_ray(None, dist_m, cfg), cfg)


def capacity_bps(snr: jax.Array, cfg: SwarmConfig) -> jax.Array:
    """Eq. 3: C = B log2(1 + 10^(SNR/10))."""
    return cfg.bandwidth_hz * jnp.log2(1.0 + jnp.power(10.0, snr / 10.0))


def link_state(pos: jax.Array, cfg: SwarmConfig, key=None, pathloss_fn=None):
    """Returns (adj [N,N] bool, capacity [N,N] bit/s) at the given positions.

    ``pathloss_fn`` defaults to the two-ray model (the paper baseline);
    ``key`` feeds stochastic pathloss models.  adj masks the diagonal and
    sub-threshold links (Eq. 9); capacity is clamped to a tiny positive
    floor off-link so downstream divisions are safe (those entries are
    never selected through adj).
    """
    if pathloss_fn is None:
        pathloss_fn = two_ray
    dist = pairwise_distance(pos)
    snr = snr_from_pathloss_db(pathloss_fn(key, dist, cfg), cfg)
    n = pos.shape[0]
    eye = jnp.eye(n, dtype=bool)
    adj = (snr >= cfg.snr_min_db) & ~eye
    cap = jnp.where(adj, capacity_bps(snr, cfg), 1.0)
    return adj, cap
