"""Vectorized time-stepped swarm simulator (paper §5 environment).

One simulation = ``lax.scan`` over decision epochs (Δt = 200 ms); each epoch
refreshes the scenario (mobility → positions, channel → adjacency/capacity,
fault → alive mask), runs the offloading strategy's decision rule once
(Alg. 1), then an inner scan over fine ticks (default 10 ms) advances
compute, transfers and Markov task arrivals.  The whole thing jits and
``vmap``s over Monte-Carlo runs (50 per the paper).

This module is only the scan skeleton + strategy dispatch; the parts live in
  * ``swarm/scenario.py`` — mobility/channel/fault registries + arrivals,
  * ``swarm/queues.py``   — struct-of-arrays task-queue ops,
  * ``swarm/transfer.py`` — transfer initiate/progress/deliver,
and the epoch φ update dispatches through ``kernels/ops.diffusive_phi``
(Pallas on TPU, jnp reference elsewhere) via ``core.diffusive.phi_update_op``.

Strategies (paper §5): 0 LocalOnly · 1 Random · 2 RandomAcyclic · 3 Greedy ·
4 Distributed (ours, diffusive φ).  The strategy id is a *traced* scalar so
all five share one executable; the scenario is *static* config, so sweeping
scenarios costs one compile per (cfg, n) pair and zero code edits.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import SwarmConfig
from repro.core.decision import transfer_decision, transfer_decision_sparse
from repro.core.diffusive import phi_update_op, phi_update_op_sparse
from repro.core.early_exit import (congestion_update, exit_accuracy,
                                   exit_boundary_layers, exit_label)
from repro.core.early_exit import CongestionState
from repro.swarm import transfer as transfer_mod
from repro.swarm.channel import edge_rate, link_state, link_state_sparse
from repro.swarm.neighbors import mask_neighbors, neighbor_lists
from repro.swarm.queues import head_slot, push, queued_gflops
from repro.swarm.scenario import (burst_arrivals, get_channel,
                                  get_channel_edges, get_fault,
                                  get_mobility, mask_adjacency)
from repro.swarm.tasks import TaskProfile, make_profile
from repro.trace import record as trace_record

BIG = 1e30

LOCAL_ONLY, RANDOM, RANDOM_ACYCLIC, GREEDY, DISTRIBUTED = range(5)
STRATEGY_NAMES = ("LocalOnly", "Random", "RandomAcyclic", "Greedy",
                  "Distributed")


# ---------------------------------------------------------------------------
# state
# ---------------------------------------------------------------------------


def init_state(key, cfg: SwarmConfig, n: int) -> Dict:
    Q = cfg.queue_slots
    # one split, three independent subkeys (R001): the init key used to be
    # dual-derived — split(key) for capability/mobility AND fold_in(key, 7)
    # for faults, two sink families off one threefry counter.  The default-
    # scenario streams this moves are pinned by test_default_scenario_rng_pin.
    kf, km, k_fault = jax.random.split(key, 3)
    F = jnp.maximum(
        cfg.capability_mean
        + cfg.capability_std * jax.random.normal(kf, (n,), jnp.float32),
        50.0)
    return {
        "mob": get_mobility(cfg).init(km, cfg, n),
        "alive": get_fault(cfg).init(k_fault, cfg, n),
        "F": F,
        # queues (struct-of-arrays)
        "q_active": jnp.zeros((n, Q), bool),
        "q_cum": jnp.zeros((n, Q), jnp.float32),
        "q_created": jnp.zeros((n, Q), jnp.float32),
        "q_seq": jnp.zeros((n, Q), jnp.int32),
        "q_visited": jnp.zeros((n, Q, n), bool),
        "seq_counter": jnp.int32(0),
        # single outgoing transfer per node (§3.2)
        "tx_active": jnp.zeros((n,), bool),
        "tx_dst": jnp.zeros((n,), jnp.int32),
        "tx_bits": jnp.zeros((n,), jnp.float32),
        "tx_cum": jnp.zeros((n,), jnp.float32),
        "tx_created": jnp.zeros((n,), jnp.float32),
        "tx_visited": jnp.zeros((n, n), bool),
        "tx_start": jnp.zeros((n,), jnp.float32),
        # protocol state
        "phi": F,
        "cong_prev": jnp.zeros((n,), jnp.float32),
        "cong_D": jnp.zeros((n,), jnp.float32),
        "xi_layers": jnp.full((n,), cfg.exit_points[2], jnp.int32),
        "xi_label": jnp.zeros((n,), jnp.int32),
        # Markov-modulated arrival chain (bursty workload, Fig. 1)
        "burst_on": jnp.zeros((n,), bool),
        # metric accumulators; event *counts* carry as i32 — integer
        # accumulation is exact under any reduction order, so the in-scan
        # cross-node count sums stay bit-identical across the executor
        # backends' different batchings (swarmlint J001, DESIGN.md §8.2)
        "done_count": jnp.int32(0), "lat_sum": jnp.float32(0),
        "acc_sum": jnp.float32(0), "proc_gflops": jnp.zeros((n,), jnp.float32),
        # energy accrues per node, not as a swarm scalar: elementwise
        # accumulation is bit-identical under any batching (vmap, sharded,
        # streaming chunks), whereas an in-scan cross-node sum reassociates
        # with the batch shape and breaks backend parity at the ulp level
        "e_comp": jnp.zeros((n,), jnp.float32),
        "e_tx": jnp.zeros((n,), jnp.float32),
        "tx_count": jnp.int32(0), "tx_delivered": jnp.int32(0),
        "tx_time_sum": jnp.float32(0),
        "drop_count": jnp.int32(0), "gen_count": jnp.int32(0),
        # per-task + per-hop telemetry (repro.trace): {} when the
        # capacities are 0, so the untraced state pytree — and every
        # number downstream — is exactly the historical one
        **trace_record.init_trace(cfg, n),
        **trace_record.init_hops(cfg, n),
        **trace_record.init_state_stream(cfg, n),
    }


# ---------------------------------------------------------------------------
# per-tick dynamics
# ---------------------------------------------------------------------------


def _compute_pass(st, budget, targets_cum, t_now, cfg: SwarmConfig):
    """Advance each node's head task by up to `budget` GFLOPs."""
    eJ = cfg.energy_per_gflop_j
    n, Q = st["q_active"].shape
    rows = jnp.arange(n)
    head, has = head_slot(st)
    cur = st["q_cum"][rows, head]
    rem = jnp.maximum(targets_cum - cur, 0.0)
    adv = jnp.where(has, jnp.minimum(budget, rem), 0.0)
    new_cum = cur + adv
    completed = has & (new_cum >= targets_cum - 1e-6)
    lat = t_now - st["q_created"][rows, head]
    acc = exit_accuracy(st["xi_label"], cfg.exit_accuracy)

    st = dict(st)
    # oob: `head` is queues.head_slot's argmin, always in [0, Q); drop
    # mode is the .at[] default, never exercised (J003)
    st["q_cum"] = st["q_cum"].at[rows, head].set(
        jnp.where(has, new_cum, st["q_cum"][rows, head]))
    st["proc_gflops"] = st["proc_gflops"] + adv
    st["e_comp"] = st["e_comp"] + adv * eJ
    # dtype-pinned i32 count (bool sums widen to i64 under x64 — J002)
    st["done_count"] = st["done_count"] + jnp.sum(completed,
                                                  dtype=jnp.int32)
    st["lat_sum"] = st["lat_sum"] + jnp.sum(jnp.where(completed, lat, 0.0))
    st["acc_sum"] = st["acc_sum"] + jnp.sum(jnp.where(completed, acc, 0.0))
    # oob: in-range `head` (argmin), see the q_cum scatter above (J003)
    st["q_active"] = st["q_active"].at[rows, head].set(
        jnp.where(completed, False, st["q_active"][rows, head]))
    if trace_record.enabled(cfg):
        # oob: in-range `head` (argmin); add-where-inactive is masked by
        # adv == 0 on empty queues (J003)
        st["q_energy"] = st["q_energy"].at[rows, head].add(adv * eJ)
        st = trace_record.write_records(
            st, completed, seq=st["q_seq"][rows, head],
            src=st["q_src"][rows, head], dst=rows,
            created_t=st["q_created"][rows, head], completed_t=t_now,
            exit_label=st["xi_label"], layers=st["xi_layers"],
            hops=jnp.sum(st["q_visited"][rows, head], axis=-1),
            energy_j=st["q_energy"][rows, head],
            tx_time_s=st["q_txtime"][rows, head])
    return st, budget - adv


def _tick(st, key, cfg: SwarmConfig, profile: TaskProfile, cap, alive,
          t_now):
    n = st["F"].shape[0]
    tick = cfg.tick_s

    # (a) Markov-modulated arrivals (down nodes don't generate)
    st = dict(st)
    st["burst_on"], arrive = burst_arrivals(st["burst_on"], key, cfg)
    arrive = arrive & alive
    if trace_record.enabled(cfg):
        st = trace_record.traced_push(
            st, arrive, jnp.zeros((n,), jnp.float32),
            jnp.full((n,), t_now), jnp.zeros((n, n), bool),
            src=jnp.arange(n), energy=0.0,
            txtime=0.0, t_now=t_now, cfg=cfg)
    else:
        st = push(st, arrive, jnp.zeros((n,), jnp.float32),
                  jnp.full((n,), t_now), jnp.zeros((n, n), bool))
    st["gen_count"] = st["gen_count"] + jnp.sum(arrive, dtype=jnp.int32)

    # (b) compute (budget cascade x2: finish a task and start the next;
    #     down nodes hold their queues but burn no cycles)
    targets = profile.cum_gflops[jnp.clip(st["xi_layers"], 0,
                                          profile.gflops.shape[0])]
    budget = jnp.where(alive, st["F"] * tick, 0.0)
    for _ in range(2):
        st, budget = _compute_pass(st, budget, targets, t_now, cfg)

    # (c) transfer progress + delivery
    return transfer_mod.progress(st, cap, alive, cfg, t_now)


# ---------------------------------------------------------------------------
# epoch decision (strategy dispatch)
# ---------------------------------------------------------------------------


def _strategy_decision(st, strategy, adj, d_tx, T, key, cfg: SwarmConfig):
    """Returns (do_transfer [N] bool, target [N] i32, phi')."""
    n = st["F"].shape[0]
    k1, k2, k3 = jax.random.split(key, 3)
    head, has = head_slot(st)
    rows = jnp.arange(n)
    has_nbr = jnp.any(adj, axis=1)

    # ---- Distributed (ours): Eqs. 10-13, kernel-dispatched ----------------
    phi = phi_update_op(st["phi"], st["F"], adj, d_tx)
    dec = transfer_decision(T, phi, adj, cfg.gamma)
    dist = (dec.transfer, dec.target)

    # ---- Greedy: least instantaneous load, w.p. p_greedy -----------------
    cand = jnp.where(adj, T[None, :], BIG)
    # target dtypes pinned to i32: argmin/argmax are i64 under x64 and the
    # strategy switch needs branch-identical avals (swarmlint J002)
    g_tgt = jnp.argmin(cand, axis=1).astype(jnp.int32)
    g_less = jnp.min(cand, axis=1) < T
    g_do = (jax.random.bernoulli(k1, cfg.greedy_offload_p, (n,))
            & has_nbr & g_less)
    greedy = (g_do, g_tgt)

    # ---- Random: uniform neighbor, w.p. 0.2 ------------------------------
    # NB: the offload coin must not share k2 with the gumbel target draw —
    # threefry counters would make coin u_j bit-identical to a target score
    # for j, correlating "who offloads" with "who gets picked"
    gum = jax.random.gumbel(k2, (n, n))
    r_tgt = jnp.argmax(jnp.where(adj, gum, -BIG), axis=1).astype(jnp.int32)
    r_do = jax.random.bernoulli(jax.random.fold_in(k2, 1),
                                cfg.random_offload_p, (n,)) & has_nbr
    random_ = (r_do, r_tgt)

    # ---- RandomAcyclic: uniform unvisited neighbor, w.p. 0.1 -------------
    visited_head = st["q_visited"][rows, head]              # [N, N]
    amask = adj & ~visited_head
    a_has = jnp.any(amask, axis=1)
    a_tgt = jnp.argmax(jnp.where(amask, jax.random.gumbel(k3, (n, n)), -BIG),
                       axis=1).astype(jnp.int32)
    a_do = jax.random.bernoulli(jax.random.fold_in(k3, 1),
                                cfg.random_acyclic_p, (n,)) & a_has
    acyc = (a_do, a_tgt)

    local = (jnp.zeros((n,), bool), jnp.zeros((n,), jnp.int32))

    do = jax.lax.switch(strategy, [
        lambda: local[0], lambda: random_[0], lambda: acyc[0],
        lambda: greedy[0], lambda: dist[0]])
    tgt = jax.lax.switch(strategy, [
        lambda: local[1], lambda: random_[1], lambda: acyc[1],
        lambda: greedy[1], lambda: dist[1]])
    return do, tgt, phi


def _strategy_decision_sparse(st, strategy, adj_e, nbr, d_tx_e, T, key,
                              cfg: SwarmConfig):
    """Neighbor-list twin of ``_strategy_decision``: every per-strategy
    reduction runs over the K axis and maps back through ``nbr``.

    Offload coins reuse the dense keys/shapes, so *whether* a node
    offloads is bit-identical to dense; Greedy/Distributed targets match
    too (id-sorted lists preserve the lowest-index tie-break).  Only the
    Random/RandomAcyclic *target* draws differ — their gumbel field is
    per-slot [N, K] instead of per-node-pair [N, N], an intentionally
    different stream (still uniform over the same neighbor sets).
    """
    n = st["F"].shape[0]
    k1, k2, k3 = jax.random.split(key, 3)
    head, has = head_slot(st)
    rows = jnp.arange(n)
    has_nbr = jnp.any(adj_e, axis=1)
    K = nbr.shape[1]

    # ---- Distributed (ours): Eqs. 10-13, kernel-dispatched ----------------
    phi = phi_update_op_sparse(st["phi"], st["F"], adj_e, nbr, d_tx_e)
    dec = transfer_decision_sparse(T, phi, adj_e, nbr, cfg.gamma)
    dist = (dec.transfer, dec.target)

    # ---- Greedy: least instantaneous load, w.p. p_greedy -----------------
    cand = jnp.where(adj_e, T[nbr], BIG)
    g_tgt = nbr[rows, jnp.argmin(cand, axis=1)]
    g_less = jnp.min(cand, axis=1) < T
    g_do = (jax.random.bernoulli(k1, cfg.greedy_offload_p, (n,))
            & has_nbr & g_less)
    greedy = (g_do, g_tgt)

    # ---- Random: uniform neighbor, w.p. 0.2 ------------------------------
    gum = jax.random.gumbel(k2, (n, K))
    r_tgt = nbr[rows, jnp.argmax(jnp.where(adj_e, gum, -BIG), axis=1)]
    r_do = jax.random.bernoulli(jax.random.fold_in(k2, 1),
                                cfg.random_offload_p, (n,)) & has_nbr
    random_ = (r_do, r_tgt)

    # ---- RandomAcyclic: uniform unvisited neighbor, w.p. 0.1 -------------
    # the visited sets stay dense [N, Q, N] (a bitset redesign is ROADMAP
    # work); the epoch cost here is only the [N, K] gather of head rows
    visited_head = st["q_visited"][rows, head]              # [N, N]
    amask = adj_e & ~visited_head[rows[:, None], nbr]
    a_has = jnp.any(amask, axis=1)
    a_tgt = nbr[rows, jnp.argmax(
        jnp.where(amask, jax.random.gumbel(k3, (n, K)), -BIG), axis=1)]
    a_do = jax.random.bernoulli(jax.random.fold_in(k3, 1),
                                cfg.random_acyclic_p, (n,)) & a_has
    acyc = (a_do, a_tgt)

    local = (jnp.zeros((n,), bool), jnp.zeros((n,), jnp.int32))

    do = jax.lax.switch(strategy, [
        lambda: local[0], lambda: random_[0], lambda: acyc[0],
        lambda: greedy[0], lambda: dist[0]])
    tgt = jax.lax.switch(strategy, [
        lambda: local[1], lambda: random_[1], lambda: acyc[1],
        lambda: greedy[1], lambda: dist[1]])
    return do, tgt, phi


def _epoch(st, key, epoch_idx, strategy, cfg: SwarmConfig,
           profile: TaskProfile):
    t0 = epoch_idx.astype(jnp.float32) * cfg.decision_period_s
    # kd/kt reproduce the pre-engine key streams exactly; scenario keys are
    # folded off the epoch key so the default scenario stays bit-identical
    # (except Random/RandomAcyclic, whose key-reuse fix below is deliberate).
    kd, kt = jax.random.split(key)
    k_mob = jax.random.fold_in(key, 11)
    k_ch = jax.random.fold_in(key, 13)
    k_fault = jax.random.fold_in(key, 17)

    # 1. refresh the scenario at epoch start; 2. strategy decision (Alg. 1
    #    lines 2-5).  neighbor_mode is static config, so the branch picks
    #    the compiled representation: dense [N, N] (the historical
    #    bit-exact path) or [N, K] neighbor lists (O(N·k), DESIGN.md §11)
    st = dict(st)
    st["alive"] = get_fault(cfg).step(st["alive"], k_fault, cfg)
    st["mob"], pos = get_mobility(cfg).step(st["mob"], k_mob, cfg, t0)
    T = queued_gflops(st, profile)
    sparse = cfg.neighbor_mode == "sparse"
    if sparse:
        edge_fn = get_channel_edges(cfg)
        nbr, valid = neighbor_lists(pos, cfg)
        valid = mask_neighbors(valid, nbr, st["alive"])
        adj_e, cap_e = link_state_sparse(pos, nbr, valid, cfg, key=k_ch,
                                         pathloss_fn=edge_fn)
        d_tx_e = jnp.where(adj_e, profile.bits_per_gflop / cap_e, BIG)
        do, tgt, phi = _strategy_decision_sparse(st, strategy, adj_e, nbr,
                                                 d_tx_e, T, kd, cfg)
    else:
        adj, cap = link_state(pos, cfg, key=k_ch,
                              pathloss_fn=get_channel(cfg))
        adj = mask_adjacency(adj, st["alive"])
        d_tx = jnp.where(adj, profile.bits_per_gflop / cap, BIG)
        do, tgt, phi = _strategy_decision(st, strategy, adj, d_tx, T, kd,
                                          cfg)
    st["phi"] = phi

    # 3. congestion-aware early exit (Alg. 1 lines 10-11, Eqs. 14-16)
    cong = congestion_update(
        CongestionState(st["cong_prev"], st["cong_D"]), T,
        cfg.decision_period_s, cfg.ema_alpha)
    st["cong_prev"], st["cong_D"] = cong.prev_T, cong.D
    if cfg.early_exit_enabled:
        lbl = exit_label(cong.D, *cfg.exit_thresholds)
    else:
        lbl = jnp.zeros((st["F"].shape[0],), jnp.int32)
    st["xi_label"] = lbl
    st["xi_layers"] = exit_boundary_layers(lbl, cfg.exit_points,
                                           cfg.exit_finalize_layers)

    # 4. initiate transfers: pop head, snap to boundary (§3.1 discard)
    _, has = head_slot(st)
    elig = do & has & ~st["tx_active"] & (tgt >= 0)
    st = transfer_mod.initiate(st, elig, tgt, t0, profile)

    # 5. fine ticks.  tx_dst is frozen between decisions, so the sparse
    #    path resolves each node's outgoing link rate [N] once per epoch
    #    instead of carrying the [N, N] capacity matrix into the scan —
    #    same epoch key, so stochastic draws match the decision stage's
    if sparse:
        link = edge_rate(pos, st["tx_dst"], cfg, key=k_ch,
                         pathloss_fn=edge_fn)
    else:
        link = cap
    n_ticks = int(round(cfg.decision_period_s / cfg.tick_s))

    def tick_body(st, i):
        t_now = t0 + (i.astype(jnp.float32) + 1.0) * cfg.tick_s
        st = _tick(st, jax.random.fold_in(kt, i), cfg, profile, link,
                   st["alive"], t_now)
        return st, None

    st, _ = jax.lax.scan(tick_body, st, jnp.arange(n_ticks))

    # 6. flight recorder: snapshot node gauges + system aggregates at the
    #    end of every trace_state_every-th epoch (DESIGN.md §12)
    if trace_record.state_enabled(cfg):
        st = trace_record.write_state(st, epoch_idx,
                                      t0 + cfg.decision_period_s, cfg)
    return st


# ---------------------------------------------------------------------------
# run + metrics
# ---------------------------------------------------------------------------


def run_sim(key, cfg: SwarmConfig, strategy, n: int | None = None) -> Dict:
    """One full simulation; returns the metric dict (see summarize)."""
    n = n or cfg.num_workers
    profile = make_profile(cfg)
    k_init, k_run = jax.random.split(key)
    st = init_state(k_init, cfg, n)
    n_epochs = int(round(cfg.sim_time_s / cfg.decision_period_s))

    def body(st, i):
        st = _epoch(st, jax.random.fold_in(k_run, i), i, strategy, cfg,
                    profile)
        return st, None

    st, _ = jax.lax.scan(body, st, jnp.arange(n_epochs))
    return summarize(st, cfg, profile)


def summarize(st, cfg: SwarmConfig, profile: TaskProfile) -> Dict:
    # the i32 event counters re-enter float land here, outside the scan:
    # counts are exact in f32 up to 2^24, so every reported metric is
    # bit-identical to the historical f32-accumulator values
    done_f = st["done_count"].astype(jnp.float32)
    done = jnp.maximum(done_f, 1.0)
    rem_q = queued_gflops(st, profile)
    rem_tx = jnp.where(st["tx_active"],
                       profile.total_gflops - st["tx_cum"], 0.0)
    # Jain fairness over capability-normalized processed GFLOPs (Fig. 4d)
    x = st["proc_gflops"] / st["F"]
    jain = (jnp.sum(x) ** 2) / (x.shape[0] * jnp.sum(x * x) + 1e-12)
    tps = done_f / cfg.sim_time_s
    acc = st["acc_sum"] / done
    # single cross-node reduction, outside the scan (see init_state note)
    e_total = jnp.sum(st["e_comp"] + st["e_tx"])
    ae = e_total / done
    al = st["lat_sum"] / done
    fom = tps * acc / jnp.maximum(ae * al, 1e-12)
    out = {
        "completed": done_f,
        "generated": st["gen_count"].astype(jnp.float32),
        "avg_latency_s": al, "avg_accuracy": acc,
        "remaining_gflops": jnp.sum(rem_q) + jnp.sum(rem_tx),
        # mean over *delivered* transfers: tx_time_sum only accumulates at
        # delivery, so dividing by initiations (tx_count) would bias the
        # mean low whenever transfers are still in flight at sim end
        "avg_transfer_time_s": st["tx_time_sum"]
        / jnp.maximum(st["tx_delivered"].astype(jnp.float32), 1.0),
        "transfers": st["tx_count"].astype(jnp.float32),
        "transfers_delivered": st["tx_delivered"].astype(jnp.float32),
        "jain_fairness": jain,
        "energy_per_task_j": ae,
        "energy_total_j": e_total,
        "throughput_tps": tps,
        "dropped": st["drop_count"].astype(jnp.float32),
        "fom": fom,
    }
    if trace_record.enabled(cfg):
        # per-task telemetry rides next to the scalar metrics; downstream
        # consumers key off the trace_ prefix (report skips ci95 for them,
        # decode/aggregate turn them into task-level indices)
        out["trace_records"] = st["trace_records"]
        out["trace_overflow"] = st["trace_overflow"]
    if trace_record.hops_enabled(cfg):
        # the per-hop stream, same conventions (trace_ prefix, decoded
        # into hop-resolved indices by trace.decode_hops/hop_indices)
        out["trace_hops"] = st["trace_hops"]
        out["trace_hop_overflow"] = st["trace_hop_overflow"]
    if trace_record.state_enabled(cfg):
        # the epoch-indexed flight recorder (decode_state/state_indices)
        out["trace_state"] = st["trace_state"]
        out["trace_state_sys"] = st["trace_state_sys"]
        out["trace_state_epochs"] = st["trace_state_epochs"]
    return out


def run_many(key, cfg: SwarmConfig, strategy, n: int, num_runs: int) -> Dict:
    """vmap over Monte-Carlo runs; returns dict of [num_runs] arrays.

    Routed through ``repro.fleet.executor`` (the ``vmap`` backend is the
    historical jitted-vmap path, bit-identical), so the simulator and the
    fleet sweep engine share one batching implementation.  For multi-device
    or memory-bounded batching call ``fleet.run_batch`` with
    ``backend="sharded"`` / ``"streaming"`` instead.
    """
    from repro.fleet.executor import run_batch  # deferred: no import cycle
    return run_batch(key, cfg, strategy, n, num_runs, backend="vmap")
