"""ML task model (paper §3.1 + Fig. 1 lower panel).

A task is an L-layer sequential DAG (vertical split points at every layer
boundary).  The illustrative profile is a detection-CNN shape: GFLOPs
front-loaded, activation sizes decaying from feature-map scale to
vector scale.  Exit points at [15, 30, 60] with +3 finalize layers
(Table 2).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SwarmConfig


class TaskProfile(NamedTuple):
    gflops: jax.Array        # [L] per-layer GFLOPs
    cum_gflops: jax.Array    # [L+1] cumulative (cum[0] = 0)
    act_bits: jax.Array      # [L+1] activation size crossing boundary l
                             # (act_bits[0] = raw input)
    bits_per_gflop: float    # mean activation bits per GFLOP (for d_tx)
    total_gflops: float


def make_profile(cfg: SwarmConfig) -> TaskProfile:
    L = cfg.task_layers
    # GFLOPs: linear decay 2 -> 0.5 (conv backbone heavier than head)
    w = np.linspace(2.0, 0.5, L)
    g = w / w.sum() * cfg.task_gflops_total
    cum = np.concatenate([[0.0], np.cumsum(g)])
    # activations: raw input ~0.5 MB; feature maps decay 2 MB -> 64 KB
    act_bytes = np.concatenate([
        [0.5e6], np.geomspace(2.0e6, 64e3, L)])
    act_bits = act_bytes * 8.0
    bits_per_gflop = float(act_bits[1:].mean()) / float(g.mean())
    return TaskProfile(
        gflops=jnp.asarray(g, jnp.float32),
        cum_gflops=jnp.asarray(cum, jnp.float32),
        act_bits=jnp.asarray(act_bits, jnp.float32),
        bits_per_gflop=bits_per_gflop,
        total_gflops=float(cfg.task_gflops_total),
    )


def layer_of(profile: TaskProfile, cum_done: jax.Array) -> jax.Array:
    """Last *completed* layer boundary for a progress value (partial layer
    work does not count — §3.1 discard-on-offload)."""
    return jnp.searchsorted(profile.cum_gflops, cum_done, side="right") - 1


def boundary_bits(profile: TaskProfile, cum_done: jax.Array) -> jax.Array:
    """Bits that must be shipped when offloading at the current boundary."""
    lyr = jnp.clip(layer_of(profile, cum_done), 0, profile.act_bits.shape[0] - 1)
    return profile.act_bits[lyr]


def snap_to_boundary(profile: TaskProfile, cum_done: jax.Array) -> jax.Array:
    """Discard partial-layer progress (§3.1)."""
    lyr = jnp.clip(layer_of(profile, cum_done), 0,
                   profile.cum_gflops.shape[0] - 1)
    return profile.cum_gflops[lyr]
