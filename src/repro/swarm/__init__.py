from repro.swarm.neighbors import (comm_range_m, grid_geometry,
                                   mask_neighbors, neighbor_lists)
from repro.swarm.scenario import (CHANNEL_EDGE_MODELS, CHANNEL_MODELS,
                                  FAULT_MODELS, MOBILITY_MODELS, get_channel,
                                  get_channel_edges, get_fault, get_mobility,
                                  mask_adjacency, register_channel,
                                  register_channel_edges, register_fault,
                                  register_mobility)
from repro.swarm.simulator import (DISTRIBUTED, GREEDY, LOCAL_ONLY, RANDOM,
                                   RANDOM_ACYCLIC, STRATEGY_NAMES, run_many,
                                   run_sim)
from repro.swarm.tasks import TaskProfile, make_profile

__all__ = ["run_sim", "run_many", "make_profile", "TaskProfile",
           "LOCAL_ONLY", "RANDOM", "RANDOM_ACYCLIC", "GREEDY", "DISTRIBUTED",
           "STRATEGY_NAMES",
           "MOBILITY_MODELS", "CHANNEL_MODELS", "CHANNEL_EDGE_MODELS",
           "FAULT_MODELS",
           "register_mobility", "register_channel", "register_channel_edges",
           "register_fault", "get_mobility", "get_channel",
           "get_channel_edges", "get_fault", "mask_adjacency",
           "neighbor_lists", "mask_neighbors", "comm_range_m",
           "grid_geometry"]
