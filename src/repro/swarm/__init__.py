from repro.swarm.simulator import (DISTRIBUTED, GREEDY, LOCAL_ONLY, RANDOM,
                                   RANDOM_ACYCLIC, STRATEGY_NAMES, run_many,
                                   run_sim)
from repro.swarm.tasks import TaskProfile, make_profile

__all__ = ["run_sim", "run_many", "make_profile", "TaskProfile",
           "LOCAL_ONLY", "RANDOM", "RANDOM_ACYCLIC", "GREEDY", "DISTRIBUTED",
           "STRATEGY_NAMES"]
