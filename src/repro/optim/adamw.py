"""AdamW + global-norm clip + cosine schedule, pure JAX (no optax).

Optimizer state shards exactly like the parameters (same PartitionSpecs),
which is what makes the ZeRO-style FSDP layout work: params, m and v are
all fully sharded over ('data'[, 'pod']) × 'model'.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jax.Array     # [] int32
    m: Any              # like params
    v: Any              # like params


def init_opt(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    return OptState(jnp.zeros((), jnp.int32), zeros,
                    jax.tree.map(jnp.copy, zeros))


def opt_specs(param_specs) -> OptState:
    """PartitionSpec tree matching OptState (m/v shard like params)."""
    from jax.sharding import PartitionSpec as P
    return OptState(P(), param_specs, param_specs)


def schedule(cfg: OptConfig, step) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def apply_updates(params, grads, state: OptState, cfg: OptConfig
                  ) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(step, new_m, new_v), {
        "grad_norm": gn, "lr": lr}
