from repro.optim.adamw import (OptConfig, OptState, apply_updates,
                               clip_by_global_norm, global_norm, init_opt,
                               opt_specs, schedule)

__all__ = ["OptConfig", "OptState", "init_opt", "opt_specs", "apply_updates",
           "schedule", "global_norm", "clip_by_global_norm"]
