"""R002 — store-digest completeness.

The content-addressed store (``fleet/store.py``) addresses a sweep point
by the SHA-256 of everything that determines its numbers.  A config field
that affects the computed metrics but does not reach
:func:`point_digest` aliases distinct results onto one cache key — PR 4
hit exactly this when ``trace_capacity`` first landed outside the digest
and traced/untraced runs collided.

The rule checks, per tree:

  * every ``SwarmConfig`` field is digest-covered.  Coverage is either
    *wholesale* (``dataclasses.asdict(point.cfg)`` anywhere in
    ``point_digest`` — the shipped design, which makes new fields covered
    by construction) or *explicit* (``point.cfg.<field>`` accesses, for
    trees that enumerate fields by hand);
  * every ``SweepSpec`` field maps into the digest payload: ``base`` via
    the cfg blob, ``strategies`` via the per-point ``strategy`` entry,
    the rest by payload key name;
  * fields that are deliberately excluded appear in the
    ``[[digest_exempt]]`` table of ``analysis_baseline.toml`` with a
    reason.  Exemptions are validated live: an entry naming a field that
    no longer exists, a ``function.param`` that is gone, or a field that
    is in fact covered (shadowed exemption) is itself a finding — the
    table cannot rot.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from repro.analysis.astutil import Finding, Tree, dotted_name

RULE = "R002"
# SweepSpec fields that enter the digest under a different payload name
_SWEEP_ALIASES = {"base": "cfg", "strategies": "strategy"}


def _dataclass_fields(cls: ast.ClassDef) -> List[Tuple[str, int]]:
    return [(st.target.id, st.lineno) for st in cls.body
            if isinstance(st, ast.AnnAssign)
            and isinstance(st.target, ast.Name)]


def _find_class(tree: Tree, name: str):
    for mod in tree.src_modules():
        for node in mod.tree.body:
            if isinstance(node, ast.ClassDef) and node.name == name:
                return mod, node
    return None, None


def _find_function(tree: Tree, name: str):
    for mod in tree.src_modules():
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name == name:
                return mod, node
    return None, None


def _digest_coverage(fn: ast.AST) -> Tuple[bool, Set[str], Set[str]]:
    """(wholesale-cfg-coverage?, explicit cfg fields, payload keys)."""
    wholesale = False
    explicit: Set[str] = set()
    payload: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            name = dotted_name(node.func) or ""
            if name.split(".")[-1] == "asdict":
                for a in node.args:
                    if (dotted_name(a) or "").endswith(".cfg"):
                        wholesale = True
        if isinstance(node, ast.Attribute):
            chain = dotted_name(node)
            if chain and ".cfg." in f".{chain}.":
                tail = chain.split(".cfg.", 1)
                if len(tail) == 2 and tail[1] and "." not in tail[1]:
                    explicit.add(tail[1])
        if isinstance(node, ast.Dict):
            for k in node.keys:
                if isinstance(k, ast.Constant) and isinstance(k.value, str):
                    payload.add(k.value)
    return wholesale, explicit, payload


def check(tree: Tree, baseline=None) -> List[Finding]:
    findings: List[Finding] = []
    exempt: Dict[str, str] = dict(baseline.digest_exempt) if baseline else {}

    cfg_mod, cfg_cls = _find_class(tree, "SwarmConfig")
    spec_mod, spec_cls = _find_class(tree, "SweepSpec")
    dig_mod, dig_fn = _find_function(tree, "point_digest")
    if cfg_cls is None or dig_fn is None:
        return findings     # not a tree that carries the store contract

    wholesale, explicit, payload = _digest_coverage(dig_fn)
    seen_exempt: Set[str] = set()

    def covered_cfg(field: str) -> bool:
        return wholesale or field in explicit

    for field, line in _dataclass_fields(cfg_cls):
        tag = f"SwarmConfig.{field}"
        if covered_cfg(field):
            if tag in exempt:
                findings.append(Finding(
                    RULE, cfg_mod.path, line, tag,
                    f"shadowed exemption: {tag} is exempted in the "
                    "baseline but actually reaches point_digest — drop "
                    "the stale entry"))
                seen_exempt.add(tag)
            continue
        if tag in exempt:
            seen_exempt.add(tag)
            continue
        findings.append(Finding(
            RULE, cfg_mod.path, line, tag,
            f"SwarmConfig.{field} never reaches point_digest and has no "
            "[[digest_exempt]] entry — distinct configs would alias onto "
            "one cache key (the PR 4 trace_capacity bug class)"))

    if spec_cls is not None:
        for field, line in _dataclass_fields(spec_cls):
            tag = f"SweepSpec.{field}"
            key = _SWEEP_ALIASES.get(field, field)
            cov = (key in payload or (field == "base" and wholesale))
            if cov:
                if tag in exempt:
                    findings.append(Finding(
                        RULE, spec_mod.path, line, tag,
                        f"shadowed exemption: {tag} reaches the digest "
                        "payload — drop the stale entry"))
                    seen_exempt.add(tag)
                continue
            if tag in exempt:
                seen_exempt.add(tag)
                continue
            findings.append(Finding(
                RULE, spec_mod.path, line, tag,
                f"SweepSpec.{field} is not digest-covered (no payload key "
                f"{key!r}) and has no [[digest_exempt]] entry"))

    # validate the remaining exemptions: each must name a live field or a
    # live function parameter ("run_batch.backend")
    for tag in sorted(set(exempt) - seen_exempt):
        head, _, attr = tag.partition(".")
        if head in ("SwarmConfig", "SweepSpec"):
            anchor = cfg_mod if head == "SwarmConfig" else spec_mod
            findings.append(Finding(
                RULE, anchor.path if anchor else "analysis_baseline.toml",
                1, tag,
                f"stale exemption: {tag} names no current {head} field"))
            continue
        fmod, ffn = _find_function(tree, head)
        params = ({a.arg for a in ffn.args.args} | {a.arg for a in
                  ffn.args.kwonlyargs}) if ffn is not None else set()
        if ffn is None or attr not in params:
            findings.append(Finding(
                RULE, "analysis_baseline.toml", 1, tag,
                f"stale exemption: {tag} matches neither a config field "
                "nor a live function parameter"))
    return findings
