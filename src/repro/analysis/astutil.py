"""Shared AST plumbing for the swarmlint rules.

A :class:`Tree` is a parsed snapshot of one repository (or fixture mini-
repo): every ``.py`` file under the scanned directories as an
``ast.Module`` plus the raw text of ``DESIGN.md``.  Rules never read the
filesystem themselves — they work off the tree, which is what lets the
fixture tests under ``tests/analysis_fixtures/`` run each rule against a
tiny synthetic repo with the exact same code path as the real one.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Dict, Iterator, List, Optional, Tuple

# directories that never contain rule subjects (fixtures are deliberately
# broken; artifacts/caches are not code)
SKIP_DIRS = {"analysis_fixtures", "artifacts", "__pycache__", ".git",
             ".claude", "node_modules"}
SCAN_DIRS = ("src", "tests", "benchmarks", "examples")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str          # "R001" … "R004"
    file: str          # repo-relative posix path
    line: int
    symbol: str        # rule-specific anchor, e.g. "init_state:key"
    message: str

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Module:
    path: str          # repo-relative posix path
    tree: ast.Module
    source: str


class Tree:
    """Parsed repo snapshot: ``.py`` modules + DESIGN.md text."""

    def __init__(self, root: str, modules: Dict[str, Module],
                 texts: Dict[str, str]):
        self.root = root
        self.modules = modules
        self._texts = texts

    @classmethod
    def load(cls, root: str) -> "Tree":
        root = os.path.abspath(root)
        modules: Dict[str, Module] = {}
        for base in SCAN_DIRS:
            top = os.path.join(root, base)
            if not os.path.isdir(top):
                continue
            for dirpath, dirnames, filenames in os.walk(top):
                dirnames[:] = [d for d in sorted(dirnames)
                               if d not in SKIP_DIRS]
                for fn in sorted(filenames):
                    if not fn.endswith(".py"):
                        continue
                    full = os.path.join(dirpath, fn)
                    rel = os.path.relpath(full, root).replace(os.sep, "/")
                    try:
                        with open(full, encoding="utf-8") as f:
                            src = f.read()
                        modules[rel] = Module(rel, ast.parse(src), src)
                    except (SyntaxError, UnicodeDecodeError, OSError):
                        continue   # unparseable files are ruff's problem
        texts = {}
        for doc in ("DESIGN.md",):
            p = os.path.join(root, doc)
            if os.path.isfile(p):
                with open(p, encoding="utf-8") as f:
                    texts[doc] = f.read()
        return cls(root, modules, texts)

    def text(self, name: str) -> Optional[str]:
        return self._texts.get(name)

    def src_modules(self) -> Iterator[Module]:
        """Modules under ``src/`` — the rule *subjects* (tests and
        benchmarks are evidence, not subjects)."""
        for path, mod in self.modules.items():
            if path.startswith("src/"):
                yield mod

    def test_sources(self) -> str:
        """Concatenated raw text of every test module (R004 evidence)."""
        return "\n".join(m.source for p, m in sorted(self.modules.items())
                         if p.startswith("tests/"))


# ---------------------------------------------------------------------------
# import/alias resolution
# ---------------------------------------------------------------------------


def import_table(mod: ast.Module) -> Dict[str, str]:
    """Maps local name -> dotted origin for a module's imports.

    ``import numpy as np``            -> {"np": "numpy"}
    ``import jax.random as jr``       -> {"jr": "jax.random"}
    ``import time``                   -> {"time": "time"}
    ``from time import time``         -> {"time": "time.time"}
    ``from repro.trace import record as tr`` -> {"tr": "repro.trace.record"}
    """
    table: Dict[str, str] = {}
    for node in ast.walk(mod):
        if isinstance(node, ast.Import):
            for alias in node.names:
                table[alias.asname or alias.name.split(".")[0]] = (
                    alias.name if alias.asname else alias.name.split(".")[0])
                if alias.asname:
                    table[alias.asname] = alias.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            for alias in node.names:
                table[alias.asname or alias.name] = (
                    f"{node.module}.{alias.name}")
    return table


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` attribute/name chain as a dotted string, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def resolve_call(node: ast.Call, imports: Dict[str, str]) -> Optional[str]:
    """Fully-qualified dotted name of a call target, import-resolved.

    ``np.random.default_rng(...)`` with ``import numpy as np`` resolves to
    ``numpy.random.default_rng``; plain builtins resolve to themselves.
    """
    name = dotted_name(node.func)
    if name is None:
        return None
    head, _, rest = name.partition(".")
    origin = imports.get(head)
    if origin is None:
        return name
    return f"{origin}.{rest}" if rest else origin


def functions(mod: ast.Module) -> Dict[str, ast.AST]:
    """{qualname: FunctionDef} for module-level functions and methods
    (methods as ``Class.method``)."""
    out: Dict[str, ast.AST] = {}
    for node in mod.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out[node.name] = node
        elif isinstance(node, ast.ClassDef):
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    out[f"{node.name}.{sub.name}"] = sub
    return out


def docstrings(mod: ast.Module) -> Iterator[Tuple[int, str]]:
    """(line, text) of every docstring in the module."""
    for node in ast.walk(mod):
        if isinstance(node, (ast.Module, ast.ClassDef, ast.FunctionDef,
                             ast.AsyncFunctionDef)):
            doc = ast.get_docstring(node, clean=False)
            if doc:
                first = node.body[0]
                yield first.lineno, doc
