"""``python -m repro.analysis`` — run swarmlint over a repo tree.

Exit status 0 when the tree is clean (after the baseline), 1 when any
finding survives, 2 on usage/configuration errors.  ``--tier`` selects
the AST rules (R…, default — fast and jax-free), the jaxpr rules (J…,
trace the real programs; DESIGN.md §15), or both.  ``--format json``
emits one machine-readable document (findings + counts) for CI tooling;
``--format sarif`` emits SARIF 2.1.0 for code-scanning upload; the
default text format is one ``file:line: RULE symbol message`` row per
finding, grep- and editor-friendly.  ``--prune-baseline`` rewrites
``analysis_baseline.toml`` in place, dropping ``[[allow]]`` entries whose
finding no longer fires (dead entries would mask a future regression at
the same site).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.analysis import (ALL_RULE_IDS, JAXPR_RULE_IDS, RULE_DOCS, RULES,
                            TIERS, run)
from repro.analysis.baseline import (BASELINE_NAME, load_baseline,
                                     prune_baseline)


def _detect_root(start: str) -> str:
    """Walk up from ``start`` to the nearest directory that looks like the
    repo root (has ``src/`` and ``DESIGN.md`` or the baseline file)."""
    cur = os.path.abspath(start)
    while True:
        if os.path.isdir(os.path.join(cur, "src")) and (
                os.path.isfile(os.path.join(cur, "DESIGN.md"))
                or os.path.isfile(os.path.join(cur, BASELINE_NAME))):
            return cur
        parent = os.path.dirname(cur)
        if parent == cur:
            return os.path.abspath(start)
        cur = parent


def _tier_rule_ids(tier: str):
    ids = []
    if tier in ("ast", "all"):
        ids.extend(sorted(RULES))
    if tier in ("jaxpr", "all"):
        ids.extend(JAXPR_RULE_IDS)
    return ids


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="swarmlint: repo-native static analysis "
                    "(DESIGN.md §13, §15)")
    ap.add_argument("--root", default=None,
                    help="repo root to scan (default: auto-detect upward "
                         "from the working directory)")
    ap.add_argument("--tier", choices=TIERS, default=None,
                    help="rule tier: 'ast' (R rules, no jax needed), "
                         "'jaxpr' (J rules, traces the real programs), or "
                         "'all' (default: inferred from --rules, else ast)")
    ap.add_argument("--format", choices=("text", "json", "sarif"),
                    default="text")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids (default: all of the "
                         "selected tier)")
    ap.add_argument("--no-baseline", action="store_true",
                    help=f"ignore {BASELINE_NAME} and report everything")
    ap.add_argument("--prune-baseline", action="store_true",
                    help=f"rewrite {BASELINE_NAME}, dropping [[allow]] "
                         "entries whose finding no longer fires (only "
                         "entries of rules run this invocation)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid in ALL_RULE_IDS:
            tier = "ast" if rid in RULES else "jaxpr"
            print(f"{rid}  [{tier}]  {RULE_DOCS[rid]}")
        return 0

    root = os.path.abspath(args.root) if args.root else _detect_root(".")
    rules = None
    if args.rules:
        rules = [r.strip().upper() for r in args.rules.split(",") if r.strip()]
        unknown = set(rules) - set(ALL_RULE_IDS)
        if unknown:
            print(f"unknown rules: {sorted(unknown)} "
                  f"(known: {list(ALL_RULE_IDS)})", file=sys.stderr)
            return 2

    tier = args.tier
    if tier is None:
        # infer: explicit rules pick their tiers; default stays ast (the
        # cheap path — tier 2 re-traces every registered program)
        if rules is not None:
            has_ast = any(r in RULES for r in rules)
            has_jax = any(r in JAXPR_RULE_IDS for r in rules)
            tier = ("all" if has_ast and has_jax
                    else "jaxpr" if has_jax else "ast")
        else:
            tier = "ast"
    elif rules is not None:
        routed = [r for r in rules if r in _tier_rule_ids(tier)]
        if not routed:
            print(f"none of {rules} belong to tier {tier!r}; pass --tier "
                  "all (or drop --tier to infer it)", file=sys.stderr)
            return 2

    try:
        baseline = None if args.no_baseline else load_baseline(root)
        findings = run(root, rules=rules, baseline=baseline,
                       use_baseline=not args.no_baseline, tier=tier)
        if args.prune_baseline:
            raw = run(root, rules=rules, use_baseline=False, tier=tier)
            live = {(f.rule, f.file, f.symbol) for f in raw}
            ran = rules if rules is not None else _tier_rule_ids(tier)
            dropped = prune_baseline(root, live, ran)
            for rule, fname, symbol in dropped:
                print(f"pruned dead baseline entry: {rule} {fname} "
                      f"[{symbol}]")
            if not dropped:
                print("baseline already minimal: nothing to prune")
    except ValueError as e:       # malformed baseline is a hard error
        print(f"error: {e}", file=sys.stderr)
        return 2

    baselined = baseline.count if baseline else 0
    if args.format == "json":
        print(json.dumps({
            "root": root,
            "tier": tier,
            "rules": rules or _tier_rule_ids(tier),
            "baselined": baselined,
            "findings": [f.to_dict() for f in findings],
        }, indent=2, sort_keys=True))
    elif args.format == "sarif":
        from repro.analysis.sarif import to_sarif
        docs = {rid: RULE_DOCS[rid] for rid in
                (rules or _tier_rule_ids(tier))}
        print(json.dumps(to_sarif(findings, docs, root),
                         indent=2, sort_keys=True))
    else:
        for f in findings:
            print(f"{f.file}:{f.line}: {f.rule} [{f.symbol}] {f.message}")
        tag = f" ({baselined} baselined)" if baselined else ""
        print(f"swarmlint[{tier}]: {len(findings)} finding(s){tag} "
              f"in {root}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
