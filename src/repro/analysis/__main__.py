"""``python -m repro.analysis`` — run swarmlint over a repo tree.

Exit status 0 when the tree is clean (after the baseline), 1 when any
finding survives, 2 on usage/configuration errors.  ``--format json``
emits one machine-readable document (findings + counts) for CI tooling;
the default text format is one ``file:line: RULE symbol message`` row per
finding, grep- and editor-friendly.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.analysis import RULE_DOCS, RULES, run
from repro.analysis.baseline import BASELINE_NAME, load_baseline


def _detect_root(start: str) -> str:
    """Walk up from ``start`` to the nearest directory that looks like the
    repo root (has ``src/`` and ``DESIGN.md`` or the baseline file)."""
    cur = os.path.abspath(start)
    while True:
        if os.path.isdir(os.path.join(cur, "src")) and (
                os.path.isfile(os.path.join(cur, "DESIGN.md"))
                or os.path.isfile(os.path.join(cur, BASELINE_NAME))):
            return cur
        parent = os.path.dirname(cur)
        if parent == cur:
            return os.path.abspath(start)
        cur = parent


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="swarmlint: repo-native static analysis (DESIGN.md §13)")
    ap.add_argument("--root", default=None,
                    help="repo root to scan (default: auto-detect upward "
                         "from the working directory)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids (default: all)")
    ap.add_argument("--no-baseline", action="store_true",
                    help=f"ignore {BASELINE_NAME} and report everything")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid in sorted(RULES):
            print(f"{rid}  {RULE_DOCS[rid]}")
        return 0

    root = os.path.abspath(args.root) if args.root else _detect_root(".")
    rules = None
    if args.rules:
        rules = [r.strip().upper() for r in args.rules.split(",") if r.strip()]
        unknown = set(rules) - set(RULES)
        if unknown:
            print(f"unknown rules: {sorted(unknown)} "
                  f"(known: {sorted(RULES)})", file=sys.stderr)
            return 2

    try:
        baseline = None if args.no_baseline else load_baseline(root)
        findings = run(root, rules=rules, baseline=baseline,
                       use_baseline=not args.no_baseline)
    except ValueError as e:       # malformed baseline is a hard error
        print(f"error: {e}", file=sys.stderr)
        return 2

    baselined = baseline.count if baseline else 0
    if args.format == "json":
        print(json.dumps({
            "root": root,
            "rules": rules or sorted(RULES),
            "baselined": baselined,
            "findings": [f.to_dict() for f in findings],
        }, indent=2, sort_keys=True))
    else:
        for f in findings:
            print(f"{f.file}:{f.line}: {f.rule} [{f.symbol}] {f.message}")
        tag = f" ({baselined} baselined)" if baselined else ""
        print(f"swarmlint: {len(findings)} finding(s){tag} in {root}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
