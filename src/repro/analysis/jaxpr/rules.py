"""Tier-2 rules J001–J004: lint the traced programs (DESIGN.md §15.2).

Each rule takes the shared ``{name: TracedTarget}`` map (one trace per
target, reused by every rule) plus the repo root, and yields tier-1
:class:`repro.analysis.astutil.Finding` rows — same baseline matching,
same CLI rendering.  J005 (compile-fingerprint stability) lives in
``fingerprint.py``.
"""
from __future__ import annotations

import os
from typing import Dict, Iterable, List, Set, Tuple

from repro.analysis.astutil import Finding
from repro.analysis.jaxpr.jaxpr_util import (aval_size_bytes, iter_eqns,
                                             out_signature, source_site)
from repro.analysis.jaxpr.targets import TracedTarget

# --------------------------------------------------------------------------
# J001 — no cross-node reductions inside the scan body (DESIGN.md §8.2)
# --------------------------------------------------------------------------

#: reduction primitives that collapse an axis by *accumulation* — the
#: float cases reassociate with the batch shape across backends
_ACCUM_REDUCE = {"reduce_sum", "reduce_prod", "cumsum", "cumprod",
                 "dot_general"}
#: always-allowed reductions: exact in any association order
_EXACT_REDUCE = {"reduce_min", "reduce_max", "reduce_and", "reduce_or",
                 "argmin", "argmax", "reduce_precision"}


def _drops_n(eqn, n_axis: int) -> bool:
    """True when the equation consumes an N-sized axis its output lacks.

    Per-node neighbor aggregations ([N, N] → [N], Eqs. 10–13) keep an
    N-sized output axis and stay allowed; only full cross-node collapses
    (→ scalar, or → shapes with no N axis) are the §8.2 hazard."""
    try:
        in_has = any(n_axis in getattr(v.aval, "shape", ())
                     for v in eqn.invars)
        out_has = any(n_axis in getattr(v.aval, "shape", ())
                      for v in eqn.outvars)
    except Exception:                                # pragma: no cover
        return False
    return in_has and not out_has


def _is_float(eqn) -> bool:
    dt = getattr(eqn.invars[0].aval, "dtype", None)
    return dt is not None and dt.kind == "f"


def check_j001(traced: Dict[str, TracedTarget], root: str
               ) -> Iterable[Finding]:
    """J001: in-scan cross-node float reductions break backend parity.

    Exact reductions (min/max/arg/and/or) and integer/bool sums are
    whitelisted — they are associativity-safe, so re-chunking the batch
    axis (vmap vs shard_map vs streaming) cannot move a ulp.  Float
    accumulations over the N axis inside the scan must move to per-node
    accumulators summed outside the scan (as ``e_comp``/``e_tx`` were in
    PR 8) or carry a baseline entry documenting why the collapse is safe.
    """
    del root
    seen: Set[Tuple] = set()
    for tt in traced.values():
        if tt.jaxpr32 is None or tt.n_axis is None:
            continue
        for site in iter_eqns(tt.jaxpr32.jaxpr):
            if not site.in_scan:
                continue
            prim = site.eqn.primitive.name
            if prim not in _ACCUM_REDUCE:
                continue
            if not _drops_n(site.eqn, tt.n_axis):
                continue
            if not _is_float(site.eqn):
                continue                 # integer/bool accumulation: exact
            fname, line, func = source_site(site.eqn)
            if fname is None:
                fname, func = "src/repro/analysis/jaxpr/targets.py", tt.name
            key = ("J001", fname, line, func, prim)
            if key in seen:
                continue
            seen.add(key)
            yield Finding(
                "J001", fname, line, func,
                f"in-scan cross-node reduction: '{prim}' collapses the "
                f"N axis to a float inside the scan body (traced via "
                f"{tt.name}); float sums reassociate with the batch "
                f"shape and break cross-backend bit-identity "
                f"(DESIGN.md §8.2) — accumulate per node and "
                f"reduce in summarize, or baseline with a reason")


# --------------------------------------------------------------------------
# J002 — dtype / weak-type drift between x32 and x64 traces
# --------------------------------------------------------------------------


def check_j002(traced: Dict[str, TracedTarget], root: str
               ) -> Iterable[Finding]:
    """J002: the program's types must not depend on the global x64 flag.

    Three signals, in escalating severity: (a) a *weak* dtype in the
    x32 output signature (a python scalar leaked through to a public
    output — its dtype is promotion-context-dependent); (b) an output
    aval that differs between the x32 and x64 traces (some intermediate
    is pinned to the flag default, not to an explicit dtype — exactly
    how f64 literals sneak into compile signatures); (c) the x64 trace
    *raising* (branch/carry dtype mismatches that only materialize under
    promotion — latent until someone flips the flag).
    """
    del root
    tfile = "src/repro/analysis/jaxpr/targets.py"
    for tt in traced.values():
        if tt.jaxpr32 is None:
            continue
        weak = [i for i, v in enumerate(tt.jaxpr32.jaxpr.outvars)
                if getattr(v.aval, "weak_type", False)]
        if weak:
            yield Finding(
                "J002", tfile, 0, tt.name,
                f"weak-typed output aval(s) {weak} in target "
                f"'{tt.name}': a python scalar reaches the traced "
                f"program's outputs; pin an explicit dtype")
        if tt.err64 is not None:
            yield Finding(
                "J002", tfile, 0, tt.name,
                f"target '{tt.name}' fails to trace under x64 "
                f"({type(tt.err64).__name__}): "
                f"{str(tt.err64).splitlines()[0][:160]} — a branch "
                f"or scan-carry dtype depends on the x64 flag")
            continue
        sig32 = out_signature(tt.jaxpr32)
        sig64 = out_signature(tt.jaxpr64)
        drift = [(i, a, b) for i, (a, b) in enumerate(zip(sig32, sig64, strict=True))
                 if a != b]
        if drift:
            i, a, b = drift[0]
            yield Finding(
                "J002", tfile, 0, tt.name,
                f"dtype drift in target '{tt.name}': {len(drift)} "
                f"output aval(s) change under x64 (first: output {i} "
                f"{a} → {b}); an unpinned default dtype is leaking "
                f"into the compile signature")


# --------------------------------------------------------------------------
# J003 — gather/scatter out-of-bounds-mode audit
# --------------------------------------------------------------------------

#: OOB modes that *silently mask* a bad index (clamp or drop/fill).
#: PROMISE_IN_BOUNDS is an explicit caller contract (jnp's default for
#: array indexing) and is out of scope — see DESIGN.md §15.2.
_MASKING_MODES = ("CLIP", "FILL_OR_DROP")
#: inline annotation marker acknowledging deliberate clip/fill semantics
OOB_MARK = "# oob:"
#: source-window (lines) searched around the anchored line — multi-line
#: ``.at[...].set(...)`` statements anchor anywhere inside the call
_OOB_WINDOW = 2


# module-level source cache for _is_annotated, keyed by (root, fname) —
# a memo, not shared state: entries are only ever the file's lines
_SRC_CACHE: Dict[Tuple[str, str], List[str]] = {}


def _is_annotated(root: str, fname: str, line: int) -> bool:
    ck = (root, fname)
    if ck not in _SRC_CACHE:
        try:
            with open(os.path.join(root, fname)) as f:
                _SRC_CACHE[ck] = f.readlines()
        except OSError:
            _SRC_CACHE[ck] = []
    lines = _SRC_CACHE[ck]
    lo = max(0, line - 1 - _OOB_WINDOW)
    hi = min(len(lines), line + _OOB_WINDOW)
    return any(OOB_MARK in ln for ln in lines[lo:hi])


def check_j003(traced: Dict[str, TracedTarget], root: str
               ) -> Iterable[Finding]:
    """J003: every masking-mode gather/scatter must be annotated.

    The sparse neighbor path and the trace streams lean on clip/fill
    semantics on purpose — but the same modes also silently swallow
    genuine index bugs.  Each such site must carry an inline
    ``# oob: <why the masking is correct>`` comment within two lines of
    the operation (or a baseline entry)."""
    seen: Set[Tuple] = set()
    for tt in traced.values():
        if tt.jaxpr32 is None:
            continue
        for site in iter_eqns(tt.jaxpr32.jaxpr):
            prim = site.eqn.primitive.name
            if not prim.startswith(("gather", "scatter")):
                continue
            mode = str(site.eqn.params.get("mode"))
            if not mode.endswith(_MASKING_MODES):
                continue
            fname, line, func = source_site(site.eqn)
            if fname is None or not fname.startswith("src" + os.sep):
                continue                 # jax-internal site: not ours
            key = ("J003", fname, line)
            if key in seen:
                continue
            seen.add(key)
            if _is_annotated(root, fname, line):
                continue
            short = mode.rsplit(".", 1)[-1]
            yield Finding(
                "J003", fname, line, func,
                f"unannotated {short} {prim}: out-of-bounds indices are "
                f"silently masked here (traced via {tt.name}); add an "
                f"'{OOB_MARK} <reason>' comment within {_OOB_WINDOW} "
                f"lines or baseline with a reason")


# --------------------------------------------------------------------------
# J004 — closure-constant bloat
# --------------------------------------------------------------------------

#: bytes of closed-over constants a single program may bake in before we
#: call it bloat (recompiles duplicate it per point; at N = 64k a stray
#: [N, N] table is 16 GiB)
J004_MAX_CONST_BYTES = 1 << 20


def check_j004(traced: Dict[str, TracedTarget], root: str
               ) -> Iterable[Finding]:
    """J004: large arrays closed into a jaxpr become per-compile payload."""
    del root
    tfile = "src/repro/analysis/jaxpr/targets.py"
    for tt in traced.values():
        if tt.jaxpr32 is None:
            continue
        total = 0
        worst = None
        for cv in tt.jaxpr32.jaxpr.constvars:
            nbytes = aval_size_bytes(cv.aval)
            total += nbytes
            if worst is None or nbytes > worst[0]:
                worst = (nbytes, str(cv.aval))
        if total > J004_MAX_CONST_BYTES:
            yield Finding(
                "J004", tfile, 0, tt.name,
                f"closure-constant bloat in target '{tt.name}': "
                f"{total} bytes of consts baked into the jaxpr "
                f"(largest {worst[1]}, {worst[0]} bytes; cap "
                f"{J004_MAX_CONST_BYTES}); pass big tables as arguments "
                f"so sweep points share them")
