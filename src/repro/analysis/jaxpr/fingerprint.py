"""J005 — compile-fingerprint stability (DESIGN.md §15.3).

The fleet executors compile one program per ``(cfg, n)`` pair; the whole
sweep-economics story (DESIGN.md §8) assumes that *data-like* config
changes — gamma, arrival rates, channel parameters — retrace to the
**same** program, because the python floats fold into literals whose
values never reach program *structure*.  A "leaked static arg" breaks
that silently: a python-level branch on a float, a shape derived from a
parameter, a host-side rounding — and suddenly every grid cell of a
sweep compiles its own executable.  The perf gate sees the compile-time
cliff but cannot say *which point* started recompiling.

This module makes the contract checkable:

* :func:`program_fingerprint` — sha256 of a *canonicalized* jaxpr:
  variables renamed by first appearance, literal and constant **values**
  abstracted to their avals (so data differences vanish), sub-jaxprs
  recursed, structural params (scan ``length``, branch count, …) kept
  verbatim.  Two traces share a fingerprint iff they are the same
  program shape.
* :func:`structural_signature` — splits a :class:`SweepPoint` into the
  fields that *legitimately* change the program (n, num_runs, every
  non-float config field, and the float fields that set scan lengths)
  versus the data-like rest.
* :func:`sweep_fingerprint_table` — per-point fingerprints + stability
  verdict for a sweep, emitted into ``BENCH_fleet.json`` so the perf
  gate can name the offending point by label.
* :func:`check_j005` — the repo-level rule: expand stand-in data-only
  sweeps over the real ``run_sim`` and fail if any same-signature group
  traces more than one distinct program.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.analysis.astutil import Finding
from repro.analysis.jaxpr.jaxpr_util import HAVE_JAX

#: float config fields that legitimately change program structure: they
#: set the epoch/tick scan lengths (python-computed trip counts)
STRUCTURAL_FLOATS = frozenset({"sim_time_s", "decision_period_s", "tick_s"})

#: hex digits shown in tables / finding messages (full digest in hashes)
SHORT = 12


# --------------------------------------------------------------------------
# canonical jaxpr hashing
# --------------------------------------------------------------------------


def _canon_value(val, lines: List[str]) -> str:
    """Canonical token for one param value: recurse jaxprs, abstract
    array values to avals, keep scalars/strings verbatim (they are
    structural: scan lengths, dimension numbers, modes …)."""
    closed = getattr(val, "jaxpr", None)
    if closed is not None and hasattr(closed, "eqns"):      # ClosedJaxpr
        return "jaxpr{" + _canon_jaxpr(closed) + "}"
    if hasattr(val, "eqns"):                                # raw Jaxpr
        return "jaxpr{" + _canon_jaxpr(val) + "}"
    if isinstance(val, (tuple, list)):
        return "(" + ",".join(_canon_value(v, lines) for v in val) + ")"
    if hasattr(val, "shape") and hasattr(val, "dtype"):     # array const
        return f"arr[{val.dtype}{tuple(val.shape)}]"
    if callable(val):
        # callables in params (custom_jvp rules, …) are identified by
        # qualname only — identity would defeat cross-trace comparison
        return f"fn:{getattr(val, '__qualname__', repr(type(val)))}"
    return repr(val)


def _canon_jaxpr(jaxpr) -> str:
    """Render a jaxpr with first-appearance variable numbering and
    value-abstracted literals/consts; the digest input for fingerprints."""
    names: Dict[int, str] = {}

    def nm(v) -> str:
        if hasattr(v, "val"):                               # Literal
            return f"lit[{v.aval.str_short()}]"
        key = id(v)
        if key not in names:
            names[key] = f"v{len(names)}"
        return f"{names[key]}:{v.aval.str_short()}"

    lines: List[str] = []
    lines.append("in=" + ",".join(nm(v) for v in jaxpr.constvars))
    lines.append("arg=" + ",".join(nm(v) for v in jaxpr.invars))
    for eqn in jaxpr.eqns:
        params = ",".join(
            f"{k}={_canon_value(v, lines)}"
            for k, v in sorted(eqn.params.items()))
        lines.append(
            f"{eqn.primitive.name}[{params}]"
            f"({','.join(nm(v) for v in eqn.invars)})"
            f"->({','.join(nm(v) for v in eqn.outvars)})")
    lines.append("out=" + ",".join(nm(v) for v in jaxpr.outvars))
    return "\n".join(lines)


def program_fingerprint(closed_jaxpr) -> str:
    """sha256 hex digest of the canonicalized program."""
    text = _canon_jaxpr(closed_jaxpr.jaxpr)
    return hashlib.sha256(text.encode()).hexdigest()


def fingerprint_fn(fn, *args) -> str:
    """Trace ``fn(*args)`` and fingerprint the program."""
    import jax
    return program_fingerprint(jax.make_jaxpr(fn)(*args))


# --------------------------------------------------------------------------
# sweep-point fingerprints
# --------------------------------------------------------------------------


def structural_signature(point) -> Tuple[Tuple[str, Any], ...]:
    """The fields of a SweepPoint that may legitimately move the
    fingerprint.  Strategy is deliberately *excluded*: the executors keep
    it traced (an i32 argument), so two points differing only in strategy
    must share a program — grouping them together makes J005 catch a
    strategy that leaks to static."""
    cfg = point.cfg
    sig: List[Tuple[str, Any]] = [("n", point.n),
                                  ("num_runs", point.num_runs)]
    for f in dataclasses.fields(type(cfg)):
        val = getattr(cfg, f.name)
        if not isinstance(val, float) or f.name in STRUCTURAL_FLOATS:
            sig.append((f.name, val))
    return tuple(sig)


def point_fingerprint(point) -> str:
    """Fingerprint the single-run simulator program of one sweep point —
    the unit every executor backend batches (vmap/stream/shard all wrap
    this same trace, so its stability is theirs)."""
    import jax
    import jax.numpy as jnp

    from repro.swarm.simulator import run_sim
    cfg, n = point.cfg, point.n

    def fn(key, strategy):
        return run_sim(key, cfg, strategy, n)
    return fingerprint_fn(fn, jax.random.PRNGKey(0), jnp.int32(0))


def group_fingerprints(labeled: Iterable[Tuple[Any, str, Any]]
                       ) -> List[Dict[str, Any]]:
    """Group (signature, label, fingerprint) rows; one dict per
    signature group with its distinct fingerprints and a verdict."""
    groups: Dict[Any, Dict[str, Any]] = {}
    for sig, label, fp in labeled:
        g = groups.setdefault(sig, {"labels": [], "fingerprints": {}})
        g["labels"].append(label)
        g["fingerprints"].setdefault(fp, []).append(label)
    out = []
    for sig, g in groups.items():
        out.append({
            "signature": dict(sig) if isinstance(sig, tuple) else sig,
            "points": g["labels"],
            "distinct_programs": len(g["fingerprints"]),
            "stable": len(g["fingerprints"]) <= 1,
            "programs": {fp[:SHORT]: labels
                         for fp, labels in g["fingerprints"].items()},
        })
    return out


def sweep_fingerprint_table(spec, max_points: Optional[int] = None
                            ) -> Dict[str, Any]:
    """Fingerprint every point of a sweep; the dict lands under
    ``fingerprints:<sweep>`` in BENCH_fleet.json (benchmarks/common.py)
    so the perf gate can name which point started recompiling.

    ``max_points`` caps tracing cost for very large grids (points beyond
    the cap are reported as skipped, never silently dropped).
    """
    points = spec.expand()
    skipped = 0
    if max_points is not None and len(points) > max_points:
        skipped = len(points) - max_points
        points = points[:max_points]
    rows = []
    table: Dict[str, str] = {}
    for p in points:
        fp = point_fingerprint(p)
        table[p.label] = fp[:SHORT]
        rows.append((structural_signature(p), p.label, fp))
    groups = group_fingerprints(rows)
    return {
        "sweep": spec.name,
        "points": table,
        "groups": groups,
        "distinct_programs": len(set(table.values())),
        "unstable_groups": [g for g in groups if not g["stable"]],
        "skipped_points": skipped,
        "stable": all(g["stable"] for g in groups),
    }


# --------------------------------------------------------------------------
# the repo-level rule
# --------------------------------------------------------------------------


def _standin_specs():
    """Data-only sweeps over the real simulator: every axis below moves
    floats that must **not** move the program.  Small n / short sim keeps
    the traces cheap; fingerprints do not depend on array sizes."""
    from repro.configs.base import SwarmConfig
    from repro.fleet.sweep import SweepSpec
    base = SwarmConfig(num_workers=13, sim_time_s=1.0, num_runs=2)
    sparse = dataclasses.replace(base, neighbor_mode="sparse", neighbor_k=4)
    return [
        SweepSpec.build("j005_gamma", base,
                        axes={"gamma": (0.01, 0.02, 0.05)},
                        strategies=(0, 4), num_runs=2),
        SweepSpec.build("j005_load", base,
                        axes={"task_period_s": (0.03, 0.06),
                              "tx_power_dbm": (24.0, 30.0)},
                        strategies=(4,), num_runs=2),
        SweepSpec.build("j005_sparse_gamma", sparse,
                        axes={"gamma": (0.01, 0.05)},
                        strategies=(4,), num_runs=2),
    ]


def check_j005(traced, root: str) -> Iterable[Finding]:
    """J005: points differing only in data must trace identical programs.

    ``traced`` (the shared target map) is unused — this rule traces its
    own stand-in sweeps because the hazard lives in the *sweep grid*,
    not in any single target; same signature for registry uniformity."""
    del traced, root
    if not HAVE_JAX:                                 # pragma: no cover
        return
    sfile = "src/repro/fleet/sweep.py"
    for spec in _standin_specs():
        table = sweep_fingerprint_table(spec)
        for g in table["unstable_groups"]:
            programs = "; ".join(
                f"{fp}: {', '.join(labels[:3])}"
                f"{'…' if len(labels) > 3 else ''}"
                for fp, labels in g["programs"].items())
            yield Finding(
                "J005", sfile, 0, f"sweep:{spec.name}",
                f"compile-fingerprint instability: {g['distinct_programs']}"
                f" distinct programs in one structural-signature group of "
                f"stand-in sweep '{spec.name}' ({programs}) — a data-like "
                f"config field is leaking into program structure, so this "
                f"grid recompiles per point")
