"""swarmlint tier 2 — jaxpr-level auditing (DESIGN.md §15).

Tier 1 (``repro.analysis``'s R rules) reads source text; this tier reads
what the compiler actually traces.  The registry in ``targets.py`` names
the real programs (simulator paths, φ kernels, executor backends, the
serve-engine numeric core), traces each once under x32 *and* x64, and the
rules lint the shared traces:

  * **J001 scan-reduction purity** (``rules.py``) — no cross-node float
    reductions inside the scan body (mechanizes DESIGN.md §8.2).
  * **J002 dtype stability** (``rules.py``) — the traced types must not
    depend on the global x64 flag (weak-type leaks, f64 promotion,
    flag-dependent trace failures).
  * **J003 gather/scatter OOB audit** (``rules.py``) — every CLIP /
    FILL_OR_DROP site carries an inline ``# oob: <reason>`` annotation.
  * **J004 closure-constant bloat** (``rules.py``) — no large arrays
    baked into a program's constants.
  * **J005 compile-fingerprint stability** (``fingerprint.py``) —
    sweep points differing only in data trace identical programs.

Findings share tier 1's :class:`~repro.analysis.astutil.Finding` type and
``analysis_baseline.toml`` matching; ``python -m repro.analysis --tier
jaxpr`` (or ``all``) runs this tier.  Everything degrades to no findings
when jax is unavailable — tier 1 must keep working anywhere.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.analysis.astutil import Finding
from repro.analysis.jaxpr import fingerprint, rules
from repro.analysis.jaxpr.jaxpr_util import HAVE_JAX
from repro.analysis.jaxpr.targets import all_targets, trace_targets

JAXPR_RULES = {
    "J001": rules.check_j001,
    "J002": rules.check_j002,
    "J003": rules.check_j003,
    "J004": rules.check_j004,
    "J005": fingerprint.check_j005,
}

JAXPR_RULE_DOCS = {
    "J001": "in-scan cross-node float reduction (backend parity hazard)",
    "J002": "dtype/weak-type drift between x32 and x64 traces",
    "J003": "unannotated CLIP/FILL_OR_DROP gather/scatter",
    "J004": "oversized constants closed into a traced program",
    "J005": "data-only sweep points tracing distinct programs",
}


def run_jaxpr(root: str, rule_ids: Optional[Sequence[str]] = None
              ) -> List[Finding]:
    """Trace the target registry once, run the selected J rules over the
    shared traces.  Returns raw findings (baseline applied by the caller,
    same as the tier-1 rule functions)."""
    if not HAVE_JAX:                                 # pragma: no cover
        return []
    ids = list(rule_ids) if rule_ids is not None else sorted(JAXPR_RULES)
    # J005 traces its own sweeps; don't pay for the target registry
    # unless a structural rule actually runs
    traced = trace_targets() if any(i != "J005" for i in ids) else {}
    findings: List[Finding] = []
    for rid in ids:
        findings.extend(JAXPR_RULES[rid](traced, root))
    return findings


__all__ = ["JAXPR_RULES", "JAXPR_RULE_DOCS", "run_jaxpr", "all_targets",
           "trace_targets", "HAVE_JAX"]
