"""Shared jaxpr-introspection helpers for the tier-2 rules (DESIGN.md §15).

The tier-1 rules read *source text*; this tier reads what the compiler
actually traces.  Everything here is rule-agnostic plumbing:

* :func:`iter_eqns` — recursive equation walk through every sub-jaxpr
  (scan/while/cond bodies, pjit calls, custom_jvp wrappers …), yielding
  each equation with its nesting context (are we inside a ``scan`` body?);
* :func:`source_site` — map an equation back to a repo-relative
  ``(file, line, function)`` anchor via JAX's source_info, so jaxpr
  findings share the tier-1 ``Finding`` type and the baseline's
  (rule, file, symbol) matching;
* :func:`trace32_64` — trace a callable under default x32 *and* under
  ``jax.experimental.enable_x64`` for the J002 drift comparison.

Nothing in this module imports the simulator — target construction lives
in ``targets.py`` so the walker stays reusable for fixture programs in
tests.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

# Deliberately lazy/defensive: the analysis CLI must keep working (tier-1
# at least) on a host without jax; the jaxpr tier gates itself.
try:
    import jax
    from jax._src import source_info_util
    HAVE_JAX = True
except Exception:                                    # pragma: no cover
    jax = None
    source_info_util = None
    HAVE_JAX = False

REPO_MARKER = os.sep + "src" + os.sep + "repro" + os.sep

#: primitives that open a scan body — reductions inside them repeat per
#: step and (for J001) interact with the batch axis
_SCAN_PRIMS = {"scan"}
#: primitives whose sub-jaxprs are control flow but *not* a scan body
_FLOW_PRIMS = {"while", "cond", "pjit", "custom_jvp_call",
               "custom_vjp_call", "custom_vjp_call_jaxpr", "remat", "xla_call",
               "closed_call", "core_call", "checkpoint"}


@dataclass(frozen=True)
class EqnSite:
    """One traced equation plus its walk context."""
    eqn: object              # jax.core.JaxprEqn
    in_scan: bool            # nested (at any depth) inside a scan body
    depth: int               # sub-jaxpr nesting depth


def _sub_jaxprs(eqn) -> Iterator[object]:
    """Yield every jaxpr hiding in an equation's params."""
    for val in eqn.params.values():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for v in vals:
            if hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
                yield v.jaxpr                        # ClosedJaxpr
            elif hasattr(v, "eqns"):
                yield v                              # raw Jaxpr


def iter_eqns(jaxpr, in_scan: bool = False,
              depth: int = 0) -> Iterator[EqnSite]:
    """Depth-first walk over every equation of ``jaxpr`` and its children.

    ``in_scan`` is sticky: once the walk enters a ``scan`` body, all
    nested equations (including deeper scans and conds) report
    ``in_scan=True`` — J001's "inside the scan body" is about runtime
    repetition, not immediate nesting.
    """
    for eqn in jaxpr.eqns:
        yield EqnSite(eqn, in_scan, depth)
        child_in_scan = in_scan or eqn.primitive.name in _SCAN_PRIMS
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub, child_in_scan, depth + 1)


def source_site(eqn) -> Tuple[Optional[str], int, str]:
    """(repo-relative file, line, function) of an equation's user frame.

    Returns ``(None, 0, "<unknown>")`` when the equation has no user
    frame (jax-internal lowering helpers) — rules treat those as
    unanchorable and attribute them to the target instead.
    """
    frame = None
    if source_info_util is not None:
        try:
            frame = source_info_util.user_frame(eqn.source_info)
        except Exception:                            # pragma: no cover
            frame = None
    if frame is None:
        return None, 0, "<unknown>"
    fn = frame.file_name
    if REPO_MARKER in fn:
        fn = "src" + os.sep + "repro" + os.sep + fn.split(REPO_MARKER, 1)[1]
    return fn, int(frame.start_line), frame.function_name


def out_signature(closed_jaxpr) -> Tuple[str, ...]:
    """Canonical output-aval signature: ``f32[13,4]``-style strings."""
    return tuple(str(v.aval) for v in closed_jaxpr.jaxpr.outvars)


def trace32_64(fn, *args):
    """Trace ``fn(*args)`` under x32 and x64; returns (jaxpr32, jaxpr64,
    error64).  ``jaxpr64``/``error64`` are mutually exclusive: a raise
    under x64 is itself a J002 signal (the program's types depend on the
    global flag), so the caller gets the exception instead of a crash.
    """
    from jax.experimental import enable_x64
    j32 = jax.make_jaxpr(fn)(*args)
    try:
        import warnings
        with warnings.catch_warnings():
            # promotion FutureWarnings are the *mechanism* J002 reports
            # via avals; don't spam the CLI while retracing
            warnings.simplefilter("ignore")
            with enable_x64():
                j64 = jax.make_jaxpr(fn)(*args)
        return j32, j64, None
    except Exception as err:
        return j32, None, err


def aval_size_bytes(aval) -> int:
    """Total byte size of a shaped aval (0 when unknown)."""
    try:
        import numpy as np
        return int(np.prod(aval.shape, dtype="int64")) * aval.dtype.itemsize
    except Exception:                                # pragma: no cover
        return 0
