"""Traced-program registry for the jaxpr tier (DESIGN.md §15.1).

A *target* names one real program the repo compiles and a zero-argument
builder that returns ``(fn, example_args)`` suitable for
``jax.make_jaxpr``.  The rules never construct programs themselves — they
lint whatever this registry traces, so adding a subsystem here
automatically puts it under J001–J004.

Covered surface (mirrors how the programs are actually built):

* ``sim_*`` — ``run_sim`` end to end: the dense path, the sparse
  neighbor-list path (DESIGN.md §11), the fully-traced path (task + hop +
  state streams, §10/§12), and scenario-registry combinations (stochastic
  channel / mobility / fault entries), each with the strategy id left
  traced exactly as the executors trace it;
* ``kernel_*`` — the φ kernel dispatchers in ``repro.kernels.ops``
  (dense and sparse), traced through the same dispatch path the
  simulator uses;
* ``executor_*`` — the three fleet backends' batched programs (vmap /
  streaming ``lax.map`` / ``shard_map`` over a 1-device mesh), built the
  same way ``fleet.executor`` builds them, minus the AOT compile;
* ``serve_congestion_core`` — the jitted numerics of
  ``SplitServeEngine.step`` (congestion EMA → exit labels,
  ``repro.core.early_exit``).  The engine's step loop itself is host-side
  python over deques — there is no whole-step jaxpr to lint; its traced
  surface *is* this core (see DESIGN.md §15.1).

Targets are deliberately small (N = 13, one simulated second): jaxpr
structure does not depend on array sizes, and the distinctive prime N
lets rules identify the cross-node axis by dimension.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from repro.analysis.jaxpr.jaxpr_util import HAVE_JAX, trace32_64

#: the distinctive swarm size rules use to recognize the N axis
TARGET_N = 13
#: simulated seconds per target — two epochs at the default period
TARGET_SIM_S = 1.0


@dataclass(frozen=True)
class Target:
    name: str
    kind: str                       # sim | kernel | executor | serve
    build: Callable[[], Tuple[Callable, tuple]]
    n_axis: Optional[int] = TARGET_N   # None: no cross-node axis to audit


class TracedTarget:
    """One target's traced programs: x32 always, x64 pair for J002."""

    def __init__(self, target: Target, jaxpr32, jaxpr64, err64):
        self.target = target
        self.name = target.name
        self.n_axis = target.n_axis
        self.jaxpr32 = jaxpr32
        self.jaxpr64 = jaxpr64
        self.err64 = err64


def _sim_cfg(**over):
    from repro.configs.base import SwarmConfig
    return SwarmConfig(num_workers=TARGET_N, sim_time_s=TARGET_SIM_S,
                       **over)


def _sim_builder(**over):
    def build():
        import jax
        import jax.numpy as jnp

        from repro.swarm.simulator import run_sim
        cfg = _sim_cfg(**over)

        def fn(key, strategy):
            return run_sim(key, cfg, strategy, TARGET_N)
        return fn, (jax.random.PRNGKey(0), jnp.int32(4))
    return build


def _kernel_dense():
    import jax
    import jax.numpy as jnp

    from repro.kernels.ops import diffusive_phi
    n = TARGET_N
    k = jax.random.PRNGKey(0)
    inv_phi = jax.random.uniform(k, (n,), jnp.float32, 0.5, 1.5)
    F = jnp.ones((n,), jnp.float32)
    d_tx = jnp.ones((n, n), jnp.float32)

    def fn(inv_phi, F, d_tx):
        return diffusive_phi(inv_phi, F, d_tx)
    return fn, (inv_phi, F, d_tx)


def _kernel_sparse():
    import jax
    import jax.numpy as jnp

    from repro.kernels.ops import diffusive_phi_sparse
    n, K = TARGET_N, 4
    k = jax.random.PRNGKey(0)
    # sparse kernel contract is batched: [R, N] / [R, N, K] (kernels/ref.py)
    inv_phi = jax.random.uniform(k, (1, n), jnp.float32, 0.5, 1.5)
    F = jnp.ones((1, n), jnp.float32)
    nbr = jnp.broadcast_to(jnp.arange(K, dtype=jnp.int32)[None, None, :],
                           (1, n, K))
    d_tx_e = jnp.ones((1, n, K), jnp.float32)

    def fn(inv_phi, F, d_tx_e, nbr):
        return diffusive_phi_sparse(inv_phi, F, d_tx_e, nbr)
    return fn, (inv_phi, F, d_tx_e, nbr)


def _executor_vmap():
    import jax
    import jax.numpy as jnp

    from repro.swarm.simulator import run_sim
    cfg = _sim_cfg()
    num_runs = 3

    def fn(key, strategy):
        keys = jax.random.split(key, num_runs)
        return jax.vmap(lambda k: run_sim(k, cfg, strategy, TARGET_N))(keys)
    return fn, (jax.random.PRNGKey(0), jnp.int32(4))


def _executor_streaming():
    import jax
    import jax.numpy as jnp

    from repro.swarm.simulator import run_sim
    cfg = _sim_cfg()
    chunk = 2

    def fn(keys, strategy):
        return jax.lax.map(lambda k: run_sim(k, cfg, strategy, TARGET_N),
                           keys)
    keys = jax.random.split(jax.random.PRNGKey(0), chunk)
    return fn, (keys, jnp.int32(4))


def _executor_sharded():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.swarm.simulator import run_sim
    cfg = _sim_cfg()
    devs = np.asarray(jax.devices())
    mesh = Mesh(devs, ("mc",))
    padded = len(devs)

    def fn(keys, strategy):
        return shard_map(
            lambda ks: jax.vmap(
                lambda k: run_sim(k, cfg, strategy, TARGET_N))(ks),
            mesh=mesh, in_specs=P("mc"), out_specs=P("mc"))(keys)
    keys = jax.random.split(jax.random.PRNGKey(0), padded)
    return fn, (keys, jnp.int32(4))


def _serve_congestion_core():
    import jax.numpy as jnp

    from repro.core.early_exit import (CongestionState, congestion_update,
                                       exit_label)
    n_stages = 4

    def fn(prev_T, prev_D, qlens):
        state = congestion_update(CongestionState(prev_T, prev_D), qlens,
                                  dt=0.01, alpha=0.3)
        return state.prev_T, state.D, exit_label(state.D, 1.5, 2.5)
    z = jnp.zeros((n_stages,), jnp.float32)
    return fn, (z, z, z)


def all_targets() -> List[Target]:
    return [
        Target("sim_dense", "sim", _sim_builder()),
        Target("sim_sparse", "sim",
               _sim_builder(neighbor_mode="sparse", neighbor_k=4)),
        Target("sim_traced", "sim",
               _sim_builder(trace_capacity=64, trace_hop_capacity=64,
                            trace_state_every=2)),
        Target("sim_scenario_stochastic", "sim",
               _sim_builder(channel_model="log_normal_corr",
                            mobility_model="gauss_markov",
                            fault_model="markov")),
        Target("sim_scenario_fading", "sim",
               _sim_builder(channel_model="rician",
                            mobility_model="levy_flight")),
        Target("kernel_phi_dense", "kernel", _kernel_dense),
        Target("kernel_phi_sparse", "kernel", _kernel_sparse),
        # n_axis=None: the executor targets audit the *batching wrappers*
        # (dtype drift, closure consts, fingerprints); the cross-node-axis
        # scan audit runs on the sim targets, which trace the same body.
        # The streaming backend in particular lowers lax.map to a scan
        # over the Monte-Carlo axis, which would wrap even `summarize` in
        # a scan context and turn J001 into noise.
        Target("executor_vmap", "executor", _executor_vmap, n_axis=None),
        Target("executor_streaming", "executor", _executor_streaming,
               n_axis=None),
        Target("executor_sharded", "executor", _executor_sharded,
               n_axis=None),
        Target("serve_congestion_core", "serve", _serve_congestion_core,
               n_axis=None),
    ]


def trace_targets(targets: Optional[List[Target]] = None
                  ) -> Dict[str, TracedTarget]:
    """Trace every target once (x32 + x64); shared across all J rules."""
    if not HAVE_JAX:                                 # pragma: no cover
        return {}
    out: Dict[str, TracedTarget] = {}
    for t in (all_targets() if targets is None else targets):
        fn, args = t.build()
        j32, j64, err = trace32_64(fn, *args)
        out[t.name] = TracedTarget(t, j32, j64, err)
    return out
