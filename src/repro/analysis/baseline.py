"""Baseline / exemption file for swarmlint (``analysis_baseline.toml``).

Two table arrays:

``[[allow]]`` — one deliberate finding, matched by (rule, file, symbol)::

    [[allow]]
    rule = "R001"
    file = "src/repro/swarm/simulator.py"
    symbol = "_epoch:key"
    reason = "scenario keys folded off the epoch key for bit-identity"

Line numbers are deliberately *not* part of the match, so baselines
survive unrelated edits; ``symbol`` is the rule's stable anchor (function-
qualified variable for R001, function qualname for R003, …).  Every entry
must carry a non-empty ``reason`` — entries without one are rejected at
load time, which is the enforcement half of the "baseline with
justification" workflow (DESIGN.md §13).

``[[digest_exempt]]`` — R002's table of deliberately digest-excluded
fields, ``field = "Class.field"`` (or ``"function.param"``) plus
``reason``.  R002 validates each entry against the live dataclass/function
and flags stale or shadowed entries, so the table cannot rot.

Parsing: ``tomllib`` when available (Python ≥ 3.11), else a strict
fallback reader for exactly this shape (table arrays of ``key = "string"``
pairs) — the file format is kept to that subset on purpose so the suite
has zero dependencies beyond the repo's own requirements.
"""
from __future__ import annotations

import dataclasses
import os
import re
from typing import Dict, List, Optional

from repro.analysis.astutil import Finding

BASELINE_NAME = "analysis_baseline.toml"

try:
    import tomllib as _toml
except ImportError:                                    # Python < 3.11
    _toml = None

_KV = re.compile(r'^([A-Za-z_][A-Za-z0-9_]*)\s*=\s*"((?:[^"\\]|\\.)*)"\s*$')


def _parse_subset(text: str) -> Dict[str, List[Dict[str, str]]]:
    """Fallback parser for the table-array-of-string-pairs TOML subset."""
    doc: Dict[str, List[Dict[str, str]]] = {}
    current: Optional[Dict[str, str]] = None
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if line.startswith("[[") and line.endswith("]]"):
            name = line[2:-2].strip()
            current = {}
            doc.setdefault(name, []).append(current)
            continue
        m = _KV.match(line)
        if m and current is not None:
            current[m.group(1)] = (m.group(2)
                                   .replace('\\"', '"').replace("\\\\", "\\"))
            continue
        raise ValueError(
            f"{BASELINE_NAME}:{lineno}: unsupported syntax {line!r} "
            "(the baseline sticks to [[table]] arrays of key = \"string\")")
    return doc


@dataclasses.dataclass(frozen=True)
class Baseline:
    allows_: tuple     # of (rule, file, symbol)
    digest_exempt: Dict[str, str]      # field -> reason
    path: Optional[str] = None

    def allows(self, f: Finding) -> bool:
        return (f.rule, f.file, f.symbol) in self.allows_

    @property
    def count(self) -> int:
        return len(self.allows_)


def parse_baseline(text: str, path: Optional[str] = None) -> Baseline:
    doc = (_toml.loads(text) if _toml is not None else _parse_subset(text))
    allows = []
    for i, entry in enumerate(doc.get("allow", [])):
        missing = {"rule", "file", "symbol", "reason"} - set(entry)
        if missing:
            raise ValueError(
                f"[[allow]] entry {i} is missing {sorted(missing)}")
        if not str(entry["reason"]).strip():
            raise ValueError(
                f"[[allow]] entry {i} ({entry['rule']} {entry['symbol']}) "
                "has an empty reason — baselines must be justified")
        allows.append((entry["rule"], entry["file"], entry["symbol"]))
    exempt: Dict[str, str] = {}
    for i, entry in enumerate(doc.get("digest_exempt", [])):
        missing = {"field", "reason"} - set(entry)
        if missing:
            raise ValueError(
                f"[[digest_exempt]] entry {i} is missing {sorted(missing)}")
        if not str(entry["reason"]).strip():
            raise ValueError(
                f"[[digest_exempt]] entry {i} ({entry['field']}) has an "
                "empty reason — exemptions must be justified")
        exempt[entry["field"]] = entry["reason"]
    return Baseline(tuple(allows), exempt, path)


def load_baseline(root: str) -> Optional[Baseline]:
    path = os.path.join(root, BASELINE_NAME)
    if not os.path.isfile(path):
        return None
    with open(path, encoding="utf-8") as f:
        return parse_baseline(f.read(), path)


# ---------------------------------------------------------------------------
# pruning (``--prune-baseline``): dead [[allow]] entries mask regressions
# ---------------------------------------------------------------------------


def prune_baseline_text(text: str, live, rules_run) -> tuple:
    """Drop every ``[[allow]]`` block whose (rule, file, symbol) matches
    no live finding.  Returns ``(new_text, dropped)`` where ``dropped``
    is the list of removed triples.

    Only entries whose rule is in ``rules_run`` are candidates — an entry
    for a rule that did not execute this invocation (e.g. a J rule under
    ``--tier ast``) cannot be proven dead and is kept.  The rewrite is
    textual and scoped to the dropped blocks (first ``[[allow]]`` line
    through the last key line before the next table header), so comments
    and ``[[digest_exempt]]`` entries survive byte-for-byte.
    """
    lines = text.splitlines(keepends=True)
    # block spans: (start, end, triple) — end exclusive
    spans = []
    i = 0
    while i < len(lines):
        if lines[i].strip() == "[[allow]]":
            start = i
            entry = {}
            i += 1
            while i < len(lines):
                s = lines[i].strip()
                if s.startswith("[["):
                    break
                m = _KV.match(s)
                if m:
                    entry[m.group(1)] = m.group(2)
                i += 1
            # trim trailing blank/comment lines back out of the block so
            # the next block's leading comments aren't swallowed
            end = i
            while end > start + 1 and not _KV.match(lines[end - 1].strip()):
                end -= 1
            spans.append((start, end,
                          (entry.get("rule", ""), entry.get("file", ""),
                           entry.get("symbol", ""))))
        else:
            i += 1
    live = set(live)
    dropped = [t for _, _, t in spans
               if t not in live and t[0] in set(rules_run)]
    keep_mask = [True] * len(lines)
    for start, end, t in spans:
        if t in dropped:
            for j in range(start, end):
                keep_mask[j] = False
            # also absorb one trailing blank line left behind
            if end < len(lines) and not lines[end].strip():
                keep_mask[end] = False
    new_text = "".join(ln for ln, keep in zip(lines, keep_mask, strict=True) if keep)
    return new_text, dropped


def prune_baseline(root: str, live, rules_run) -> list:
    """Rewrite ``analysis_baseline.toml`` in place, dropping dead
    ``[[allow]]`` entries; returns the dropped (rule, file, symbol)
    triples (empty when the file is absent or already minimal)."""
    path = os.path.join(root, BASELINE_NAME)
    if not os.path.isfile(path):
        return []
    with open(path, encoding="utf-8") as f:
        text = f.read()
    new_text, dropped = prune_baseline_text(text, live, rules_run)
    if dropped:
        parse_baseline(new_text, path)     # never write an unloadable file
        with open(path, "w", encoding="utf-8") as f:
            f.write(new_text)
    return dropped
