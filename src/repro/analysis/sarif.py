"""SARIF 2.1.0 emission for swarmlint findings (``--format sarif``).

SARIF (Static Analysis Results Interchange Format, OASIS) is the
ingestion format of GitHub code scanning and most CI annotation tooling:
one ``run`` with a tool descriptor + rule metadata, one ``result`` per
finding anchored to a repo-relative artifact location.  Keeping the
emitter tiny and dependency-free matters more here than covering the
spec — only the fields code-scanning actually renders are produced.

Both tiers emit through this module: tier-1 rows anchor to real source
lines; tier-2 rows whose finding is program-level (J002/J004/J005 attach
to a target or sweep, not a line) use line 1 per the SARIF minimum and
carry the symbol in the message.
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence

from repro.analysis.astutil import Finding

SARIF_SCHEMA = ("https://raw.githubusercontent.com/oasis-tcs/sarif-spec/"
                "master/Schemata/sarif-schema-2.1.0.json")
SARIF_VERSION = "2.1.0"
TOOL_NAME = "swarmlint"


def to_sarif(findings: Sequence[Finding], rule_docs: Dict[str, str],
             root: str) -> Dict[str, Any]:
    """One SARIF document for the run: every known rule is declared (so
    code scanning shows a stable rule inventory even on clean runs) and
    every finding becomes an ``error``-level result."""
    rules: List[Dict[str, Any]] = [
        {
            "id": rid,
            "name": rid,
            "shortDescription": {"text": doc},
            "defaultConfiguration": {"level": "error"},
        }
        for rid, doc in sorted(rule_docs.items())
    ]
    results: List[Dict[str, Any]] = []
    for f in findings:
        results.append({
            "ruleId": f.rule,
            "level": "error",
            "message": {"text": f"[{f.symbol}] {f.message}"},
            "locations": [{
                "physicalLocation": {
                    "artifactLocation": {
                        "uri": f.file.replace("\\", "/"),
                        "uriBaseId": "SRCROOT",
                    },
                    # SARIF lines are 1-based; program-level findings
                    # (no source anchor) pin to line 1
                    "region": {"startLine": max(1, f.line)},
                },
            }],
        })
    return {
        "$schema": SARIF_SCHEMA,
        "version": SARIF_VERSION,
        "runs": [{
            "tool": {"driver": {
                "name": TOOL_NAME,
                "informationUri": "https://example.invalid/swarmlint",
                "rules": rules,
            }},
            "originalUriBaseIds": {"SRCROOT": {"uri": f"file://{root}/"}},
            "results": results,
        }],
    }
