"""R004 — registry/doc consistency.

Two invariants about the string-keyed extension surfaces:

  * **registry keys are load-bearing API** — every key registered into
    the mobility/channel/fault registries (``register_*`` call sites) and
    every strategy name in ``STRATEGY_NAMES`` must be referenced by at
    least one test and mentioned in DESIGN.md.  An unreferenced key is a
    scenario nobody can discover and nothing would catch regressing.
  * **§-citations resolve** — a docstring citing ``DESIGN.md §N`` (or
    ``§N.M``) must point at a real ``## §N`` / ``### §N.M`` heading.
    PR 1 cleaned up ten dangling citations by hand; this keeps them from
    coming back.
"""
from __future__ import annotations

import ast
import re
from typing import List, Tuple

from repro.analysis.astutil import (Finding, Tree, docstrings, dotted_name)

RULE = "R004"
REGISTER_FUNCS = {"register_mobility": "mobility",
                  "register_channel": "channel",
                  "register_channel_edges": "edge channel",
                  "register_fault": "fault"}
_CITE = re.compile(r"DESIGN\.md\s*§\s*(\d+)(?:\.(\d+))?")


def _registry_keys(tree: Tree) -> List[Tuple[str, str, str, int]]:
    """(kind, key, file, line) for every registered string key."""
    out = []
    for mod in tree.src_modules():
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                fname = (dotted_name(node.func) or "").split(".")[-1]
                if fname in REGISTER_FUNCS and node.args and isinstance(
                        node.args[0], ast.Constant) and isinstance(
                        node.args[0].value, str):
                    out.append((REGISTER_FUNCS[fname], node.args[0].value,
                                mod.path, node.lineno))
            if isinstance(node, ast.Assign):
                targets = [t.id for t in node.targets
                           if isinstance(t, ast.Name)]
                if "STRATEGY_NAMES" in targets and isinstance(
                        node.value, (ast.Tuple, ast.List)):
                    for el in node.value.elts:
                        if isinstance(el, ast.Constant) and isinstance(
                                el.value, str):
                            out.append(("strategy", el.value, mod.path,
                                        el.lineno))
    return out


def check(tree: Tree, baseline=None) -> List[Finding]:
    del baseline
    findings: List[Finding] = []
    design = tree.text("DESIGN.md") or ""
    tests = tree.test_sources()

    for kind, key, path, line in _registry_keys(tree):
        word = re.compile(rf"\b{re.escape(key)}\b")
        if not word.search(tests):
            findings.append(Finding(
                RULE, path, line, f"{kind}:{key}",
                f"{kind} registry key {key!r} is referenced by no test — "
                "nothing would catch it regressing"))
        if not word.search(design):
            findings.append(Finding(
                RULE, path, line, f"{kind}:{key}",
                f"{kind} registry key {key!r} is not mentioned in "
                "DESIGN.md — undiscoverable scenario surface"))

    for mod in tree.src_modules():
        for line, doc in docstrings(mod.tree):
            for m in _CITE.finditer(doc):
                major, minor = m.group(1), m.group(2)
                sec = f"§{major}.{minor}" if minor else f"§{major}"
                pat = (rf"^###\s*§{major}\.{minor}\b" if minor
                       else rf"^##\s*§{major}\b")
                if not re.search(pat, design, re.MULTILINE):
                    findings.append(Finding(
                        RULE, mod.path, line, f"cite:{sec}",
                        f"dangling citation: DESIGN.md {sec} has no "
                        f"matching heading (the class PR 1 cleaned up)"))
    return findings
