"""swarmlint — repo-native static analysis (DESIGN.md §13).

The repo's correctness story rests on invariants that ordinary linters
cannot see: bit-identical backends require strict PRNG key hygiene (PR 1
fixed a threefry-correlated Random/RandomAcyclic coin), content-addressed
caching requires every numerics-affecting config field to enter the store
digest (PR 4 fixed ``trace_capacity`` aliasing), and the jitted scan must
stay free of host-side impurity or it stops being a scan.  This package
checks those invariants at the AST level, so a future PR that breaks one
fails the tier-1 suite instead of corrupting a cache or an RNG stream.

Rules (each in its own module):

  * **R001 key-discipline** (``keys.py``)  — a ``jax.random`` key consumed
    by two independent sinks inside one function body.
  * **R002 digest-completeness** (``digest.py``) — every ``SwarmConfig`` /
    ``SweepSpec`` field reaches ``fleet/store.point_digest`` or is listed
    in the exemption table with a reason.
  * **R003 in-scan purity** (``purity.py``) — no host-side effects in the
    call graph reachable from ``run_sim`` / ``_epoch`` / ``_tick`` /
    ``ServeEngine.step`` and the scenario-registry callables.
  * **R004 registry/doc consistency** (``consistency.py``) — every
    registry key is referenced by a test and documented in DESIGN.md;
    ``DESIGN.md §N[.M]`` docstring citations must resolve.

The R rules above are **tier 1** (pure-AST: fast, dependency-free).
``repro.analysis.jaxpr`` adds **tier 2** — J001–J005 lint the *traced*
programs (scan-reduction purity, x64 dtype drift, gather OOB modes,
closure-constant bloat, compile-fingerprint stability; DESIGN.md §15) —
selected with ``--tier ast|jaxpr|all``.  Both tiers share the
:class:`Finding` type and the baseline file.

Entry points: ``python -m repro.analysis`` (CLI, nonzero exit on
unbaselined findings) and :func:`run` (used by ``tests/test_analysis.py``
to keep the tree clean under tier-1).  Deliberate violations are
allowlisted per (rule, file, symbol) in ``analysis_baseline.toml`` at the
repo root — every entry carries a ``reason`` string.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.analysis import consistency, digest, keys, purity
from repro.analysis.astutil import Finding, Tree
from repro.analysis.baseline import Baseline, load_baseline

RULES = {
    "R001": keys.check,
    "R002": digest.check,
    "R003": purity.check,
    "R004": consistency.check,
}

#: tier-2 rule ids, known here so the CLI can validate ``--rules`` and
#: ``--list-rules`` without importing jax (the implementation registry
#: lives in ``repro.analysis.jaxpr`` and is imported lazily by tier)
JAXPR_RULE_IDS = ("J001", "J002", "J003", "J004", "J005")

RULE_DOCS = {
    "R001": "PRNG key consumed by two independent sinks (def-use)",
    "R002": "config field missing from the store digest (no exemption)",
    "R003": "host-side impurity reachable from the jitted scan",
    "R004": "registry key untested/undocumented, or dangling §-citation",
    "J001": "in-scan cross-node float reduction (backend parity hazard)",
    "J002": "dtype/weak-type drift between x32 and x64 traces",
    "J003": "unannotated CLIP/FILL_OR_DROP gather/scatter",
    "J004": "oversized constants closed into a traced program",
    "J005": "data-only sweep points tracing distinct programs",
}

#: every rule id across both tiers (CLI validation surface)
ALL_RULE_IDS = tuple(sorted(RULES)) + JAXPR_RULE_IDS

TIERS = ("ast", "jaxpr", "all")


def run(root: str, rules: Optional[Sequence[str]] = None,
        baseline: Optional[Baseline] = None,
        use_baseline: bool = True, tier: str = "ast") -> List[Finding]:
    """Run ``rules`` (default: all of the selected tier) over the tree at
    ``root``; returns the findings that survive the baseline (i.e. the
    ones that should fail).  ``tier`` picks the AST rules (default — the
    fast, jax-free path the tier-1 suite gates on), the jaxpr rules, or
    both; explicit ``rules`` are routed to their tier automatically."""
    if tier not in TIERS:
        raise ValueError(f"unknown tier {tier!r} (known: {TIERS})")
    if baseline is None and use_baseline:
        baseline = load_baseline(root)
    ast_ids = [r for r in rules if r in RULES] if rules is not None else None
    jax_ids = ([r for r in rules if r in JAXPR_RULE_IDS]
               if rules is not None else None)
    findings: List[Finding] = []
    if tier in ("ast", "all") and (ast_ids is None or ast_ids):
        tree = Tree.load(root)
        for rid in ast_ids if ast_ids is not None else sorted(RULES):
            findings.extend(RULES[rid](tree, baseline))
    if tier in ("jaxpr", "all") and (jax_ids is None or jax_ids):
        from repro.analysis.jaxpr import run_jaxpr   # lazy: imports jax
        findings.extend(run_jaxpr(root, jax_ids))
    if baseline is not None:
        findings = [f for f in findings if not baseline.allows(f)]
    return sorted(findings, key=lambda f: (f.file, f.line, f.rule))
