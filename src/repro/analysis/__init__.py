"""swarmlint — repo-native static analysis (DESIGN.md §13).

The repo's correctness story rests on invariants that ordinary linters
cannot see: bit-identical backends require strict PRNG key hygiene (PR 1
fixed a threefry-correlated Random/RandomAcyclic coin), content-addressed
caching requires every numerics-affecting config field to enter the store
digest (PR 4 fixed ``trace_capacity`` aliasing), and the jitted scan must
stay free of host-side impurity or it stops being a scan.  This package
checks those invariants at the AST level, so a future PR that breaks one
fails the tier-1 suite instead of corrupting a cache or an RNG stream.

Rules (each in its own module):

  * **R001 key-discipline** (``keys.py``)  — a ``jax.random`` key consumed
    by two independent sinks inside one function body.
  * **R002 digest-completeness** (``digest.py``) — every ``SwarmConfig`` /
    ``SweepSpec`` field reaches ``fleet/store.point_digest`` or is listed
    in the exemption table with a reason.
  * **R003 in-scan purity** (``purity.py``) — no host-side effects in the
    call graph reachable from ``run_sim`` / ``_epoch`` / ``_tick`` /
    ``ServeEngine.step`` and the scenario-registry callables.
  * **R004 registry/doc consistency** (``consistency.py``) — every
    registry key is referenced by a test and documented in DESIGN.md;
    ``DESIGN.md §N[.M]`` docstring citations must resolve.

Entry points: ``python -m repro.analysis`` (CLI, nonzero exit on
unbaselined findings) and :func:`run` (used by ``tests/test_analysis.py``
to keep the tree clean under tier-1).  Deliberate violations are
allowlisted per (rule, file, symbol) in ``analysis_baseline.toml`` at the
repo root — every entry carries a ``reason`` string.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.analysis.astutil import Finding, Tree
from repro.analysis.baseline import Baseline, load_baseline
from repro.analysis import consistency, digest, keys, purity

RULES = {
    "R001": keys.check,
    "R002": digest.check,
    "R003": purity.check,
    "R004": consistency.check,
}

RULE_DOCS = {
    "R001": "PRNG key consumed by two independent sinks (def-use)",
    "R002": "config field missing from the store digest (no exemption)",
    "R003": "host-side impurity reachable from the jitted scan",
    "R004": "registry key untested/undocumented, or dangling §-citation",
}


def run(root: str, rules: Optional[Sequence[str]] = None,
        baseline: Optional[Baseline] = None,
        use_baseline: bool = True) -> List[Finding]:
    """Run ``rules`` (default: all) over the tree at ``root``; returns the
    findings that survive the baseline (i.e. the ones that should fail)."""
    tree = Tree.load(root)
    if baseline is None and use_baseline:
        baseline = load_baseline(root)
    findings: List[Finding] = []
    for rid in rules or sorted(RULES):
        findings.extend(RULES[rid](tree, baseline))
    if baseline is not None:
        findings = [f for f in findings if not baseline.allows(f)]
    return sorted(findings, key=lambda f: (f.file, f.line, f.rule))
