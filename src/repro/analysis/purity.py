"""R003 — in-scan purity.

``run_sim`` is one jitted ``lax.scan``; everything in its call graph runs
under trace.  A host-side effect there — wall clocks, ``np.random``,
``io_callback`` / ``host_callback``, file or console I/O, ``datetime`` —
either breaks tracing outright or (worse) silently bakes one host value
into the compiled executable, destroying the bit-identical-backends
contract the fleet store's cache keys rely on.  The serve engine's
``step`` shares the constraint: its determinism contract (PR 3) is that
all timestamps come from the caller's clock domain, never wall time.

The rule builds a conservative static call graph over the tree:

  * **roots** — ``run_sim`` / ``_epoch`` / ``_tick`` wherever defined,
    ``step`` methods of ``ServeEngine``-named classes, and every callable
    registered into the scenario registries (``register_mobility`` /
    ``register_channel`` / ``register_channel_edges`` / ``register_fault``
    call sites), since registry dispatch is invisible to static analysis;
  * **edges** — direct calls, ``from``-imported names, module-alias
    attribute calls (``trace_record.write_records``), and ``self.``
    method calls, resolved against each module's import table; calls into
    code outside the tree are ignored.

Any reachable function whose body calls a banned API is a finding
anchored at the function's qualname, with the root→…→function chain in
the message.  Host-side helpers that are *legitimately* impure (e.g. the
fleet dispatch heartbeat, if it ever becomes reachable) go on the
``[[allow]]`` baseline with a reason.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from repro.analysis.astutil import (Finding, Tree, dotted_name, functions,
                                    import_table, resolve_call)

RULE = "R003"
# run_open_loop / slo_indices: the PR 9 observability surface makes the
# same promise as the engine ("timestamps from the caller's clock domain,
# never wall time"), so the open-loop driver and the SLO reducer are
# audited as roots too
ROOT_FUNCS = {"run_sim", "_epoch", "_tick", "run_open_loop", "slo_indices"}
# class entries are *suffix*-matched, so SplitServeEngine.step and
# SyntheticServeEngine.submit (obs/loadgen.py) are roots, not just a
# class literally named ServeEngine
ROOT_METHODS = {("ServeEngine", "step"), ("ServeEngine", "submit")}
REGISTER_FUNCS = {"register_mobility", "register_channel",
                  "register_channel_edges", "register_fault"}

BANNED_PREFIXES = (
    "time.", "datetime.", "numpy.random", "random.",
    "jax.experimental.io_callback", "jax.experimental.host_callback",
    "jax.pure_callback", "jax.debug.callback", "jax.debug.print",
)
BANNED_EXACT = {"open", "print", "input", "time", "datetime"}


def _banned(full: str) -> Optional[str]:
    if full in BANNED_EXACT:
        return full
    for p in BANNED_PREFIXES:
        if full == p.rstrip(".") or full.startswith(p):
            return full
    return None


class _Graph:
    """qualname-level call graph, keyed by (module path, qualname)."""

    def __init__(self, tree: Tree):
        self.tree = tree
        self.funcs: Dict[Tuple[str, str], ast.AST] = {}
        self.imports: Dict[str, Dict[str, str]] = {}
        self.by_name: Dict[str, List[Tuple[str, str]]] = {}
        self.module_of: Dict[str, str] = {}   # dotted module -> path
        for mod in tree.src_modules():
            self.imports[mod.path] = import_table(mod.tree)
            for qual, fn in functions(mod.tree).items():
                self.funcs[(mod.path, qual)] = fn
                self.by_name.setdefault(qual.split(".")[-1], []).append(
                    (mod.path, qual))
            dotted = (mod.path[len("src/"):-len(".py")]
                      .replace("/__init__", "").replace("/", "."))
            self.module_of[dotted] = mod.path

    def _module_path(self, dotted: str) -> Optional[str]:
        """Resolve a dotted module name to a tree path (suffix-tolerant,
        so fixture trees with shallow layouts still resolve)."""
        if dotted in self.module_of:
            return self.module_of[dotted]
        for name, path in self.module_of.items():
            if name.endswith("." + dotted) or dotted.endswith("." + name):
                return path
        return None

    def callees(self, path: str, qual: str) -> List[Tuple[str, str]]:
        fn = self.funcs[(path, qual)]
        imports = self.imports[path]
        cls = qual.split(".")[0] if "." in qual else None
        out: List[Tuple[str, str]] = []
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            if name is None:
                continue
            parts = name.split(".")
            if parts[0] == "self" and cls and len(parts) == 2:
                key = (path, f"{cls}.{parts[1]}")
                if key in self.funcs:
                    out.append(key)
                continue
            if len(parts) == 1:
                # bare name: same module, else a from-import
                if (path, parts[0]) in self.funcs:
                    out.append((path, parts[0]))
                    continue
                origin = imports.get(parts[0])
                if origin and "." in origin:
                    mod_dotted, fname = origin.rsplit(".", 1)
                    tgt = self._module_path(mod_dotted)
                    if tgt and (tgt, fname) in self.funcs:
                        out.append((tgt, fname))
                continue
            # attribute call: resolve the head as a module alias
            origin = imports.get(parts[0])
            if origin:
                dotted = ".".join([origin] + parts[1:-1])
                tgt = self._module_path(dotted)
                if tgt and (tgt, parts[-1]) in self.funcs:
                    out.append((tgt, parts[-1]))
        return out

    def banned_calls(self, path: str, qual: str) -> List[Tuple[int, str]]:
        fn = self.funcs[(path, qual)]
        imports = self.imports[path]
        hits = []
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                full = resolve_call(node, imports)
                if full is not None:
                    b = _banned(full)
                    if b is not None:
                        hits.append((node.lineno, b))
        return hits


def _roots(graph: _Graph, tree: Tree) -> List[Tuple[str, str]]:
    roots: List[Tuple[str, str]] = []
    for (path, qual), _fn in graph.funcs.items():
        base = qual.split(".")[-1]
        if "." not in qual and base in ROOT_FUNCS:
            roots.append((path, qual))
        if "." in qual:
            cls, meth = qual.split(".", 1)
            if any(cls.endswith(c) and meth == m for c, m in ROOT_METHODS):
                roots.append((path, qual))
    # registry-registered callables are dispatch targets of the scan
    for mod in tree.src_modules():
        imports = graph.imports[mod.path]
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call)
                    and (dotted_name(node.func) or "").split(".")[-1]
                    in REGISTER_FUNCS):
                continue
            for a in node.args[1:]:
                name = dotted_name(a)
                if name is None:
                    continue
                parts = name.split(".")
                if len(parts) == 1 and (mod.path, parts[0]) in graph.funcs:
                    roots.append((mod.path, parts[0]))
                elif len(parts) > 1:
                    origin = imports.get(parts[0])
                    if origin:
                        dotted = ".".join([origin] + parts[1:-1])
                        tgt = graph._module_path(dotted)
                        if tgt and (tgt, parts[-1]) in graph.funcs:
                            roots.append((tgt, parts[-1]))
    return sorted(set(roots))


def check(tree: Tree, baseline=None) -> List[Finding]:
    del baseline
    graph = _Graph(tree)
    findings: List[Finding] = []
    chain: Dict[Tuple[str, str], Tuple[str, ...]] = {}
    stack = []
    for r in _roots(graph, tree):
        chain[r] = (r[1],)
        stack.append(r)
    while stack:
        cur = stack.pop()
        for nxt in graph.callees(*cur):
            if nxt not in chain:
                chain[nxt] = chain[cur] + (nxt[1],)
                stack.append(nxt)
    for (path, qual), trail in sorted(chain.items()):
        for line, api in graph.banned_calls(path, qual):
            findings.append(Finding(
                RULE, path, line, qual,
                f"host-side call {api!r} is reachable from the jitted "
                f"scan via {' -> '.join(trail)}"))
    return findings
