"""R001 — PRNG key discipline (def-use over function bodies).

The invariant: a ``jax.random`` key is consumed **once**.  Handing the
same key to two independent sinks — two samplers, a sampler and ``split``,
or a sampler and ``fold_in`` — produces threefry-counter-correlated
streams: the exact bug PR 1 fixed, where the Random/RandomAcyclic offload
coin reused the gumbel target-draw key and "who offloads" became
bit-correlated with "who gets picked".

Analysis (per function body, nested defs included — a closure that
captures an outer key consumes it on the outer function's behalf):

  * **key variables** are parameters named ``key`` / ``rng`` / ``*_key``,
    and any variable assigned (or tuple-unpacked) from
    ``jax.random.split`` / ``fold_in`` / ``PRNGKey``;
  * a **consumption** is any use of a key variable as a call argument —
    sampler, ``split``, ``fold_in``, or an opaque callee (which must be
    assumed to consume it);
  * rebinding (``key = fold_in(key, 1)``) starts a fresh def with its own
    use count; ``if``/``else`` arms count as alternatives (max), not as a
    sequence (sum), so branch-exclusive uses don't false-positive.

A variable with ≥ 2 consumptions is a finding anchored at
``func:variable``.  Scope: ``swarm/``, ``core/``, ``trace/`` under
``src/`` — the modules whose streams the bit-identity contracts cover.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from repro.analysis.astutil import (Finding, Module, Tree, dotted_name,
                                    import_table)

RULE = "R001"
SCOPES = ("/swarm/", "/core/", "/trace/", "/obs/", "/splitcompute/")
# jax.random constructors whose *result* is a key (tracked as new defs)
KEY_MAKERS = {"split", "fold_in", "PRNGKey", "key", "clone"}
_PARAM_KEY = ("key", "rng")


def _is_key_param(name: str) -> bool:
    return name in _PARAM_KEY or name.endswith("_key")


class _RandomNS:
    """Recognizes ``jax.random.<fn>`` under the module's import aliases."""

    def __init__(self, mod: Module):
        self.imports = import_table(mod.tree)

    def maker_call(self, node: ast.AST) -> Optional[str]:
        """'split' / 'fold_in' / 'PRNGKey' if node is such a call."""
        if not isinstance(node, ast.Call):
            return None
        name = dotted_name(node.func)
        if name is None:
            return None
        head, _, rest = name.partition(".")
        full = name
        if head in self.imports:
            origin = self.imports[head]
            full = f"{origin}.{rest}" if rest else origin
        if full.startswith("jax.random.") and full.rsplit(".", 1)[-1] in \
                KEY_MAKERS:
            return full.rsplit(".", 1)[-1]
        return None


class _Counts:
    """Per-def consumption counts: def id -> (var, line-of-def, [uses])."""

    def __init__(self):
        self.defs: Dict[int, Tuple[str, int, List[int]]] = {}
        self.env: Dict[str, int] = {}      # var name -> live def id
        self._next = 0

    def bind(self, var: str, line: int) -> None:
        self.defs[self._next] = (var, line, [])
        self.env[var] = self._next
        self._next += 1

    def use(self, var: str, line: int) -> None:
        if var in self.env:
            self.defs[self.env[var]][2].append(line)


def _scan_function(fn: ast.AST, ns: _RandomNS, mod: Module,
                   findings: List[Finding]) -> None:
    counts = _Counts()
    for arg in ([*fn.args.posonlyargs, *fn.args.args, *fn.args.kwonlyargs]
                if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef))
                else []):
        if _is_key_param(arg.arg):
            counts.bind(arg.arg, fn.lineno)

    def scan_expr(node: ast.AST) -> None:
        """Count key uses inside one expression (call args only)."""
        for call in [n for n in ast.walk(node) if isinstance(n, ast.Call)]:
            args = list(call.args) + [kw.value for kw in call.keywords]
            for a in args:
                if isinstance(a, ast.Name) and a.id in counts.env:
                    counts.use(a.id, a.lineno)

    def bind_targets(target: ast.AST, value: ast.AST) -> None:
        """Track key defs created by an assignment."""
        if ns.maker_call(value) is None:
            return
        names = []
        if isinstance(target, ast.Name):
            names = [target]
        elif isinstance(target, (ast.Tuple, ast.List)):
            names = [e for e in target.elts if isinstance(e, ast.Name)]
        for name in names:
            counts.bind(name.id, name.lineno)

    def scan_block(stmts) -> None:
        for stmt in stmts:
            if isinstance(stmt, ast.Assign):
                scan_expr(stmt.value)
                for t in stmt.targets:
                    bind_targets(t, stmt.value)
            elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
                if stmt.value is not None:
                    scan_expr(stmt.value)
                if isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                    bind_targets(stmt.target, stmt.value)
            elif isinstance(stmt, ast.If):
                scan_expr(stmt.test)
                _scan_branches([stmt.body, stmt.orelse])
            elif isinstance(stmt, (ast.For, ast.AsyncFor)):
                scan_expr(stmt.iter)
                _scan_branches([stmt.body + stmt.orelse])
            elif isinstance(stmt, ast.While):
                scan_expr(stmt.test)
                _scan_branches([stmt.body + stmt.orelse])
            elif isinstance(stmt, (ast.With, ast.AsyncWith)):
                for item in stmt.items:
                    scan_expr(item.context_expr)
                scan_block(stmt.body)
            elif isinstance(stmt, ast.Try):
                _scan_branches([stmt.body + stmt.finalbody]
                               + [h.body for h in stmt.handlers])
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # closure body: uses of *outer* keys count against them;
                # the nested function's own keys are scanned separately
                inner = {a.arg for a in stmt.args.args}
                for call in [n for n in ast.walk(stmt)
                             if isinstance(n, ast.Call)]:
                    for a in (list(call.args)
                              + [kw.value for kw in call.keywords]):
                        if (isinstance(a, ast.Name) and a.id not in inner
                                and a.id in counts.env):
                            counts.use(a.id, a.lineno)
            elif isinstance(stmt, (ast.Return, ast.Expr)):
                if stmt.value is not None:
                    scan_expr(stmt.value)
            else:
                scan_expr(stmt)

    def _scan_branches(branches) -> None:
        """Mutually exclusive arms: per-def use count is the max over
        arms, not the sum — a key consumed once in *each* arm of an
        if/else is consumed once per execution."""
        before = {i: len(uses) for i, (_, _, uses) in counts.defs.items()}
        best: Dict[int, List[int]] = {}
        for branch in branches:
            # rewind to the pre-branch counts, scan, keep the max
            for i, (_, _, uses) in counts.defs.items():
                del uses[before.get(i, 0):]
            env_before = dict(counts.env)
            scan_block(branch)
            for i, (_, _, uses) in counts.defs.items():
                new = uses[before.get(i, 0):]
                if len(new) > len(best.get(i, [])):
                    best[i] = list(new)
            counts.env = env_before
        for i, (_, _, uses) in counts.defs.items():
            del uses[before.get(i, 0):]
            uses.extend(best.get(i, []))

    scan_block(fn.body)
    for var, line, uses in counts.defs.values():
        if len(uses) >= 2:
            findings.append(Finding(
                RULE, mod.path, uses[1], f"{fn.name}:{var}",
                f"key {var!r} (defined line {line}) is consumed "
                f"{len(uses)} times (lines {', '.join(map(str, uses))}); "
                "split or fold_in fresh subkeys per sink"))


def check(tree: Tree, baseline=None) -> List[Finding]:
    del baseline
    findings: List[Finding] = []
    for mod in tree.src_modules():
        if not any(s in f"/{mod.path}" for s in SCOPES):
            continue
        ns = _RandomNS(mod)
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                _scan_function(node, ns, mod, findings)
    return findings
