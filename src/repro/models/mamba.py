"""Mamba-1 block (falcon-mamba-7b) with a chunked selective scan.

    x, z = split(in_proj(u))                # d_inner = expand * d_model
    x    = silu(causal_conv1d(x))
    Δ,B,C = x_proj(x)  ;  Δ = softplus(dt_proj(Δ))
    h_t  = exp(Δ_t A) h_{t-1} + Δ_t B_t x_t      (A diag-negative, [d_in, N])
    y_t  = C_t · h_t + D x_t
    out  = out_proj(y * silu(z))

The train-path scan is *chunked*: an exact associative scan inside chunks of
``cfg.ssm.chunk`` tokens plus a sequential ``lax.scan`` carry across chunks —
the [B, S, d_in, N] tensor is never materialized beyond one chunk (the
full-length version would claim ~34 GB/device at train_4k).  d_inner is
sharded over 'model' (the recurrence is per-channel, so this is
communication-free).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.common import dense_init
from repro.models.rglru import causal_conv1d


def dt_rank_of(cfg: ModelConfig) -> int:
    return cfg.ssm.dt_rank or math.ceil(cfg.d_model / 16)


def init_mamba_block(key, cfg: ModelConfig, dtype):
    s, d = cfg.ssm, cfg.d_model
    d_in = s.expand * d
    R, N = dt_rank_of(cfg), s.d_state
    ks = jax.random.split(key, 6)
    # A init: -(1..N) per channel (S4D-real); dt bias init for softplus range.
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (d_in, 1))
    dt = jnp.exp(jax.random.uniform(ks[0], (d_in,), jnp.float32)
                 * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))   # inverse softplus
    return {
        "in_proj": dense_init(ks[1], (d, 2 * d_in), d, dtype),
        "conv_w": dense_init(ks[2], (s.d_conv, d_in), s.d_conv, dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": dense_init(ks[3], (d_in, R + 2 * N), d_in, dtype),
        "dt_proj": dense_init(ks[4], (R, d_in), R, dtype),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(A),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[5], (d_in, d), d_in, dtype),
    }


def specs_mamba_block(cfg: ModelConfig):
    return {
        "in_proj": P("data", "model"),
        "conv_w": P(None, "model"), "conv_b": P("model"),
        "x_proj": P("model", None),
        "dt_proj": P(None, "model"), "dt_bias": P("model"),
        "A_log": P("model", None), "D": P("model"),
        "out_proj": P("model", "data"),
    }


# ---------------------------------------------------------------------------
# selective scan
# ---------------------------------------------------------------------------


def ssm_coeffs(p, cfg: ModelConfig, x):
    """x [B,S,d_in] (post-conv, fp32) -> decay a [B,S,d_in,N], drive b [.,N],
    readout C [B,S,N]."""
    N = cfg.ssm.d_state
    R = dt_rank_of(cfg)
    dbc = x @ p["x_proj"].astype(x.dtype)               # [B,S,R+2N]
    dt_raw, Bc, Cc = jnp.split(dbc, [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        (dt_raw @ p["dt_proj"].astype(x.dtype)).astype(jnp.float32)
        + p["dt_bias"])                                 # [B,S,d_in]
    A = -jnp.exp(p["A_log"])                            # [d_in, N]
    a = jnp.exp(dt[..., None] * A[None, None])          # [B,S,d_in,N]
    b = (dt[..., None] * Bc.astype(jnp.float32)[:, :, None, :]
         * x.astype(jnp.float32)[..., None])            # [B,S,d_in,N]
    return a, b, Cc.astype(jnp.float32)


def selective_scan_fused(p, cfg: ModelConfig, x, h0=None):
    """Chunked scan with bounded state expansion.

    The FLOP-carrying projections (x_proj, dt_proj — counted exactly by HLO
    cost analysis) run over the full sequence; only the [chunk, d_in, N]
    decay/drive expansion and the associative scan live inside the chunk
    loop, so the [B, S, d_in, N] tensor never materializes (full-sequence
    form claims ~34 GB/device at train_4k).

    x [B, S, d_in] (post-conv, fp32) -> (y [B, S, d_in], h_last [B, d_in, N]).
    """
    Bb, S, d_in = x.shape
    N = cfg.ssm.d_state
    R = dt_rank_of(cfg)
    chunk = min(cfg.ssm.chunk, S)
    if S % chunk != 0:
        chunk = S
    nc = S // chunk
    if h0 is None:
        h0 = jnp.zeros((Bb, d_in, N), jnp.float32)

    # low-rank coefficients over the full sequence ([B,S,R+2N] is small)
    dbc = x @ p["x_proj"].astype(x.dtype)                 # [B,S,R+2N]
    dt_raw, Bc, Cc = jnp.split(dbc, [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        (dt_raw @ p["dt_proj"].astype(x.dtype)).astype(jnp.float32)
        + p["dt_bias"])                                   # [B,S,d_in]
    A = -jnp.exp(p["A_log"])                              # [d_in, N]

    def comb(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    def chunked(t):
        return jnp.moveaxis(t.reshape((Bb, nc, chunk) + t.shape[2:]), 1, 0)

    xs = (chunked(x), chunked(dt), chunked(Bc.astype(jnp.float32)),
          chunked(Cc.astype(jnp.float32)))

    def per_chunk(h, xs_i):
        x_i, dt_i, B_i, C_i = xs_i
        a_i = jnp.exp(dt_i[..., None] * A[None, None])    # [B,c,d,N]
        b_i = dt_i[..., None] * B_i[:, :, None, :] \
            * x_i.astype(jnp.float32)[..., None]
        b_i = b_i.at[:, 0].add(a_i[:, 0] * h)
        _, hh = jax.lax.associative_scan(comb, (a_i, b_i), axis=1)
        y_i = jnp.einsum("bsdn,bsn->bsd", hh, C_i)
        return hh[:, -1], y_i

    if cfg.ssm.chunk_remat:
        per_chunk = jax.checkpoint(
            per_chunk, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        h_last, ys = jax.lax.scan(per_chunk, h0, xs)
    else:   # unrolled for exact HLO cost accounting (dry-run)
        from repro.models.common import unrolled_scan
        h_last, ys = unrolled_scan(per_chunk, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bb, S, d_in)
    return y, h_last


def selective_scan_ref(a, b, C, h0=None, chunk: int = 64):
    """Chunked scan. a,b [B,S,d,N]; C [B,S,N]; h0 [B,d,N].

    Returns y [B,S,d] = C_t · h_t and final state h_last [B,d,N].
    """
    Bb, S, d, N = a.shape
    if h0 is None:
        h0 = jnp.zeros((Bb, d, N), jnp.float32)
    chunk = min(chunk, S)
    if S % chunk != 0:
        chunk = S
    nc = S // chunk

    def comb(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    ac = jnp.moveaxis(a.reshape(Bb, nc, chunk, d, N), 1, 0)
    bc = jnp.moveaxis(b.reshape(Bb, nc, chunk, d, N), 1, 0)
    Cc = jnp.moveaxis(C.reshape(Bb, nc, chunk, N), 1, 0)

    def per_chunk(h, xs):
        a_i, b_i, C_i = xs                             # [B,chunk,d,N]
        b_i = b_i.at[:, 0].add(a_i[:, 0] * h)
        aa, hh = jax.lax.associative_scan(comb, (a_i, b_i), axis=1)
        y_i = jnp.einsum("bsdn,bsn->bsd", hh, C_i)
        return hh[:, -1], y_i

    h_last, ys = jax.lax.scan(per_chunk, h0, (ac, bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bb, S, d)
    return y, h_last


def selective_scan_step(a, b, C, h):
    """Decode: a,b [B,d,N]; C [B,N]; h [B,d,N] -> (y [B,d], h')."""
    h = a * h + b
    y = jnp.einsum("bdn,bn->bd", h, C)
    return y, h


def apply_mamba_block(p, cfg: ModelConfig, u, *, conv_state=None,
                      h_state=None, return_state=False):
    """u [B,S,d] -> y [B,S,d] (+ conv/ssm states when return_state)."""
    cd = u.dtype
    d_in = cfg.ssm.expand * cfg.d_model
    xz = u @ p["in_proj"].astype(cd)
    x, z = jnp.split(xz, 2, axis=-1)
    x, new_conv = causal_conv1d(x, p["conv_w"], p["conv_b"], conv_state)
    x = jax.nn.silu(x.astype(jnp.float32))
    if u.shape[1] == 1 and h_state is not None:        # decode fast path
        a, b, C = ssm_coeffs(p, cfg, x)
        y1, h_last = selective_scan_step(a[:, 0], b[:, 0], C[:, 0], h_state)
        y = y1[:, None, :]
    else:
        y, h_last = selective_scan_fused(p, cfg, x, h0=h_state)
    y = y + p["D"] * x
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(cd)
    out = y @ p["out_proj"].astype(cd)
    if return_state:
        return out, new_conv, h_last
    return out
