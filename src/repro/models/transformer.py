"""Decoder-only transformer LM (dense / moe / vlm families).

Layers are *stacked* ([L, ...] leaves) and executed with ``lax.scan`` +
configurable remat — compact HLO (one layer body), bounded activation
memory, and O(1) split-point extraction for the split-computing engine
(a stage is a static slice of the stacked tree, see ``common.slice_layers``).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models.common import (apply_norm, dt, embed_init, init_norm,
                                 scan_fn, specs_norm)

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def remat_wrap(fn, policy: str):
    if policy == "none":
        return fn
    pol = {"nothing": jax.checkpoint_policies.nothing_saveable,
           "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
           }[policy]
    return jax.checkpoint(fn, policy=pol)


def shard_hint(x, spec, mesh):
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def batch_axes_of(mesh, cfg=None) -> Tuple[str, ...]:
    axes = ("data", "model") if (cfg is not None and cfg.pure_dp) \
        else ("data",)
    if mesh is not None and "pod" in mesh.axis_names:
        return ("pod",) + axes
    return axes


# ---------------------------------------------------------------------------
# layer init / specs
# ---------------------------------------------------------------------------


def init_layer(key, cfg: ModelConfig, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"ln1": init_norm(k1, cfg.d_model, cfg.norm, dtype),
         "attn": attn.init_attention(k2, cfg, dtype),
         "ln2": init_norm(k3, cfg.d_model, cfg.norm, dtype)}
    if cfg.family == "moe":
        p["moe"] = moe_mod.init_moe(k4, cfg, dtype)
    else:
        p["mlp"] = mlp_mod.init_mlp(k4, cfg, dtype)
    return p


def specs_layer(cfg: ModelConfig):
    s = {"ln1": specs_norm(cfg.norm), "attn": attn.specs_attention(cfg),
         "ln2": specs_norm(cfg.norm)}
    if cfg.family == "moe":
        s["moe"] = moe_mod.specs_moe(cfg)
    else:
        s["mlp"] = mlp_mod.specs_mlp(cfg)
    # stacked over L: prepend None axis
    return jax.tree.map(lambda sp: P(*((None,) + tuple(sp))), s,
                        is_leaf=lambda x: isinstance(x, P))


def init_lm(key, cfg: ModelConfig):
    dtype = dt(cfg.param_dtype)
    ke, kl, kh = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.num_layers)
    params = {
        "embed": embed_init(ke, (cfg.vocab_size, cfg.d_model), dtype),
        "layers": jax.vmap(lambda k: init_layer(k, cfg, dtype))(layer_keys),
        "final_norm": init_norm(kh, cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(kh, (cfg.d_model, cfg.vocab_size),
                                       dtype)
    return params


def specs_lm(cfg: ModelConfig):
    s = {"embed": P("model", "data"),
         "layers": specs_layer(cfg),
         "final_norm": specs_norm(cfg.norm)}
    if not cfg.tie_embeddings:
        s["lm_head"] = P("data", "model")
    return s


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def embed_in(params, cfg: ModelConfig, batch, mesh=None):
    """Token / precomputed-embedding input. Returns (h [B,S,d], positions)."""
    cd = dt(cfg.compute_dtype)
    if "embeds" in batch:                      # vlm/audio stub frontend
        h = batch["embeds"].astype(cd)
        B, S = h.shape[:2]
    else:
        h = jnp.take(params["embed"], batch["tokens"], axis=0).astype(cd)
        B, S = batch["tokens"].shape
    if "positions" in batch:
        positions = batch["positions"]         # [B,S] or [R,B,S] (M-RoPE)
    else:
        pos = jnp.arange(S, dtype=jnp.int32)[None, :]
        positions = jnp.broadcast_to(pos, (B, S))
        if cfg.mrope_sections:
            positions = jnp.broadcast_to(
                positions[None], (len(cfg.mrope_sections), B, S))
    h = shard_hint(h, P(batch_axes_of(mesh, cfg), None, None), mesh)
    return h, positions


def head_out(params, cfg: ModelConfig, h, mesh=None):
    h = apply_norm(params["final_norm"], h, cfg.norm)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h,
                            params["embed"].astype(h.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", h,
                            params["lm_head"].astype(h.dtype))
    vocab_ax = None if cfg.pure_dp else "model"
    return shard_hint(logits, P(batch_axes_of(mesh, cfg), None, vocab_ax),
                      mesh)


def _layer_apply(lp, cfg: ModelConfig, h, positions, *, mesh, mode,
                 cache_kv=None, pos_scalar=None):
    """One transformer layer. mode: train|prefill|decode.

    Returns (h, new_cache_kv_or_None, aux).
    """
    a_in = apply_norm(lp["ln1"], h, cfg.norm)
    q, k, v = attn.qkv_project(lp["attn"], cfg, a_in, positions)
    # TP hint: q heads over 'model'.  For head counts that don't divide the
    # axis (28/40/12-head qwens) this is an *uneven* internal sharding —
    # legal for WSC (XLA pads), unlike jit-boundary shardings; the padding
    # waste shows up honestly in the §Roofline useful-FLOP ratio.
    q = shard_hint(q, P(batch_axes_of(mesh), None, "model", None), mesh)
    B, S = h.shape[:2]
    aux = {}
    new_cache = None
    if mode == "decode":
        ck, cv = cache_kv                                  # [B,Skv,Hkv,hd]
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, pos_scalar, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, pos_scalar, 0, 0))
        Skv = ck.shape[1]
        k_positions = jnp.broadcast_to(
            jnp.arange(Skv, dtype=jnp.int32)[None, :], (B, Skv))
        q_position = jnp.full((B,), pos_scalar, jnp.int32)
        o = attn.decode_attention_ref(q, ck, cv, q_position=q_position,
                                      k_positions=k_positions)
        new_cache = (ck, cv)
    else:
        qpos = positions if positions.ndim == 2 else positions[0]
        o = attn.chunked_attention(
            q, k, v, q_positions=qpos, k_positions=qpos, causal=True,
            chunk=cfg.attn_chunk, unroll=not cfg.scan_layers)
        if mode == "prefill":
            new_cache = (k, v)
    h = h + attn.out_project(lp["attn"], cfg, o)

    m_in = apply_norm(lp["ln2"], h, cfg.norm)
    if cfg.family == "moe":
        y, aux = moe_mod.apply_moe(
            lp["moe"], cfg, m_in, mesh=mesh,
            batch_axes=batch_axes_of(mesh),
            fsdp=(mode == "train") or cfg.serve_param_fsdp)
    else:
        y = mlp_mod.apply_mlp(lp["mlp"], cfg, m_in)
    h = h + y
    return h, new_cache, aux


def run_layers(params_layers, cfg: ModelConfig, h, positions, *, mesh=None,
               mode="train", caches=None, pos_scalar=None):
    """Scan over stacked layers.

    train:   returns (h, None, aux_mean)
    prefill: returns (h, {'k': [L,B,S,Hkv,hd], 'v': ...}, aux_mean)
    decode:  caches = {'k': [L,...], 'v': [L,...]}; returns (h, caches', aux)
    """
    def body(carry, xs):
        h = carry
        if mode == "decode":
            lp, ck, cv = xs
            h, new_cache, aux = _layer_apply(lp, cfg, h, positions, mesh=mesh,
                                             mode=mode, cache_kv=(ck, cv),
                                             pos_scalar=pos_scalar)
            return h, (new_cache[0], new_cache[1])
        lp = xs
        h, new_cache, aux = _layer_apply(lp, cfg, h, positions, mesh=mesh,
                                         mode=mode)
        aux_t = (aux.get("moe_aux", jnp.zeros((), jnp.float32)),
                 aux.get("moe_dropped", jnp.zeros((), jnp.float32)))
        if mode == "prefill":
            return h, (new_cache[0], new_cache[1], *aux_t)
        return h, aux_t

    scan = scan_fn(cfg.scan_layers)
    if mode == "decode":
        h, (ks, vs) = scan(body, h,
                           (params_layers, caches["k"], caches["v"]))
        return h, {"k": ks, "v": vs}, {}

    wrapped = remat_wrap(body, cfg.remat_policy) if mode == "train" else body
    h, ys = scan(wrapped, h, params_layers)
    if mode == "prefill":
        ks, vs, aux_l, drop_l = ys
        return h, {"k": ks, "v": vs}, {"moe_aux": jnp.mean(aux_l),
                                       "moe_dropped": jnp.mean(drop_l)}
    aux_l, drop_l = ys
    return h, None, {"moe_aux": jnp.mean(aux_l),
                     "moe_dropped": jnp.mean(drop_l)}


# ---------------------------------------------------------------------------
# top-level model functions
# ---------------------------------------------------------------------------


def forward(params, cfg: ModelConfig, batch, *, mesh=None, mode="train"):
    params = cast_weights(params, cfg)
    h, positions = embed_in(params, cfg, batch, mesh)
    h, caches, aux = run_layers(params["layers"], cfg, h, positions,
                                mesh=mesh, mode=mode)
    logits = head_out(params, cfg, h, mesh)
    return logits, caches, aux


def cast_weights(params, cfg: ModelConfig):
    """Hillclimb lever: pre-convert big weight matrices to compute dtype so
    ZeRO-3 all-gathers move bf16 (convert commutes below the gather).
    Small/1-D leaves (norms, Λ, A_log, dt_bias) stay fp32."""
    if not cfg.cast_weights_bf16:
        return params
    cd = dt(cfg.compute_dtype)

    def one(x):
        if (jnp.issubdtype(x.dtype, jnp.floating) and x.ndim >= 2
                and x.size >= 1_000_000):
            return x.astype(cd)
        return x

    return jax.tree.map(one, params)


def head_loss(params, cfg: ModelConfig, h, labels, mesh=None):
    """Final norm + lm head + CE, optionally sequence-chunked (loss_chunk)
    so the [B, S, vocab] fp32 logits tensor never materializes."""
    C = cfg.loss_chunk
    B, S, _ = h.shape
    if not C or S % C != 0 or S <= C:
        logits = head_out(params, cfg, h, mesh)
        return lm_loss(logits, labels, vocab=cfg.vocab_size)
    nc = S // C
    hc = jnp.moveaxis(h.reshape(B, nc, C, -1), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, nc, C), 1, 0)

    def one(carry, xs):
        h_i, l_i = xs
        logits = head_out(params, cfg, h_i, mesh)
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        gold = jnp.take_along_axis(
            lf, jnp.clip(l_i, 0, cfg.vocab_size - 1)[..., None],
            axis=-1)[..., 0]
        mask = (l_i >= 0).astype(jnp.float32)
        ce, cnt = carry
        return (ce + jnp.sum((lse - gold) * mask), cnt + jnp.sum(mask)), None

    if cfg.scan_layers:
        (ce, cnt), _ = jax.lax.scan(one, (jnp.float32(0), jnp.float32(0)),
                                    (hc, lc))
    else:
        from repro.models.common import unrolled_scan
        (ce, cnt), _ = unrolled_scan(one, (jnp.float32(0), jnp.float32(0)),
                                     (hc, lc))
    return ce / jnp.maximum(cnt, 1.0)


def lm_loss(logits, labels, *, vocab: int, z_coef: float = 0.0):
    """Mean CE (fp32) with optional z-loss; labels < 0 are masked."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(
        lf, jnp.clip(labels, 0, vocab - 1)[..., None], axis=-1)[..., 0]
    ce = lse - gold
    mask = (labels >= 0).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(ce * mask) / denom
    if z_coef:
        loss = loss + z_coef * jnp.sum(jnp.square(lse) * mask) / denom
    return loss


def loss_fn(params, cfg: ModelConfig, batch, *, mesh=None):
    params = cast_weights(params, cfg)
    h, positions = embed_in(params, cfg, batch, mesh)
    h, _, aux = run_layers(params["layers"], cfg, h, positions, mesh=mesh,
                           mode="train")
    loss = head_loss(params, cfg, h, batch["labels"], mesh)
    if cfg.family == "moe" and cfg.moe.router_aux_loss:
        loss = loss + cfg.moe.router_aux_loss * aux["moe_aux"]
    metrics = {"loss": loss, **aux}
    return loss, metrics


def prefill(params, cfg: ModelConfig, batch, *, mesh=None):
    logits, caches, _ = forward(params, cfg, batch, mesh=mesh, mode="prefill")
    # only the last-position logits are needed to start decoding
    return logits[:, -1], caches


def decode_step(params, cfg: ModelConfig, caches, batch, *, mesh=None):
    """batch: {'token': [B,1]} or {'embeds': [B,1,d]}, 'pos': scalar int32."""
    pos = batch["pos"]
    cd = dt(cfg.compute_dtype)
    if "embeds" in batch:
        h = batch["embeds"].astype(cd)
        B = h.shape[0]
    else:
        h = jnp.take(params["embed"], batch["token"], axis=0).astype(cd)
        B = batch["token"].shape[0]
    positions = jnp.broadcast_to(jnp.asarray(pos, jnp.int32)[None, None],
                                 (B, 1))
    if cfg.mrope_sections:
        positions = jnp.broadcast_to(positions[None],
                                     (len(cfg.mrope_sections), B, 1))
    h, caches, _ = run_layers(params["layers"], cfg, h, positions, mesh=mesh,
                              mode="decode", caches=caches, pos_scalar=pos)
    logits = head_out(params, cfg, h, mesh)
    return logits[:, 0], caches


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    hd, Hkv, L = cfg.head_dim_, cfg.num_kv_heads, cfg.num_layers
    cd = dt(cfg.compute_dtype)
    shape = (L, batch, seq_len, Hkv, hd)
    return {"k": jnp.zeros(shape, cd), "v": jnp.zeros(shape, cd)}


def cache_specs(cfg: ModelConfig):
    # sequence dim sharded over 'model' => distributed flash-decode.
    sp = P(None, "data", "model", None, None)
    return {"k": sp, "v": sp}
