"""Attention: GQA with qk-norm / bias / sliding window.

Two execution paths:
  * ``chunked_attention`` — q-chunked, ``lax.scan`` over chunks; peak live
    score tensor is [B, Hkv, G, chunk, S_kv] instead of [B, H, S, S].  This is
    what the multi-pod dry-run lowers (prefill_32k would otherwise claim a
    TB-scale buffer).  On TPU the Pallas ``flash_attention`` kernel replaces it
    (``repro.kernels.ops`` dispatch).
  * ``decode_attention_ref`` — single-query attention over a KV cache, exact
    row softmax; KV cache sequence dim is sharded over ``'model'`` so XLA
    partitions the softmax reductions into partial-max/partial-sum
    all-reduces (distributed flash-decode).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.common import (apply_rope, dense_init, rms_head_norm,
                                 rope_angles)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, dtype):
    d, hd = cfg.d_model, cfg.head_dim_
    Hq, Hkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 8)
    p = {
        "wq": dense_init(ks[0], (d, Hq, hd), d, dtype),
        "wk": dense_init(ks[1], (d, Hkv, hd), d, dtype),
        "wv": dense_init(ks[2], (d, Hkv, hd), d, dtype),
        "wo": dense_init(ks[3], (Hq, hd, d), Hq * hd, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hq, hd), dtype)
        p["bk"] = jnp.zeros((Hkv, hd), dtype)
        p["bv"] = jnp.zeros((Hkv, hd), dtype)
    if cfg.attn_out_bias:
        p["bo"] = jnp.zeros((d,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def specs_attention(cfg: ModelConfig):
    # q heads sharded over 'model' (padded when H % shards != 0); kv heads are
    # few (1..16) => replicated over 'model'; all weights FSDP over 'data'.
    s = {
        "wq": P("data", "model", None),
        "wk": P("data", None, None),
        "wv": P("data", None, None),
        "wo": P("model", None, "data"),
    }
    if cfg.qkv_bias:
        s.update({"bq": P("model", None), "bk": P(None, None),
                  "bv": P(None, None)})
    if cfg.attn_out_bias:
        s["bo"] = P(None)
    if cfg.qk_norm:
        s.update({"q_norm": P(None), "k_norm": P(None)})
    return s


# ---------------------------------------------------------------------------
# projections
# ---------------------------------------------------------------------------


def qkv_project(p, cfg: ModelConfig, x, positions, *, rope=True):
    """x [B,S,d] -> q [B,S,Hq,hd], k,v [B,S,Hkv,hd] (rope applied)."""
    cd = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cd))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q)
        k = rms_head_norm(p["k_norm"], k)
    if rope:
        cos, sin = rope_angles(positions, cfg.head_dim_, cfg.rope_theta,
                               cfg.mrope_sections)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def out_project(p, cfg: ModelConfig, o):
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))
    if cfg.attn_out_bias:
        y = y + p["bo"].astype(o.dtype)
    return y


# ---------------------------------------------------------------------------
# chunked attention (train / prefill reference path)
# ---------------------------------------------------------------------------


def _mask_bias(q_pos, k_pos, causal: bool, window: int):
    """[..., Cq, Sk] additive bias from causal/window constraints."""
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    keep = jnp.ones_like(diff, dtype=bool)
    if causal:
        keep &= diff >= 0
    if window and window > 0:
        keep &= diff < window
    return jnp.where(keep, 0.0, NEG_INF)


def chunked_attention(q, k, v, *, q_positions, k_positions, causal=True,
                      window: int = 0, chunk: int = 1024,
                      standard_layout: bool = True,
                      unroll: bool = False) -> jax.Array:
    """q [B,Sq,Hq,hd], k/v [B,Sk,Hkv,hd] -> [B,Sq,Hq,hd].

    lax.scan over q chunks; per-chunk full-row scores (fp32 softmax).
    On TPU (and under REPRO_FORCE_INTERPRET) dispatches to the Pallas
    flash-attention kernel when positions are the standard arange layout.
    """
    if standard_layout:
        from repro.kernels import ops as kops
        if kops._mode() != "ref" and q.shape[1] % 128 == 0 \
                and k.shape[1] % 128 == 0:
            return kops.flash_attention(q, k, v, causal=causal,
                                        window=window)
    B, Sq, Hq, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)

    chunk = min(chunk, Sq)
    if Sq % chunk != 0:   # smoke-sized inputs: single chunk
        chunk = Sq
    nq = Sq // chunk

    qg = q.reshape(B, nq, chunk, Hkv, G, hd)
    qg = jnp.moveaxis(qg, 1, 0)                       # [nq,B,C,Hkv,G,hd]
    qpos = jnp.moveaxis(q_positions.reshape(B, nq, chunk), 1, 0)

    def one_chunk(_, xs):
        qc, qp = xs                                   # [B,C,Hkv,G,hd], [B,C]
        s = jnp.einsum("bckgd,bskd->bkgcs", qc, k).astype(jnp.float32) * scale
        bias = _mask_bias(qp[:, None, None, :], k_positions[:, None, None, :],
                          causal, window)             # [B,1,1,C,Sk]
        s = s + bias
        m = jnp.max(s, axis=-1, keepdims=True)
        e = jnp.exp(s - jax.lax.stop_gradient(m))
        z = jnp.sum(e, axis=-1, keepdims=True)
        pattn = (e / z).astype(v.dtype)
        o = jnp.einsum("bkgcs,bskd->bckgd", pattn, v)
        return None, o

    if unroll:   # exact HLO cost accounting for the dry-run (DESIGN.md §6)
        from repro.models.common import unrolled_scan
        _, os = unrolled_scan(one_chunk, None, (qg, qpos))
    else:
        _, os = jax.lax.scan(one_chunk, None, (qg, qpos))
    o = jnp.moveaxis(os, 0, 1).reshape(B, Sq, Hq, hd)
    return o


# ---------------------------------------------------------------------------
# decode attention (single new token vs. KV cache)
# ---------------------------------------------------------------------------


def decode_attention_ref(q, k_cache, v_cache, *, q_position, k_positions,
                         window: int = 0,
                         standard_layout: bool = True) -> jax.Array:
    """q [B,1,Hq,hd]; caches [B,S,Hkv,hd]; attend to k_pos <= q_pos.

    Exact row softmax; with the cache S-dim sharded over 'model', XLA emits
    partial max/sum all-reduces (distributed flash-decode).  On TPU,
    arange-layout caches dispatch to the Pallas flash-decode kernel
    (ring-buffer caches — non-monotone k_positions — stay on this path).
    """
    if standard_layout:
        from repro.kernels import ops as kops
        if kops._mode() != "ref" and k_cache.shape[1] % 128 == 0:
            o = kops.decode_attention(q[:, 0], k_cache, v_cache,
                                      q_position[0], window=window)
            return o[:, None]
    B, _, Hq, hd = q.shape
    Hkv = k_cache.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Hkv, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache).astype(jnp.float32) * scale
    diff = q_position[:, None] - k_positions[:, :]     # [B,S] (broadcast pos)
    keep = (diff >= 0) & (k_positions >= 0)   # ring-buffer unwritten slots < 0
    if window and window > 0:
        keep &= diff < window
    s = s + jnp.where(keep, 0.0, NEG_INF)[:, None, None, :]
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache)
    return o.reshape(B, 1, Hq, hd)
