"""RecurrentGemma-style hybrid LM: repeating (rec, rec, attn) superblocks.

Every residual layer is  ln1 → mixer → +res → ln2 → MLP → +res  where the
mixer alternates between an RG-LRU recurrent block and *local* (windowed)
attention per ``cfg.hybrid.pattern``.  Layers are scanned per-superblock so
the stacked-params trick still applies with a heterogeneous pattern; the
remainder layers (38 = 12×3 + 2 for the 9b config) form a homogeneous tail.

Local attention + bounded recurrent state is what makes `long_500k`
tractable: the decode cache is O(window + lru_width), not O(S).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models import rglru
from repro.models.common import (apply_norm, dt, embed_init, init_norm,
                                 scan_fn, specs_norm)
from repro.models.transformer import (batch_axes_of, cast_weights, head_loss,
                                      head_out, remat_wrap, shard_hint)


def _pattern(cfg: ModelConfig):
    pat = cfg.hybrid.pattern
    L = cfg.num_layers
    n_super, tail = divmod(L, len(pat))
    tail_types = pat[:tail]
    assert len(set(tail_types)) <= 1, "tail layers must share a mixer type"
    return pat, n_super, tail, (tail_types[0] if tail else None)


# ---------------------------------------------------------------------------
# init / specs
# ---------------------------------------------------------------------------


def _init_sublayer(key, cfg: ModelConfig, kind: str, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    mixer = (rglru.init_rec_block(k1, cfg, dtype) if kind == "rec"
             else attn.init_attention(k1, cfg, dtype))
    return {"ln1": init_norm(k2, cfg.d_model, cfg.norm, dtype),
            "mixer": mixer,
            "ln2": init_norm(k3, cfg.d_model, cfg.norm, dtype),
            "mlp": mlp_mod.init_mlp(k2, cfg, dtype)}


def _specs_sublayer(cfg: ModelConfig, kind: str):
    mixer = (rglru.specs_rec_block(cfg) if kind == "rec"
             else attn.specs_attention(cfg))
    return {"ln1": specs_norm(cfg.norm), "mixer": mixer,
            "ln2": specs_norm(cfg.norm), "mlp": mlp_mod.specs_mlp(cfg)}


def init_hybrid(key, cfg: ModelConfig):
    dtype = dt(cfg.param_dtype)
    pat, n_super, tail, tail_kind = _pattern(cfg)
    ke, kh, ksup, ktail = jax.random.split(key, 4)

    def init_super(k):
        ks = jax.random.split(k, len(pat))
        return {f"s{i}_{kind}": _init_sublayer(ks[i], cfg, kind, dtype)
                for i, kind in enumerate(pat)}

    params = {
        "embed": embed_init(ke, (cfg.vocab_size, cfg.d_model), dtype),
        "super": jax.vmap(init_super)(jax.random.split(ksup, n_super)),
        "final_norm": init_norm(kh, cfg.d_model, cfg.norm, dtype),
    }
    if tail:
        params["tail"] = jax.vmap(
            lambda k: _init_sublayer(k, cfg, tail_kind, dtype))(
                jax.random.split(ktail, tail))
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(kh, (cfg.d_model, cfg.vocab_size),
                                       dtype)
    return params


def specs_hybrid(cfg: ModelConfig):
    pat, n_super, tail, tail_kind = _pattern(cfg)
    stack = lambda tree: jax.tree.map(
        lambda sp: P(*((None,) + tuple(sp))), tree,
        is_leaf=lambda x: isinstance(x, P))
    s = {
        "embed": P("model", "data"),
        "super": stack({f"s{i}_{kind}": _specs_sublayer(cfg, kind)
                        for i, kind in enumerate(pat)}),
        "final_norm": specs_norm(cfg.norm),
    }
    if tail:
        s["tail"] = stack(_specs_sublayer(cfg, tail_kind))
    if not cfg.tie_embeddings:
        s["lm_head"] = P("data", "model")
    return s


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _apply_sublayer(lp, cfg: ModelConfig, kind, h, positions, *, mode,
                    cache=None, pos_scalar=None):
    """cache (decode): rec -> (conv_state, h_state); attn -> (ck, cv).
    Returns (h, new_cache)."""
    W = cfg.hybrid.window
    x = apply_norm(lp["ln1"], h, cfg.norm)
    new_cache = None
    if kind == "rec":
        if mode == "decode":
            conv_s, h_s = cache
            y, conv_s, h_s = rglru.apply_rec_block(
                lp["mixer"], cfg, x, conv_state=conv_s, h_state=h_s,
                return_state=True)
            new_cache = (conv_s, h_s)
        elif mode == "prefill":
            y, conv_s, h_s = rglru.apply_rec_block(lp["mixer"], cfg, x,
                                                   return_state=True)
            new_cache = (conv_s, h_s)
        else:
            y = rglru.apply_rec_block(lp["mixer"], cfg, x)
    else:
        q, k, v = attn.qkv_project(lp["mixer"], cfg, x, positions)
        B = h.shape[0]
        if mode == "decode":
            ck, cv = cache                         # ring buffers [B,W,Hkv,hd]
            slot = jnp.mod(pos_scalar, W)
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                              (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                              (0, slot, 0, 0))
            sl = jnp.arange(W, dtype=jnp.int32)
            k_pos = pos_scalar - jnp.mod(pos_scalar - sl, W)   # may be < 0
            k_positions = jnp.broadcast_to(k_pos[None, :], (B, W))
            q_position = jnp.full((B,), pos_scalar, jnp.int32)
            o = attn.decode_attention_ref(q, ck, cv, q_position=q_position,
                                          k_positions=k_positions, window=W,
                                          standard_layout=False)
            new_cache = (ck, cv)
        else:
            qpos = positions
            o = attn.chunked_attention(q, k, v, q_positions=qpos,
                                       k_positions=qpos, causal=True,
                                       window=W, chunk=cfg.attn_chunk,
                                       unroll=not cfg.scan_layers)
            if mode == "prefill":
                S = k.shape[1]
                Wc = min(W, S)
                kl, vl = k[:, -Wc:], v[:, -Wc:]
                pl = jnp.arange(S - Wc, S, dtype=jnp.int32)
                slots = jnp.mod(pl, W)
                ck = jnp.zeros((B, W) + k.shape[2:], k.dtype
                               ).at[:, slots].set(kl)
                cv = jnp.zeros((B, W) + v.shape[2:], v.dtype
                               ).at[:, slots].set(vl)
                new_cache = (ck, cv)
        o = attn.out_project(lp["mixer"], cfg, o)
        y = o
    h = h + y
    m = apply_norm(lp["ln2"], h, cfg.norm)
    h = h + mlp_mod.apply_mlp(lp["mlp"], cfg, m)
    return h, new_cache


def _run_super(params, cfg: ModelConfig, h, positions, *, mode,
               caches=None, pos_scalar=None, mesh=None):
    pat, n_super, tail, tail_kind = _pattern(cfg)

    def super_body(carry, xs):
        h = carry
        if mode == "decode":
            lp, cin = xs
        else:
            lp, cin = xs, None
        new_caches = {}
        for i, kind in enumerate(pat):
            name = f"s{i}_{kind}"
            c_i = cin[name] if (mode == "decode") else None
            h, nc = _apply_sublayer(lp[name], cfg, kind, h, positions,
                                    mode=mode, cache=c_i,
                                    pos_scalar=pos_scalar)
            if mode in ("decode", "prefill"):
                new_caches[name] = nc
        if mode in ("decode", "prefill"):
            return h, new_caches
        return h, None

    body = remat_wrap(super_body, cfg.remat_policy) if mode == "train" \
        else super_body
    scan = scan_fn(cfg.scan_layers)
    if mode == "decode":
        h, sc = scan(body, h, (params["super"], caches["super"]))
    elif mode == "prefill":
        h, sc = scan(body, h, params["super"])
    else:
        h, _ = scan(body, h, params["super"])
        sc = None

    tc = None
    if tail:
        def tail_body(carry, xs):
            h = carry
            if mode == "decode":
                lp, cin = xs
            else:
                lp, cin = xs, None
            h, nc = _apply_sublayer(lp, cfg, tail_kind, h, positions,
                                    mode=mode, cache=cin,
                                    pos_scalar=pos_scalar)
            if mode in ("decode", "prefill"):
                return h, nc
            return h, None

        tbody = remat_wrap(tail_body, cfg.remat_policy) if mode == "train" \
            else tail_body
        if mode == "decode":
            h, tc = scan(tbody, h, (params["tail"], caches["tail"]))
        elif mode == "prefill":
            h, tc = scan(tbody, h, params["tail"])
        else:
            h, _ = scan(tbody, h, params["tail"])
    return h, ({"super": sc, "tail": tc} if mode in ("decode", "prefill")
               else None)


def forward(params, cfg: ModelConfig, batch, *, mesh=None, mode="train"):
    params = cast_weights(params, cfg)
    cd = dt(cfg.compute_dtype)
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0).astype(cd)
    h = shard_hint(h, P(batch_axes_of(mesh), None, None), mesh)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    h, caches = _run_super(params, cfg, h, positions, mode=mode, mesh=mesh)
    logits = head_out(params, cfg, h, mesh)
    return logits, caches, {}


def loss_fn(params, cfg: ModelConfig, batch, *, mesh=None):
    params = cast_weights(params, cfg)
    cd = dt(cfg.compute_dtype)
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0).astype(cd)
    h = shard_hint(h, P(batch_axes_of(mesh), None, None), mesh)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    h, _ = _run_super(params, cfg, h, positions, mode="train", mesh=mesh)
    loss = head_loss(params, cfg, h, batch["labels"], mesh)
    return loss, {"loss": loss}


def prefill(params, cfg: ModelConfig, batch, *, mesh=None):
    logits, caches, _ = forward(params, cfg, batch, mesh=mesh, mode="prefill")
    return logits[:, -1], caches


def decode_step(params, cfg: ModelConfig, caches, batch, *, mesh=None):
    cd = dt(cfg.compute_dtype)
    pos = batch["pos"]
    tok = batch["token"]
    B = tok.shape[0]
    h = jnp.take(params["embed"], tok, axis=0).astype(cd)
    positions = jnp.broadcast_to(jnp.asarray(pos, jnp.int32)[None, None],
                                 (B, 1))
    h, caches = _run_super(params, cfg, h, positions, mode="decode",
                           caches=caches, pos_scalar=pos, mesh=mesh)
    logits = head_out(params, cfg, h, mesh)
    return logits[:, 0], caches


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    """Decode caches; attention caches are ring buffers of size window."""
    pat, n_super, tail, tail_kind = _pattern(cfg)
    cd = dt(cfg.compute_dtype)
    w = cfg.hybrid.lru_width or cfg.d_model
    W = cfg.hybrid.window
    cw = cfg.hybrid.conv_width

    def one(kind, n):
        if kind == "rec":
            return (jnp.zeros((n, batch, cw - 1, w), cd),
                    jnp.zeros((n, batch, w), jnp.float32))
        return (jnp.zeros((n, batch, W, cfg.num_kv_heads, cfg.head_dim_), cd),
                jnp.zeros((n, batch, W, cfg.num_kv_heads, cfg.head_dim_), cd))

    caches = {"super": {f"s{i}_{kind}": one(kind, n_super)
                        for i, kind in enumerate(pat)}}
    caches["tail"] = one(tail_kind, tail) if tail else None
    return caches


def cache_specs(cfg: ModelConfig):
    pat, n_super, tail, tail_kind = _pattern(cfg)

    def one(kind):
        if kind == "rec":
            return (P(None, "data", None, "model"),
                    P(None, "data", "model"))
        return (P(None, "data", "model", None, None),
                P(None, "data", "model", None, None))

    s = {"super": {f"s{i}_{kind}": one(kind) for i, kind in enumerate(pat)}}
    s["tail"] = one(tail_kind) if tail else None
    return s
