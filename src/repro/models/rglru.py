"""Griffin/RecurrentGemma recurrent block: conv1d + RG-LRU + gated output.

RG-LRU (arXiv:2402.19427 eq. 1-4):
    r_t = sigmoid(W_a x_t)          (recurrence gate, block-diag W_a)
    i_t = sigmoid(W_x x_t)          (input gate,      block-diag W_x)
    a_t = a^(c * r_t),  a = sigmoid(Λ)    (elementwise)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training path uses an exact associative scan (first-order linear recurrence
is associative under (a, b) ∘ (a', b') = (a·a', a'·b + b')); decode is the
one-step update.  Gate matrices are block-diagonal with 16 blocks so the
blocks align with the 16-way 'model' sharding of the width dimension.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.common import dense_init

N_GATE_BLOCKS = 16
C_SOFTPLUS = 8.0   # Λ init offset so a ≈ 0.9..0.999


def init_rec_block(key, cfg: ModelConfig, dtype):
    h = cfg.hybrid
    d, w = cfg.d_model, (h.lru_width or cfg.d_model)
    nb = min(N_GATE_BLOCKS, w)
    bs = w // nb
    ks = jax.random.split(key, 7)
    # Λ init: a uniform in [0.9, 0.999] => Λ = logit(a^(1/c)) approx — use
    # the Griffin recipe: -softplus-inverse spread.
    u = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u ** (1.0 / h.c) / (1 - u ** (1.0 / h.c)))
    return {
        "w_in_x": dense_init(ks[1], (d, w), d, dtype),     # recurrence branch
        "w_in_g": dense_init(ks[2], (d, w), d, dtype),     # gelu gate branch
        "conv_w": dense_init(ks[3], (h.conv_width, w), h.conv_width, dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "gate_a": dense_init(ks[4], (nb, bs, bs), bs, dtype),
        "gate_x": dense_init(ks[5], (nb, bs, bs), bs, dtype),
        "lam": lam.astype(jnp.float32),
        "w_out": dense_init(ks[6], (w, d), w, dtype),
    }


def specs_rec_block(cfg: ModelConfig):
    return {
        "w_in_x": P("data", "model"), "w_in_g": P("data", "model"),
        "conv_w": P(None, "model"), "conv_b": P("model"),
        "gate_a": P("model", None, None), "gate_x": P("model", None, None),
        "lam": P("model"), "w_out": P("model", "data"),
    }


# ---------------------------------------------------------------------------
# RG-LRU recurrence
# ---------------------------------------------------------------------------


def _gates(p, x, cfg: ModelConfig):
    """Block-diagonal gate projections. x [B,S,w] -> r, i [B,S,w]."""
    w = x.shape[-1]
    nb = p["gate_a"].shape[0]
    bs = w // nb
    xb = x.reshape(x.shape[:-1] + (nb, bs))
    r = jnp.einsum("bsnd,nde->bsne", xb, p["gate_a"].astype(x.dtype))
    i = jnp.einsum("bsnd,nde->bsne", xb, p["gate_x"].astype(x.dtype))
    r = jax.nn.sigmoid(r.reshape(x.shape).astype(jnp.float32))
    i = jax.nn.sigmoid(i.reshape(x.shape).astype(jnp.float32))
    return r, i


def rglru_coeffs(p, x, cfg: ModelConfig):
    """a_t, b_t of the linear recurrence h_t = a_t h + b_t (fp32)."""
    r, i = _gates(p, x, cfg)
    log_a = -cfg.hybrid.c * jax.nn.softplus(p["lam"]) * r   # log a_t <= 0
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed stably via expm1: 1-a^2 = -expm1(2 log a)
    norm = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    b = norm * (i * x.astype(jnp.float32))
    return a, b


def rglru_scan_ref(a, b, h0=None):
    """Associative scan of h_t = a_t h_{t-1} + b_t over axis 1 (seq).

    a, b: [B, S, w] fp32; h0 [B, w] initial state. Returns (h_seq, h_last).
    """
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def comb(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(comb, (a, b), axis=1)
    return hh, hh[:, -1]


def rglru_step(a, b, h):
    """One decode step: a, b [B, w]; h [B, w]."""
    return a * h + b


# ---------------------------------------------------------------------------
# temporal conv (depthwise, causal, width cw)
# ---------------------------------------------------------------------------


def causal_conv1d(x, conv_w, conv_b, state=None):
    """x [B,S,w]; conv_w [cw, w] depthwise causal conv.

    state: [B, cw-1, w] trailing inputs from the previous segment (decode).
    Returns (y [B,S,w], new_state [B, cw-1, w]).
    """
    cw = conv_w.shape[0]
    B, S, w = x.shape
    if state is None:
        state = jnp.zeros((B, cw - 1, w), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)           # [B, S+cw-1, w]
    y = sum(xp[:, i:i + S, :] * conv_w[i][None, None, :].astype(x.dtype)
            for i in range(cw))
    y = y + conv_b.astype(x.dtype)
    new_state = xp[:, -(cw - 1):, :] if cw > 1 else state
    return y, new_state


def apply_rec_block(p, cfg: ModelConfig, x, *, conv_state=None, h_state=None,
                    return_state=False):
    """Full recurrent block. x [B,S,d] -> y [B,S,d] (+ states)."""
    cd = x.dtype
    xr = x @ p["w_in_x"].astype(cd)                    # recurrence branch
    xg = jax.nn.gelu(x @ p["w_in_g"].astype(cd))       # gate branch
    xr, new_conv = causal_conv1d(xr, p["conv_w"], p["conv_b"], conv_state)
    a, b = rglru_coeffs(p, xr, cfg)
    if x.shape[1] == 1 and h_state is not None:        # decode fast path
        h_last = rglru_step(a[:, 0], b[:, 0], h_state)
        h = h_last[:, None, :]
    else:
        h0 = h_state
        h, h_last = rglru_scan_ref(a, b, h0)
    y = (h.astype(cd) * xg) @ p["w_out"].astype(cd)
    if return_state:
        return y, new_conv, h_last
    return y
