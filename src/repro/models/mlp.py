"""Dense FFN: SwiGLU / GeGLU / plain-GELU."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.common import act_fn, dense_init


def init_mlp(key, cfg: ModelConfig, dtype):
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act in ("swiglu", "geglu"):
        return {"w_gate": dense_init(ks[0], (d, ff), d, dtype),
                "w_up": dense_init(ks[1], (d, ff), d, dtype),
                "w_down": dense_init(ks[2], (ff, d), ff, dtype)}
    return {"w_up": dense_init(ks[0], (d, ff), d, dtype),
            "b_up": jnp.zeros((ff,), dtype),
            "w_down": dense_init(ks[1], (ff, d), ff, dtype),
            "b_down": jnp.zeros((d,), dtype)}


def specs_mlp(cfg: ModelConfig):
    if cfg.act in ("swiglu", "geglu"):
        return {"w_gate": P("data", "model"), "w_up": P("data", "model"),
                "w_down": P("model", "data")}
    return {"w_up": P("data", "model"), "b_up": P("model"),
            "w_down": P("model", "data"), "b_down": P(None)}


def apply_mlp(p, cfg: ModelConfig, x):
    cd = x.dtype
    a = act_fn(cfg.act)
    if cfg.act in ("swiglu", "geglu"):
        g = a(x @ p["w_gate"].astype(cd))
        u = x @ p["w_up"].astype(cd)
        return (g * u) @ p["w_down"].astype(cd)
    h = a(x @ p["w_up"].astype(cd) + p["b_up"].astype(cd))
    return h @ p["w_down"].astype(cd) + p["b_down"].astype(cd)
