"""Shared building blocks: inits, norms, activations, rotary embeddings.

Parameters are plain nested dicts of jnp arrays.  Every ``init_*`` has a
matching ``specs_*`` returning a PyTree of ``jax.sharding.PartitionSpec``
templates over logical axes ``'data'`` (batch/FSDP) and ``'model'`` (tensor).
``repro.launch.mesh.resolve_specs`` maps the templates onto a concrete mesh
(multi-pod meshes substitute ``('pod','data')`` for ``'data'``).
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# dtype / init helpers
# ---------------------------------------------------------------------------


def dt(name: str):
    return jnp.dtype(name)


def dense_init(key, shape, in_axis_size, dtype) -> jax.Array:
    """Truncated-normal fan-in init (LeCun-ish, matches common LM practice)."""
    std = 1.0 / math.sqrt(max(in_axis_size, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(key, d, kind, dtype):
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype)}


def specs_norm(kind):
    if kind == "layernorm":
        return {"scale": P(None), "bias": P(None)}
    return {"scale": P(None)}


def apply_norm(params, x, kind="rmsnorm", eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * params["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(scale, x, eps=1e-6):
    """Per-head RMS norm over head_dim (qwen3 qk-norm); scale [head_dim]."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "swiglu": jax.nn.silu, "geglu": jax.nn.gelu}[name]


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE + M-RoPE)
# ---------------------------------------------------------------------------


def rope_angles(positions: jax.Array, head_dim: int, theta: float,
                sections: Sequence[int] = ()) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables.

    positions: [B, S] (standard RoPE) or [R, B, S] with R == len(sections)
      (M-RoPE: per-frequency-section position streams, qwen2-vl).
    Returns cos, sin of shape [B, S, head_dim] (half-rotation layout).
    """
    half = head_dim // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    if sections:
        assert positions.ndim == 3 and positions.shape[0] == len(sections), (
            "M-RoPE expects positions [R, B, S]")
        # section id per frequency index: freq f takes its position stream
        # from section sec_id[f] (qwen2-vl temporal/height/width split).
        sec_id = jnp.concatenate([
            jnp.full((n,), i, dtype=jnp.int32) for i, n in enumerate(sections)])
        pos = positions.astype(jnp.float32)            # [R, B, S]
        ang_all = pos[..., None] * inv_freq            # [R, B, S, half]
        idx = jnp.broadcast_to(sec_id[None, None, None, :],
                               (1,) + ang_all.shape[1:])
        ang = jnp.squeeze(jnp.take_along_axis(ang_all, idx, axis=0), axis=0)
    else:
        pos = positions.astype(jnp.float32)            # [B, S]
        ang = pos[..., None] * inv_freq                # [B, S, half]
    cos = jnp.concatenate([jnp.cos(ang)] * 2, axis=-1)
    sin = jnp.concatenate([jnp.sin(ang)] * 2, axis=-1)
    return cos, sin


def rotate_half(x):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([-x2, x1], axis=-1)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [B, S, H, hd]; cos/sin: [B, S, hd]."""
    c = cos[:, :, None, :].astype(jnp.float32)
    s = sin[:, :, None, :].astype(jnp.float32)
    xf = x.astype(jnp.float32)
    return (xf * c + rotate_half(xf) * s).astype(x.dtype)


# ---------------------------------------------------------------------------
# scan-or-unroll over stacked layers
# ---------------------------------------------------------------------------


def unrolled_scan(body, carry, xs, length: Optional[int] = None):
    """Drop-in for ``lax.scan`` that python-unrolls the loop.

    The dry-run uses this (cfg.scan_layers=False) because XLA's HLO cost
    analysis counts a while-loop body once instead of ×trip-count — unrolled
    HLO gives exact FLOP/byte/collective accounting for §Roofline.
    """
    if length is None:
        length = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(length):
        xsl = None if xs is None else jax.tree.map(lambda a, i=i: a[i], xs)
        carry, y = body(carry, xsl)
        ys.append(y)
    if not ys or all(l is None for l in jax.tree.leaves(
            ys[0], is_leaf=lambda x: x is None)):
        return carry, None
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *ys)
    return carry, stacked


def scan_fn(cfg_scan_layers: bool):
    return jax.lax.scan if cfg_scan_layers else unrolled_scan


# ---------------------------------------------------------------------------
# tree utilities
# ---------------------------------------------------------------------------


def slice_layers(tree, start: int, stop: int):
    """Static slice of stacked-layer params (split-computing stage extraction)."""
    return jax.tree.map(lambda a: a[start:stop], tree)


def count_params(tree) -> int:
    return int(sum(x.size for x in jax.tree.leaves(tree)))
