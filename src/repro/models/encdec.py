"""Whisper-style encoder-decoder (audio family; conv frontend stubbed).

Encoder consumes pre-computed frame embeddings [B, F, d] (the conv1d+GELU
frontend is a stub per the assignment), adds learned positions, runs
bidirectional self-attention layers.  Decoder layers: causal self-attention
(+KV cache), cross-attention over the encoder memory (cross K/V computed
once at prefill), LayerNorm + GELU MLP, learned positions, no RoPE.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models.common import (apply_norm, dt, embed_init, init_norm,
                                 scan_fn, specs_norm)
from repro.models.transformer import (batch_axes_of, lm_loss, remat_wrap,
                                      shard_hint)

# ---------------------------------------------------------------------------
# init / specs
# ---------------------------------------------------------------------------


def _init_enc_layer(key, cfg, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {"ln1": init_norm(k1, cfg.d_model, cfg.norm, dtype),
            "attn": attn.init_attention(k2, cfg, dtype),
            "ln2": init_norm(k3, cfg.d_model, cfg.norm, dtype),
            "mlp": mlp_mod.init_mlp(k4, cfg, dtype)}


def _init_dec_layer(key, cfg, dtype):
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    return {"ln1": init_norm(k1, cfg.d_model, cfg.norm, dtype),
            "self_attn": attn.init_attention(k2, cfg, dtype),
            "ln_x": init_norm(k3, cfg.d_model, cfg.norm, dtype),
            "cross_attn": attn.init_attention(k4, cfg, dtype),
            "ln2": init_norm(k5, cfg.d_model, cfg.norm, dtype),
            "mlp": mlp_mod.init_mlp(k6, cfg, dtype)}


def init_encdec(key, cfg: ModelConfig):
    dtype = dt(cfg.param_dtype)
    e = cfg.encdec
    ke, kp1, kp2, kenc, kdec, kn1, kn2 = jax.random.split(key, 7)
    return {
        "embed": embed_init(ke, (cfg.vocab_size, cfg.d_model), dtype),
        "enc_pos": embed_init(kp1, (e.source_positions, cfg.d_model), dtype),
        "dec_pos": embed_init(kp2, (e.max_target_positions, cfg.d_model),
                              dtype),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(
            jax.random.split(kenc, e.encoder_layers)),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg, dtype))(
            jax.random.split(kdec, cfg.num_layers)),
        "enc_norm": init_norm(kn1, cfg.d_model, cfg.norm, dtype),
        "dec_norm": init_norm(kn2, cfg.d_model, cfg.norm, dtype),
    }


def specs_encdec(cfg: ModelConfig):
    stack = lambda tree: jax.tree.map(
        lambda sp: P(*((None,) + tuple(sp))), tree,
        is_leaf=lambda x: isinstance(x, P))
    enc_layer = {"ln1": specs_norm(cfg.norm),
                 "attn": attn.specs_attention(cfg),
                 "ln2": specs_norm(cfg.norm), "mlp": mlp_mod.specs_mlp(cfg)}
    dec_layer = {"ln1": specs_norm(cfg.norm),
                 "self_attn": attn.specs_attention(cfg),
                 "ln_x": specs_norm(cfg.norm),
                 "cross_attn": attn.specs_attention(cfg),
                 "ln2": specs_norm(cfg.norm), "mlp": mlp_mod.specs_mlp(cfg)}
    return {"embed": P("model", "data"),
            "enc_pos": P(None, "data"), "dec_pos": P(None, "data"),
            "enc_layers": stack(enc_layer), "dec_layers": stack(dec_layer),
            "enc_norm": specs_norm(cfg.norm),
            "dec_norm": specs_norm(cfg.norm)}


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------


def encode(params, cfg: ModelConfig, enc_embeds, *, mesh=None):
    cd = dt(cfg.compute_dtype)
    B, F, _ = enc_embeds.shape
    h = enc_embeds.astype(cd) + params["enc_pos"][None, :F].astype(cd)
    h = shard_hint(h, P(batch_axes_of(mesh), None, None), mesh)
    pos = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32)[None], (B, F))

    def body(carry, lp):
        h = carry
        a = apply_norm(lp["ln1"], h, cfg.norm)
        q, k, v = attn.qkv_project(lp["attn"], cfg, a, pos, rope=False)
        o = attn.chunked_attention(q, k, v, q_positions=pos, k_positions=pos,
                                   causal=False, chunk=cfg.attn_chunk,
                                   unroll=not cfg.scan_layers)
        h = h + attn.out_project(lp["attn"], cfg, o)
        m = apply_norm(lp["ln2"], h, cfg.norm)
        return h + mlp_mod.apply_mlp(lp["mlp"], cfg, m), None

    wrapped = remat_wrap(body, cfg.remat_policy)
    h, _ = scan_fn(cfg.scan_layers)(wrapped, h, params["enc_layers"])
    return apply_norm(params["enc_norm"], h, cfg.norm)


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------


def _dec_layer(lp, cfg, h, positions, *, mode, memory=None, cache=None,
               pos_scalar=None):
    """cache (decode): (ck, cv, xk, xv) — self KV + precomputed cross KV."""
    B = h.shape[0]
    a = apply_norm(lp["ln1"], h, cfg.norm)
    q, k, v = attn.qkv_project(lp["self_attn"], cfg, a, positions, rope=False)
    new_cache = None
    if mode == "decode":
        ck, cv, xk, xv = cache
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, pos_scalar, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, pos_scalar, 0, 0))
        Skv = ck.shape[1]
        k_positions = jnp.broadcast_to(
            jnp.arange(Skv, dtype=jnp.int32)[None], (B, Skv))
        q_position = jnp.full((B,), pos_scalar, jnp.int32)
        o = attn.decode_attention_ref(q, ck, cv, q_position=q_position,
                                      k_positions=k_positions)
    else:
        o = attn.chunked_attention(q, k, v, q_positions=positions,
                                   k_positions=positions, causal=True,
                                   chunk=cfg.attn_chunk,
                                   unroll=not cfg.scan_layers)
    h = h + attn.out_project(lp["self_attn"], cfg, o)

    # cross-attention
    x_in = apply_norm(lp["ln_x"], h, cfg.norm)
    qx = attn.qkv_project(lp["cross_attn"], cfg, x_in, positions,
                          rope=False)[0]
    if mode == "decode":
        kx, vx = xk, xv
        new_cache = (ck, cv, xk, xv)
    else:
        mpos = jnp.broadcast_to(
            jnp.arange(memory.shape[1], dtype=jnp.int32)[None],
            (B, memory.shape[1]))
        _, kx, vx = attn.qkv_project(lp["cross_attn"], cfg, memory, mpos,
                                     rope=False)
        if mode == "prefill":
            new_cache = (k, v, kx, vx)
    F = kx.shape[1]
    fpos = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32)[None], (B, F))
    if mode == "decode":
        ox = attn.decode_attention_ref(
            qx, kx, vx, q_position=jnp.full((B,), F - 1 + 10**9, jnp.int32),
            k_positions=fpos)   # huge q_pos => attend all memory
    else:
        qpos = positions
        ox = attn.chunked_attention(qx, kx, vx, q_positions=qpos,
                                    k_positions=fpos, causal=False,
                                    chunk=cfg.attn_chunk,
                                    unroll=not cfg.scan_layers)
    h = h + attn.out_project(lp["cross_attn"], cfg, ox)

    m = apply_norm(lp["ln2"], h, cfg.norm)
    h = h + mlp_mod.apply_mlp(lp["mlp"], cfg, m)
    return h, new_cache


def decode_tokens(params, cfg: ModelConfig, tokens, memory, *, mesh=None,
                  mode="train"):
    cd = dt(cfg.compute_dtype)
    B, S = tokens.shape
    h = (jnp.take(params["embed"], tokens, axis=0).astype(cd)
         + params["dec_pos"][None, :S].astype(cd))
    h = shard_hint(h, P(batch_axes_of(mesh), None, None), mesh)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(carry, lp):
        h = carry
        h, nc = _dec_layer(lp, cfg, h, positions, mode=mode, memory=memory)
        return h, nc

    wrapped = remat_wrap(body, cfg.remat_policy) if mode == "train" else body
    h, caches = scan_fn(cfg.scan_layers)(wrapped, h, params["dec_layers"])
    h = apply_norm(params["dec_norm"], h, cfg.norm)
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype))
    logits = shard_hint(logits, P(batch_axes_of(mesh), None, "model"), mesh)
    return logits, (caches if mode == "prefill" else None)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


def forward(params, cfg: ModelConfig, batch, *, mesh=None, mode="train"):
    memory = encode(params, cfg, batch["enc_embeds"], mesh=mesh)
    logits, caches = decode_tokens(params, cfg, batch["tokens"], memory,
                                   mesh=mesh, mode=mode)
    return logits, caches, {}


def loss_fn(params, cfg: ModelConfig, batch, *, mesh=None):
    logits, _, _ = forward(params, cfg, batch, mesh=mesh, mode="train")
    loss = lm_loss(logits, batch["labels"], vocab=cfg.vocab_size)
    return loss, {"loss": loss}


def prefill(params, cfg: ModelConfig, batch, *, mesh=None):
    """Builds decode caches. Self-KV is written into a full-capacity buffer
    sized by the shape cell (batch['cache_len'] static via shape)."""
    logits, caches, _ = forward(params, cfg, batch, mesh=mesh, mode="prefill")
    return logits[:, -1], caches


def decode_step(params, cfg: ModelConfig, caches, batch, *, mesh=None):
    cd = dt(cfg.compute_dtype)
    pos = batch["pos"]
    tok = batch["token"]
    B = tok.shape[0]
    pe = jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1, axis=0)
    h = jnp.take(params["embed"], tok, axis=0).astype(cd) + pe[None].astype(cd)
    positions = jnp.broadcast_to(jnp.asarray(pos, jnp.int32)[None, None],
                                 (B, 1))

    def body(carry, xs):
        h = carry
        lp, cin = xs
        h, nc = _dec_layer(lp, cfg, h, positions, mode="decode", cache=cin,
                           pos_scalar=pos)
        return h, nc

    h, new_caches = scan_fn(cfg.scan_layers)(body, h,
                                             (params["dec_layers"], caches))
    h = apply_norm(params["dec_norm"], h, cfg.norm)
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype))
    return logits[:, 0], new_caches


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    cd = dt(cfg.compute_dtype)
    L, Hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim_
    F = cfg.encdec.source_positions
    return (jnp.zeros((L, batch, seq_len, Hkv, hd), cd),
            jnp.zeros((L, batch, seq_len, Hkv, hd), cd),
            jnp.zeros((L, batch, F, Hkv, hd), cd),
            jnp.zeros((L, batch, F, Hkv, hd), cd))


def cache_specs(cfg: ModelConfig):
    sp = P(None, "data", "model", None, None)
    xp = P(None, "data", None, "model", None)   # cross-KV: heads over model
    return (sp, sp, xp, xp)
