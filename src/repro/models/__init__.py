from repro.models.registry import (Model, build_model, input_partition_specs,
                                   input_structs)

__all__ = ["Model", "build_model", "input_structs", "input_partition_specs"]
