"""Falcon-Mamba LM: attention-free stack of Mamba-1 blocks.

Layer = ln → mamba block → +res (mamba1 blocks embed their own expansion;
no separate MLP).  Decode state is O(d_inner·(d_conv-1) + d_inner·N) per
layer — no KV cache, which is why `long_500k` is tractable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import mamba as mamba_mod
from repro.models.common import (apply_norm, dt, embed_init, init_norm,
                                 scan_fn, specs_norm)
from repro.models.transformer import (batch_axes_of, cast_weights, head_loss,
                                      head_out, remat_wrap, shard_hint)


def init_ssm_lm(key, cfg: ModelConfig):
    dtype = dt(cfg.param_dtype)
    ke, kl, kh = jax.random.split(key, 3)

    def init_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln": init_norm(k1, cfg.d_model, cfg.norm, dtype),
                "mamba": mamba_mod.init_mamba_block(k2, cfg, dtype)}

    params = {
        "embed": embed_init(ke, (cfg.vocab_size, cfg.d_model), dtype),
        "layers": jax.vmap(init_layer)(jax.random.split(kl, cfg.num_layers)),
        "final_norm": init_norm(kh, cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(kh, (cfg.d_model, cfg.vocab_size),
                                       dtype)
    return params


def specs_ssm_lm(cfg: ModelConfig):
    layer = {"ln": specs_norm(cfg.norm),
             "mamba": mamba_mod.specs_mamba_block(cfg)}
    stacked = jax.tree.map(lambda sp: P(*((None,) + tuple(sp))), layer,
                           is_leaf=lambda x: isinstance(x, P))
    s = {"embed": P("model", "data"), "layers": stacked,
         "final_norm": specs_norm(cfg.norm)}
    if not cfg.tie_embeddings:
        s["lm_head"] = P("data", "model")
    return s


def _run(params, cfg: ModelConfig, h, *, mode, caches=None, mesh=None):
    def body(carry, xs):
        h = carry
        if mode == "decode":
            lp, (conv_s, h_s) = xs
        else:
            lp, conv_s, h_s = xs, None, None
        x = apply_norm(lp["ln"], h, cfg.norm)
        if mode == "train":
            y = mamba_mod.apply_mamba_block(lp["mamba"], cfg, x)
            return h + y, None
        y, conv_s, h_s = mamba_mod.apply_mamba_block(
            lp["mamba"], cfg, x, conv_state=conv_s, h_state=h_s,
            return_state=True)
        return h + y, (conv_s, h_s)

    wrapped = remat_wrap(body, cfg.remat_policy) if mode == "train" else body
    scan = scan_fn(cfg.scan_layers)
    if mode == "decode":
        h, new_caches = scan(wrapped, h, (params["layers"], caches))
        return h, new_caches
    h, ys = scan(wrapped, h, params["layers"])
    return h, (ys if mode == "prefill" else None)


def forward(params, cfg: ModelConfig, batch, *, mesh=None, mode="train"):
    params = cast_weights(params, cfg)
    cd = dt(cfg.compute_dtype)
    tokens = batch["tokens"]
    h = jnp.take(params["embed"], tokens, axis=0).astype(cd)
    h = shard_hint(h, P(batch_axes_of(mesh, cfg), None, None), mesh)
    h, caches = _run(params, cfg, h, mode=mode, mesh=mesh)
    logits = head_out(params, cfg, h, mesh)
    return logits, caches, {}


def loss_fn(params, cfg: ModelConfig, batch, *, mesh=None):
    params = cast_weights(params, cfg)
    cd = dt(cfg.compute_dtype)
    h = jnp.take(params["embed"], batch["tokens"], axis=0).astype(cd)
    h = shard_hint(h, P(batch_axes_of(mesh, cfg), None, None), mesh)
    h, _ = _run(params, cfg, h, mode="train", mesh=mesh)
    loss = head_loss(params, cfg, h, batch["labels"], mesh)
    return loss, {"loss": loss}


def prefill(params, cfg: ModelConfig, batch, *, mesh=None):
    logits, caches, _ = forward(params, cfg, batch, mesh=mesh, mode="prefill")
    return logits[:, -1], caches


def decode_step(params, cfg: ModelConfig, caches, batch, *, mesh=None):
    cd = dt(cfg.compute_dtype)
    h = jnp.take(params["embed"], batch["token"], axis=0).astype(cd)
    h, caches = _run(params, cfg, h, mode="decode", caches=caches, mesh=mesh)
    logits = head_out(params, cfg, h, mesh)
    return logits[:, 0], caches


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    L = cfg.num_layers
    cd = dt(cfg.compute_dtype)
    return (jnp.zeros((L, batch, s.d_conv - 1, d_in), cd),
            jnp.zeros((L, batch, d_in, s.d_state), jnp.float32))


def cache_specs(cfg: ModelConfig):
    return (P(None, "data", None, "model"), P(None, "data", "model", None))
