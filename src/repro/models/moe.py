"""Capacity-based expert-parallel MoE (qwen3-moe / granite-moe).

Design (DESIGN.md §5):
  * experts sharded over the ``'model'`` axis (EP), activations replicated
    over ``'model'`` inside the block; each shard processes only assignments
    whose expert it owns, then a single ``psum('model')`` combines — the same
    collective cost as a TP FFN, with *no dense one-hot dispatch einsums*
    (dispatch is gather/scatter, so HLO FLOPs stay ≈ active FLOPs × capacity
    factor, keeping the roofline useful-FLOP ratio honest).
  * expert weights are additionally FSDP-sharded over the batch axes and
    all-gathered on entry (ZeRO-3 style).
  * per-expert capacity C = ceil(T·k/E · cf); overflow assignments drop
    (Switch-style); slots are filled via an inverse slot→token map so no
    [T·k, d] intermediate is ever materialized.

Works identically without a mesh (single shard, no collectives) — that path
is what the CPU smoke tests exercise.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig
from repro.models.common import dense_init


def init_moe(key, cfg: ModelConfig, dtype):
    m, d = cfg.moe, cfg.d_model
    E, ff = m.num_experts, m.d_ff_expert
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, E), d, dtype),
        "w_gate": dense_init(ks[1], (E, d, ff), d, dtype),
        "w_up": dense_init(ks[2], (E, d, ff), d, dtype),
        "w_down": dense_init(ks[3], (E, ff, d), ff, dtype),
    }


def specs_moe(cfg: ModelConfig):
    return {
        "router": P(None, None),
        "w_gate": P("model", "data", None),
        "w_up": P("model", "data", None),
        "w_down": P("model", None, "data"),
    }


# ---------------------------------------------------------------------------
# core (single-shard) MoE body
# ---------------------------------------------------------------------------


def _moe_shard(x2d, router_w, w_gate, w_up, w_down, cfg: ModelConfig,
               shard_id, n_shards: int):
    """x2d [T, d] -> ([T, d] local contribution, aux metrics).

    Only assignments owned by this shard's experts contribute; caller psums.
    """
    m = cfg.moe
    T, d = x2d.shape
    E, k = m.num_experts, m.experts_per_token
    E_loc = E // n_shards
    ff = m.d_ff_expert
    cd = x2d.dtype

    # --- routing (computed redundantly on every model shard; T×E is cheap) --
    logits = (x2d @ router_w.astype(cd)).astype(jnp.float32)       # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                  # [T, k]
    if m.router_norm_topk:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # --- flatten assignments --------------------------------------------
    A = T * k
    eid = gate_idx.reshape(A)                                      # [A]
    wgt = gate_vals.reshape(A).astype(jnp.float32)
    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)

    lo = shard_id * E_loc
    leid = eid - lo
    mine = (leid >= 0) & (leid < E_loc)
    leid_c = jnp.clip(leid, 0, E_loc - 1)

    # position within expert via cumulative count over [A, E_loc] one-hot
    oh = (mine[:, None] & (leid_c[:, None]
                           == jnp.arange(E_loc, dtype=jnp.int32)[None, :]))
    pos = jnp.take_along_axis(jnp.cumsum(oh.astype(jnp.int32), axis=0) - 1,
                              leid_c[:, None], axis=1)[:, 0]        # [A]

    C = max(1, math.ceil(A / E * m.capacity_factor))
    keep = mine & (pos < C)
    slot = jnp.where(keep, leid_c * C + pos, E_loc * C)             # dummy=last

    # --- inverse maps: slot -> (token, weight, valid) ---------------------
    n_slots = E_loc * C
    slot_tok = jnp.zeros((n_slots + 1,), jnp.int32).at[slot].set(tok)
    slot_wgt = jnp.zeros((n_slots + 1,), jnp.float32).at[slot].set(wgt)
    slot_ok = jnp.zeros((n_slots + 1,), jnp.bool_).at[slot].set(True)
    slot_tok, slot_wgt, slot_ok = (slot_tok[:n_slots], slot_wgt[:n_slots],
                                   slot_ok[:n_slots])

    # --- dispatch: gather tokens into [E_loc, C, d] -----------------------
    buf = x2d[slot_tok] * slot_ok[:, None].astype(cd)
    buf = buf.reshape(E_loc, C, d)

    # --- expert FFN (batched over local experts) --------------------------
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(cd)))
    u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(cd))
    y_e = jnp.einsum("ecf,efd->ecd", g * u, w_down.astype(cd))
    y_flat = y_e.reshape(n_slots, d)

    # --- combine: scatter-add weighted expert outputs back to tokens ------
    contrib = (y_flat.astype(jnp.float32)
               * (slot_wgt * slot_ok.astype(jnp.float32))[:, None])
    y = jnp.zeros((T, d), jnp.float32).at[slot_tok].add(
        jnp.where(slot_ok[:, None], contrib, 0.0))

    # --- aux: load-balance loss (Switch eq. 4) + drop fraction ------------
    me = jnp.mean(probs, axis=0)                                    # [E]
    ce = jnp.zeros((E,), jnp.float32).at[eid].add(1.0) / A
    aux = E * jnp.sum(me * ce)
    dropped = 1.0 - jnp.sum(keep.astype(jnp.float32)) * n_shards / A
    return y.astype(cd), aux, dropped


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------


def apply_moe(p, cfg: ModelConfig, x, *, mesh=None,
              batch_axes: Tuple[str, ...] = ("data",), model_axis="model",
              fsdp: bool = True):
    """x [B, S, d] -> (y [B, S, d], aux dict).

    fsdp=False (inference weight layout): expert weights enter the shard_map
    replicated across the batch axes — no per-layer ZeRO-3 re-gather, which
    otherwise costs params/16 of link traffic *per decode step* (§Perf).
    """
    B, S, d = x.shape

    if mesh is None or model_axis not in getattr(mesh, "axis_names", ()):
        y, aux, dropped = _moe_shard(
            x.reshape(B * S, d), p["router"], p["w_gate"], p["w_up"],
            p["w_down"], cfg, shard_id=0, n_shards=1)
        return y.reshape(B, S, d), {"moe_aux": aux, "moe_dropped": dropped}

    n_shards = mesh.shape[model_axis]
    bspec = P(batch_axes, None, None)
    fax = batch_axes if fsdp else None

    def body(xb, router_w, w_gate, w_up, w_down):
        sid = jax.lax.axis_index(model_axis)
        if fsdp:
            # ZeRO-3: expert weights FSDP-sharded on d / ff; gather at use.
            w_gate = jax.lax.all_gather(w_gate, batch_axes, axis=1,
                                        tiled=True)
            w_up = jax.lax.all_gather(w_up, batch_axes, axis=1, tiled=True)
            w_down = jax.lax.all_gather(w_down, batch_axes, axis=2,
                                        tiled=True)
        Bl, Sl, dl = xb.shape
        y, aux, dropped = _moe_shard(xb.reshape(Bl * Sl, dl), router_w,
                                     w_gate, w_up, w_down, cfg,
                                     shard_id=sid, n_shards=n_shards)
        y = jax.lax.psum(y, model_axis)
        aux = jax.lax.pmean(aux, model_axis)
        dropped = jax.lax.psum(dropped, model_axis) / n_shards
        return y.reshape(Bl, Sl, dl), aux, dropped

    y, aux, dropped = shard_map(
        body, mesh=mesh,
        in_specs=(bspec, P(None, None), P(model_axis, fax, None),
                  P(model_axis, fax, None),
                  P(model_axis, None, fax)),
        out_specs=(bspec, P(), P()),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return y, {"moe_aux": aux, "moe_dropped": dropped}
