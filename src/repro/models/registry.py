"""``build_model(cfg, mesh=None)`` — uniform Model API over all families.

Model functions are pure (params explicit) so they jit/lower cleanly with
``ShapeDtypeStruct`` stand-ins for the multi-pod dry-run.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec as encdec_mod
from repro.models import hybrid as hybrid_mod
from repro.models import ssm_lm as ssm_mod
from repro.models import transformer as tf_mod
from repro.models.common import dt


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable            # key -> params
    specs: Callable           # () -> PyTree[PartitionSpec] templates
    loss: Callable            # (params, batch) -> (loss, metrics)
    forward: Callable         # (params, batch) -> (logits, caches, aux)
    prefill: Callable         # (params, batch) -> (last_logits, caches)
    decode_step: Callable     # (params, caches, batch) -> (logits, caches)
    init_cache: Callable      # (batch, seq_len) -> caches
    cache_specs: Callable     # () -> PyTree[PartitionSpec]


def build_model(cfg: ModelConfig, mesh=None) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        mod = tf_mod
        init = partial(tf_mod.init_lm, cfg=cfg)
        specs = partial(tf_mod.specs_lm, cfg)
    elif fam == "hybrid":
        mod = hybrid_mod
        init = partial(hybrid_mod.init_hybrid, cfg=cfg)
        specs = partial(hybrid_mod.specs_hybrid, cfg)
    elif fam == "ssm":
        mod = ssm_mod
        init = partial(ssm_mod.init_ssm_lm, cfg=cfg)
        specs = partial(ssm_mod.specs_ssm_lm, cfg)
    elif fam == "encdec":
        mod = encdec_mod
        init = partial(encdec_mod.init_encdec, cfg=cfg)
        specs = partial(encdec_mod.specs_encdec, cfg)
    else:
        raise ValueError(f"unknown family {fam}")

    return Model(
        cfg=cfg,
        init=lambda key: init(key),
        specs=specs,
        loss=lambda params, batch: mod.loss_fn(params, cfg, batch, mesh=mesh),
        forward=lambda params, batch, mode="train": mod.forward(
            params, cfg, batch, mesh=mesh, mode=mode),
        prefill=lambda params, batch: mod.prefill(params, cfg, batch,
                                                  mesh=mesh),
        decode_step=lambda params, caches, batch: mod.decode_step(
            params, cfg, caches, batch, mesh=mesh),
        init_cache=lambda batch, seq_len: mod.init_cache(cfg, batch, seq_len),
        cache_specs=lambda: mod.cache_specs(cfg),
    )


# ---------------------------------------------------------------------------
# input specs (dry-run stand-ins + partition templates)
# ---------------------------------------------------------------------------


def input_structs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStructs for every model input of this (arch, shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    cd = dt(cfg.compute_dtype)
    i32 = jnp.int32
    sd = jax.ShapeDtypeStruct

    if shape.kind == "decode":
        batch = {"token": sd((B, 1), i32), "pos": sd((), i32)}
        return batch

    if cfg.family == "vlm":
        batch = {"embeds": sd((B, S, cfg.d_model), cd),
                 "positions": sd((len(cfg.mrope_sections), B, S), i32)}
    elif cfg.family == "encdec":
        F = cfg.encdec.source_positions
        batch = {"enc_embeds": sd((B, F, cfg.d_model), cd),
                 "tokens": sd((B, S), i32)}
    else:
        batch = {"tokens": sd((B, S), i32)}
    if shape.kind == "train":
        batch["labels"] = sd((B, S), i32)
    return batch


def input_partition_specs(cfg: ModelConfig, shape: ShapeConfig,
                          batch_axes=("data",)) -> Dict[str, P]:
    b = batch_axes
    if shape.kind == "decode":
        return {"token": P(b, None), "pos": P()}
    if cfg.family == "vlm":
        sp = {"embeds": P(b, None, None), "positions": P(None, b, None)}
    elif cfg.family == "encdec":
        sp = {"enc_embeds": P(b, None, None), "tokens": P(b, None)}
    else:
        sp = {"tokens": P(b, None)}
    if shape.kind == "train":
        sp["labels"] = P(b, None)
    return sp
