"""Fixed-width, log-bucketed, **mergeable** latency histograms
(DESIGN.md §14.1) — the metrics core of the SLO observatory.

A histogram is nothing but a ``[buckets + 2]`` integer count vector over
a static log-spaced edge grid (:class:`HistSpec`): slot 0 is the
underflow bin (``x < lo``, including zeros), slots ``1..buckets`` are the
finite log buckets, and the last slot is the overflow bin (``x >= hi``).
Because the *state* is a plain integer vector and the *fill* is a
scatter-add, every operation the serve path needs is trivially:

  * **jit-compatible** — ``fill`` is ``jnp.searchsorted`` + ``.at[].add``
    over a statically-shaped buffer, so it runs inside ``lax.scan``
    bodies, under ``vmap``/``shard_map``, and inside
    ``ServeEngine.step()`` host loops (``fill_np`` is the bit-identical
    numpy mirror over the same float32 edge grid);
  * **mergeable** — ``merge`` is elementwise integer addition, which is
    exactly associative and commutative, so merge-of-shards equals
    whole-stream fill *bit-exactly* no matter how the executor backends
    batch, chunk, or resume the stream (the property
    ``tests/test_obs.py`` pins across vmap/sharded/streaming).

Quantiles come from the counts on the host: ``quantile`` returns the
*upper edge* of the bucket where the cumulative count crosses the rank,
so a histogram-derived p50/p99/p999 is always within one bucket of the
exact sample quantile (bucket width ≈ 4.9 % at the default 384-bucket
grid over [1e-4 s, 1e4 s)).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache
from typing import Dict, Optional, Sequence

import jax.numpy as jnp
import numpy as np

# p50 / p99 / p999 — the SLO grid (ISSUE 9); summary() labels 0.999 "p999"
SLO_QS = (0.5, 0.99, 0.999)


@dataclass(frozen=True)
class HistSpec:
    """Static histogram geometry: ``buckets`` log-spaced bins over
    ``[lo, hi)`` plus an underflow and an overflow bin."""
    lo: float = 1e-4
    hi: float = 1e4
    buckets: int = 384

    @property
    def num_bins(self) -> int:
        return self.buckets + 2

    @property
    def growth(self) -> float:
        """Multiplicative bucket width (relative quantile resolution)."""
        return (self.hi / self.lo) ** (1.0 / self.buckets)


# the default spec all latency surfaces share: 0.1 ms .. 10 000 s at
# ~4.9 % relative resolution — covers serve epochs and 100 s sim runs
DEFAULT_LATENCY_HIST = HistSpec()


@lru_cache(maxsize=None)
def edges(spec: HistSpec) -> np.ndarray:
    """``[buckets + 1]`` float32 bin edges (shared by fill and fill_np —
    one grid, so host and device fills can never disagree on a bucket)."""
    e = np.geomspace(spec.lo, spec.hi, spec.buckets + 1)
    return e.astype(np.float32)


@lru_cache(maxsize=None)
def upper_edges(spec: HistSpec) -> np.ndarray:
    """Per-bin conservative upper bound (float64; overflow bin = +inf)."""
    e = edges(spec).astype(np.float64)
    return np.concatenate([e[:1], e[1:], [np.inf]])


@lru_cache(maxsize=None)
def lower_edges(spec: HistSpec) -> np.ndarray:
    """Per-bin lower bound (underflow bin = 0)."""
    e = edges(spec).astype(np.float64)
    return np.concatenate([[0.0], e[:-1], [e[-1]]])


def empty(spec: HistSpec) -> jnp.ndarray:
    """Device-side zero counts (int32: in-scan carries stay 32-bit)."""
    return jnp.zeros((spec.num_bins,), jnp.int32)


def empty_np(spec: HistSpec) -> np.ndarray:
    """Host-side zero counts (int64: a long-lived accumulator)."""
    return np.zeros((spec.num_bins,), np.int64)


def bucket_of(spec: HistSpec, values) -> jnp.ndarray:
    """Bin index of each value (jit-compatible; float32 grid)."""
    e = jnp.asarray(edges(spec))
    return jnp.searchsorted(e, jnp.asarray(values, jnp.float32).ravel(),
                            side="right")


def fill(spec: HistSpec, counts, values, weights=None) -> jnp.ndarray:
    """Scatter ``values`` (optionally ``weights``-weighted) into
    ``counts`` — pure, jittable, vmappable.  Returns the new counts."""
    idx = bucket_of(spec, values)
    if weights is None:
        w = jnp.ones(idx.shape, counts.dtype)
    else:
        w = jnp.broadcast_to(jnp.asarray(weights, counts.dtype).ravel(),
                             idx.shape)
    return counts.at[idx].add(w)


def fill_np(spec: HistSpec, counts: np.ndarray, values,
            weights=None) -> np.ndarray:
    """In-place host fill over the *same* float32 edge grid as ``fill``
    (same searchsorted semantics ⇒ same buckets, bit for bit)."""
    x = np.asarray(values, np.float32).ravel()
    idx = np.searchsorted(edges(spec), x, side="right")
    if weights is None:
        np.add.at(counts, idx, 1)
    else:
        w = np.broadcast_to(np.asarray(weights, counts.dtype).ravel(),
                            idx.shape)
        np.add.at(counts, idx, w)
    return counts


def merge(*counts) -> np.ndarray:
    """Sum count vectors — exactly associative and commutative (integer
    addition), so any shard/chunk/resume merge order yields the same
    histogram as one whole-stream fill."""
    out = np.zeros_like(np.asarray(counts[0], np.int64))
    for c in counts:
        out = out + np.asarray(c, np.int64)
    return out


def total(counts) -> int:
    return int(np.sum(np.asarray(counts, np.int64)))


def quantile(spec: HistSpec, counts, q: float) -> Optional[float]:
    """Conservative quantile: the upper edge of the bucket where the CDF
    crosses ``q`` (``+inf`` if it lands in the overflow bin, ``None`` on
    an empty histogram).  Always >= the exact sample quantile and within
    one bucket of it."""
    c = np.asarray(counts, np.int64)
    n = c.sum()
    if n == 0:
        return None
    cum = np.cumsum(c)
    k = int(np.searchsorted(cum, q * n, side="left"))
    return float(upper_edges(spec)[k])


def quantile_bucket(spec: HistSpec, counts, q: float) -> Optional[int]:
    """Bin index the quantile falls in (for one-bucket-accuracy checks)."""
    c = np.asarray(counts, np.int64)
    n = c.sum()
    if n == 0:
        return None
    return int(np.searchsorted(np.cumsum(c), q * n, side="left"))


def q_label(q: float) -> str:
    """0.5 → "p50", 0.99 → "p99", 0.999 → "p999"."""
    return "p" + format(q * 100, "g").replace(".", "")


def summary(spec: HistSpec, counts, qs: Sequence[float] = SLO_QS
            ) -> Dict[str, Optional[float]]:
    """JSON-ready quantile summary of one count vector.

    Quantiles landing in the overflow bin come back ``None`` (strict JSON
    has no Infinity); the overflow count itself is always reported, so an
    under-provisioned grid is visible rather than silently clamped.
    """
    c = np.asarray(counts, np.int64)
    out: Dict[str, Optional[float]] = {
        "count": int(c.sum()),
        "underflow": int(c[0]),
        "overflow": int(c[-1]),
    }
    for q in qs:
        v = quantile(spec, c, q)
        out[q_label(q)] = (None if v is None or math.isinf(v) else v)
    return out
