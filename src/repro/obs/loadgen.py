"""Open-loop load generation for the serve path (DESIGN.md §14.2).

Three arrival processes on the **deterministic clock** — every arrival
time is a pure function of (process parameters, seed), drawn up front
from an explicitly seeded generator, never from ambient randomness:

  * :func:`poisson_arrivals` — homogeneous Poisson (i.i.d. exponential
    inter-arrivals at ``rate_hz``);
  * :func:`mmpp_arrivals` — 2-state Markov-modulated Poisson process
    (exponential dwell in a low-rate and a high-rate state; the serve
    analogue of the sim's ON/OFF bursty arrivals, DESIGN.md §3.2);
  * :func:`replay_arrivals` — replay a recorded timestamp trace.

:func:`run_open_loop` drives an engine's ``submit`` with those arrivals
coalesced onto the epoch grid — *open-loop*: arrivals never wait for
completions, so overload shows up as queue growth / drops, not as a
throttled generator.  The engine's service capacity is one batch per
stage per epoch, so offered load is controlled as
``rate_hz · dt / max_batch`` batches per epoch and the knee sits at
``rate_hz* = max_batch / dt`` rows/s.

:class:`SyntheticServeEngine` is the scheduling-faithful double of
:class:`~repro.splitcompute.serve_engine.SplitServeEngine` — same queues,
same epoch snapshot, same congestion EMA and exit ladder (a numpy mirror
of Eqs. 14-16), same ``ServeStats`` — with the model math replaced by
identity stage functions, so a ≥ 1M-request load test completes on CPU
in seconds while exercising exactly the scheduler the real engine runs.
"""
from __future__ import annotations

from collections import deque
from types import SimpleNamespace
from typing import Callable, Optional

import numpy as np

from repro.core.early_exit import CongestionState
from repro.obs.hist import HistSpec
from repro.splitcompute.serve_engine import ServeStats, SplitServeEngine

# generation chunk for arrival draws (bounds memory while staying vector)
_CHUNK = 65_536


def poisson_arrivals(rate_hz: float, horizon_s: float,
                     seed: int = 0) -> np.ndarray:
    """Arrival times of a homogeneous Poisson process on [0, horizon)."""
    if rate_hz <= 0.0 or horizon_s <= 0.0:
        return np.zeros((0,), np.float64)
    rng = np.random.default_rng(seed)
    out = []
    t = 0.0
    while t < horizon_s:
        gaps = rng.exponential(1.0 / rate_hz, size=_CHUNK)
        times = t + np.cumsum(gaps)
        out.append(times)
        t = float(times[-1])
    times = np.concatenate(out)
    return times[times < horizon_s]


def mmpp_arrivals(rate_lo_hz: float, rate_hi_hz: float, horizon_s: float,
                  *, mean_lo_s: float = 6.0, mean_hi_s: float = 2.0,
                  seed: int = 0) -> np.ndarray:
    """2-state MMPP: Poisson at ``rate_lo_hz`` / ``rate_hi_hz`` while the
    modulating chain dwells (exponentially, means ``mean_lo_s`` /
    ``mean_hi_s``) in its low/high state.  Starts low; long-run mean rate
    is the dwell-weighted average of the two rates."""
    rng = np.random.default_rng(seed)
    out = []
    t = 0.0
    hi = False
    while t < horizon_s:
        dwell = rng.exponential(mean_hi_s if hi else mean_lo_s)
        rate = rate_hi_hz if hi else rate_lo_hz
        end = min(t + dwell, horizon_s)
        if rate > 0.0:
            seg = t
            while seg < end:
                gaps = rng.exponential(1.0 / rate, size=_CHUNK)
                times = seg + np.cumsum(gaps)
                out.append(times[times < end])
                seg = float(times[-1])
        t = end
        hi = not hi
    if not out:
        return np.zeros((0,), np.float64)
    return np.sort(np.concatenate(out))


def replay_arrivals(times, horizon_s: Optional[float] = None) -> np.ndarray:
    """Replay a recorded arrival-time trace: sorted, non-negative,
    optionally clipped to [0, horizon)."""
    t = np.sort(np.asarray(times, np.float64).ravel())
    t = t[t >= 0.0]
    if horizon_s is not None:
        t = t[t < horizon_s]
    return t


class SyntheticServeEngine(SplitServeEngine):
    """Scheduling-faithful, model-free serve engine for load tests.

    Inherits ``step``/``drain``/``_enqueue``/``_exit_stage`` — the entire
    scheduler — from :class:`SplitServeEngine`; only the model execution
    (identity stage fns over empty ``[rows, 0]`` payloads) and the
    congestion block (a numpy mirror of Eqs. 14-16, bypassing device
    dispatch in the million-epoch loop) are replaced.
    """

    def __init__(self, *, n_stages: int = 4, layers_per_stage: int = 15,
                 tau_med: float = 1.5, tau_high: float = 2.5,
                 alpha: float = 0.3, max_queue: Optional[int] = None,
                 state_every: int = 1, max_records: Optional[int] = None,
                 latency_hist: Optional[HistSpec] = None):
        num_layers = n_stages * layers_per_stage
        self.cfg = SimpleNamespace(
            family="dense", num_layers=num_layers,
            exit_layers_=(max(num_layers // 4, 1), max(num_layers // 2, 1)))
        self.plan = SimpleNamespace(
            boundaries=[i * layers_per_stage for i in range(n_stages + 1)],
            executors=list(range(n_stages)))
        self.n_stages = n_stages
        self.cong = CongestionState(np.zeros((n_stages,), np.float64),
                                    np.zeros((n_stages,), np.float64))
        self.tau = (tau_med, tau_high)
        self.alpha = alpha
        self.queues = [deque() for _ in range(n_stages)]
        self.max_queue = max_queue
        self.state_every = max(int(state_every), 1)
        self._epoch = 0
        self.stats = ServeStats(max_records=max_records,
                                latency_hist=latency_hist)
        self.results = {}
        self.max_results = 0          # never stash synthetic logits
        self.clock = 0.0
        self._next_id = 0
        self._stage_fns = [lambda h, positions: h] * n_stages
        self._head_fn = lambda h: h

    def submit(self, rows: int = 1,
               t_now: Optional[float] = None) -> Optional[int]:
        """Enqueue one synthetic batch of ``rows`` samples (no tokens, no
        embedding — the payload is an empty ``[rows, 0]`` array, so memory
        stays flat at any request count)."""
        h = np.empty((int(rows), 0), np.float32)
        return self._enqueue(h, None, t_now, rows=int(rows))

    def _congestion_labels(self, qlens, dt: float) -> np.ndarray:
        # numpy mirror of congestion_update + exit_label (same strict
        # inequalities as core.early_exit) — no device round-trip per epoch
        T = np.asarray(qlens, np.float64)
        dT = (T - self.cong.prev_T) / dt
        D = self.cong.D + self.alpha * (dT - self.cong.D)
        self.cong = CongestionState(T, D)
        return np.where(D > self.tau[1], 2, np.where(D > self.tau[0], 1, 0))


def run_open_loop(engine, arrivals, *, dt: float = 0.01,
                  max_batch: int = 64, drain_epochs: int = 1_000_000,
                  on_epoch: Optional[Callable] = None) -> ServeStats:
    """Drive ``engine`` with ``arrivals`` (sorted seconds) in open loop.

    Arrivals are coalesced onto the epoch grid into **full** batches of
    ``max_batch`` rows — a partial batch waits for the next epoch's
    arrivals rather than consuming a whole service slot (the engine
    serves one batch per stage per epoch, so full batches make the
    batch-level utilization exactly ``rate · dt / max_batch`` and the
    knee land at capacity; the tail is flushed partial once arrivals
    end).  Each batch is stamped with its *first* row's true arrival
    time — coalescing quantizes service start, never the latency origin.
    After the last arrival the engine drains (bounded by
    ``drain_epochs``).  ``on_epoch(epoch, t, engine)`` fires every epoch
    for progress/gauge emission.  Returns ``engine.stats``.
    """
    times = np.asarray(arrivals, np.float64)
    n = int(times.size)
    i = 0                 # arrivals admitted to the batching window
    s = 0                 # arrivals submitted to the engine
    epoch = 0
    idle = 0
    while True:
        t = (epoch + 1) * dt
        while i < n and times[i] <= t:
            i += 1
        while i - s >= max_batch:
            engine.submit(max_batch, t_now=float(times[s]))
            s += max_batch
        if i >= n and s < n:
            # tail flush: no future arrival can complete this batch
            engine.submit(n - s, t_now=float(times[s]))
            s = n
        engine.step(dt=dt, t_now=t)
        if on_epoch is not None:
            on_epoch(epoch, t, engine)
        epoch += 1
        if s >= n:
            if not any(engine.queues):
                break
            idle += 1
            if idle >= drain_epochs:
                break
    return engine.stats
