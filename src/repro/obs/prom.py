"""Prometheus text exposition (DESIGN.md §14.3): render a
:class:`repro.obs.registry.Registry` to the v0.0.4 text format, plus a
strict parser used by tests and the ``slo-smoke`` CI job to prove the
exposition round-trips.

Histograms render the standard cumulative form — ``<name>_bucket`` rows
with ``le`` upper-edge labels (finite edges from the
:class:`~repro.obs.hist.HistSpec` grid, then ``le="+Inf"``), followed by
``<name>_sum`` and ``<name>_count`` — so any Prometheus scraper computes
the same quantiles the BENCH report does.
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

import numpy as np

from repro.obs import hist as _hist


def _fmt_labels(labels: Dict[str, str], extra: Tuple[str, str] = None) -> str:
    items = list(labels.items()) + ([extra] if extra else [])
    if not items:
        return ""
    body = ",".join(f'{k}="{_escape(v)}"' for k, v in items)
    return "{" + body + "}"


def _escape(v) -> str:
    return str(v).replace("\\", r"\\").replace('"', r'\"').replace(
        "\n", r"\n")


def _fmt_num(x: float) -> str:
    if np.isposinf(x):
        return "+Inf"
    return repr(float(x))


def render(registry) -> str:
    """Registry → Prometheus text exposition (ends with a newline)."""
    lines: List[str] = []
    for m in registry.collect():
        if m.help:
            lines.append(f"# HELP {m.name} {_escape(m.help)}")
        lines.append(f"# TYPE {m.name} {m.kind}")
        if m.kind in ("counter", "gauge"):
            lines.append(f"{m.name}{_fmt_labels(m.labels)} "
                         f"{_fmt_num(m.value)}")
        elif m.kind == "histogram":
            uppers = _hist.upper_edges(m.spec)
            cum = np.cumsum(np.asarray(m.counts, np.int64))
            # fold the underflow bin into the first finite bucket (its
            # upper edge is the grid's lo, a legal le value), keep the
            # rest of the grid, end on +Inf
            for k in range(1, m.spec.num_bins):
                le = _fmt_num(uppers[k])
                lines.append(
                    f"{m.name}_bucket"
                    f"{_fmt_labels(m.labels, ('le', le))} {int(cum[k])}")
            lines.append(f"{m.name}_sum{_fmt_labels(m.labels)} "
                         f"{_fmt_num(m.sum)}")
            lines.append(f"{m.name}_count{_fmt_labels(m.labels)} "
                         f"{int(cum[-1])}")
        else:
            raise ValueError(f"unknown metric kind {m.kind!r}")
    return "\n".join(lines) + "\n"


_SAMPLE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?P<labels>\{[^}]*\})?\s+(?P<value>\S+)$")
_LABEL = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def parse(text: str) -> Dict:
    """Strict parse of an exposition: ``{"types": {family: kind},
    "samples": [(name, labels, value)]}``.  Raises ``ValueError`` on any
    malformed line, and checks histogram invariants (bucket rows
    cumulative, ``+Inf`` bucket == ``_count``) — the CI validity check.
    """
    types: Dict[str, str] = {}
    samples: List[Tuple[str, Dict[str, str], float]] = []
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4:
                raise ValueError(f"line {lineno}: malformed TYPE: {line!r}")
            types[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE.match(line)
        if not m:
            raise ValueError(f"line {lineno}: malformed sample: {line!r}")
        labels = dict(_LABEL.findall(m.group("labels") or ""))
        raw = m.group("value")
        value = float("inf") if raw == "+Inf" else float(raw)
        samples.append((m.group("name"), labels, value))
    # histogram invariants
    for fam, kind in types.items():
        if kind != "histogram":
            continue
        buckets = [(float("inf") if s[1].get("le") == "+Inf"
                    else float(s[1]["le"]), s[2])
                   for s in samples if s[0] == f"{fam}_bucket"]
        if not buckets:
            raise ValueError(f"histogram {fam}: no bucket rows")
        buckets.sort(key=lambda t: t[0])
        counts = [c for _, c in buckets]
        if counts != sorted(counts):
            raise ValueError(f"histogram {fam}: buckets not cumulative")
        count_rows = [s[2] for s in samples if s[0] == f"{fam}_count"]
        if not count_rows or buckets[-1][1] != count_rows[0]:
            raise ValueError(f"histogram {fam}: +Inf bucket != _count")
    return {"types": types, "samples": samples}
