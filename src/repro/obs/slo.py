"""SLO report builder (DESIGN.md §14.3): turn one load-test run's
:class:`~repro.splitcompute.serve_engine.ServeStats` into the JSON-ready
``slo_serve`` payload — p50/p99/p999 latency, goodput, time-to-first-exit,
drop rate, queue-saturation gauges, and the per-segment latency quantiles
with their exact reconciliation residual — plus the Prometheus registry
and Perfetto counter-track exports of the same numbers.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

import numpy as np

from repro.obs import hist as obs_hist
from repro.obs.registry import Registry
from repro.trace import decode_state, state_counter_events
from repro.trace.critical import SEGMENTS


def _none_if_nan(x: float) -> Optional[float]:
    return None if x is None or math.isnan(x) else float(x)


def slo_indices(stats, *, horizon_s: float, offered_rows: int,
                rate_rps: Optional[float] = None,
                max_queue: Optional[int] = None) -> Dict:
    """One run's ServeStats → the per-point ``slo_serve`` section.

    Stable key set; quantiles are the streaming-histogram summaries
    (``None`` in the overflow bin), ``segment_reconcile_err_s`` is
    ``|Σ latency − Σ segments|`` — exactly 0 up to float rounding because
    the serve path computes queue-wait as the per-record remainder.
    """
    lat = stats.latency_quantiles()
    segs = {}
    seg_sum_total = 0.0
    for name in SEGMENTS:
        s = obs_hist.summary(stats.hist_spec, stats.segment_counts[name])
        s["sum_s"] = float(stats.segment_sums[name])
        seg_sum_total += s["sum_s"]
        segs[name] = s
    drop_rate = (stats.dropped / max(stats.generated_rows, 1)
                 if stats.generated_rows else 0.0)
    out: Dict = {
        "offered_rows": int(offered_rows),
        "offered_rate_rps": (None if rate_rps is None else float(rate_rps)),
        "horizon_s": float(horizon_s),
        "completed": int(stats.completed),
        "dropped": int(stats.dropped),
        "drop_rate": float(drop_rate),
        "goodput_rps": (float(stats.completed / horizon_s)
                        if horizon_s > 0 else 0.0),
        "avg_latency_s": _none_if_nan(stats.avg_latency),
        "time_to_first_exit_s": _none_if_nan(stats.time_to_first_exit),
        "exit_counts": {str(k): int(v)
                        for k, v in sorted(stats.exit_counts.items())},
        "latency_s": lat,
        "segments": segs,
        "segment_reconcile_err_s": abs(float(stats.latency_sum)
                                       - seg_sum_total),
    }
    # queue-saturation gauges from the flight-recorder stream
    out["queue_depth_mean"] = None
    out["queue_depth_max"] = None
    out["queue_depth_final"] = None
    out["queue_saturation"] = None
    sysbuf = stats.state_records
    if sysbuf.shape[0]:
        sdec = decode_state(sys=sysbuf)
        qmean = np.asarray(sdec["queue_depth_mean"], np.float64)[0]
        qmax = np.asarray(sdec["queue_depth_max"], np.float64)[0]
        out["queue_depth_mean"] = float(qmean.mean())
        out["queue_depth_max"] = float(qmax.max())
        out["queue_depth_final"] = float(qmax[-1])
        if max_queue:
            out["queue_saturation"] = float(qmax.max() / max_queue)
    return out


def fill_registry(reg: Registry, stats, *, prefix: str = "repro_slo",
                  process: str = "poisson") -> Registry:
    """Export one run's ServeStats into Prometheus instruments.

    Family names embed the arrival process (one exposition file carries
    every process without duplicate-family TYPE rows); histograms merge
    the streaming count vectors directly — no re-binning.
    """
    p = f"{prefix}_{process}"
    labels = {"process": process}
    reg.counter(f"{p}_completed_total", "rows completed",
                labels).inc(stats.completed)
    reg.counter(f"{p}_dropped_total", "rows dropped by admission control",
                labels).inc(stats.dropped)
    reg.counter(f"{p}_offered_total", "rows offered",
                labels).inc(stats.generated_rows)
    ttfe = stats.time_to_first_exit
    if not math.isnan(ttfe):
        reg.gauge(f"{p}_time_to_first_exit_seconds",
                  "first completion minus first submit", labels).set(ttfe)
    h = reg.histogram(f"{p}_latency_seconds", "end-to-end request latency",
                      labels, spec=stats.hist_spec)
    h.merge_from(stats.latency_counts, sum_=stats.latency_sum)
    for name in SEGMENTS:
        base = name[:-2] if name.endswith("_s") else name
        hs = reg.histogram(f"{p}_segment_{base}_seconds",
                           f"critical-path segment: {base}", labels,
                           spec=stats.hist_spec)
        hs.merge_from(stats.segment_counts[name],
                      sum_=stats.segment_sums[name])
    return reg


def perfetto_counter_events(stats) -> List[Dict]:
    """ServeStats flight-recorder stream → Perfetto counter-track events
    (the serve-side twin of the sim's state counters)."""
    sysbuf = stats.state_records
    stage = stats.stage_state
    if not sysbuf.shape[0]:
        return []
    sdec = decode_state(state=stage if stage.shape[0] else None, sys=sysbuf)
    return state_counter_events(sdec)
