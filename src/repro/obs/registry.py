"""Counter / gauge / histogram registry (DESIGN.md §14.1).

A :class:`Registry` is an ordered, name-keyed collection of metric
instruments that the load generator and serve path fill as they run and
the exporters read when they report — the host-side complement of the
jit-compatible count vectors in :mod:`repro.obs.hist`:

  * :class:`Counter` — monotone float total (completions, drops, bytes);
  * :class:`Gauge`   — last-write-wins level (queue depth, in-flight);
  * :class:`Histogram` — a :class:`~repro.obs.hist.HistSpec` count vector
    plus a running sum, filled via ``observe`` / ``observe_many`` and
    mergeable across shards with ``merge_from``.

``get-or-create`` semantics (``registry.counter(name)`` twice returns the
same instrument) keep call sites free of plumbing; re-registering a name
as a different kind is an error, not a silent shadow.  Rendering to
Prometheus exposition text lives in :mod:`repro.obs.prom`.
"""
from __future__ import annotations

from typing import Dict, Iterable, Mapping, Optional, Sequence

import numpy as np

from repro.obs import hist as _hist


class Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labels: Optional[Mapping[str, str]] = None):
        self.name = name
        self.help = help
        self.labels: Dict[str, str] = dict(labels or {})


class Counter(Metric):
    kind = "counter"

    def __init__(self, name: str, help: str = "", labels=None):
        super().__init__(name, help, labels)
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative inc {amount}")
        self.value += float(amount)


class Gauge(Metric):
    kind = "gauge"

    def __init__(self, name: str, help: str = "", labels=None):
        super().__init__(name, help, labels)
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += float(amount)

    def dec(self, amount: float = 1.0) -> None:
        self.value -= float(amount)


class Histogram(Metric):
    kind = "histogram"

    def __init__(self, name: str, help: str = "", labels=None,
                 spec: _hist.HistSpec = _hist.DEFAULT_LATENCY_HIST):
        super().__init__(name, help, labels)
        self.spec = spec
        self.counts = _hist.empty_np(spec)
        self.sum = 0.0

    def observe(self, value: float, weight: int = 1) -> None:
        _hist.fill_np(self.spec, self.counts, [value], [weight])
        self.sum += float(value) * int(weight)

    def observe_many(self, values, weights=None) -> None:
        x = np.asarray(values, np.float64).ravel()
        if weights is None:
            _hist.fill_np(self.spec, self.counts, x)
            self.sum += float(x.sum())
        else:
            w = np.broadcast_to(np.asarray(weights, np.int64).ravel(),
                                x.shape)
            _hist.fill_np(self.spec, self.counts, x, w)
            self.sum += float((x * w).sum())

    def merge_from(self, counts, sum_: float = 0.0) -> None:
        """Fold a shard's count vector (e.g. an in-scan fill) in."""
        self.counts = _hist.merge(self.counts, counts)
        self.sum += float(sum_)

    @property
    def count(self) -> int:
        return _hist.total(self.counts)

    def quantile(self, q: float) -> Optional[float]:
        return _hist.quantile(self.spec, self.counts, q)

    def summary(self, qs: Sequence[float] = _hist.SLO_QS) -> Dict:
        return _hist.summary(self.spec, self.counts, qs)


class Registry:
    """Ordered name → instrument map with get-or-create accessors."""

    def __init__(self):
        self._metrics: Dict[str, Metric] = {}

    def _get_or_create(self, cls, name: str, help: str, labels, **kw):
        m = self._metrics.get(name)
        if m is not None:
            if not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{m.kind}, requested {cls.kind}")
            return m
        m = cls(name, help, labels, **kw)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "", labels=None) -> Counter:
        return self._get_or_create(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", labels=None) -> Gauge:
        return self._get_or_create(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "", labels=None,
                  spec: _hist.HistSpec = _hist.DEFAULT_LATENCY_HIST
                  ) -> Histogram:
        return self._get_or_create(Histogram, name, help, labels, spec=spec)

    def collect(self) -> Iterable[Metric]:
        return list(self._metrics.values())

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __getitem__(self, name: str) -> Metric:
        return self._metrics[name]

    def to_prometheus(self) -> str:
        from repro.obs import prom
        return prom.render(self)
