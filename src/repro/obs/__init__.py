"""`repro.obs` — the SLO observatory (DESIGN.md §14).

The metrics core of the serve path: fixed-width, log-bucketed,
**mergeable** latency histograms (:mod:`~repro.obs.hist` — jit-compatible
fills, integer-exact shard merges), host-side counter/gauge/histogram
registries (:mod:`~repro.obs.registry`), and Prometheus text exposition
with a strict round-trip parser (:mod:`~repro.obs.prom`).

The open-loop load generator lives in :mod:`repro.obs.loadgen` and the
SLO report builder in :mod:`repro.obs.slo`; both import the serve engine,
which itself imports this package's leaf modules — so neither is imported
here (import them explicitly; keeping the package root a leaf breaks the
cycle).
"""
from __future__ import annotations

import os
import platform

from repro.obs.hist import (DEFAULT_LATENCY_HIST, SLO_QS, HistSpec, edges,
                            empty, empty_np, fill, fill_np, merge, q_label,
                            quantile, summary)
from repro.obs.registry import Counter, Gauge, Histogram, Registry

__all__ = ["HistSpec", "DEFAULT_LATENCY_HIST", "SLO_QS", "edges", "empty",
           "empty_np", "fill", "fill_np", "merge", "quantile", "summary",
           "q_label", "Counter", "Gauge", "Histogram", "Registry",
           "host_class"]


def host_class() -> str:
    """Coarse machine-class identifier for perf-profile comparability
    (DESIGN.md §14.5): OS, ISA, and physical core count — enough to tell
    "same class of box" from "CI runner vs laptop" without fingerprinting
    the exact host.  Override with ``REPRO_HOST_CLASS`` for fleets whose
    hardware labels don't reduce to these fields.
    """
    override = os.environ.get("REPRO_HOST_CLASS")
    if override:
        return override
    cores = os.cpu_count() or 0
    return (f"{platform.system().lower()}-{platform.machine().lower()}"
            f"-c{cores}")
