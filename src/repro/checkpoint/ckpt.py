"""Sharded checkpointing with elastic re-sharding.

Layout:  <dir>/step_<k>/
            manifest.json       — step, mesh shape/axes, tree structure,
                                  per-leaf dtype/shape
            arrays.npz          — flattened leaves (gathered to host)

Design points for 1000+-node fleets (scaled down to this container):
  * atomic publish: write to ``step_<k>.tmp`` then rename — a crashed writer
    never corrupts the latest checkpoint;
  * retention: keep the newest `keep` checkpoints;
  * elastic restore: leaves are saved unsharded (host-gathered); on restore
    they are re-placed under the *current* mesh's NamedShardings, so the
    mesh shape may change between save and load (elastic scaling);
  * restart-safe data: the synthetic pipeline is stateless in `step`, so
    save(step) + restore() resumes bit-identically (tested).

On a real multi-host fleet the np.savez path would be replaced by per-host
shard files (one writer per data-parallel replica group); the manifest/
rename/retention logic is unchanged — noted in DESIGN.md.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(p) for p in kp) for kp, _ in flat]
    leaves = [v for _, v in flat]
    return paths, leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3,
         extra: Optional[Dict] = None) -> str:
    """Atomically persist `tree` (params/opt-state/pytree of arrays)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    paths, leaves, _ = _flatten_with_paths(tree)
    host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{f"a{i}": v for i, v in enumerate(host_leaves)})
    manifest = {
        "step": step,
        "time": time.time(),
        "paths": paths,
        "shapes": [list(v.shape) for v in host_leaves],
        "dtypes": [str(v.dtype) for v in host_leaves],
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    os.rename(tmp, final)

    # retention
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
    return final


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            out.append(int(d.split("_")[1]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, like: Any, *, step: Optional[int] = None,
            mesh=None, specs=None) -> Tuple[Any, Dict]:
    """Restore into the structure of `like`.

    With (mesh, specs) the leaves are placed as NamedSharding-ed global
    arrays under the *current* mesh — elastic re-sharding across mesh-shape
    changes is free because leaves are persisted unsharded.
    """
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    leaves = [data[f"a{i}"] for i in range(len(manifest["paths"]))]

    like_paths, like_leaves, treedef = _flatten_with_paths(like)
    if like_paths != manifest["paths"]:
        missing = set(manifest["paths"]) ^ set(like_paths)
        raise ValueError(f"checkpoint tree mismatch; differing: {missing}")

    if mesh is not None and specs is not None:
        from jax.sharding import NamedSharding
        spec_leaves = jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(
                x, jax.sharding.PartitionSpec))
        placed = [jax.device_put(v, NamedSharding(mesh, s))
                  for v, s in zip(leaves, spec_leaves, strict=True)]
    else:
        placed = [jnp.asarray(v) for v in leaves]
    return jax.tree_util.tree_unflatten(treedef, placed), manifest
