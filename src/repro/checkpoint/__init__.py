from repro.checkpoint.ckpt import all_steps, latest_step, restore, save

__all__ = ["save", "restore", "latest_step", "all_steps"]
