"""Repo-level pytest config.

``hypothesis`` is an optional dependency: when it is not installed, a
minimal fixed-seed stand-in from ``tests/_shims`` is put on ``sys.path`` so
the property tests still collect and run (as seeded example sweeps rather
than adaptive search).  The real package always wins when present.
"""
import os
import sys

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "tests",
                                    "_shims"))
