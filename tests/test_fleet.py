"""Fleet sweep-engine tests (DESIGN.md §8): grid expansion, cross-backend
bit-for-bit equivalence, content-addressed caching, and kill/resume
determinism of the streaming backend.

The CI fleet smoke job runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the sharded
backend exercises a real 8-device mesh; the tests themselves are
device-count agnostic (the mesh spans whatever is available).
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SwarmConfig
from repro.fleet import (ResultStore, SweepInterrupted, SweepSpec,
                         build_report, execute, point_digest, run_batch,
                         run_point, write_bench_json)
from repro.swarm import DISTRIBUTED, LOCAL_ONLY, run_many

KEY = jax.random.PRNGKey(0)
CFG = dataclasses.replace(SwarmConfig(), sim_time_s=2.0, num_workers=8)
N, RUNS = 8, 6


@pytest.fixture(autouse=True)
def _pinned_code_version(monkeypatch):
    """Digests must not drift with the working tree while tests run."""
    from repro.fleet.store import code_version
    monkeypatch.setenv("REPRO_CODE_VERSION", "test-version")
    code_version.cache_clear()
    yield
    code_version.cache_clear()


@pytest.fixture(scope="module")
def vmap_metrics():
    out = run_batch(KEY, CFG, jnp.int32(DISTRIBUTED), N, RUNS,
                    backend="vmap")
    return {k: np.asarray(v) for k, v in out.items()}


def _np(tree):
    return {k: np.asarray(v) for k, v in tree.items()}


# ---------------------------------------------------------------------------
# sweep expansion
# ---------------------------------------------------------------------------


def test_sweep_expands_full_grid_with_unique_labels_and_digests():
    spec = SweepSpec.build(
        "grid", CFG,
        axes={"gamma": (0.02, 0.1),
              "scenario": (("base", {}),
                           ("rwp", {"mobility_model": "random_waypoint"}))},
        strategies=(LOCAL_ONLY, DISTRIBUTED), num_runs=3)
    pts = spec.expand()
    assert len(pts) == len(spec) == 2 * 2 * 2
    labels = [p.label for p in pts]
    assert len(set(labels)) == len(labels)
    digests = {point_digest(p) for p in pts}
    # the two scenario cells of equal gamma/strategy differ only via
    # overrides — digests must still all be distinct
    assert len(digests) == len(pts)
    rwp = [p for p in pts if p.values["scenario"] == "rwp"]
    assert all(p.cfg.mobility_model == "random_waypoint" for p in rwp)
    assert all(p.n == CFG.num_workers for p in pts)


def test_sweep_rejects_unknown_fields():
    with pytest.raises(ValueError, match="not a SwarmConfig field"):
        SweepSpec.build("bad", CFG, axes={"gama": (0.1,)}).expand()
    with pytest.raises(ValueError, match="unknown SwarmConfig fields"):
        SweepSpec.build("bad", CFG, axes={
            "scenario": (("x", {"mobility": "rwp"}),)}).expand()


# ---------------------------------------------------------------------------
# cross-backend equivalence (acceptance: identical summary metrics)
# ---------------------------------------------------------------------------


def test_sharded_backend_bit_identical_to_vmap(vmap_metrics):
    got = _np(run_batch(KEY, CFG, jnp.int32(DISTRIBUTED), N, RUNS,
                        backend="sharded"))
    assert set(got) == set(vmap_metrics)
    for k in got:
        np.testing.assert_array_equal(got[k], vmap_metrics[k], err_msg=k)


def test_streaming_backend_bit_identical_to_vmap(vmap_metrics):
    # chunk_size=4 over 6 runs: exercises the padded final chunk
    got = _np(run_batch(KEY, CFG, jnp.int32(DISTRIBUTED), N, RUNS,
                        backend="streaming", chunk_size=4))
    for k in got:
        np.testing.assert_array_equal(got[k], vmap_metrics[k], err_msg=k)


def test_sharded_pads_non_divisible_run_counts(vmap_metrics):
    if len(jax.devices()) == 1:
        pytest.skip("padding is a no-op on a single device")
    runs = len(jax.devices()) + 1
    got = _np(run_batch(KEY, CFG, jnp.int32(DISTRIBUTED), N, runs,
                        backend="sharded"))
    ref = _np(run_batch(KEY, CFG, jnp.int32(DISTRIBUTED), N, runs,
                        backend="vmap"))
    for k in got:
        np.testing.assert_array_equal(got[k], ref[k], err_msg=k)


def test_run_many_routes_through_executor(vmap_metrics):
    got = _np(run_many(KEY, CFG, jnp.int32(DISTRIBUTED), N, RUNS))
    for k in got:
        np.testing.assert_array_equal(got[k], vmap_metrics[k], err_msg=k)


def test_unknown_backend_raises():
    with pytest.raises(ValueError, match="unknown backend"):
        run_batch(KEY, CFG, jnp.int32(0), N, 2, backend="pmap")


# ---------------------------------------------------------------------------
# store: content addressing + cache hits
# ---------------------------------------------------------------------------


def test_store_roundtrip_is_bitwise(tmp_path):
    spec = SweepSpec.build("cache", CFG, strategies=(DISTRIBUTED,),
                           num_runs=RUNS)
    (pt,) = spec.expand()
    store = ResultStore(str(tmp_path))
    first = run_point(pt, backend="vmap", store=store)
    digest = point_digest(pt)
    assert store.get(digest) is not None
    hit = run_point(pt, backend="vmap", store=store)
    for k in first:
        np.testing.assert_array_equal(hit[k], first[k], err_msg=k)
    # a result computed on one backend is a valid hit for another
    hit2 = run_point(pt, backend="streaming", store=store, chunk_size=2)
    for k in first:
        np.testing.assert_array_equal(hit2[k], first[k], err_msg=k)


def test_digest_covers_config_and_code_version(monkeypatch):
    spec = SweepSpec.build("d", CFG, strategies=(DISTRIBUTED,), num_runs=2)
    (pt,) = spec.expand()
    base = point_digest(pt)
    assert point_digest(pt._replace(
        cfg=dataclasses.replace(CFG, gamma=0.5))) != base
    assert point_digest(pt._replace(seed=1)) != base
    assert point_digest(pt._replace(num_runs=3)) != base
    assert point_digest(pt, version="other") != base
    assert point_digest(pt) == base     # and it is deterministic


# ---------------------------------------------------------------------------
# kill/resume (acceptance: resumed == uninterrupted, down to BENCH json)
# ---------------------------------------------------------------------------


def test_killed_and_resumed_sweep_matches_uninterrupted(tmp_path):
    spec = SweepSpec.build("resume", CFG, axes={"gamma": (0.02, 0.1)},
                           strategies=(DISTRIBUTED,), num_runs=RUNS)
    store = ResultStore(str(tmp_path / "cache"))

    # kill after 1 of 3 chunks of the first point
    with pytest.raises(SweepInterrupted):
        for pt in spec.expand():
            run_point(pt, backend="streaming", store=store, chunk_size=2,
                      max_chunks=1)
    # partial progress was checkpointed
    done, accum = store.load_partial(point_digest(spec.expand()[0]))
    assert done == 1 and accum is not None
    assert next(iter(accum.values())).shape == (2,)

    # resume to completion, then compare against a storeless fresh run
    resumed = execute(spec, backend="streaming", store=store, chunk_size=2)
    fresh = execute(spec, backend="streaming", chunk_size=2)
    for label in fresh:
        for k in fresh[label]:
            if k.startswith("_"):
                continue
            np.testing.assert_array_equal(resumed[label][k],
                                          fresh[label][k],
                                          err_msg=f"{label}/{k}")

    # ... and the emitted BENCH_fleet.json files are byte-identical
    p_resumed = str(tmp_path / "bench_resumed.json")
    p_fresh = str(tmp_path / "bench_fresh.json")
    write_bench_json(p_resumed, "sweep:resume", build_report(resumed))
    write_bench_json(p_fresh, "sweep:resume", build_report(fresh))
    with open(p_resumed) as f1, open(p_fresh) as f2:
        assert f1.read() == f2.read()


def test_resume_with_different_chunk_size_discards_stale_partial(tmp_path):
    """chunks_done only indexes runs together with its chunk size: resuming
    under a different chunking must restart cleanly, not skip/duplicate
    Monte-Carlo runs."""
    spec = SweepSpec.build("rechunk", CFG, strategies=(DISTRIBUTED,),
                           num_runs=RUNS)
    (pt,) = spec.expand()
    store = ResultStore(str(tmp_path))
    with pytest.raises(SweepInterrupted):
        run_point(pt, backend="streaming", store=store, chunk_size=2,
                  max_chunks=1)
    # the size-2 partial is unusable at size 3 and must be dropped
    done, _ = store.load_partial(point_digest(pt), chunk_size=3)
    assert done == 0
    resumed = run_point(pt, backend="streaming", store=store, chunk_size=3)
    ref = _np(run_batch(KEY, CFG, jnp.int32(DISTRIBUTED), N, RUNS,
                        backend="vmap"))
    for k in ref:
        np.testing.assert_array_equal(resumed[k], ref[k], err_msg=k)


def test_bench_json_sections_merge(tmp_path):
    path = str(tmp_path / "bench.json")
    write_bench_json(path, "a", {"x": 1})
    write_bench_json(path, "b", {"y": 2})
    write_bench_json(path, "a", {"x": 3})
    with open(path) as f:
        doc = json.load(f)
    assert doc == {"a": {"x": 3}, "b": {"y": 2}}
    assert os.path.exists(path) and not os.path.exists(path + ".tmp")
