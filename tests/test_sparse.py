"""Sparse neighbor-list φ path tests (DESIGN.md §11).

Three layers of parity, each pinned exactly:

  * the spatial-hash neighbor builder against an O(N²) brute force
    (coverage is provable when the cell edge >= the candidate radius);
  * the gather-based Pallas kernel (interpret mode) against the jnp
    reference, including padded / multi-tile shapes;
  * the whole sparse epoch pipeline — per-edge channel, φ update,
    offload decisions — against the dense [N, N] path, bit-for-bit,
    whenever ``neighbor_k`` covers the true max degree.

The e2e equivalence holds for the deterministic channels and the
LocalOnly/Greedy/Distributed strategies; Random/RandomAcyclic draw their
target gumbels over [N, K] instead of [N, N] (an intentional stream
divergence, exercised for sanity only), and the stochastic channels draw
per-edge rather than per-matrix (symmetry + self-consistency pinned
instead).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SwarmConfig
from repro.core.diffusive import (NEG, phi_update, phi_update_op,
                                  phi_update_op_sparse, phi_update_sparse)
from repro.fleet import run_batch
from repro.kernels import ref
from repro.kernels.diffusive_phi import \
    diffusive_phi_sparse as pl_phi_sparse
from repro.swarm import (DISTRIBUTED, GREEDY, LOCAL_ONLY, RANDOM,
                         RANDOM_ACYCLIC, comm_range_m, get_channel_edges,
                         grid_geometry, neighbor_lists, run_many)
from repro.swarm.channel import (edge_rate, link_state, link_state_sparse,
                                 pairwise_distance)

KEY = jax.random.PRNGKey(0)

# small swarm where K = N - 1 covers any degree: the exact-parity regime
N, RUNS = 12, 3
CFG = dataclasses.replace(SwarmConfig(), sim_time_s=5.0, num_workers=N)
CFG_SP = dataclasses.replace(CFG, neighbor_mode="sparse", neighbor_k=N - 1)


def _np(tree):
    return {k: np.asarray(v) for k, v in tree.items()}


# ---------------------------------------------------------------------------
# neighbor builder vs brute force
# ---------------------------------------------------------------------------


def test_neighbor_lists_match_brute_force_radius():
    """Radius-limited regime (cell >= range): the lists must hold *exactly*
    the within-range node sets, in ascending id order."""
    n, r = 64, 3000.0
    cfg = dataclasses.replace(SwarmConfig(), neighbor_range_m=r,
                              neighbor_k=n - 1)
    pos = jax.random.uniform(KEY, (n, 2), jnp.float32, 0.0, cfg.area_m)
    nbr, valid = neighbor_lists(pos, cfg)
    nbr, valid = np.asarray(nbr), np.asarray(valid)
    d = np.asarray(pairwise_distance(pos))
    within = (d <= r) & ~np.eye(n, dtype=bool)
    for i in range(n):
        got = nbr[i, valid[i]]
        assert sorted(got) == list(got), f"node {i} not id-sorted"
        assert set(got.tolist()) == set(np.where(within[i])[0].tolist()), i
    # invalid slots are index 0 (masked downstream), pushed to the end
    assert np.all(nbr[~valid] == 0)


def test_neighbor_lists_keep_k_nearest():
    """K < degree: the kept neighbors are the K nearest within range."""
    n, k, r = 200, 8, 2000.0
    cfg = dataclasses.replace(SwarmConfig(), neighbor_range_m=r,
                              neighbor_k=k)
    pos = jax.random.uniform(jax.random.fold_in(KEY, 1), (n, 2),
                             jnp.float32, 0.0, cfg.area_m)
    nbr, valid = neighbor_lists(pos, cfg)
    nbr, valid = np.asarray(nbr), np.asarray(valid)
    d = np.asarray(pairwise_distance(pos)).copy()
    d[np.eye(n, dtype=bool)] = np.inf
    d[d > r] = np.inf
    for i in range(n):
        finite = np.isfinite(d[i]).sum()
        want = set(np.argsort(d[i])[:min(k, finite)].tolist())
        assert set(nbr[i, valid[i]].tolist()) == want, i
        assert valid[i].sum() == min(k, finite)


def test_grid_geometry_is_static_and_covering():
    cfg = dataclasses.replace(SwarmConfig(), neighbor_range_m=3000.0)
    G, cell, cap = grid_geometry(cfg, 64, 16)
    assert isinstance(G, int) and isinstance(cap, int)
    assert isinstance(cell, float)
    # floor-derived grid: realized cell never shrinks below the range, so
    # the 3x3 window provably covers every in-range neighbor
    assert cell >= comm_range_m(cfg)
    assert cap == 64            # small swarms: exact (cap = n)
    G2, _, cap2 = grid_geometry(cfg, 65_536, 16)
    assert G2 > 1 and cap2 < 65_536


def test_comm_range_override_and_default():
    cfg = SwarmConfig()
    diag = cfg.area_m * np.sqrt(2.0)
    assert comm_range_m(cfg) == pytest.approx(diag)   # two-ray reaches far
    cfg_r = dataclasses.replace(cfg, neighbor_range_m=1234.0)
    assert comm_range_m(cfg_r) == 1234.0


# ---------------------------------------------------------------------------
# sparse kernel: interpret-mode Pallas vs jnp reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("r,n,k", [(1, 64, 8),    # single tile
                                   (2, 40, 37),   # padded N and K
                                   (1, 100, 1),   # degenerate K
                                   (1, 40, 130)])  # K spans two BK tiles
def test_sparse_kernel_interpret_matches_ref(r, n, k):
    kk = jax.random.split(jax.random.fold_in(KEY, n * 1000 + k), 3)
    F = jax.random.uniform(kk[0], (r, n), jnp.float32, 100, 500)
    nbr = jax.random.randint(kk[1], (r, n, k), 0, n)
    ok = jax.random.bernoulli(kk[2], 0.6, (r, n, k))
    dtx = jnp.where(ok, jax.random.uniform(kk[2], (r, n, k),
                                           jnp.float32, 1e-4, 1e-2), NEG)
    want = ref.diffusive_phi_sparse(1.0 / F, F, dtx, nbr)
    got = pl_phi_sparse(1.0 / F, F, dtx, nbr, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sparse_kernel_isolated_fallback():
    """Zero-degree rows fall back to phi = F (the Eq. 10 convention the
    dense kernel pins)."""
    F = jnp.full((1, 8), 250.0)
    nbr = jnp.zeros((1, 8, 4), jnp.int32)
    dtx = jnp.full((1, 8, 4), NEG)
    out = pl_phi_sparse(1.0 / F, F, dtx, nbr, interpret=True)
    np.testing.assert_allclose(np.asarray(1.0 / out), np.full((1, 8), 250.0))


# ---------------------------------------------------------------------------
# sparse φ update vs dense, through the channel
# ---------------------------------------------------------------------------


def _sparse_epoch_inputs(cfg, n, key):
    pos = jax.random.uniform(key, (n, 2), jnp.float32, 0.0, cfg.area_m)
    adj, cap = link_state(pos, cfg)
    nbr, valid = neighbor_lists(pos, cfg, k=n - 1)
    adj_e, cap_e = link_state_sparse(pos, nbr, valid, cfg)
    return pos, (adj, cap), (nbr, valid, adj_e, cap_e)


def test_link_state_sparse_matches_dense_entries():
    cfg = SwarmConfig()
    n = 20
    _, (adj, cap), (nbr, valid, adj_e, cap_e) = _sparse_epoch_inputs(
        cfg, n, KEY)
    adj, cap = np.asarray(adj), np.asarray(cap)
    nbr, valid = np.asarray(nbr), np.asarray(valid)
    adj_e, cap_e = np.asarray(adj_e), np.asarray(cap_e)
    for i in range(n):
        # every dense neighbor appears in the list (K = n-1 covers all) …
        assert set(np.where(adj[i])[0]) <= set(nbr[i, valid[i]].tolist())
        for s in range(n - 1):
            if valid[i, s]:
                # … and gathered entries agree exactly
                assert adj_e[i, s] == adj[i, nbr[i, s]]
                if adj_e[i, s]:
                    assert cap_e[i, s] == cap[i, nbr[i, s]]


def test_phi_update_sparse_bitwise_matches_dense():
    cfg = SwarmConfig()
    n = 20
    bpg = 1.0e4
    _, (adj, cap), (nbr, valid, adj_e, cap_e) = _sparse_epoch_inputs(
        cfg, n, KEY)
    F = jax.random.uniform(jax.random.fold_in(KEY, 2), (n,),
                           jnp.float32, 100, 500)
    dtx = jnp.where(adj, bpg / cap, 1e30)
    dtx_e = jnp.where(adj_e, bpg / cap_e, 1e30)
    dense = phi_update(F, F, adj, dtx)
    sparse = phi_update_sparse(F, F, adj_e, nbr, dtx_e)
    np.testing.assert_array_equal(np.asarray(sparse), np.asarray(dense))
    dense_op = phi_update_op(F, F, adj, dtx)
    sparse_op = phi_update_op_sparse(F, F, adj_e, nbr, dtx_e)
    np.testing.assert_array_equal(np.asarray(sparse_op),
                                  np.asarray(dense_op))


# ---------------------------------------------------------------------------
# end-to-end: sparse simulator == dense simulator (K >= max degree)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", [LOCAL_ONLY, GREEDY, DISTRIBUTED])
def test_e2e_sparse_matches_dense_exactly(strategy):
    dense = _np(run_many(KEY, CFG, jnp.int32(strategy), N, RUNS))
    sparse = _np(run_many(KEY, CFG_SP, jnp.int32(strategy), N, RUNS))
    assert sorted(dense) == sorted(sparse)
    for k in dense:
        np.testing.assert_array_equal(sparse[k], dense[k], err_msg=k)


@pytest.mark.parametrize("backend,kw", [("sharded", {}),
                                        ("streaming", {"chunk_size": 2})])
def test_sparse_bit_identical_across_backends(backend, kw):
    want = _np(run_batch(KEY, CFG_SP, jnp.int32(DISTRIBUTED), N, RUNS))
    got = _np(run_batch(KEY, CFG_SP, jnp.int32(DISTRIBUTED), N, RUNS,
                        backend=backend, **kw))
    for k in want:
        np.testing.assert_array_equal(got[k], want[k], err_msg=k)


@pytest.mark.parametrize("strategy", [RANDOM, RANDOM_ACYCLIC])
def test_sparse_random_strategies_are_sane(strategy):
    """Random/RandomAcyclic sample targets over the [N, K] lists — a
    different gumbel stream than dense [N, N] (documented divergence), so
    pin physics, not parity."""
    m = _np(run_many(KEY, CFG_SP, jnp.int32(strategy), N, RUNS))
    assert np.all(np.isfinite(m["avg_latency_s"]))
    assert np.all(m["generated"] > 0)
    assert np.all(m["completed"] + m["dropped"] <= m["generated"] + 1e-3)
    assert np.all(m["energy_total_j"] > 0)
    assert np.all(m["transfers_delivered"] <= m["transfers"])


def test_sparse_stochastic_channel_runs():
    """Per-edge stochastic channels (different draw stream than dense, by
    design) still produce a physical simulation."""
    cfg = dataclasses.replace(CFG_SP, channel_model="log_normal")
    m = _np(run_many(KEY, cfg, jnp.int32(DISTRIBUTED), N, RUNS))
    assert np.all(np.isfinite(m["avg_latency_s"]))
    assert np.all(m["energy_total_j"] > 0)


# ---------------------------------------------------------------------------
# per-edge channel draws: symmetry, self-consistency, fail-loud coverage
# ---------------------------------------------------------------------------


def test_edge_draws_are_symmetric():
    """Gain draw on (i, j) must equal the draw on (j, i) — the sparse twin
    of the dense models' matrix symmetrization."""
    from repro.swarm.channel import log_normal_edges, nakagami_edges
    cfg = SwarmConfig()
    key = jax.random.fold_in(KEY, 7)
    src = jnp.asarray([[0, 3, 5]], jnp.int32)
    dst = jnp.asarray([[3, 0, 2]], jnp.int32)
    d = jnp.full((1, 3), 800.0, jnp.float32)
    for fn in (log_normal_edges, nakagami_edges):
        pl = np.asarray(fn(key, d, src, dst, cfg))
        assert pl[0, 0] == pl[0, 1], fn.__name__   # (0,3) == (3,0)
        assert pl[0, 0] != pl[0, 2], fn.__name__   # distinct edges differ


def test_edge_rate_consistent_with_link_state_sparse():
    """The per-tick [N] rate vector and the per-epoch [N, K] capacity table
    gather the *same* per-edge draw for the same (src, dst) pair."""
    cfg = dataclasses.replace(SwarmConfig(), neighbor_mode="sparse",
                              channel_model="log_normal")
    edge_fn = get_channel_edges(cfg)
    n = 16
    key = jax.random.fold_in(KEY, 11)
    pos = jax.random.uniform(key, (n, 2), jnp.float32, 0.0, cfg.area_m)
    nbr, valid = neighbor_lists(pos, cfg, k=n - 1)
    adj_e, cap_e = link_state_sparse(pos, nbr, valid, cfg, key=key,
                                     pathloss_fn=edge_fn)
    # each node targets its first listed neighbor (itself when isolated)
    dst = jnp.where(valid[:, 0], nbr[:, 0], jnp.arange(n))
    rate = edge_rate(pos, dst, cfg, key=key, pathloss_fn=edge_fn)
    want = jnp.where(adj_e[:, 0] & valid[:, 0], cap_e[:, 0], 1.0)
    np.testing.assert_array_equal(np.asarray(rate), np.asarray(want))


def test_unported_channel_fails_loud_in_sparse_mode():
    """log_normal_corr has no per-edge twin (its Gudmundson field is
    inherently O(N²)); sparse mode must refuse it, not silently diverge."""
    cfg = dataclasses.replace(CFG_SP, channel_model="log_normal_corr")
    with pytest.raises(KeyError, match="log_normal_corr"):
        run_many(KEY, cfg, jnp.int32(DISTRIBUTED), N, 1)
