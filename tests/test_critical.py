"""Critical-path attribution + load-generator tests (DESIGN.md §14.2,
§14.4-§14.5): per-task segment reconciliation against latency_s,
stable key sets under degraded inputs, perf-gate segment attribution and
host-class gating, arrival-process determinism, and the open-loop
SLO smoke over the synthetic serve engine.
"""
import numpy as np
import pytest

from benchmarks.perf_gate import attribute_failure, compare
from repro.obs.loadgen import (SyntheticServeEngine, mmpp_arrivals,
                               poisson_arrivals, replay_arrivals,
                               run_open_loop)
from repro.obs.slo import slo_indices
from repro.trace import schema
from repro.trace.critical import (SEGMENTS, attribute, decompose,
                                  hop_stall_fraction, segment_indices)
from repro.trace.decode import decode, decode_hops

RNG = np.random.default_rng(3)
TICK = 0.05


def _task_rows(n=400, dropped_every=0, tx_frac=0.3):
    rows = []
    for i in range(n):
        created = float(RNG.uniform(0, 20))
        lat = float(RNG.lognormal(-1.0, 1.0))
        is_drop = dropped_every and i % dropped_every == 0
        rows.append(schema.pack_np(
            i, 0, 1, created, created + lat,
            schema.DROPPED if is_drop else 0,
            0 if is_drop else 30, 2, energy_j=0.1,
            tx_time_s=tx_frac * lat))
    return np.stack(rows)


def _hop_rows(n=200, stall_ticks=2):
    rows = np.zeros((n, schema.NUM_HOP_FIELDS), np.float64)
    rows[:, schema.HOP_SEQ] = np.arange(n)
    rows[:, schema.HOP_T_ARRIVE] = RNG.uniform(0.5, 1.5, size=n)
    rows[:, schema.HOP_BITS] = 1e6
    rows[:, schema.HOP_STALL_TICKS] = stall_ticks
    return rows


# ---------------------------------------------------------------------------
# decompose / segment_indices
# ---------------------------------------------------------------------------

def test_decompose_reconciles_per_task():
    dec = decode(_task_rows(dropped_every=7))
    hdec = decode_hops(_hop_rows())
    seg = decompose(dec, hdec, tick_s=TICK, gflops_per_layer=0.2,
                    capability_gflops=400.0)
    total = sum(seg[name] for name in SEGMENTS)
    np.testing.assert_allclose(total, seg["latency_s"], rtol=0, atol=1e-9)
    assert (seg["latency_s"].size
            == int((~dec["is_dropped"]).sum()))       # completed only
    for name in SEGMENTS:
        assert (seg[name] >= -1e-12).all()


def test_decompose_degrades_keep_sum_exact():
    dec = decode(_task_rows())
    # no hop stream → all in-flight time is airtime
    seg = decompose(dec, None, gflops_per_layer=0.2,
                    capability_gflops=400.0)
    assert float(seg["stall_s"].sum()) == 0.0
    # no compute-rate estimate → compute absorbs on-node, queue-wait 0
    seg2 = decompose(dec)
    assert float(seg2["queue_wait_s"].sum()) == 0.0
    for s in (seg, seg2):
        total = sum(s[name] for name in SEGMENTS)
        np.testing.assert_allclose(total, s["latency_s"],
                                   rtol=0, atol=1e-9)


def test_hop_stall_fraction_bounds():
    hdec = decode_hops(_hop_rows(stall_ticks=0))
    assert hop_stall_fraction(hdec, TICK) == 0.0
    hdec = decode_hops(_hop_rows(stall_ticks=1000))   # stalls > transfer
    assert hop_stall_fraction(hdec, TICK) == 1.0
    empty = decode_hops(np.full((4, schema.NUM_HOP_FIELDS), -1.0))
    assert hop_stall_fraction(empty, TICK) == 0.0


def test_segment_indices_stable_keys():
    dec = decode(_task_rows())
    out = segment_indices(dec, decode_hops(_hop_rows()), tick_s=TICK,
                          gflops_per_layer=0.2, capability_gflops=400.0)
    assert out["task_count"] == 400
    assert out["reconcile_max_err_s"] < 1e-9
    shares = [out[f"{n}_share"] for n in SEGMENTS]
    assert sum(shares) == pytest.approx(1.0)
    # all-dropped trace: same key set, null quantiles, zero shares
    empty = segment_indices(decode(_task_rows(n=5, dropped_every=1)))
    assert sorted(empty) == sorted(out)
    assert empty["task_count"] == 0
    for n in SEGMENTS:
        assert empty[f"{n}_quantiles"] is None
        assert empty[f"{n}_share"] == 0.0


def test_attribute_names_the_moved_segment():
    base = segment_indices(decode(_task_rows()), tick_s=TICK,
                           gflops_per_layer=0.2, capability_gflops=400.0)
    cur = {k: (dict(v) if isinstance(v, dict) else v)
           for k, v in base.items()}
    cur["queue_wait_s_quantiles"] = dict(base["queue_wait_s_quantiles"])
    cur["queue_wait_s_quantiles"]["p50"] = \
        base["queue_wait_s_quantiles"]["p50"] + 1.0
    hit = attribute(base, cur)
    assert hit["segment"] == "queue_wait_s"
    assert hit["delta_s"] == pytest.approx(1.0)
    assert attribute(base, base) is None              # nothing regressed
    assert attribute({}, {}) is None                  # nothing comparable


# ---------------------------------------------------------------------------
# perf gate: host classes, rel-tol, attribution lookup
# ---------------------------------------------------------------------------

def test_perf_gate_host_class_and_rel_tol():
    base = {"s": {"pt": {"cached": False, "execute_s": 1.0,
                         "host_class": "linux-x86_64-c8"}}}

    def cur(ratio, hc):
        return {"s": {"pt": {"cached": False, "execute_s": ratio,
                             "host_class": hc}}}

    _, _, failures = compare(base, cur(3.0, "linux-x86_64-c8"), 2.0, 0.0)
    assert failures                                   # same class: gate
    _, skipped, failures = compare(base, cur(3.0, "darwin-arm64-c10"),
                                   2.0, 0.0)
    assert not failures                               # cross class: warn
    assert any("host classes differ" in why for _, why in skipped)
    _, _, failures = compare(base, cur(2.4, "linux-x86_64-c8"),
                             2.0, 0.0, rel_tol=0.5)
    assert not failures                               # inside the slack
    # untagged current gates as same-class (pre-tag baselines keep teeth)
    untagged = {"s": {"pt": {"cached": False, "execute_s": 3.0}}}
    _, _, failures = compare(base, untagged, 2.0, 0.0)
    assert failures


def test_perf_gate_attribution_lookup():
    seg = segment_indices(decode(_task_rows()), tick_s=TICK,
                          gflops_per_layer=0.2, capability_gflops=400.0)
    worse = {k: (dict(v) if isinstance(v, dict) else v)
             for k, v in seg.items()}
    worse["airtime_s_quantiles"] = dict(seg["airtime_s_quantiles"])
    worse["airtime_s_quantiles"]["p50"] += 0.7
    base_doc = {"sweep:fig": {"points": {"pt": {"latency_segments": seg}}}}
    cur_doc = {"sweep:fig": {"points": {"pt": {"latency_segments": worse}}}}
    hit = attribute_failure(base_doc, cur_doc, "fig", "pt")
    assert hit and hit["segment"] == "airtime_s"
    assert attribute_failure({}, cur_doc, "fig", "pt") is None


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------

def test_arrivals_deterministic_and_sorted():
    a = poisson_arrivals(500.0, 10.0, seed=4)
    b = poisson_arrivals(500.0, 10.0, seed=4)
    np.testing.assert_array_equal(a, b)
    assert (np.diff(a) >= 0).all() and a[-1] < 10.0
    assert a.size == pytest.approx(5000, rel=0.1)
    m1 = mmpp_arrivals(400.0, 800.0, 20.0, seed=4)
    m2 = mmpp_arrivals(400.0, 800.0, 20.0, seed=4)
    np.testing.assert_array_equal(m1, m2)
    assert (np.diff(m1) >= 0).all()


def test_mmpp_mean_rate_near_dwell_weighted_target():
    # 6 s low at 0.8r, 2 s high at 1.6r → long-run mean r (loadtest.py)
    r = 1000.0
    t = mmpp_arrivals(0.8 * r, 1.6 * r, 200.0, seed=11)
    assert t.size / 200.0 == pytest.approx(r, rel=0.15)


def test_replay_arrivals_clips_and_sorts():
    t = replay_arrivals([3.0, 1.0, -2.0, 9.0], horizon_s=5.0)
    np.testing.assert_array_equal(t, [1.0, 3.0])


# ---------------------------------------------------------------------------
# open-loop SLO smoke (the scheduling-faithful synthetic engine)
# ---------------------------------------------------------------------------

def test_open_loop_slo_smoke():
    eng = SyntheticServeEngine(n_stages=4, max_queue=256)
    times = poisson_arrivals(3000.0, 2.0, seed=1)
    stats = run_open_loop(eng, times, dt=0.01, max_batch=64)
    out = slo_indices(stats, horizon_s=float(eng.clock),
                      offered_rows=int(times.size), rate_rps=3000.0,
                      max_queue=256)
    assert out["completed"] + out["dropped"] == times.size   # full drain
    assert out["drop_rate"] == 0.0                           # sub-capacity
    assert out["goodput_rps"] > 0 and out["latency_s"]["p50"] is not None
    assert out["latency_s"]["p50"] <= out["latency_s"]["p999"]
    assert out["time_to_first_exit_s"] > 0
    assert out["segment_reconcile_err_s"] < 1e-6
    assert out["queue_depth_mean"] is not None
    assert set(out["segments"]) == set(SEGMENTS)


def test_open_loop_overload_drops_and_saturates():
    eng = SyntheticServeEngine(n_stages=2, max_queue=8)
    times = poisson_arrivals(20_000.0, 1.0, seed=2)   # ~3x capacity
    stats = run_open_loop(eng, times, dt=0.01, max_batch=64)
    out = slo_indices(stats, horizon_s=float(eng.clock),
                      offered_rows=int(times.size), max_queue=8)
    assert out["dropped"] > 0 and out["drop_rate"] > 0
    # state snapshots land after the epoch's service, so the sampled max
    # sits one batch under the admission bound
    assert out["queue_saturation"] >= 0.8
    assert out["completed"] + out["dropped"] == stats.generated_rows


def test_slo_indices_zero_completions_well_defined():
    eng = SyntheticServeEngine(n_stages=2)
    out = slo_indices(eng.stats, horizon_s=0.0, offered_rows=0)
    assert out["avg_latency_s"] is None               # not NaN in JSON
    assert out["time_to_first_exit_s"] is None
    assert out["goodput_rps"] == 0.0 and out["drop_rate"] == 0.0
    assert out["latency_s"]["p50"] is None
