"""Cost-model + local-search planner properties (splitcompute/planner.py)."""
import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro.configs import get_config
from repro.splitcompute import (layer_profile, plan_and_refine, plan_cost,
                                plan_stages, split_points)
from repro.splitcompute.partitioner import StagePlan


def test_layer_profile_shapes_and_positivity():
    cfg = get_config("qwen3-4b")
    g, a = layer_profile(cfg, 128, 4)
    assert g.shape == (cfg.num_layers,) and a.shape == (cfg.num_layers + 1,)
    assert (g > 0).all() and (a > 0).all()


def test_state_ships_with_activation_for_ssm_and_hybrid():
    """Paper Fig. 1 / DESIGN §4: recurrent state adds to the split cost."""
    dense = layer_profile(get_config("qwen3-4b"), 64, 2)[1][1]
    ssm = get_config("falcon-mamba-7b")
    hyb = get_config("recurrentgemma-9b")
    assert layer_profile(ssm, 64, 2)[1][1] > 2 * 64 * ssm.d_model * 2.0
    assert layer_profile(hyb, 64, 2)[1][1] > 2 * 64 * hyb.d_model * 2.0
    assert dense == pytest.approx(2 * 64 * 2560 * 2.0)


def test_refinement_never_worse_than_seed():
    cfg = get_config("qwen3-1.7b")
    rng = np.random.default_rng(0)
    for _seed in range(5):
        F = np.maximum(rng.normal(400, 150, 4), 50.0)
        bw = rng.uniform(0.2e9, 2e9, (4, 4))
        s, sc, r, rc = plan_and_refine(cfg, F, bw, objective="throughput")
        assert rc.throughput_rps >= sc.throughput_rps - 1e-12
        assert r.boundaries[0] == 0 and r.boundaries[-1] == cfg.num_layers
        # refined boundaries remain legal split points
        legal = set(split_points(cfg)) | {0, cfg.num_layers}
        assert set(r.boundaries) <= legal


def test_latency_objective_prefers_fewer_transfers_on_slow_links():
    """With near-zero link bandwidth, min-latency collapses toward a single
    stage on the fastest executor (transfers dominate)."""
    cfg = get_config("qwen3-1.7b")
    F = [400.0, 420.0, 380.0]
    bw = np.full((3, 3), 1e4)           # pathological 10 kb/s links
    s, sc, r, rc = plan_and_refine(cfg, F, bw, objective="latency")
    assert rc.latency_s <= sc.latency_s + 1e-12
    g, a = layer_profile(cfg, 128, 4)
    single = StagePlan((0, cfg.num_layers), (1,), r.phi)
    c1 = plan_cost(single, g, a, F, bw)
    # refined multi-stage plan cannot beat the no-transfer plan here
    assert rc.latency_s >= c1.latency_s - 1e-9


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 6))
def test_plan_cost_invariants(seed, n):
    cfg = get_config("qwen3-4b")
    rng = np.random.default_rng(seed)
    F = np.maximum(rng.normal(400, 100, n), 50.0)
    bw = rng.uniform(1e8, 1e10, (n, n))
    plan = plan_stages(cfg, F)
    g, a = layer_profile(cfg, 64, 2)
    c = plan_cost(plan, g, a, F, bw)
    assert c.latency_s > 0 and c.throughput_rps > 0
    assert c.latency_s >= max(c.stage_times_s) - 1e-12
    assert abs(c.latency_s - sum(c.stage_times_s)) < 1e-9
    assert c.throughput_rps == pytest.approx(1.0 / max(c.stage_times_s))
