"""Optimizer, data pipeline, checkpoint, compression, fault driver."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.data import DataConfig, batch_at
from repro.optim import OptConfig, apply_updates, init_opt, schedule
from repro.runtime import (DriverConfig, compress_grads, dequantize,
                           init_compression, quantize, run_with_restarts)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    cfg = OptConfig(lr=0.2, weight_decay=0.0, warmup_steps=0,
                    total_steps=200, grad_clip=10.0)
    state = init_opt(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, m = apply_updates(params, grads, state, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1
    assert m["grad_norm"] > 0


def test_schedule_warmup_and_cosine():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=110,
                    min_lr_frac=0.1)
    assert float(schedule(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(schedule(cfg, jnp.asarray(10))) - 1.0) < 1e-6
    assert abs(float(schedule(cfg, jnp.asarray(110))) - 0.1) < 1e-5
    mid = float(schedule(cfg, jnp.asarray(60)))
    assert 0.1 < mid < 1.0


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_data_restart_deterministic_and_host_sharded():
    cfg = DataConfig(vocab_size=101, seq_len=16, global_batch=8)
    b1 = batch_at(cfg, 7)
    b2 = batch_at(cfg, 7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(batch_at(cfg, 8)["tokens"]),
                              np.asarray(b1["tokens"]))
    # labels are next-token shifted
    h0 = DataConfig(vocab_size=101, seq_len=16, global_batch=8,
                    num_hosts=2, host_id=0)
    h1 = DataConfig(vocab_size=101, seq_len=16, global_batch=8,
                    num_hosts=2, host_id=1)
    a, b = batch_at(h0, 3), batch_at(h1, 3)
    assert a["tokens"].shape == (4, 16)
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(b["tokens"]))
    full = batch_at(cfg, 3)
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(a["tokens"]), np.asarray(b["tokens"])]),
        np.asarray(full["tokens"]))


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_retention_atomicity(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray([1, 2, 3], jnp.int32)}}
    for s in (10, 20, 30, 40):
        save(d, s, tree, keep=2)
    assert latest_step(d) == 40
    from repro.checkpoint import all_steps
    assert all_steps(d) == [30, 40]       # retention
    like = jax.tree.map(jnp.zeros_like, tree)
    got, man = restore(d, like)
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(got["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))
    assert man["step"] == 40
    # structure mismatch is detected
    with pytest.raises(ValueError):
        restore(d, {"a": like["a"], "x": like["b"]})


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


def test_quantize_dequantize_bounded_error():
    g = jnp.asarray(np.random.default_rng(0).normal(0, 3, (128,)),
                    jnp.float32)
    q, s = quantize(g)
    err = np.abs(np.asarray(dequantize(q, s) - g))
    assert err.max() <= float(s) / 2 + 1e-6


def test_error_feedback_mean_converges():
    """With error feedback, the time-average of compressed grads converges
    to the true gradient (bias → 0) even at coarse quantization."""
    g_true = {"w": jnp.asarray([0.003, -0.7, 1.9], jnp.float32)}
    st = init_compression(g_true)
    acc = jnp.zeros(3)
    n = 200
    for _ in range(n):
        deq, st = compress_grads(g_true, st)
        acc = acc + deq["w"]
    np.testing.assert_allclose(np.asarray(acc / n), np.asarray(g_true["w"]),
                               atol=1e-3)


# ---------------------------------------------------------------------------
# fault-tolerant driver
# ---------------------------------------------------------------------------


def test_driver_restart_resumes_identically(tmp_path):
    """Train with an injected failure + restart; final state must equal an
    uninterrupted run (checkpoint/restart correctness)."""
    def make(dirname, fail_at):
        d = str(tmp_path / dirname)

        def init_state():
            return {"w": jnp.zeros((4,), jnp.float32), "n": jnp.int32(0)}

        @jax.jit
        def step(state, batch):
            w = state["w"] + batch["x"]
            return {"w": w, "n": state["n"] + 1}, {"loss": jnp.sum(w)}

        def batch_fn(s):
            rng = np.random.default_rng(s)
            return {"x": jnp.asarray(rng.normal(size=4), jnp.float32)}

        cfg = DriverConfig(ckpt_dir=d, ckpt_every=5, max_steps=20,
                           fail_at_step=fail_at)
        return run_with_restarts(cfg, init_state=init_state,
                                 train_step=step, batch_fn=batch_fn)

    clean = make("clean", None)
    faulty = make("faulty", 13)    # dies at step 13, resumes from 10
    assert int(clean["n"]) == int(faulty["n"]) == 20
    np.testing.assert_allclose(np.asarray(clean["w"]),
                               np.asarray(faulty["w"]), rtol=1e-6)


def test_straggler_counter():
    from repro.runtime import StepStats
    st = StepStats()
    for dt in [1.0, 1.0, 1.0, 10.0, 1.0]:
        st.update(dt, factor=3.0)
    assert st.stragglers == 1
    assert st.steps == 5
