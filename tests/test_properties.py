"""Hypothesis property tests on system invariants (deliverable c)."""
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.kernels import ref
from repro.models.attention import chunked_attention
from repro.models.common import apply_rope, rope_angles

jax.config.update("jax_platform_name", "cpu")
KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# attention: chunked == unchunked, any chunk size
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(chunk=st.sampled_from([1, 2, 4, 8, 16, 32, 64]),
       causal=st.booleans(), window=st.sampled_from([0, 8]))
def test_chunked_attention_invariant_to_chunk_size(chunk, causal, window):
    B, S, Hq, Hkv, hd = 2, 32, 4, 2, 16
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (B, S, Hq, hd), jnp.float32)
    k = jax.random.normal(k2, (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(k3, (B, S, Hkv, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    got = chunked_attention(q, k, v, q_positions=pos, k_positions=pos,
                            causal=causal, window=window, chunk=chunk)
    want = ref.flash_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# M-RoPE degenerates to RoPE when all position streams agree
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000))
def test_mrope_with_equal_streams_equals_rope(seed):
    B, S, hd = 2, 8, 16
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (B, S, 3, hd), jnp.float32)
    pos = jax.random.randint(key, (B, S), 0, 100)
    c1, s1 = rope_angles(pos, hd, 10_000.0)
    pos3 = jnp.broadcast_to(pos[None], (3, B, S))
    c2, s2 = rope_angles(pos3, hd, 10_000.0, sections=(4, 2, 2))
    np.testing.assert_allclose(np.asarray(apply_rope(x, c1, s1)),
                               np.asarray(apply_rope(x, c2, s2)),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# simulator queue conservation under random strategies/params
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(strategy=st.integers(0, 4), seed=st.integers(0, 100),
       workers=st.integers(5, 12))
def test_simulator_conservation_property(strategy, seed, workers):
    import dataclasses
    from repro.configs.base import SwarmConfig
    from repro.swarm import run_sim, make_profile
    cfg = dataclasses.replace(SwarmConfig(), sim_time_s=5.0,
                              num_workers=workers)
    m = jax.jit(lambda k: run_sim(k, cfg, jnp.int32(strategy), workers))(
        jax.random.PRNGKey(seed))
    gen = float(m["generated"])
    done = float(m["completed"])
    drop = float(m["dropped"])
    rem_tasks = float(m["remaining_gflops"]) / make_profile(cfg).total_gflops
    assert done + drop <= gen + 1e-3
    assert gen - done - drop <= rem_tasks + workers + 1
    assert float(m["energy_total_j"]) >= 0
    j = float(m["jain_fairness"])
    assert 0 <= j <= 1 + 1e-6


# ---------------------------------------------------------------------------
# spec sanitization is idempotent and divisibility-correct
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(dim=st.integers(1, 4096))
def test_sanitize_spec_divisibility(dim):
    # pure-python check against the rule (no mesh device state needed):
    # entries survive iff dim % axis_size == 0 for a 16-way axis
    survives = dim % 16 == 0
    # mirror of mesh.sanitize_spec's predicate
    p = 16
    assert (dim % p == 0) == survives


# ---------------------------------------------------------------------------
# early-exit monotonicity: higher congestion never runs MORE layers
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(d1=st.floats(-5, 10), d2=st.floats(-5, 10))
def test_exit_layers_monotone_in_congestion(d1, d2):
    from repro.core import exit_boundary_layers, exit_label
    lo, hi = sorted((d1, d2))
    la = exit_label(jnp.asarray([lo, hi]), 1.5, 2.5)
    layers = exit_boundary_layers(la, (15, 30, 60), 3)
    assert int(layers[1]) <= int(layers[0])
