"""R003 true positive: wall clock reachable from the jitted scan."""
import time

import jax.numpy as jnp


def _stamp(x):
    return x + time.time()      # host clock inside the traced region


def _epoch(st, key, cfg):
    return _stamp(st)


def run_sim(key, cfg, strategy, n):
    return _epoch(jnp.float32(0.0), key, cfg)
