"""R002 true positive config: one field never reaches the digest."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class SwarmConfig:
    num_workers: int = 8
    tick_s: float = 0.05
    trace_capacity: int = 0     # missing from point_digest — the PR 4 bug
