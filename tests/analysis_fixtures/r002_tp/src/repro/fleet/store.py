"""Explicit field enumeration that forgot ``trace_capacity``."""
import hashlib
import json


def point_digest(point, code_version):
    payload = {
        "num_workers": point.cfg.num_workers,
        "tick_s": point.cfg.tick_s,
        "strategy": point.strategy,
        "n": point.n,
        "num_runs": point.num_runs,
        "seed": point.seed,
        "code": code_version,
    }
    blob = json.dumps(payload, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()
