"""R001 true negatives: the idioms the rule must NOT flag."""
import jax
import jax.random as jr


def split_per_sink(key):
    k1, k2 = jax.random.split(key)
    noise = jax.random.normal(k1, (4,))
    coin = jax.random.bernoulli(k2, 0.5)
    return noise, coin


def branch_exclusive(key, flag):
    # one consumption per execution: if/else arms are alternatives
    if flag:
        out = jax.random.normal(key, (2,))
    else:
        out = jax.random.uniform(key, (2,))
    return out


def rebind_chain(key):
    # rebinding starts a fresh def: each def is consumed exactly once
    key = jr.fold_in(key, 1)
    return jr.normal(key)


def closure_single_use(key):
    def body(x):
        return x + jax.random.normal(key)
    return body(0.0)
