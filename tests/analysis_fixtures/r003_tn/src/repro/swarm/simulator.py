"""R003 true negative: pure scan; impure helper exists but is unreachable."""
import time

import jax.numpy as jnp


def _step(x):
    return x * jnp.float32(2.0)


def _epoch(st, key, cfg):
    return _step(st)


def run_sim(key, cfg, strategy, n):
    return _epoch(jnp.float32(1.0), key, cfg)


def host_report(metrics):
    # impure on purpose — but only ever called from the host side, never
    # from the scan's call graph, so the rule must stay silent
    print(metrics, time.time())
