"""Wholesale cfg digest: every SwarmConfig field covered by construction."""
import dataclasses
import hashlib
import json


def point_digest(point, code_version):
    payload = {
        "cfg": dataclasses.asdict(point.cfg),
        "strategy": point.strategy,
        "num_runs": point.num_runs,
        "seed": point.seed,
        "code": code_version,
    }
    blob = json.dumps(payload, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()
