"""R002 true negative config: wholesale digest + exempted spec field."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class SwarmConfig:
    num_workers: int = 8
    tick_s: float = 0.05
    trace_capacity: int = 0


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    name: str = ""          # display label — exempted in the baseline
    base: object = None
    num_runs: int = 1
    seed: int = 0
