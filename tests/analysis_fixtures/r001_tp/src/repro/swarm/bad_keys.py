"""R001 true positive: one key feeds two independent sinks."""
import jax


def sample_pair(key):
    noise = jax.random.normal(key, (4,))
    coin = jax.random.bernoulli(key, 0.5)   # same key, second sink
    return noise, coin


def split_then_reuse(key):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1)
    b = jax.random.uniform(k1)              # k1 consumed twice
    return a + b + jax.random.normal(k2)
