"""Evidence that the registry keys are exercised by tests."""


def test_ghost_walk_registered():
    assert "ghost_walk_model"


def test_strategy_names():
    assert ("LocalOnly", "Distributed")
