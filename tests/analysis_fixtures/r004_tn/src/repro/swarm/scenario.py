"""Registry whose key is tested and documented (DESIGN.md §4)."""

MOBILITY_MODELS = {}

STRATEGY_NAMES = ("LocalOnly", "Distributed")


def register_mobility(name, fn):
    MOBILITY_MODELS[name] = fn


def ghost_walk(key, cfg, n):
    return None


register_mobility("ghost_walk_model", ghost_walk)
