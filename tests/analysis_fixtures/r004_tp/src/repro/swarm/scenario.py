"""Registry with an undocumented key (cf. DESIGN.md §42)."""

MOBILITY_MODELS = {}


def register_mobility(name, fn):
    MOBILITY_MODELS[name] = fn


def ghost_walk(key, cfg, n):
    return None


register_mobility("ghost_walk_model", ghost_walk)
