"""Unit + property tests for the paper's core protocol (Eqs. 9-16)."""
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import (CongestionState, congestion_update, decision_epoch,
                        exit_accuracy, exit_boundary_layers, exit_label,
                        init_protocol, phi_bounds_ok, phi_fixpoint,
                        phi_update, transfer_decision)

jax.config.update("jax_platform_name", "cpu")


def ring_topology(n, d=1e-3):
    adj = np.zeros((n, n), bool)
    for i in range(n):
        adj[i, (i + 1) % n] = adj[i, (i - 1) % n] = True
    return jnp.asarray(adj), jnp.full((n, n), d, jnp.float32)


# ---------------------------------------------------------------------------
# Eq. 10 — diffusive metric
# ---------------------------------------------------------------------------


def test_phi_isolated_node_equals_local_capability():
    F = jnp.asarray([100.0, 200.0, 300.0])
    adj = jnp.zeros((3, 3), bool)
    d_tx = jnp.zeros((3, 3))
    phi = phi_update(F, F, adj, d_tx)
    np.testing.assert_allclose(np.asarray(phi), np.asarray(F))


def test_phi_converges_geometrically_on_connected_graph():
    """Paper's claim: residuals contract >= 2x per round for |M_i| >= 1."""
    n = 24
    adj, d_tx = ring_topology(n)
    F = jnp.asarray(np.random.default_rng(0).uniform(100, 800, n),
                    jnp.float32)
    phi, residuals = phi_fixpoint(F, adj, d_tx, iters=20)
    res = np.asarray(residuals)
    # after a couple of rounds residual strictly decays; final ~ 0
    assert res[-1] < 1e-6
    late = res[3:12]
    ratios = late[1:] / np.maximum(late[:-1], 1e-30)
    assert np.all(ratios < 0.75), ratios


def test_phi_bounds_invariant():
    n = 16
    rng = np.random.default_rng(1)
    adj = rng.uniform(size=(n, n)) < 0.4
    adj = np.logical_and(adj, ~np.eye(n, dtype=bool))
    F = jnp.asarray(rng.uniform(100, 500, n), jnp.float32)
    d_tx = jnp.where(jnp.asarray(adj), 1e-3, -1e30)
    phi, _ = phi_fixpoint(F, jnp.asarray(adj), d_tx, iters=16)
    assert bool(phi_bounds_ok(phi, F, jnp.asarray(adj)))


def test_phi_prefers_fast_neighborhoods():
    """A node with strong neighbors must end with higher φ than an identical
    node with weak neighbors (the metric's whole point)."""
    # star A: center 0 with strong leaves; star B: center 3 with weak leaves
    F = jnp.asarray([200.0, 800.0, 800.0, 200.0, 50.0, 50.0], jnp.float32)
    adj = np.zeros((6, 6), bool)
    adj[0, 1] = adj[1, 0] = adj[0, 2] = adj[2, 0] = True
    adj[3, 4] = adj[4, 3] = adj[3, 5] = adj[5, 3] = True
    d_tx = jnp.where(jnp.asarray(adj), 1e-4, -1e30)
    phi, _ = phi_fixpoint(F, jnp.asarray(adj), d_tx, iters=16)
    assert float(phi[0]) > float(phi[3])


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(3, 20),
    seed=st.integers(0, 2**31 - 1),
)
def test_phi_update_positive_and_finite(n, seed):
    rng = np.random.default_rng(seed)
    F = jnp.asarray(rng.uniform(50, 1000, n), jnp.float32)
    adj = rng.uniform(size=(n, n)) < 0.5
    adj = np.logical_and(adj, ~np.eye(n, dtype=bool))
    d_tx = jnp.where(jnp.asarray(adj),
                     jnp.asarray(rng.uniform(1e-5, 1e-2, (n, n)),
                                 jnp.float32), -1e30)
    phi = F
    for _ in range(5):
        phi = phi_update(phi, F, jnp.asarray(adj), d_tx)
        a = np.asarray(phi)
        assert np.all(np.isfinite(a)) and np.all(a > 0)


# ---------------------------------------------------------------------------
# Eqs. 11-13 — transfer decision
# ---------------------------------------------------------------------------


def test_transfer_picks_least_utilized_neighbor_and_respects_gamma():
    phi = jnp.asarray([100.0, 100.0, 100.0])
    T = jnp.asarray([50.0, 10.0, 30.0])      # U = [.5, .1, .3]
    adj = jnp.asarray(~np.eye(3, dtype=bool))
    dec = transfer_decision(T, phi, adj, gamma=0.1)
    assert int(dec.target[0]) == 1           # least utilized neighbor
    assert bool(dec.transfer[0])             # 0.5 - 0.1 > γ
    assert not bool(dec.transfer[1])         # already the least utilized
    # γ hysteresis: huge γ → nobody transfers
    dec2 = transfer_decision(T, phi, adj, gamma=10.0)
    assert not bool(jnp.any(dec2.transfer))


def test_no_neighbors_means_no_transfer():
    dec = transfer_decision(jnp.asarray([99.0]), jnp.asarray([1.0]),
                            jnp.zeros((1, 1), bool), gamma=0.0)
    assert not bool(dec.transfer[0]) and int(dec.target[0]) == -1


# ---------------------------------------------------------------------------
# Eqs. 14-16 — congestion-aware early exit
# ---------------------------------------------------------------------------


def test_congestion_ema_and_labels():
    st0 = CongestionState(jnp.zeros((1,)), jnp.zeros((1,)))
    # queue grows by 1 GFLOP per 0.2 s epoch => dT/dt = 5
    s = st0
    for k in range(1, 30):
        s = congestion_update(s, jnp.asarray([float(k)]), 0.2, 0.3)
    assert abs(float(s.D[0]) - 5.0) < 0.1    # EMA converges to the true slope
    lbl = exit_label(s.D, 1.5, 2.5)
    assert int(lbl[0]) == 2                  # high congestion
    lbl2 = exit_label(jnp.asarray([2.0]), 1.5, 2.5)
    assert int(lbl2[0]) == 1                 # medium
    lbl3 = exit_label(jnp.asarray([0.0]), 1.5, 2.5)
    assert int(lbl3[0]) == 0


def test_exit_boundaries_and_accuracy_levels():
    pts = (15, 30, 60)
    layers = exit_boundary_layers(jnp.asarray([0, 1, 2]), pts, 3)
    np.testing.assert_array_equal(np.asarray(layers), [60, 33, 18])
    acc = exit_accuracy(jnp.asarray([0, 1, 2]), (0.6, 0.9, 0.95))
    np.testing.assert_allclose(np.asarray(acc), [0.95, 0.9, 0.6])


def test_exit_boundary_layers_pins_table2_mapping():
    """Table 2 label→layers mapping, pinned against the default config:
    truncation depth decreases as congestion rises (full → L2+3 → L1+3)."""
    from repro.configs.base import SwarmConfig
    cfg = SwarmConfig()
    L1, L2, L_full = cfg.exit_points
    fin = cfg.exit_finalize_layers
    layers = exit_boundary_layers(jnp.asarray([0, 1, 2]), cfg.exit_points,
                                  fin)
    np.testing.assert_array_equal(
        np.asarray(layers), [L_full, L2 + fin, L1 + fin])   # 60 / 33 / 18
    # finalize layers can never push a truncated exit past the full network
    capped = exit_boundary_layers(jnp.asarray([1, 2]), (59, 59, 60), 3)
    np.testing.assert_array_equal(np.asarray(capped), [60, 60])


# ---------------------------------------------------------------------------
# Alg. 1 — composed epoch
# ---------------------------------------------------------------------------


def test_decision_epoch_runs_and_is_consistent():
    n = 8
    rng = np.random.default_rng(2)
    F = jnp.asarray(rng.uniform(100, 500, n), jnp.float32)
    adj, d_tx = ring_topology(n)
    state = init_protocol(F)
    out = decision_epoch(
        state, F=F, adj=adj, d_tx=d_tx,
        queued_gflops=jnp.asarray(rng.uniform(0, 100, n), jnp.float32),
        gamma=0.02, dt=0.2, alpha=0.3, tau_med=1.5, tau_high=2.5,
        exit_points=(15, 30, 60), finalize_layers=3)
    assert out.exit_layers.shape == (n,)
    assert bool(jnp.all(out.state.phi > 0))
    # transfers only point at actual neighbors
    tgt = np.asarray(out.decision.target)
    tr = np.asarray(out.decision.transfer)
    adj_np = np.asarray(adj)
    for i in range(n):
        if tr[i]:
            assert adj_np[i, tgt[i]]
