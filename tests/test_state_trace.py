"""Flight-recorder tests (DESIGN.md §12): off-invariance, buffer shapes
and stride/subsample exactness, accounting against the scalar
accumulators, bit-identical state buffers across all three executor
backends, kill/resume preservation through the store (SweepInterrupted
and a real SIGKILL'd spawned worker), report/export surfaces, the
shared-schema serve gauges, and the profile spans the perf gate reads.
"""
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SwarmConfig
from repro.fleet import (ResultStore, SweepInterrupted, SweepSpec,
                         build_report, collect, dispatch, execute,
                         point_digest, read_progress, run_batch, run_point,
                         spawn_workers, write_bench_json)
from repro.swarm import DISTRIBUTED
from repro.trace import (decode_state, schema, state_counter_events,
                         state_indices, write_chrome_trace)

KEY = jax.random.PRNGKey(0)
N, RUNS = 8, 6
CFG = dataclasses.replace(SwarmConfig(), sim_time_s=2.0, num_workers=N)
CFG_ST = dataclasses.replace(CFG, trace_state_every=1)
N_EPOCHS = int(round(CFG.sim_time_s / CFG.decision_period_s))
SPEC_KILL = SweepSpec.build(
    "statekill", dataclasses.replace(CFG, sim_time_s=1.0, num_workers=6,
                                     trace_state_every=2),
    axes={"gamma": (0.02, 0.1)}, strategies=(0, 4), num_runs=3)


@pytest.fixture(scope="module", autouse=True)
def _pinned_code_version():
    """Digests must agree with spawned workers and not drift mid-run."""
    from repro.fleet.store import code_version
    old = os.environ.get("REPRO_CODE_VERSION")
    os.environ["REPRO_CODE_VERSION"] = "test-state"
    code_version.cache_clear()
    yield
    if old is None:
        del os.environ["REPRO_CODE_VERSION"]
    else:
        os.environ["REPRO_CODE_VERSION"] = old
    code_version.cache_clear()


def _np(tree):
    return {k: np.asarray(v) for k, v in tree.items()}


@pytest.fixture(scope="module")
def recorded():
    return _np(run_batch(KEY, CFG_ST, jnp.int32(DISTRIBUTED), N, RUNS))


@pytest.fixture(scope="module")
def plain():
    return _np(run_batch(KEY, CFG, jnp.int32(DISTRIBUTED), N, RUNS))


@pytest.fixture(scope="module")
def sdec(recorded):
    return decode_state(recorded["trace_state"],
                        recorded["trace_state_sys"],
                        recorded["trace_state_epochs"])


# ---------------------------------------------------------------------------
# recorder off == historical simulator; recorder on perturbs nothing
# ---------------------------------------------------------------------------


def test_stride_zero_emits_no_state_buffers(plain):
    assert not any(k.startswith("trace_state") for k in plain)
    assert "state_e_tx" not in plain


def test_recording_does_not_perturb_metrics(recorded, plain):
    """The flight recorder must be observation, not intervention: every
    scalar metric of a recorded run is bit-identical to the plain run."""
    for k in plain:
        np.testing.assert_array_equal(recorded[k], plain[k], err_msg=k)
    assert "state_e_tx" not in recorded     # working accumulator, not output


# ---------------------------------------------------------------------------
# buffer shapes, epoch map, gauge accounting vs the scalar accumulators
# ---------------------------------------------------------------------------


def test_state_buffer_shapes_and_epoch_map(recorded):
    assert recorded["trace_state"].shape == \
        (RUNS, N_EPOCHS, N, schema.NUM_STATE_GAUGES)
    assert recorded["trace_state_sys"].shape == \
        (RUNS, N_EPOCHS, schema.NUM_SYS_GAUGES)
    assert recorded["trace_state_epochs"].shape == (RUNS, N_EPOCHS)
    np.testing.assert_array_equal(recorded["trace_state_epochs"][0],
                                  np.arange(N_EPOCHS, dtype=np.float32))


def test_state_gauges_are_physical(sdec):
    assert np.all(sdec["queue_depth"] >= 0)
    assert np.all(sdec["queue_depth"] <= CFG.queue_slots)
    assert np.all((sdec["alive"] == 0) | (sdec["alive"] == 1))
    assert np.all(sdec["e_comp_j"] >= 0) and np.all(sdec["e_tx_j"] >= 0)
    # cumulative gauges never decrease along the epoch axis
    for k in ("e_comp_j", "e_tx_j"):
        assert np.all(np.diff(sdec[k], axis=1) >= -1e-6), k
    for k in ("completed", "dropped", "generated", "energy_j"):
        assert np.all(np.diff(sdec[k], axis=1) >= -1e-6), k
    jain = sdec["queue_jain"]
    assert np.all((jain >= 0) & (jain <= 1.0001))
    assert np.all(jain[sdec["queue_depth_mean"] > 0] > 0)
    np.testing.assert_allclose(
        sdec["t"][0], (np.arange(N_EPOCHS) + 1) * CFG.decision_period_s,
        rtol=1e-5)


def test_final_sample_pins_the_scalar_accumulators(recorded, sdec):
    """The last system sample *is* the end-of-mission accounting: counters
    bit-equal, energy f32-equal, and the per-node cumulative energy
    gauges sum back to the scalar totals."""
    np.testing.assert_array_equal(sdec["completed"][:, -1],
                                  recorded["completed"])
    np.testing.assert_array_equal(sdec["dropped"][:, -1],
                                  recorded["dropped"])
    np.testing.assert_array_equal(
        sdec["energy_j"][:, -1].astype(np.float32),
        recorded["energy_total_j"])
    per_node = sdec["e_comp_j"][:, -1, :] + sdec["e_tx_j"][:, -1, :]
    np.testing.assert_allclose(per_node.sum(axis=1),
                               recorded["energy_total_j"], rtol=1e-4)


def test_stride_and_subsample_are_exact_slices(recorded):
    """every=3 / nodes=4 records exactly the full stream's sampled epochs
    and node prefix — subsampling selects, never re-aggregates."""
    cfg = dataclasses.replace(CFG, trace_state_every=3,
                              trace_state_nodes=4)
    m = _np(run_batch(KEY, cfg, jnp.int32(DISTRIBUTED), N, RUNS))
    S = -(-N_EPOCHS // 3)
    assert m["trace_state"].shape == (RUNS, S, 4, schema.NUM_STATE_GAUGES)
    np.testing.assert_array_equal(m["trace_state_epochs"][0],
                                  np.arange(0, N_EPOCHS, 3))
    np.testing.assert_array_equal(
        m["trace_state"], recorded["trace_state"][:, ::3, :4])
    np.testing.assert_array_equal(
        m["trace_state_sys"], recorded["trace_state_sys"][:, ::3])


# ---------------------------------------------------------------------------
# acceptance: buffers bit-identical across all three executor backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend,kw", [("sharded", {}),
                                        ("streaming", {"chunk_size": 4})])
def test_state_bit_identical_across_backends(recorded, backend, kw):
    got = _np(run_batch(KEY, CFG_ST, jnp.int32(DISTRIBUTED), N, RUNS,
                        backend=backend, **kw))
    for k in ("trace_state", "trace_state_sys", "trace_state_epochs"):
        np.testing.assert_array_equal(got[k], recorded[k], err_msg=k)


# ---------------------------------------------------------------------------
# store/resume: buffers survive interrupts and SIGKILL'd workers
# ---------------------------------------------------------------------------


def test_interrupted_streaming_sweep_preserves_state(tmp_path, recorded):
    spec = SweepSpec.build("stateresume", CFG_ST,
                           strategies=(DISTRIBUTED,), num_runs=RUNS)
    (pt,) = spec.expand()
    store = ResultStore(str(tmp_path))
    with pytest.raises(SweepInterrupted):
        run_point(pt, backend="streaming", store=store, chunk_size=2,
                  max_chunks=1)
    done, accum = store.load_partial(point_digest(pt))
    assert done == 1
    assert accum["trace_state"].shape == \
        (2, N_EPOCHS, N, schema.NUM_STATE_GAUGES)
    resumed = run_point(pt, backend="streaming", store=store, chunk_size=2)
    np.testing.assert_array_equal(resumed["trace_state"],
                                  recorded["trace_state"])
    # store round-trip (f32 JSON) reproduces the buffers bit-for-bit —
    # epoch-indexed buffers have no slack, so no compaction applies
    hit = run_point(pt, backend="vmap", store=store)
    for k in ("trace_state", "trace_state_sys", "trace_state_epochs"):
        np.testing.assert_array_equal(hit[k], recorded[k], err_msg=k)


def _bench_bytes(path, res):
    write_bench_json(path, "sweep:cmp", build_report(res))
    with open(path, "rb") as f:
        return f.read()


def test_sigkilled_state_dispatch_resumes_to_identical_report(tmp_path):
    """A state-traced sweep whose worker is SIGKILL'd mid-run redispatches
    to a BENCH report byte-identical to an uninterrupted single-process
    run — φ-convergence and heatmap indices included."""
    ref = _bench_bytes(str(tmp_path / "ref.json"), execute(SPEC_KILL))
    assert b"phi_residual_curve" in ref
    assert b"queue_depth_heatmap" in ref
    store = ResultStore(str(tmp_path / "cache"))
    prog = str(tmp_path / "progress.jsonl")
    (proc,) = spawn_workers(SPEC_KILL, store.root, 1, lease_ttl_s=2.0,
                            progress_path=prog)
    try:
        deadline = time.time() + 300
        while time.time() < deadline:
            if any(r.get("event") == "point"
                   for r in read_progress(prog)):
                break
            assert proc.is_alive(), "worker died before first point"
            time.sleep(0.05)
        else:
            pytest.fail("worker produced no point within 300s")
        proc.kill()
    finally:
        proc.join()
    with pytest.raises(RuntimeError, match="redispatch to resume"):
        collect(SPEC_KILL, store)
    res = dispatch(SPEC_KILL, store, workers=2, lease_ttl_s=2.0,
                   progress_path=prog)
    assert _bench_bytes(str(tmp_path / "resumed.json"), res) == ref
    # workers surfaced live gauges while computing
    assert any(r.get("event") == "gauges" and "queue_depth_mean" in r
               for r in read_progress(prog))


# ---------------------------------------------------------------------------
# report + export surfaces
# ---------------------------------------------------------------------------


def test_report_carries_state_indices(recorded, plain, sdec):
    doc = build_report({"pt": recorded})["points"]["pt"]
    assert "trace_state" not in doc         # buffers aggregated, not dumped
    assert doc["state_sample_count"] == N_EPOCHS
    assert doc["state_nodes"] == N
    curve = doc["phi_residual_curve"]
    assert len(curve) == N_EPOCHS and curve[-1] == 0.0
    assert doc["queue_jain_final"] == pytest.approx(
        float(sdec["queue_jain"][:, -1].mean()), rel=1e-4)
    heat = np.asarray(doc["queue_depth_heatmap"])
    assert heat.shape == (N_EPOCHS, N)      # < 128 epochs: no downsampling
    assert doc["completion_rate_final"] > 0
    # unrecorded points keep the historical shape: no state section at all
    doc0 = build_report({"pt": plain})["points"]["pt"]
    assert not any(k.startswith("state_") or k.startswith("phi_")
                   for k in doc0)


def test_state_counter_track_export(tmp_path, sdec):
    path = write_chrome_trace(str(tmp_path / "t.json"),
                              {k: np.zeros((0,)) for k in
                               ("seq", "src", "dst", "created_t",
                                "completed_t", "latency_s", "exit_label",
                                "layers", "hops", "is_dropped")},
                              state=sdec)
    with open(path) as f:
        doc = json.load(f)                  # validates as JSON
    counters = [e for e in doc["traceEvents"] if e.get("ph") == "C"]
    assert counters, "no counter events emitted"
    assert all(e["pid"] == 1 and "args" in e and e["ts"] >= 0
               for e in counters)
    names = {e["name"] for e in counters}
    assert "swarm queue depth" in names and "swarm phi" in names
    assert any(n.startswith("uav ") and n.endswith(" phi") for n in names)
    # counter samples: one per gauge lane per valid epoch
    lane = [e for e in counters if e["name"] == "swarm queue depth"]
    assert len(lane) == N_EPOCHS
    assert set(lane[0]["args"]) == {"mean", "max"}
    assert doc["otherData"]["state_schema"] == list(schema.STATE_GAUGES)
    assert doc["otherData"]["state_sys_schema"] == list(schema.SYS_GAUGES)


def test_counter_events_standalone_without_sys():
    """Node-only decode (no sys buffer) still renders per-UAV lanes."""
    state = np.zeros((3, 2, schema.NUM_STATE_GAUGES))
    state[:, :, schema.ST_PHI] = 1.0
    ev = state_counter_events(decode_state(state))
    assert any(e["name"] == "uav 0 phi" for e in ev)
    assert not any(e["name"].startswith("swarm ") for e in ev
                   if e.get("ph") == "C")


def test_serve_stats_share_the_state_gauge_schema():
    """ServeStats.record_state rows decode through the same repro.trace
    pipeline as the simulator's flight recorder."""
    from repro.splitcompute.serve_engine import ServeStats
    st = ServeStats()
    st._generated = 4
    st.record_state(t=0.05, queue_depths=[3, 1, 0], load=[0.5, 0.2, 0.1])
    st._completed = 2
    st.record_state(t=0.10, queue_depths=[1, 1, 0], load=[0.4, 0.3, 0.1])
    assert st.state_records.shape == (2, schema.NUM_SYS_GAUGES)
    assert st.stage_state.shape == (2, 3, schema.NUM_STATE_GAUGES)
    dec = decode_state(st.stage_state, st.state_records)
    assert dec["completed"][0, -1] == 2
    assert dec["queue_depth_max"][0, 0] == 3
    np.testing.assert_allclose(dec["phi"][0, 0], [0.5, 0.2, 0.1])
    idx = state_indices(dec)
    assert idx["state_sample_count"] == 2 and idx["state_nodes"] == 3
    assert idx["queue_jain_final"] is not None
    # gauges render as counter tracks like the sim side's
    assert any(e.get("ph") == "C" for e in state_counter_events(dec))


def test_serve_engine_steps_record_state():
    """SplitServeEngine.step() samples the recorder each epoch, with the
    congestion metric D in the φ lane."""
    from repro.configs import get_config, reduced
    from repro.models import build_model
    from repro.splitcompute import SplitServeEngine, plan_stages
    cfg = reduced(get_config("qwen3-1.7b"))
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    plan = plan_stages(cfg, [400.0, 420.0])
    eng = SplitServeEngine(cfg, params, plan, tau_med=1e9, tau_high=2e9)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    eng.submit({"tokens": toks})
    eng.step()
    eng.step()
    assert eng.stats.state_records.shape[0] == 2
    dec = decode_state(eng.stats.stage_state, eng.stats.state_records)
    assert dec["generated"][0, -1] == 1
    assert dec["queue_depth"].shape == (1, 2, eng.n_stages)
    assert np.all(dec["t"][0] == [0.05, 0.10])


# ---------------------------------------------------------------------------
# profile spans (the perf gate's input)
# ---------------------------------------------------------------------------


def test_run_point_fills_spans_only_when_computing(tmp_path):
    spec = SweepSpec.build("spans", CFG_ST, strategies=(DISTRIBUTED,),
                           num_runs=2)
    (pt,) = spec.expand()
    store = ResultStore(str(tmp_path))
    spans = {}
    first = run_point(pt, store=store, spans=spans)
    assert spans["_compile_s"] >= 0 and spans["_execute_s"] > 0
    assert not any(k.startswith("_") for k in first)
    hit_spans = {}
    hit = run_point(pt, store=store, spans=hit_spans)
    assert hit_spans == {}                  # a cache hit cost nothing
    assert sorted(hit) == sorted(first)     # identical metric surface


def test_execute_emits_profile_rows_and_perf_gate_reads_them(tmp_path):
    from benchmarks.perf_gate import compare
    spec = SweepSpec.build("profile", CFG, strategies=(DISTRIBUTED,),
                           num_runs=2)
    res = execute(spec)
    (label,) = res
    assert res[label]["_wall_s"] > 0
    assert res[label]["_execute_s"] is not None
    base = {"profile": {label: {
        "cached": False, "execute_s": float(res[label]["_execute_s"]),
        "compile_s": float(res[label]["_compile_s"])}}}
    checked, skipped, failures = compare(base, base, 2.0, 0.0)
    assert not failures and len(checked) == 1
    _, _, failures = compare(
        base,
        {"profile": {label: {
            "cached": False,
            "execute_s": 10 * float(res[label]["_execute_s"])}}},
        2.0, 0.0)
    assert failures                          # 10x regression trips the gate
