from hypothesis.extra import numpy  # noqa: F401
