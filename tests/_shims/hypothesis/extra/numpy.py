"""``hypothesis.extra.numpy`` stand-in: array strategies over the shim."""
from __future__ import annotations

import numpy as np
from hypothesis.strategies import SearchStrategy


def arrays(dtype, shape, *, elements: SearchStrategy = None,
           fill=None, unique: bool = False) -> SearchStrategy:
    if unique or fill is not None:
        raise NotImplementedError("shim arrays(): unique/fill unsupported")
    dtype = np.dtype(dtype)
    dims = (shape,) if isinstance(shape, int) else tuple(shape)

    def draw(rng: np.random.Generator):
        if elements is not None:
            flat = [elements.draw(rng) for _ in range(int(np.prod(dims)))]
            return np.asarray(flat, dtype=dtype).reshape(dims)
        if dtype.kind == "f":
            return rng.standard_normal(dims).astype(dtype)
        if dtype.kind in "iu":
            return rng.integers(0, 100, dims).astype(dtype)
        if dtype.kind == "b":
            return rng.integers(0, 2, dims).astype(bool)
        raise NotImplementedError(f"shim arrays(): dtype {dtype}")

    return SearchStrategy(draw)
