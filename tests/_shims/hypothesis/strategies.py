"""Strategy objects for the fixed-seed hypothesis shim.

Each strategy wraps a draw function ``rng -> value`` plus the combinators
the repo's tests use (``map``/``filter``).  Bounds are inclusive, matching
real hypothesis semantics.
"""
from __future__ import annotations

import math

import numpy as np


class SearchStrategy:
    def __init__(self, draw_fn):
        self._draw_fn = draw_fn

    def draw(self, rng: np.random.Generator):
        return self._draw_fn(rng)

    def map(self, f):
        return SearchStrategy(lambda rng: f(self.draw(rng)))

    def filter(self, pred):
        def draw(rng):
            for _ in range(100):
                v = self.draw(rng)
                if pred(v):
                    return v
            raise ValueError("shim strategy filter rejected 100 draws")
        return SearchStrategy(draw)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value: float, max_value: float, *, allow_nan: bool = False,
           allow_infinity: bool = False, width: int = 64) -> SearchStrategy:
    lo, hi = float(min_value), float(max_value)
    if not (math.isfinite(lo) and math.isfinite(hi)):
        raise NotImplementedError("shim floats() needs finite bounds")
    return SearchStrategy(lambda rng: float(rng.uniform(lo, hi)))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(elements) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(
        lambda rng: elements[int(rng.integers(0, len(elements)))])


def lists(element: SearchStrategy, *, min_size: int = 0,
          max_size: int = 10) -> SearchStrategy:
    def draw(rng):
        k = int(rng.integers(min_size, max_size + 1))
        return [element.draw(rng) for _ in range(k)]
    return SearchStrategy(draw)


def tuples(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(lambda rng: tuple(s.draw(rng)
                                            for s in strategies))


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng: value)


def one_of(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: strategies[int(rng.integers(0, len(strategies)))]
        .draw(rng))
