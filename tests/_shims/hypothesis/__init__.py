"""Minimal fixed-seed stand-in for the ``hypothesis`` package.

Activated by the repo-level ``conftest.py`` only when the real package is
not installed.  It implements exactly the surface this repo's tests use —
``@given`` with keyword strategies, ``@settings(max_examples=, deadline=)``,
``assume``, and the ``strategies`` / ``extra.numpy`` modules — and replaces
adaptive property search with a deterministic per-test example sweep: each
strategy draws from a ``numpy`` Generator seeded by the test's qualname, so
runs are reproducible and failures are replayable.  No shrinking, no
database, no health checks.
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

__version__ = "0.0-shim"
_DEFAULT_EXAMPLES = 10


class _Unsatisfied(Exception):
    """Raised by assume(False); the example is skipped, not failed."""


def assume(condition) -> bool:
    if not condition:
        raise _Unsatisfied
    return True


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
    """Decorator recording the example budget on the test function."""
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(*args, **strategies):
    """Keyword-strategy ``@given``.  Draws ``max_examples`` example dicts
    from a per-test seeded RNG and runs the test once per example."""
    if args:
        raise NotImplementedError(
            "the hypothesis shim supports keyword strategies only; install "
            "the real hypothesis for positional @given")

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*wargs, **wkw):
            budget = getattr(wrapper, "_shim_max_examples",
                             _DEFAULT_EXAMPLES)
            seed = zlib.adler32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            ran = 0
            attempts = 0
            while ran < budget and attempts < budget * 10:
                attempts += 1
                example = {k: s.draw(rng) for k, s in strategies.items()}
                try:
                    fn(*wargs, **wkw, **example)
                except _Unsatisfied:
                    continue
                except Exception as e:
                    raise AssertionError(
                        f"shim-hypothesis falsifying example "
                        f"{fn.__qualname__}({example!r})") from e
                ran += 1
            return None

        # pytest must not see the strategy kwargs as fixtures: drop the
        # functools.wraps signature forwarding, keep only the test's own
        # (usually empty) parameter list.  NB: do not attach a
        # `.hypothesis` attribute — pytest's built-in integration would
        # mistake the wrapper for a real hypothesis test.
        del wrapper.__wrapped__
        sig = inspect.signature(fn)
        params = [p for name, p in sig.parameters.items()
                  if name not in strategies]
        wrapper.__signature__ = sig.replace(parameters=params)
        return wrapper

    return deco


class HealthCheck:
    """Placeholder so ``suppress_health_check=[...]`` settings parse."""
    too_slow = data_too_large = filter_too_much = all = None


from hypothesis import strategies  # noqa: E402,F401  (self-import for API parity)
from hypothesis import extra  # noqa: E402,F401
