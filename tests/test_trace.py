"""Per-task telemetry tests (DESIGN.md §10): trace-off invariance, record
accounting against the scalar accumulators, bit-identical records across
all three executor backends, kill/resume preservation through the store
(SweepInterrupted and a real SIGKILL'd spawned worker), overflow
semantics, report/export surfaces, and the shared-schema serve stats.
"""
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SwarmConfig
from repro.fleet import (ResultStore, SweepInterrupted, SweepSpec,
                         build_report, collect, dispatch, execute,
                         point_digest, read_progress, run_batch, run_point,
                         spawn_workers, write_bench_json)
from repro.swarm import DISTRIBUTED, run_many
from repro.trace import (chrome_trace_events, decode, schema, split_runs,
                         trace_indices, write_chrome_trace)

KEY = jax.random.PRNGKey(0)
N, RUNS = 8, 6
CFG = dataclasses.replace(SwarmConfig(), sim_time_s=2.0, num_workers=N)
CFG_TR = dataclasses.replace(CFG, trace_capacity=512)
SPEC_KILL = SweepSpec.build(
    "tracekill", dataclasses.replace(CFG, sim_time_s=1.0, num_workers=6,
                                     trace_capacity=256,
                                     trace_hop_capacity=256),
    axes={"gamma": (0.02, 0.1)}, strategies=(0, 4), num_runs=3)


@pytest.fixture(scope="module", autouse=True)
def _pinned_code_version():
    """Digests must agree with spawned workers and not drift mid-run."""
    from repro.fleet.store import code_version
    old = os.environ.get("REPRO_CODE_VERSION")
    os.environ["REPRO_CODE_VERSION"] = "test-trace"
    code_version.cache_clear()
    yield
    if old is None:
        del os.environ["REPRO_CODE_VERSION"]
    else:
        os.environ["REPRO_CODE_VERSION"] = old
    code_version.cache_clear()


def _np(tree):
    return {k: np.asarray(v) for k, v in tree.items()}


@pytest.fixture(scope="module")
def traced():
    return _np(run_batch(KEY, CFG_TR, jnp.int32(DISTRIBUTED), N, RUNS))


@pytest.fixture(scope="module")
def untraced():
    return _np(run_batch(KEY, CFG, jnp.int32(DISTRIBUTED), N, RUNS))


# ---------------------------------------------------------------------------
# trace off == historical simulator; trace on perturbs nothing
# ---------------------------------------------------------------------------


def test_capacity_zero_emits_no_trace_state(untraced):
    assert not any(k.startswith("trace_") for k in untraced)


def test_tracing_does_not_perturb_metrics(traced, untraced):
    """Capturing records must be observation, not intervention: every
    scalar metric of a traced run is bit-identical to the untraced run."""
    for k in untraced:
        np.testing.assert_array_equal(traced[k], untraced[k], err_msg=k)


# ---------------------------------------------------------------------------
# record accounting vs the scalar accumulators
# ---------------------------------------------------------------------------


def test_records_account_for_every_finished_task(traced):
    dec = decode(traced["trace_records"], traced["trace_overflow"])
    finished = traced["completed"].sum() + traced["dropped"].sum()
    assert dec["seq"].size + int(dec["overflow"]) == int(finished)
    done = ~dec["is_dropped"]
    assert int(done.sum()) == int(traced["completed"].sum())
    assert int(dec["is_dropped"].sum()) == int(traced["dropped"].sum())
    # per-record latencies reproduce the scalar accumulator sum
    lat_sum_metrics = float((traced["avg_latency_s"]
                             * traced["completed"]).sum())
    assert np.isclose(dec["latency_s"][done].sum(), lat_sum_metrics,
                      rtol=1e-4)
    # records are scatter-by-seq: in-run seqs are unique and slot-ordered
    for run in split_runs(traced["trace_records"]):
        assert np.all(np.diff(run["seq"]) > 0)


def test_record_fields_are_physical(traced):
    dec = decode(traced["trace_records"], traced["trace_overflow"])
    assert np.all(dec["completed_t"] >= dec["created_t"])
    assert np.all((dec["src"] >= 0) & (dec["src"] < N))
    assert np.all((dec["dst"] >= 0) & (dec["dst"] < N))
    assert np.all(dec["hops"] >= 0) and np.all(dec["hops"] < N)
    assert np.all(dec["energy_j"] >= 0) and np.all(dec["tx_time_s"] >= 0)
    done = ~dec["is_dropped"]
    assert np.all(dec["exit_label"][done] <= 2)
    assert np.all(dec["layers"][done] > 0)
    # a task that never moved has zero transfer time; a forwarded one, > 0
    assert np.all(dec["tx_time_s"][dec["hops"] == 0] == 0.0)
    moved = done & (dec["hops"] > 0)
    if moved.any():
        assert np.all(dec["tx_time_s"][moved] > 0.0)
        assert np.any(dec["src"][moved] != dec["dst"][moved])


def test_overflow_counter_saturates_capture_exactly():
    """Completions beyond trace_capacity are dropped from capture (never
    wrapped over earlier records) and counted exactly."""
    cap = 16
    cfg = dataclasses.replace(CFG_TR, trace_capacity=cap)
    m = _np(run_batch(KEY, cfg, jnp.int32(DISTRIBUTED), N, 3))
    dec = decode(m["trace_records"], m["trace_overflow"])
    finished = m["completed"].sum() + m["dropped"].sum()
    assert int(dec["overflow"]) > 0
    assert dec["seq"].size + int(dec["overflow"]) == int(finished)
    assert np.all(dec["seq"] < cap)          # kept records: first seqs only
    # the captured prefix agrees with the uncapped run, record for record
    full = _np(run_batch(KEY, CFG_TR, jnp.int32(DISTRIBUTED), N, 3))
    for small, big in zip(split_runs(m["trace_records"]),
                          split_runs(full["trace_records"]), strict=True):
        keep = big["seq"] < cap
        for f in schema.FIELDS:
            np.testing.assert_array_equal(small[f], big[f][keep],
                                          err_msg=f)


# ---------------------------------------------------------------------------
# acceptance: records bit-identical across all three executor backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend,kw", [("sharded", {}),
                                        ("streaming", {"chunk_size": 4})])
def test_records_bit_identical_across_backends(traced, backend, kw):
    got = _np(run_batch(KEY, CFG_TR, jnp.int32(DISTRIBUTED), N, RUNS,
                        backend=backend, **kw))
    np.testing.assert_array_equal(got["trace_records"],
                                  traced["trace_records"])
    np.testing.assert_array_equal(got["trace_overflow"],
                                  traced["trace_overflow"])


def test_run_many_carries_records(traced):
    got = _np(run_many(KEY, CFG_TR, jnp.int32(DISTRIBUTED), N, RUNS))
    np.testing.assert_array_equal(got["trace_records"],
                                  traced["trace_records"])


# ---------------------------------------------------------------------------
# store/resume: records survive interrupts and SIGKILL'd workers
# ---------------------------------------------------------------------------


def test_interrupted_streaming_sweep_preserves_records(tmp_path, traced):
    spec = SweepSpec.build("traceresume", CFG_TR,
                           strategies=(DISTRIBUTED,), num_runs=RUNS)
    (pt,) = spec.expand()
    store = ResultStore(str(tmp_path))
    with pytest.raises(SweepInterrupted):
        run_point(pt, backend="streaming", store=store, chunk_size=2,
                  max_chunks=1)
    # the partial checkpoint round-trips the [runs, capacity, F] buffer
    done, accum = store.load_partial(point_digest(pt))
    assert done == 1
    assert accum["trace_records"].shape == (2, 512, schema.NUM_FIELDS)
    resumed = run_point(pt, backend="streaming", store=store, chunk_size=2)
    np.testing.assert_array_equal(resumed["trace_records"],
                                  traced["trace_records"])
    # the store hit trims only trailing unwritten slots (JSON compaction):
    # every written record survives the round-trip bit-for-bit
    hit = run_point(pt, backend="vmap", store=store)
    assert hit["trace_records"].shape[1] <= traced["trace_records"].shape[1]
    dh, dt = decode(hit["trace_records"]), decode(traced["trace_records"])
    for f in schema.FIELDS:
        np.testing.assert_array_equal(dh[f], dt[f], err_msg=f)


def _bench_bytes(path, res):
    write_bench_json(path, "sweep:cmp", build_report(res))
    with open(path, "rb") as f:
        return f.read()


def test_sigkilled_traced_dispatch_resumes_to_identical_report(tmp_path):
    """A traced sweep whose worker is SIGKILL'd mid-run redispatches to a
    BENCH report byte-identical to an uninterrupted single-process run —
    task-level CDFs and hop-resolved indices included (SPEC_KILL carries
    both record streams)."""
    ref = _bench_bytes(str(tmp_path / "ref.json"), execute(SPEC_KILL))
    assert b"task_latency_cdf_s" in ref
    assert b"hop_transfer_time_s_quantiles" in ref
    store = ResultStore(str(tmp_path / "cache"))
    prog = str(tmp_path / "progress.jsonl")
    (proc,) = spawn_workers(SPEC_KILL, store.root, 1, lease_ttl_s=2.0,
                            progress_path=prog)
    try:
        deadline = time.time() + 300
        while time.time() < deadline:
            if any(r.get("event") == "point"
                   for r in read_progress(prog)):
                break
            assert proc.is_alive(), "worker died before first point"
            time.sleep(0.05)
        else:
            pytest.fail("worker produced no point within 300s")
        proc.kill()
    finally:
        proc.join()
    with pytest.raises(RuntimeError, match="redispatch to resume"):
        collect(SPEC_KILL, store)
    res = dispatch(SPEC_KILL, store, workers=2, lease_ttl_s=2.0,
                   progress_path=prog)
    assert _bench_bytes(str(tmp_path / "resumed.json"), res) == ref


# ---------------------------------------------------------------------------
# report + timeline export surfaces
# ---------------------------------------------------------------------------


def test_report_feeds_task_cdf_from_records(traced, untraced):
    doc = build_report({"pt": traced})["points"]["pt"]
    assert "trace_records" not in doc       # buffers aggregated, not dumped
    cdf = doc["task_latency_cdf_s"]
    dec = decode(traced["trace_records"])
    lat = dec["latency_s"][~dec["is_dropped"]]
    assert cdf["p50"] == pytest.approx(float(np.quantile(lat, 0.5)))
    assert doc["task_count"] == int(traced["completed"].sum())
    assert 0.0 < doc["task_latency_jain"] <= 1.0
    # untraced points keep the PR 3 shape: no task-level section at all
    doc0 = build_report({"pt": untraced})["points"]["pt"]
    assert not any(k.startswith("task_") for k in doc0)


def test_chrome_trace_export_is_valid_and_complete(tmp_path, traced):
    dec = split_runs(traced["trace_records"],
                     traced["trace_overflow"])[0]
    path = write_chrome_trace(str(tmp_path / "t.json"), dec)
    with open(path) as f:
        doc = json.load(f)                  # validates as JSON
    ev = doc["traceEvents"]
    slices = [e for e in ev if e["ph"] == "X"]
    drops = [e for e in ev if e["ph"] == "i"]
    assert len(slices) == int((~dec["is_dropped"]).sum())
    assert len(drops) == int(dec["is_dropped"].sum())
    assert all(e["dur"] >= 0 and e["ts"] >= 0 for e in slices)
    # every forwarded task draws a src → dst flow arrow
    flows = [e for e in ev if e["ph"] in ("s", "f")]
    moved = int(((dec["hops"] > 0) & ~dec["is_dropped"]).sum())
    assert len(flows) == 2 * moved


def test_serve_stats_share_the_task_record_schema():
    """ServeStats rows decode through the same repro.trace pipeline as the
    simulator's in-scan records."""
    from repro.splitcompute.serve_engine import ServeStats
    st = ServeStats()
    st.record(seq=0, src=0, dst=1, created_t=0.0, completed_t=0.4,
              exit_label=1, layers=8, hops=1, count=2)
    st.record(seq=1, src=0, dst=0, created_t=0.1, completed_t=0.2,
              exit_label=0, layers=16, hops=0)
    assert st.records.shape == (3, schema.NUM_FIELDS)
    assert (st.completed, st.exit_counts) == (3, {0: 1, 1: 2, 2: 0})
    assert st.latency_sum == pytest.approx(0.4 * 2 + 0.1)
    dec = decode(st.records)
    idx = trace_indices(dec)
    assert idx["task_count"] == 3 and idx["dropped_count"] == 0
    assert idx["exit_label_histogram"] == {"0": 1, "1": 2}
    events = chrome_trace_events(dec)
    assert sum(e["ph"] == "X" for e in events) == 3
    # labels outside the 0/1/2 ladder (shared vocabulary) must not crash
    st.record(seq=2, src=0, dst=0, created_t=0.5, completed_t=0.5,
              exit_label=schema.DROPPED, layers=0, hops=0)
    assert st.exit_counts[schema.DROPPED] == 1 and st.completed == 4
    # bounded capture: counters keep counting past max_records
    st2 = ServeStats(max_records=1)
    for i in range(3):
        st2.record(seq=i, src=0, dst=0, created_t=0.0, completed_t=1.0,
                   exit_label=0, layers=1, hops=0)
    assert (st2.completed, len(st2.records), st2.record_overflow) == (3, 1, 2)
    assert st2.latency_sum == pytest.approx(3.0)
