"""Queueing-theory validation of the simulator's waiting dynamics.

Under LocalOnly with zero capability spread and a degenerate burst chain
(duty → 1), every node is an independent discrete-time Geo/D/1 queue:

  * arrivals: one Bernoulli(p_arr) draw per tick with
    ``p_arr = 1 - exp(-tick / (task_period_s · duty))`` — the memoryless
    (Poisson-discretized) stream of ``scenario.burst_arrivals``;
  * service: deterministic ``D = task_gflops_total / capability_mean``
    seconds (an exact multiple of the tick by construction here), and a
    task receives compute in its arrival tick, so pure service shows up
    in the latency metric as ``D - tick``.

The mean queue wait of that system is the discrete Pollaczek–Khinchine
value ``W_q = ρ·(D - tick) / (2·(1 - ρ))`` with ``ρ = λ·D`` and
``λ = p_arr / tick`` — the continuous M/D/1 formula ``ρD/(2(1-ρ))``
recovered as tick → 0.  The measured decomposition
``avg_latency_s = W_q + (D - tick)`` must pin both, which validates the
queue/compute/arrival plumbing end-to-end against theory rather than
against the simulator's own accounting.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SwarmConfig
from repro.swarm import LOCAL_ONLY, run_many

KEY = jax.random.PRNGKey(0)
N, RUNS = 16, 4
TICK = 0.005


def _mdl_cfg(period_s: float) -> SwarmConfig:
    return dataclasses.replace(
        SwarmConfig(), num_workers=N, sim_time_s=30.0, tick_s=TICK,
        # deterministic service: F = capability_mean exactly, and
        # D = 12 GFLOP / 300 GFLOP/s = 40 ms = 8 ticks
        capability_mean=300.0, capability_std=0.0,
        # degenerate ON/OFF chain: duty -> 1, i.e. plain memoryless arrivals
        burst_on_s=1e6, burst_off_s=1e-6,
        task_period_s=period_s)


def _analytics(cfg: SwarmConfig):
    D = cfg.task_gflops_total / cfg.capability_mean
    duty = cfg.burst_on_s / (cfg.burst_on_s + cfg.burst_off_s)
    p_arr = 1.0 - math.exp(-cfg.tick_s / (cfg.task_period_s * duty))
    lam = p_arr / cfg.tick_s
    rho = lam * D
    wq_disc = rho * (D - cfg.tick_s) / (2.0 * (1.0 - rho))
    wq_cont = rho * D / (2.0 * (1.0 - rho))
    return D, rho, wq_disc, wq_cont


@pytest.fixture(scope="module")
def measured():
    out = {}
    for period in (0.12, 0.06):            # rho ~= 0.33 and ~= 0.64
        cfg = _mdl_cfg(period)
        m = run_many(KEY, cfg, jnp.int32(LOCAL_ONLY), N, RUNS)
        out[period] = (cfg, {k: np.asarray(v) for k, v in m.items()})
    return out


@pytest.mark.parametrize("period", [0.12, 0.06])
def test_queue_wait_matches_pollaczek_khinchine(measured, period):
    cfg, m = measured[period]
    D, rho, wq_disc, wq_cont = _analytics(cfg)
    assert rho < 1.0
    # the queue never saturates: the analytic regime requires no loss
    assert m["dropped"].sum() == 0.0
    wq_meas = m["avg_latency_s"] - (D - cfg.tick_s)
    # ~16k / ~31k completed tasks per point: Monte-Carlo error on the mean
    # wait is < 1%, so an 8% band is dominated by model error, not noise
    np.testing.assert_allclose(wq_meas.mean(), wq_disc, rtol=0.08)
    # and the textbook continuous M/D/1 value is the tick -> 0 limit: it
    # must bracket the measurement from above within ~15%
    assert wq_meas.mean() < wq_cont * 1.05
    assert wq_meas.mean() > wq_cont * 0.85


def test_queue_wait_grows_with_load(measured):
    (_, lo), (_, hi) = measured[0.12], measured[0.06]
    cfg = _mdl_cfg(0.06)
    D = cfg.task_gflops_total / cfg.capability_mean
    assert (hi["avg_latency_s"] - (D - TICK)).mean() > \
        2.5 * (lo["avg_latency_s"] - (D - TICK)).mean()


@pytest.mark.parametrize("period", [0.12, 0.06])
def test_arrival_rate_matches_bernoulli_thinning(measured, period):
    """Generated-task counts pin the arrival side of the model: n nodes ×
    ticks × p_arr, within Monte-Carlo error (binomial, ~1%)."""
    cfg, m = measured[period]
    duty = cfg.burst_on_s / (cfg.burst_on_s + cfg.burst_off_s)
    p_arr = 1.0 - math.exp(-cfg.tick_s / (cfg.task_period_s * duty))
    ticks = round(cfg.sim_time_s / cfg.tick_s)
    expect = N * ticks * p_arr
    np.testing.assert_allclose(m["generated"].mean(), expect, rtol=0.03)


def test_service_floor_at_vanishing_load():
    """rho -> 0: latency collapses to the pure service time D - tick and
    the wait formula's prediction goes to ~0 with it."""
    cfg = _mdl_cfg(2.0)                    # rho ~= 0.02
    D, rho, wq_disc, _ = _analytics(cfg)
    m = run_many(KEY, cfg, jnp.int32(LOCAL_ONLY), N, RUNS)
    lat = float(np.asarray(m["avg_latency_s"]).mean())
    assert wq_disc < 1e-3
    assert lat == pytest.approx(D - cfg.tick_s, abs=2e-3)
