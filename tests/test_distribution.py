"""Distribution correctness: these tests need a multi-device jax runtime,
which requires XLA_FLAGS before import — so they exec a child process with
16 host devices and assert on its output (the dry-run itself covers the
full 256/512-chip meshes)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")

CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.configs import get_config, reduced
from repro.launch.mesh import (batch_axes_of, make_production_mesh,
                               resolve_spec, sanitize_spec, shardings)
from repro.models import build_model

out = {}

# --- mesh + spec resolution -------------------------------------------------
mesh = jax.make_mesh((4, 4), ("data", "model"))
sp = sanitize_spec(P("model", "data"), (49155, 1024), mesh)
out["sanitize_vocab"] = list(sp)           # model must drop (49155 % 4 != 0)
sp2 = sanitize_spec(P("data", "model"), (64, 64), mesh)
out["sanitize_ok"] = list(sp2)

mp = jax.make_mesh((2, 2, 4), ("pod", "data", "model"))
rp = resolve_spec(P("data", None), mp)
out["resolve_pod"] = [list(e) if isinstance(e, tuple) else e for e in rp]

# --- MoE expert-parallel numerics vs single device ---------------------------
cfg = reduced(get_config("qwen3-moe-30b-a3b"))
model_1 = build_model(cfg)                       # no mesh: single shard
params = model_1.init(jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)
batch = {"tokens": toks, "labels": toks}
loss_1, _ = model_1.loss(params, batch)

model_n = build_model(cfg, mesh=mesh)            # shard_map EP over 4 shards
with mesh:
    pshard = shardings(model_n.specs(), mesh, params)
    params_n = jax.device_put(params, pshard)
    loss_n, _ = jax.jit(model_n.loss)(params_n, batch)
out["moe_loss_single"] = float(loss_1)
out["moe_loss_sharded"] = float(loss_n)

# --- dense train step lowers + runs on the mesh ------------------------------
cfg_d = reduced(get_config("qwen3-1.7b"))
model_d = build_model(cfg_d, mesh=mesh)
params_d = model_d.init(jax.random.PRNGKey(0))
with mesh:
    pshard = shardings(model_d.specs(), mesh, params_d)
    params_ds = jax.device_put(params_d, pshard)
    loss_d, _ = jax.jit(model_d.loss)(params_ds, batch)
loss_ref, _ = build_model(cfg_d).loss(params_d, batch)
out["dense_loss_mesh"] = float(loss_d)
out["dense_loss_ref"] = float(loss_ref)

print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def child_out():
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", CHILD], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_sanitize_drops_nondivisible(child_out):
    assert child_out["sanitize_vocab"] == [None, "data"]
    assert child_out["sanitize_ok"] == ["data", "model"]


def test_pod_axis_resolution(child_out):
    assert child_out["resolve_pod"][0] == ["pod", "data"]


def test_moe_expert_parallel_matches_single_device(child_out):
    assert abs(child_out["moe_loss_single"]
               - child_out["moe_loss_sharded"]) < 2e-2


def test_dense_mesh_loss_matches_reference(child_out):
    assert abs(child_out["dense_loss_mesh"]
               - child_out["dense_loss_ref"]) < 2e-2
