"""End-to-end system test: the full production stack (model + data +
optimizer + checkpoint/restart driver) trains and recovers from failure."""
import tempfile

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.data import DataConfig, batch_at
from repro.launch.step import init_train_state, make_train_step
from repro.models import build_model
from repro.optim import OptConfig
from repro.runtime import DriverConfig, run_with_restarts


def _run(tmp, fail_at, steps=24):
    cfg = reduced(get_config("qwen3-1.7b"))
    model = build_model(cfg)
    opt = OptConfig(lr=3e-3, warmup_steps=4, total_steps=steps)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4)
    step = jax.jit(make_train_step(model, opt))
    losses = []
    state = run_with_restarts(
        DriverConfig(ckpt_dir=tmp, ckpt_every=8, max_steps=steps,
                     fail_at_step=fail_at),
        init_state=lambda: init_train_state(model, jax.random.PRNGKey(0)),
        train_step=step, batch_fn=lambda s: batch_at(dcfg, s),
        on_metrics=lambda s, m: losses.append(float(m["loss"])))
    return state, losses


def test_train_recovers_from_failure_and_loss_decreases():
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        clean, losses = _run(d1, fail_at=None)
        faulty, _ = _run(d2, fail_at=13)
        assert int(clean.opt.step) == int(faulty.opt.step) == 24
        for a, b in zip(jax.tree.leaves(clean.params),
                        jax.tree.leaves(faulty.params), strict=True):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-5, atol=1e-6)
        assert losses[-1] < losses[0]
