"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, assert output shapes + finiteness (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced
from repro.models import build_model
from repro.models.common import count_params

ARCH_IDS = sorted(ARCHS)


def make_batch(cfg, B=2, S=16, key=0):
    k = jax.random.PRNGKey(key)
    if cfg.family == "vlm":
        batch = {
            "embeds": jax.random.normal(k, (B, S, cfg.d_model), jnp.float32),
            "positions": jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None, None],
                (len(cfg.mrope_sections), B, S)),
        }
    elif cfg.family == "encdec":
        F = cfg.encdec.source_positions
        batch = {
            "enc_embeds": jax.random.normal(k, (B, F, cfg.d_model),
                                            jnp.float32),
            "tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
        }
    else:
        batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size)}
    batch["labels"] = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_finite(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    assert count_params(params) > 0
    batch = make_batch(cfg)
    logits, _, _ = model.forward(params, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_decreases_loss(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)

    @jax.jit
    def step(p):
        (loss, metrics), grads = jax.value_and_grad(model.loss,
                                                    has_aux=True)(p, batch)
        p2 = jax.tree.map(lambda w, g: w - 0.05 * g.astype(w.dtype)
                          if jnp.issubdtype(w.dtype, jnp.floating) else w,
                          p, grads)
        return p2, loss

    p, l0 = step(params)
    for _ in range(3):
        p, l1 = step(p)
    assert np.isfinite(float(l0)) and np.isfinite(float(l1))
    assert float(l1) < float(l0), f"loss did not decrease: {l0} -> {l1}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode_matches_forward(arch):
    """Decode path consistency: prefill S-1 tokens then decode the S-th;
    logits must match the full-sequence forward at that position."""
    cfg = reduced(get_config(arch))
    if cfg.family == "vlm":
        pytest.skip("vlm decode consumes tokens after an embeds prompt; "
                    "covered by test_decode_cache_vlm")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 8
    batch = make_batch(cfg, B=B, S=S)

    logits_full, _, _ = model.forward(params, batch, mode="train")

    prompt = {k: (v[:, :S - 1] if k in ("tokens",) else v)
              for k, v in batch.items() if k != "labels"}
    caches = model.init_cache(B, S)
    if cfg.family in ("dense", "moe", "encdec"):
        # write prompt KV into the allocated cache: replay via decode steps
        pass
    logits = None
    # replay all tokens through decode_step (tests cache correctness)
    tok_seq = batch["tokens"]
    if cfg.family == "encdec":
        # encdec decode needs cross-KV: build caches via prefill of full len
        last, caches = model.prefill(params, {**prompt,
                                              "tokens": tok_seq[:, :S - 1]})
        np.testing.assert_allclose(
            np.asarray(last, np.float32),
            np.asarray(logits_full[:, S - 2], np.float32), rtol=2e-2,
            atol=2e-2)
        return
    for t in range(S):
        step_batch = {"token": tok_seq[:, t:t + 1], "pos": jnp.int32(t)}
        logits, caches = model.decode_step(params, caches, step_batch)
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(logits_full[:, -1], np.float32),
                               rtol=2e-2, atol=2e-2)
