"""swarmlint (repro.analysis): clean tree, per-rule fixtures, CLI contract.

The fixture mini-repos under ``tests/analysis_fixtures/`` each carry one
rule's defect (``*_tp``) or the closest correct idiom (``*_tn``); they go
through :func:`repro.analysis.run` — the exact code path the CLI and the
CI gate use — so a rule that silently stops firing fails here first.
"""
import ast
import json
import os
import shutil
import subprocess
import sys

import pytest

from repro.analysis import run
from repro.analysis.baseline import parse_baseline

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(REPO, "tests", "analysis_fixtures")


def fixture(name):
    return os.path.join(FIXTURES, name)


def run_rule(root, rule):
    return run(root, rules=[rule])


# ---------------------------------------------------------------------------
# the shipped tree is clean (tier-1 enforcement of the CI gate)
# ---------------------------------------------------------------------------


def test_repo_tree_is_clean():
    """The committed tree must carry zero findings beyond the baseline —
    the same assertion ``python -m repro.analysis`` makes in CI."""
    findings = run(REPO)
    assert findings == [], "\n".join(
        f"{f.file}:{f.line}: {f.rule} [{f.symbol}] {f.message}"
        for f in findings)


def test_repo_baseline_entries_all_fire():
    """Every [[allow]] entry must still match a live finding: an entry
    whose finding is gone is dead weight that would mask a future
    regression at the same (rule, file, symbol).  Runs tier *all*: the
    baseline carries J entries too, and an ast-only raw run would report
    them stale (jaxpr findings are invisible to it)."""
    try:
        import jax  # noqa: F401
    except Exception:                                # pragma: no cover
        pytest.skip("jax not installed: J-rule baseline entries "
                    "cannot be validated")
    raw = run(REPO, use_baseline=False, tier="all")
    live = {(f.rule, f.file, f.symbol) for f in raw}
    from repro.analysis.baseline import load_baseline
    bl = load_baseline(REPO)
    assert bl is not None
    stale = [a for a in bl.allows_ if a not in live]
    assert stale == [], f"baseline entries with no live finding: {stale}"


# ---------------------------------------------------------------------------
# R001 — key discipline
# ---------------------------------------------------------------------------


def test_r001_true_positive():
    found = run_rule(fixture("r001_tp"), "R001")
    symbols = {f.symbol for f in found}
    assert "sample_pair:key" in symbols
    assert "split_then_reuse:k1" in symbols
    assert all(f.rule == "R001" for f in found)


def test_r001_true_negative():
    assert run_rule(fixture("r001_tn"), "R001") == []


# ---------------------------------------------------------------------------
# R002 — digest completeness
# ---------------------------------------------------------------------------


def test_r002_true_positive():
    found = run_rule(fixture("r002_tp"), "R002")
    assert [f.symbol for f in found] == ["SwarmConfig.trace_capacity"]
    assert "point_digest" in found[0].message


def test_r002_true_negative():
    # wholesale asdict coverage + a justified SweepSpec.name exemption
    assert run_rule(fixture("r002_tn"), "R002") == []


def test_r002_new_field_without_coverage_fails(tmp_path):
    """The satellite contract: adding a SwarmConfig field to a tree whose
    digest enumerates fields explicitly must fail R002 until the field is
    digested or exempted.  The tmp tree uses the *real* SwarmConfig plus a
    generated explicit-enumeration ``point_digest`` so the test tracks the
    live field list instead of a frozen copy."""
    cfg_src = os.path.join(REPO, "src", "repro", "configs", "base.py")
    cls = next(n for n in ast.parse(open(cfg_src).read()).body
               if isinstance(n, ast.ClassDef) and n.name == "SwarmConfig")
    fields = [st.target.id for st in cls.body
              if isinstance(st, ast.AnnAssign)
              and isinstance(st.target, ast.Name)]
    assert len(fields) > 10     # sanity: we really parsed the dataclass

    dst_cfg = tmp_path / "src" / "repro" / "configs"
    dst_fleet = tmp_path / "src" / "repro" / "fleet"
    dst_cfg.mkdir(parents=True)
    dst_fleet.mkdir(parents=True)
    shutil.copy(cfg_src, dst_cfg / "base.py")
    lines = [f'        "{f}": point.cfg.{f},' for f in fields]
    (dst_fleet / "store.py").write_text(
        "import hashlib, json\n\n\n"
        "def point_digest(point, code_version):\n"
        "    payload = {\n" + "\n".join(lines) + "\n"
        '        "code": code_version,\n'
        "    }\n"
        "    return hashlib.sha256(json.dumps(\n"
        "        payload, sort_keys=True).encode()).hexdigest()\n")

    assert run_rule(str(tmp_path), "R002") == []    # fully enumerated

    with open(dst_cfg / "base.py", "a") as f:
        f.write("    brand_new_knob: int = 0\n")
    found = run_rule(str(tmp_path), "R002")
    assert [f.symbol for f in found] == ["SwarmConfig.brand_new_knob"]


# ---------------------------------------------------------------------------
# R003 — in-scan purity
# ---------------------------------------------------------------------------


def test_r003_true_positive():
    found = run_rule(fixture("r003_tp"), "R003")
    assert [f.symbol for f in found] == ["_stamp"]
    # the chain starts at whichever root reached it first (_epoch is a
    # root in its own right) and must end at the offending function
    assert "-> _stamp" in found[0].message
    assert "time.time" in found[0].message


def test_r003_true_negative():
    # host_report calls print()/time.time() but is unreachable from run_sim
    assert run_rule(fixture("r003_tn"), "R003") == []


# ---------------------------------------------------------------------------
# R004 — registry/doc consistency
# ---------------------------------------------------------------------------


def test_r004_true_positive():
    found = run_rule(fixture("r004_tp"), "R004")
    msgs = "\n".join(f.message for f in found)
    assert "referenced by no test" in msgs
    assert "not mentioned in DESIGN.md" in msgs
    assert any(f.symbol == "cite:§42" for f in found)


def test_r004_true_negative():
    assert run_rule(fixture("r004_tn"), "R004") == []


# ---------------------------------------------------------------------------
# baseline parsing contract
# ---------------------------------------------------------------------------


def test_baseline_rejects_missing_reason():
    with pytest.raises(ValueError, match="reason"):
        parse_baseline('[[allow]]\nrule = "R001"\nfile = "f.py"\n'
                       'symbol = "f:key"\nreason = ""\n')
    with pytest.raises(ValueError, match="missing"):
        parse_baseline('[[digest_exempt]]\nfield = "SweepSpec.name"\n')


def test_baseline_matches_without_line_numbers():
    from repro.analysis.astutil import Finding
    bl = parse_baseline('[[allow]]\nrule = "R001"\nfile = "a.py"\n'
                        'symbol = "f:key"\nreason = "why"\n')
    assert bl.allows(Finding("R001", "a.py", 1, "f:key", "m"))
    assert bl.allows(Finding("R001", "a.py", 999, "f:key", "m"))
    assert not bl.allows(Finding("R003", "a.py", 1, "f:key", "m"))


# ---------------------------------------------------------------------------
# CLI contract: exit codes + JSON shape
# ---------------------------------------------------------------------------


def _cli(*argv):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *argv],
        capture_output=True, text=True, env=env, cwd=REPO)


def test_cli_clean_tree_exits_zero():
    p = _cli("--root", REPO)
    assert p.returncode == 0, p.stdout + p.stderr
    assert "0 finding(s)" in p.stdout


@pytest.mark.parametrize("fix,rule", [("r001_tp", "R001"),
                                      ("r002_tp", "R002"),
                                      ("r003_tp", "R003"),
                                      ("r004_tp", "R004")])
def test_cli_true_positive_exits_nonzero(fix, rule):
    p = _cli("--root", fixture(fix), "--rules", rule, "--format", "json")
    assert p.returncode == 1, p.stdout + p.stderr
    doc = json.loads(p.stdout)
    assert doc["rules"] == [rule]
    assert doc["findings"], "expected at least one finding"
    assert all(set(f) >= {"rule", "file", "line", "symbol", "message"}
               for f in doc["findings"])


def test_cli_unknown_rule_exits_two():
    p = _cli("--rules", "R999")
    assert p.returncode == 2
