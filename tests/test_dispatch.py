"""Multi-host dispatch tests (DESIGN.md §9): spec JSON contract, lease
claim/renew/steal semantics, rank-strided scheduling, progress surface, and
the acceptance properties — a 2-worker spawned dispatch produces a
``BENCH_fleet.json`` byte-identical to a single-process run, and a worker
killed mid-sweep is survivable: redispatch resumes from the store to an
identical file.

Spawned-worker tests use a tiny grid (two configs, traced strategies) so
each child pays one JAX compile; everything else runs in-process.
"""
import dataclasses
import json
import os
import time

import pytest

from repro.configs.base import SwarmConfig
from repro.fleet import (ResultStore, SweepSpec, build_report, collect,
                         dispatch, execute, point_digest, progress_summary,
                         read_progress, render_progress, run_worker,
                         spawn_workers, worker_env, write_bench_json)
from repro.fleet.dispatch import claim_order

CFG = dataclasses.replace(SwarmConfig(), sim_time_s=1.0, num_workers=6)
SPEC = SweepSpec.build("disp", CFG, axes={"gamma": (0.02, 0.1)},
                       strategies=(0, 4), num_runs=3)
SPEC_KILL = SweepSpec.build("dispkill", CFG, axes={"gamma": (0.02, 0.1)},
                            strategies=(0, 2, 4), num_runs=3)


@pytest.fixture(scope="module", autouse=True)
def _pinned_code_version():
    """Digests must agree between this process and spawned workers (which
    inherit os.environ), and must not drift with the working tree.

    ``code_version`` is lru_cached, so the cache is cleared around the
    pin — otherwise a digest computed by an *earlier* test file would
    freeze a different version in this process while spawned children
    read the env fresh, and collect() would miss the children's results.
    """
    from repro.fleet.store import code_version
    old = os.environ.get("REPRO_CODE_VERSION")
    os.environ["REPRO_CODE_VERSION"] = "test-dispatch"
    code_version.cache_clear()
    yield
    if old is None:
        del os.environ["REPRO_CODE_VERSION"]
    else:
        os.environ["REPRO_CODE_VERSION"] = old
    code_version.cache_clear()


def _bench_bytes(path, res):
    write_bench_json(path, "sweep:cmp", build_report(res))
    with open(path, "rb") as f:
        return f.read()


@pytest.fixture(scope="module")
def ref_bytes(tmp_path_factory):
    """Single-process reference BENCH bytes for both sweep specs."""
    d = tmp_path_factory.mktemp("ref")
    return {
        "disp": _bench_bytes(str(d / "a.json"), execute(SPEC)),
        "dispkill": _bench_bytes(str(d / "b.json"), execute(SPEC_KILL)),
    }


# ---------------------------------------------------------------------------
# spec JSON contract + scheduling + env contract (in-process, fast)
# ---------------------------------------------------------------------------


def test_spec_json_roundtrip_preserves_digests():
    spec = SweepSpec.build(
        "rt", CFG,
        axes={"gamma": (0.02, 0.1),
              "scenario": (("base", {}),
                           ("rwp", {"mobility_model": "random_waypoint"}))},
        strategies=(0, 4), num_runs=3, seed=7)
    spec2 = SweepSpec.from_json(spec.to_json())
    assert spec2 == spec
    assert [point_digest(p) for p in spec2.expand()] == \
           [point_digest(p) for p in spec.expand()]
    # and the JSON itself is deterministic (publishable content)
    assert spec.to_json() == spec2.to_json()


def test_spec_json_restores_tuple_fields_in_overrides():
    """Tuple-typed config fields inside composite overrides come through
    JSON as lists; they must be restored or the rebuilt frozen config is
    unhashable under jit's static cfg argument."""
    spec = SweepSpec.build(
        "tup", CFG,
        axes={"ee": (("deep", {"exit_points": (10, 30, 60)}),)},
        strategies=(4,), num_runs=2)
    spec2 = SweepSpec.from_json(spec.to_json())
    (pt,) = spec2.expand()
    assert pt.cfg.exit_points == (10, 30, 60)
    hash(pt.cfg)    # static-under-jit requires hashability
    assert point_digest(pt) == point_digest(spec.expand()[0])


def test_lease_claim_renew_and_steal(tmp_path):
    store = ResultStore(str(tmp_path))
    d = "ab" + "0" * 62
    assert store.try_claim(d, "w0", ttl_s=60)
    assert store.lease_info(d)["owner"] == "w0"
    assert not store.try_claim(d, "w1", ttl_s=60)   # live lease holds
    assert store.renew_lease(d, "w0", ttl_s=60)
    assert not store.renew_lease(d, "w1", ttl_s=60)  # not the owner
    store.release_lease(d)
    assert store.lease_info(d) is None
    # an expired lease is stolen by the next claimer
    assert store.try_claim(d, "w1", ttl_s=0.05)
    time.sleep(0.1)
    assert store.try_claim(d, "w2", ttl_s=60)
    assert store.lease_info(d)["owner"] == "w2"
    # owner-checked release: the robbed worker can't unlink the stealer's
    # fresh lease, the stealer can
    store.release_lease(d, owner="w1")
    assert store.lease_info(d)["owner"] == "w2"
    store.release_lease(d, owner="w2")
    assert store.lease_info(d) is None


def test_claim_order_shards_then_steals():
    assert claim_order(5, 0, 2) == [0, 2, 4, 1, 3]
    assert claim_order(5, 1, 2) == [1, 3, 0, 2, 4]
    # every worker eventually visits every point (work stealing)
    for r in range(3):
        assert sorted(claim_order(7, r, 3)) == list(range(7))
    assert claim_order(4, 0, 1) == [0, 1, 2, 3]


def test_worker_env_contract(monkeypatch):
    monkeypatch.delenv("REPRO_FLEET_WORLD_SIZE", raising=False)
    monkeypatch.delenv("REPRO_FLEET_RANK", raising=False)
    assert worker_env() == worker_env()  # stable
    assert worker_env().world == 1 and worker_env().rank == 0
    monkeypatch.setenv("REPRO_FLEET_HOSTS", "h0,h1,h2")
    monkeypatch.setenv("REPRO_FLEET_RANK", "2")
    env = worker_env()
    assert (env.rank, env.world) == (2, 3)
    monkeypatch.setenv("REPRO_FLEET_WORLD_SIZE", "4")  # overrides roster
    assert worker_env().world == 4
    monkeypatch.setenv("REPRO_FLEET_COORD", "h0:9876")
    assert worker_env().coordinator == "h0:9876"
    monkeypatch.setenv("REPRO_FLEET_RANK", "4")        # out of range
    with pytest.raises(ValueError, match="bad fleet env"):
        worker_env()


def test_progress_summary_and_render(tmp_path):
    path = str(tmp_path / "p.jsonl")
    rows = [{"event": "sweep_start", "sweep": "s", "total": 4, "t": 0.0},
            {"event": "point", "digest": "d0", "label": "a", "t": 30.0},
            {"event": "point", "digest": "d1", "label": "b", "t": 60.0},
            {"event": "point", "digest": "d1", "label": "b", "t": 60.0}]
    with open(path, "w") as f:
        for r in rows:
            f.write(json.dumps(r) + "\n")
        f.write('{"torn')                      # live-writer tail: skipped
    s = progress_summary(read_progress(path))
    assert (s["completed"], s["total"]) == (2, 4)     # digest-deduped
    assert s["points_per_min"] == pytest.approx(2.0)
    assert s["eta_s"] == pytest.approx(60.0)
    assert "2/4" in render_progress(s) and "ETA" in render_progress(s)
    assert progress_summary([]) is None
    # storeless execute() rows carry digest=null: they must dedup by
    # label, not collapse onto one None key
    rows_null = [{"event": "sweep_start", "sweep": "s", "total": 2,
                  "t": 0.0},
                 {"event": "point", "digest": None, "label": "a", "t": 1.0},
                 {"event": "point", "digest": None, "label": "b", "t": 2.0}]
    s2 = progress_summary(rows_null)
    assert (s2["completed"], s2["total"]) == (2, 2)


# ---------------------------------------------------------------------------
# in-process worker: max_points interrupt + resume, rank striding
# ---------------------------------------------------------------------------


def test_interrupted_worker_resumes_from_store(tmp_path, ref_bytes):
    """A worker that dies after one point (max_points — the dispatch-level
    max_chunks analogue) leaves a resumable store: collect refuses, a
    redispatch completes, and the report equals the uninterrupted one."""
    store = ResultStore(str(tmp_path / "cache"))
    n = run_worker(SPEC, store, max_points=1)
    assert n == 1
    with pytest.raises(RuntimeError, match="redispatch to resume"):
        collect(SPEC, store)
    res = dispatch(SPEC, store, workers=1)
    assert _bench_bytes(str(tmp_path / "b.json"), res) == ref_bytes["disp"]


def test_two_sequential_ranks_complete_via_stealing(tmp_path, ref_bytes):
    """World of two, but rank 1 never shows up: rank 0 walks its own shard
    first, then steals the absentee's unleased points — the sweep still
    completes and collects identically."""
    store = ResultStore(str(tmp_path / "cache"))
    run_worker(SPEC, store, rank=0, world=2)
    res = collect(SPEC, store)
    assert _bench_bytes(str(tmp_path / "b.json"), res) == ref_bytes["disp"]


# ---------------------------------------------------------------------------
# acceptance: spawned workers (multiprocessing 'spawn')
# ---------------------------------------------------------------------------


def test_two_worker_dispatch_bit_identical_to_single_process(
        tmp_path, ref_bytes):
    store = ResultStore(str(tmp_path / "cache"))
    prog = str(tmp_path / "progress.jsonl")
    res = dispatch(SPEC, store, workers=2, progress_path=prog)
    assert _bench_bytes(str(tmp_path / "b.json"), res) == ref_bytes["disp"]
    rows = read_progress(prog)
    s = progress_summary(rows)
    assert (s["completed"], s["total"]) == (len(SPEC.expand()),
                                            len(SPEC.expand()))
    # per-point timing rows carry worker identity and wall time
    pts = [r for r in rows if r["event"] == "point"]
    assert all(r["wall_s"] >= 0 and r["worker"] for r in pts)


def test_killed_worker_mid_sweep_then_redispatch_is_identical(
        tmp_path, ref_bytes):
    store = ResultStore(str(tmp_path / "cache"))
    prog = str(tmp_path / "progress.jsonl")
    (proc,) = spawn_workers(SPEC_KILL, store.root, 1, lease_ttl_s=2.0,
                            progress_path=prog)
    try:
        # SIGKILL as soon as the first point lands: mid-sweep, possibly
        # mid-claim — whatever lease survives must expire into a steal
        deadline = time.time() + 300
        while time.time() < deadline:
            if any(r.get("event") == "point"
                   for r in read_progress(prog)):
                break
            assert proc.is_alive(), "worker died before first point"
            time.sleep(0.05)
        else:
            pytest.fail("worker produced no point within 300s")
        proc.kill()
    finally:
        proc.join()

    with pytest.raises(RuntimeError, match="redispatch to resume"):
        collect(SPEC_KILL, store)

    res = dispatch(SPEC_KILL, store, workers=2, lease_ttl_s=2.0,
                   progress_path=prog)
    assert _bench_bytes(str(tmp_path / "b.json"), res) == \
        ref_bytes["dispkill"]
    # the redispatch's progress reaches its sweep_start total — points
    # finished before the kill surface as cached rows, so --watch
    # terminates on resumed sweeps too
    s = progress_summary(read_progress(prog))
    assert (s["completed"], s["total"]) == (len(SPEC_KILL.expand()),
                                            len(SPEC_KILL.expand()))
