"""Hop-stream telemetry tests (DESIGN.md §10.5) mirroring the TaskRecord
suite: hop-capture-off invariance, delivery accounting against the scalar
accumulators, bit-identical hop records across all three executor
backends, overflow exactness, interrupt/resume preservation — plus the
transfer-accounting regressions this PR fixes (contended-delivery energy
freeze, delivered-transfer denominator, stable report key sets).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SwarmConfig
from repro.fleet import (ResultStore, SweepInterrupted, SweepSpec,
                         point_digest, run_batch, run_point)
from repro.swarm import DISTRIBUTED, make_profile
from repro.swarm import simulator as sim
from repro.swarm import transfer as transfer_mod
from repro.trace import (decode, decode_hops, hop_airtime_s, hop_energy_j,
                         hop_indices, link_energy_j, schema, split_runs,
                         trace_indices)

KEY = jax.random.PRNGKey(0)
N, RUNS = 8, 6
CFG = dataclasses.replace(SwarmConfig(), sim_time_s=2.0, num_workers=N)
CFG_HOP = dataclasses.replace(CFG, trace_hop_capacity=512)
CFG_BOTH = dataclasses.replace(CFG_HOP, trace_capacity=512)


def _np(tree):
    return {k: np.asarray(v) for k, v in tree.items()}


@pytest.fixture(scope="module")
def hopped():
    return _np(run_batch(KEY, CFG_HOP, jnp.int32(DISTRIBUTED), N, RUNS))


@pytest.fixture(scope="module")
def plain():
    return _np(run_batch(KEY, CFG, jnp.int32(DISTRIBUTED), N, RUNS))


# ---------------------------------------------------------------------------
# hop capture off == no hop state; on perturbs nothing
# ---------------------------------------------------------------------------


def test_capacity_zero_emits_no_hop_state(plain):
    assert not any(k.startswith("trace_") for k in plain)


def test_hop_capture_does_not_perturb_metrics(hopped, plain):
    for k in plain:
        np.testing.assert_array_equal(hopped[k], plain[k], err_msg=k)


def test_hop_stream_independent_of_task_stream(hopped):
    """Either stream can be on without the other; the hop buffers are
    bit-identical both ways."""
    both = _np(run_batch(KEY, CFG_BOTH, jnp.int32(DISTRIBUTED), N, RUNS))
    np.testing.assert_array_equal(both["trace_hops"], hopped["trace_hops"])
    np.testing.assert_array_equal(both["trace_hop_overflow"],
                                  hopped["trace_hop_overflow"])


# ---------------------------------------------------------------------------
# hop accounting vs the scalar accumulators
# ---------------------------------------------------------------------------


def test_hops_account_for_every_delivery(hopped):
    """records + overflow == delivered transfers (in-flight-at-end hops
    are neither), and per-hop times reproduce the delivered-mean metric."""
    hdec = decode_hops(hopped["trace_hops"], hopped["trace_hop_overflow"])
    delivered = hopped["transfers_delivered"].sum()
    assert hdec["seq"].size + int(hdec["overflow"]) == int(delivered)
    assert np.all(hopped["transfers_delivered"] <= hopped["transfers"])
    # tx_time_sum == Σ per-hop (t_arrive - t_depart), per run
    per_run = split_runs(hopped["trace_hops"], hops=True)
    tsum = (hopped["avg_transfer_time_s"]
            * np.maximum(hopped["transfers_delivered"], 1.0))
    for run, s, d in zip(per_run, tsum, hopped["transfers_delivered"], strict=True):
        if d > 0:
            assert np.isclose(run["transfer_time_s"].sum(), s, rtol=1e-4)
        assert np.all(np.diff(run["seq"]) > 0)   # scatter-by-seq ordering


def test_hop_fields_are_physical(hopped):
    hdec = decode_hops(hopped["trace_hops"], hopped["trace_hop_overflow"])
    assert np.all(hdec["t_arrive"] > hdec["t_depart"])
    assert np.all((hdec["src"] >= 0) & (hdec["src"] < N))
    assert np.all((hdec["dst"] >= 0) & (hdec["dst"] < N))
    assert np.all(hdec["src"] != hdec["dst"])
    assert np.all(hdec["bits"] > 0)
    assert np.all(hdec["boundary_layer"] >= 0)
    assert np.all(hdec["boundary_layer"] <= CFG.task_layers)
    assert np.all(hdec["stall_ticks"] >= 0)
    # stalls never exceed the hop's own duration
    assert np.all(hdec["stall_ticks"] * CFG.tick_s
                  <= hdec["transfer_time_s"] + 1e-6)


def test_hop_overflow_saturates_capture_exactly():
    cap = 4
    cfg = dataclasses.replace(CFG_HOP, trace_hop_capacity=cap)
    m = _np(run_batch(KEY, cfg, jnp.int32(DISTRIBUTED), N, 3))
    hdec = decode_hops(m["trace_hops"], m["trace_hop_overflow"])
    delivered = m["transfers_delivered"].sum()
    assert int(hdec["overflow"]) > 0
    assert hdec["seq"].size + int(hdec["overflow"]) == int(delivered)
    assert np.all(hdec["seq"] < cap)
    # the captured prefix agrees with the uncapped run, record for record
    full = _np(run_batch(KEY, CFG_HOP, jnp.int32(DISTRIBUTED), N, 3))
    for small, big in zip(split_runs(m["trace_hops"], hops=True),
                          split_runs(full["trace_hops"], hops=True),
                          strict=True):
        keep = big["seq"] < cap
        for f in schema.HOP_FIELDS:
            np.testing.assert_array_equal(small[f], big[f][keep],
                                          err_msg=f)


# ---------------------------------------------------------------------------
# backends + resume
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend,kw", [("sharded", {}),
                                        ("streaming", {"chunk_size": 4})])
def test_hops_bit_identical_across_backends(hopped, backend, kw):
    got = _np(run_batch(KEY, CFG_HOP, jnp.int32(DISTRIBUTED), N, RUNS,
                        backend=backend, **kw))
    np.testing.assert_array_equal(got["trace_hops"], hopped["trace_hops"])
    np.testing.assert_array_equal(got["trace_hop_overflow"],
                                  hopped["trace_hop_overflow"])


def test_interrupted_streaming_sweep_preserves_hops(tmp_path, hopped,
                                                    monkeypatch):
    monkeypatch.setenv("REPRO_CODE_VERSION", "test-hops")
    from repro.fleet.store import code_version
    code_version.cache_clear()
    spec = SweepSpec.build("hopresume", CFG_HOP,
                           strategies=(DISTRIBUTED,), num_runs=RUNS)
    (pt,) = spec.expand()
    store = ResultStore(str(tmp_path))
    with pytest.raises(SweepInterrupted):
        run_point(pt, backend="streaming", store=store, chunk_size=2,
                  max_chunks=1)
    done, accum = store.load_partial(point_digest(pt))
    assert done == 1
    assert accum["trace_hops"].shape == (2, 512, schema.NUM_HOP_FIELDS)
    resumed = run_point(pt, backend="streaming", store=store, chunk_size=2)
    np.testing.assert_array_equal(resumed["trace_hops"],
                                  hopped["trace_hops"])
    # store round-trip (trailing-slot compaction) preserves every record
    hit = run_point(pt, backend="vmap", store=store)
    dh = decode_hops(hit["trace_hops"])
    dt = decode_hops(hopped["trace_hops"])
    for f in schema.HOP_FIELDS:
        np.testing.assert_array_equal(dh[f], dt[f], err_msg=f)
    code_version.cache_clear()


# ---------------------------------------------------------------------------
# transfer-accounting regressions (the PR's bugfix satellites)
# ---------------------------------------------------------------------------


def _contention_state(cfg, bits, rate):
    """Two senders (0, 1) -> one receiver (2), same bits, same tick."""
    st = sim.init_state(jax.random.PRNGKey(1), cfg, 3)
    st = dict(st)
    st["tx_active"] = jnp.asarray([True, True, False])
    st["tx_dst"] = jnp.asarray([2, 2, 0], jnp.int32)
    st["tx_bits"] = jnp.asarray([bits, bits, 0.0], jnp.float32)
    st["tx_start"] = jnp.zeros((3,), jnp.float32)
    st["tx_count"] = jnp.int32(2)      # event counters carry as i32 (J001)
    if "hop_seq" in st:
        st["hop_seq"] = jnp.asarray([0, 1, 0], jnp.int32)
        st["hop_bits"] = st["tx_bits"]
        st["hop_counter"] = jnp.int32(2)
    cap = jnp.full((3, 3), rate, jnp.float32)
    alive = jnp.ones((3,), bool)
    return st, cap, alive


def test_contended_delivery_energy_pins_to_single_transfer_value():
    """The loser of receiver contention must stop accruing airtime energy
    once its payload has fully arrived: both tasks cost exactly one tick
    of transmit power, and tx_bits never runs below zero forever."""
    cfg = dataclasses.replace(SwarmConfig(), num_workers=3,
                              trace_capacity=64, trace_hop_capacity=64)
    tick = cfg.tick_s
    tx_w = 10.0 ** (cfg.tx_power_dbm / 10.0) * 1e-3
    # both payloads arrive within one tick
    st, cap, alive = _contention_state(cfg, bits=100.0, rate=100.0 / tick)
    st = transfer_mod.progress(st, cap, alive, cfg, tick)        # tick 1
    assert bool(st["tx_active"][1]) and not bool(st["tx_active"][0])
    assert float(jnp.sum(st["e_tx"])) == pytest.approx(2 * tx_w * tick)
    bits_frozen = float(st["tx_bits"][1])
    st = transfer_mod.progress(st, cap, alive, cfg, 2 * tick)    # tick 2
    assert not bool(st["tx_active"][1])                          # delivered
    # no further accrual for the waiting tick, bits frozen at arrival
    assert float(jnp.sum(st["e_tx"])) == pytest.approx(2 * tx_w * tick)
    assert float(st["tx_bits"][1]) == pytest.approx(bits_frozen)
    # per-task attribution matches: loser pays the same as the winner
    assert float(st["tx_energy"][0]) == pytest.approx(tx_w * tick)
    assert float(st["tx_energy"][1]) == pytest.approx(tx_w * tick)
    # the delivery wait is kept: loser's transfer time is one tick longer
    assert float(st["tx_delivered"]) == 2.0
    assert float(st["tx_time_sum"]) == pytest.approx(tick + 2 * tick)
    # hop records: winner stalled 0 ticks, loser 1 (the contention wait)
    hdec = decode_hops(np.asarray(st["trace_hops"]))
    assert hdec["seq"].size == 2
    assert hdec["stall_ticks"].tolist() == [0, 1]
    assert np.allclose(hdec["transfer_time_s"], [tick, 2 * tick])


def test_avg_transfer_time_uses_delivered_denominator():
    """An in-flight transfer at sim end must not drag the mean down."""
    cfg = dataclasses.replace(SwarmConfig(), num_workers=3)
    profile = make_profile(cfg)
    tick = cfg.tick_s
    st, cap, alive = _contention_state(cfg, bits=100.0, rate=100.0 / tick)
    # sender 1 now targets a different receiver but with a huge payload:
    # it is still in flight when the sim ends
    st["tx_dst"] = jnp.asarray([2, 0, 0], jnp.int32)
    st["tx_bits"] = jnp.asarray([100.0, 1e12, 0.0], jnp.float32)
    st = transfer_mod.progress(st, cap, alive, cfg, tick)
    out = {k: float(v) for k, v in sim.summarize(st, cfg, profile).items()}
    assert out["transfers"] == 2.0
    assert out["transfers_delivered"] == 1.0
    # delivered mean is the delivered transfer's time — not halved by the
    # still-in-flight initiation
    assert out["avg_transfer_time_s"] == pytest.approx(tick)


def test_hop_energy_join_reproduces_e_tx():
    """Per-hop airtime-J attribution joins back to the scalar ``e_tx``
    accumulator exactly once every transfer delivers: both contenders pay
    two flying ticks of transmit power; the loser's extra stalled tick
    costs wall time but no energy."""
    cfg = dataclasses.replace(SwarmConfig(), num_workers=3,
                              trace_hop_capacity=64)
    tick = cfg.tick_s
    tx_w = 10.0 ** (cfg.tx_power_dbm / 10.0) * 1e-3
    st, cap, alive = _contention_state(cfg, bits=100.0,
                                       rate=100.0 / (2 * tick))
    for i in range(1, 8):
        st = transfer_mod.progress(st, cap, alive, cfg, i * tick)
    assert float(st["tx_delivered"]) == 2.0
    hdec = decode_hops(np.asarray(st["trace_hops"]))
    air = hop_airtime_s(hdec, tick)
    e = hop_energy_j(hdec, tick, cfg.tx_power_dbm)
    np.testing.assert_allclose(e, air * tx_w)
    assert e.sum() == pytest.approx(float(jnp.sum(st["e_tx"])))
    # the stall is excluded: the loser's wall clock exceeds its airtime
    assert np.any(air < hdec["transfer_time_s"])
    # per-link rollup is the same join, grouped by directed link
    le = link_energy_j(hdec, tick, cfg.tx_power_dbm)
    assert set(le) == {"0->2", "1->2"}
    assert sum(le.values()) == pytest.approx(float(jnp.sum(st["e_tx"])))


def test_hop_energy_in_report_and_schema(hopped):
    """``tx_power_dbm`` fills the airtime-energy entries; without it the
    keys are present but None (stable BENCH schema either way)."""
    from repro.fleet import build_report
    doc = build_report({"pt": hopped}, tick_s=CFG.tick_s,
                       tx_power_dbm=CFG.tx_power_dbm)["points"]["pt"]
    assert doc["hop_energy_j_quantiles"]["p50"] > 0
    assert doc["link_energy_j_quantiles"]["p50"] > 0
    assert doc["tx_energy_total_j"] > 0
    assert doc["tx_airtime_total_s"] > 0
    tx_w = 10.0 ** (CFG.tx_power_dbm / 10.0) * 1e-3
    assert doc["tx_energy_total_j"] == pytest.approx(
        doc["tx_airtime_total_s"] * tx_w)
    bare = build_report({"pt": hopped}, tick_s=CFG.tick_s)["points"]["pt"]
    assert sorted(bare) == sorted(doc)
    assert bare["tx_airtime_total_s"] is not None   # needs only tick_s
    assert bare["tx_energy_total_j"] is None
    assert bare["hop_energy_j_quantiles"] is None


def test_trace_indices_schema_is_stable():
    """An all-drop trace must emit the same key set as a populated one
    (empty histograms / null quantiles), so BENCH diffs stay comparable."""
    drop_row = schema.pack_np(0, 1, 2, 0.0, 0.5, schema.DROPPED, 0, 1)
    done_row = schema.pack_np(1, 0, 0, 0.0, 0.2, 0, 60, 0)
    all_drop = trace_indices(decode(np.asarray([drop_row])))
    populated = trace_indices(decode(np.asarray([drop_row, done_row])))
    assert sorted(all_drop) == sorted(populated)
    assert all_drop["task_count"] == 0
    assert all_drop["task_latency_cdf_s"] is None
    assert all_drop["task_latency_jain"] is None
    assert all_drop["hop_histogram"] == {}
    assert populated["task_latency_cdf_s"] is not None
    # the hop section has the same guarantee
    empty = hop_indices(decode_hops(schema.empty_hop_buffer(4)))
    full = hop_indices(decode_hops(np.asarray(
        [[0, 0, 1, 0.0, 0.1, 8e6, 3, 2]], np.float32)), tick_s=0.01)
    assert sorted(empty) == sorted(full)
    assert empty["hop_count"] == 0
    assert empty["hop_transfer_time_s_quantiles"] is None
    assert full["hop_queue_wait_s_quantiles"]["p50"] == pytest.approx(0.02)
    assert full["hop_in_flight_s_quantiles"]["p50"] == pytest.approx(0.08)


# ---------------------------------------------------------------------------
# report + export surfaces
# ---------------------------------------------------------------------------


def test_report_gains_hop_resolved_indices(hopped, plain):
    from repro.fleet import build_report
    doc = build_report({"pt": hopped},
                       tick_s=CFG.tick_s)["points"]["pt"]
    assert "trace_hops" not in doc          # buffers aggregated, not dumped
    hdec = decode_hops(hopped["trace_hops"], hopped["trace_hop_overflow"])
    assert doc["hop_count"] == hdec["seq"].size
    assert doc["hop_transfer_time_s_quantiles"]["p50"] == pytest.approx(
        float(np.quantile(hdec["transfer_time_s"], 0.5)))
    assert doc["hop_queue_wait_s_quantiles"] is not None
    # un-hopped points keep their historical shape: no hop-level section
    doc0 = build_report({"pt": plain})["points"]["pt"]
    assert not any(k.startswith("hop_") for k in doc0)


def test_perhop_chrome_trace_export(tmp_path):
    import json
    from repro.trace import write_chrome_trace
    m = _np(run_batch(KEY, CFG_BOTH, jnp.int32(DISTRIBUTED), N, 1))
    dec = decode(m["trace_records"][0], m["trace_overflow"][0])
    hdec = decode_hops(m["trace_hops"][0], m["trace_hop_overflow"][0])
    path = write_chrome_trace(str(tmp_path / "t.json"), dec, hdec,
                              CFG.tick_s)
    with open(path) as f:
        doc = json.load(f)                  # validates as JSON
    ev = doc["traceEvents"]
    hops = [e for e in ev if e.get("cat") == "hop"]
    flows = [e for e in ev if e.get("cat") == "transfer"]
    queues = [e for e in ev if e.get("cat") == "queue"]
    # one slice + one flow arrow (s/f pair) per delivered hop — not per task
    assert len(hops) == hdec["seq"].size
    assert len(flows) == 2 * hdec["seq"].size
    # in-flight slices live on the sender's track
    assert all(e["tid"] == e["args"]["src"] for e in hops)
    assert all(e["dur"] >= 0 for e in hops)
    # one queue-wait slice per stalled hop, on the visited receiver track
    assert len(queues) == int((hdec["stall_ticks"] > 0).sum())
    assert all(e["tid"] == e["args"]["dst"] for e in queues)
    assert all(e["dur"] > 0 for e in queues)
