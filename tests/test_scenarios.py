"""Scenario-engine tests: registries, mobility/channel model contracts,
fault-injector invariants, and the Pallas φ-kernel parity through the
simulator path (DESIGN.md §3.4)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SwarmConfig
from repro.core.diffusive import phi_update, phi_update_op
from repro.swarm import (CHANNEL_MODELS, DISTRIBUTED, FAULT_MODELS,
                         MOBILITY_MODELS, get_channel, get_fault,
                         get_mobility, make_profile, mask_adjacency,
                         run_many)
from repro.swarm.channel import link_state

KEY = jax.random.PRNGKey(0)
N = 12


# ---------------------------------------------------------------------------
# registries
# ---------------------------------------------------------------------------


def test_registry_roundtrip():
    assert set(MOBILITY_MODELS) == {"circular", "random_waypoint",
                                    "gauss_markov", "levy_flight"}
    assert set(CHANNEL_MODELS) == {"two_ray", "free_space", "log_normal",
                                   "log_normal_corr", "rician", "nakagami"}
    assert set(FAULT_MODELS) == {"none", "markov"}
    for name in MOBILITY_MODELS:
        cfg = dataclasses.replace(SwarmConfig(), mobility_model=name)
        assert get_mobility(cfg) is MOBILITY_MODELS[name]
    for name in CHANNEL_MODELS:
        cfg = dataclasses.replace(SwarmConfig(), channel_model=name)
        assert get_channel(cfg) is CHANNEL_MODELS[name]
    for name in FAULT_MODELS:
        cfg = dataclasses.replace(SwarmConfig(), fault_model=name)
        assert get_fault(cfg) is FAULT_MODELS[name]


def test_registry_unknown_key_raises_with_known_keys():
    cfg = dataclasses.replace(SwarmConfig(), mobility_model="brownian")
    with pytest.raises(KeyError, match="circular"):
        get_mobility(cfg)
    cfg = dataclasses.replace(SwarmConfig(), channel_model="weibull")
    with pytest.raises(KeyError, match="two_ray"):
        get_channel(cfg)
    cfg = dataclasses.replace(SwarmConfig(), fault_model="byzantine")
    with pytest.raises(KeyError, match="markov"):
        get_fault(cfg)


# ---------------------------------------------------------------------------
# mobility models: shapes, finiteness, area containment
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["circular", "random_waypoint",
                                  "gauss_markov", "levy_flight"])
def test_mobility_shapes_and_finiteness(name):
    cfg = dataclasses.replace(SwarmConfig(), mobility_model=name)
    model = get_mobility(cfg)
    state = model.init(KEY, cfg, N)
    for i in range(30):
        k = jax.random.fold_in(KEY, i)
        t0 = i * cfg.decision_period_s
        state, pos = model.step(state, k, cfg, jnp.float32(t0))
        assert pos.shape == (N, 2)
        assert bool(jnp.all(jnp.isfinite(pos)))
        if name != "circular":   # orbits may overhang grid-cell centers
            assert bool(jnp.all((pos >= 0.0) & (pos <= cfg.area_m)))


def test_random_waypoint_respects_speed_bound():
    cfg = dataclasses.replace(SwarmConfig(), mobility_model="random_waypoint")
    model = get_mobility(cfg)
    state = model.init(KEY, cfg, N)
    state, prev = model.step(state, KEY, cfg, jnp.float32(0.0))
    for i in range(1, 11):
        state, pos = model.step(state, jax.random.fold_in(KEY, i), cfg,
                                jnp.float32(i * cfg.decision_period_s))
        hop = np.asarray(jnp.linalg.norm(pos - prev, axis=-1))
        assert np.all(hop <= cfg.speed_max_mps * cfg.decision_period_s
                      + 1e-3)
        assert np.any(hop > 0)                           # it does move
        prev = pos


@pytest.mark.parametrize("name", ["random_waypoint", "gauss_markov",
                                  "levy_flight"])
def test_stepped_mobility_epoch0_returns_initial_placement(name):
    """Epoch-start contract: the t0 = 0 step observes the init placement
    (no one-period phase offset vs the closed-form circular model)."""
    cfg = dataclasses.replace(SwarmConfig(), mobility_model=name)
    model = get_mobility(cfg)
    state = model.init(KEY, cfg, N)
    init_pos = np.asarray(state["pos"])
    _, pos = model.step(state, jax.random.fold_in(KEY, 99), cfg,
                        jnp.float32(0.0))
    np.testing.assert_array_equal(np.asarray(pos), init_pos)


# ---------------------------------------------------------------------------
# channel models: finiteness, symmetry, monotone deterministic pathloss
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["two_ray", "free_space", "log_normal",
                                  "log_normal_corr", "rician", "nakagami"])
def test_channel_link_state_contract(name):
    cfg = dataclasses.replace(SwarmConfig(), channel_model=name)
    pos = jax.random.uniform(KEY, (N, 2), jnp.float32, 0.0, cfg.area_m)
    adj, cap = link_state(pos, cfg, key=KEY, pathloss_fn=get_channel(cfg))
    assert adj.shape == (N, N) and cap.shape == (N, N)
    assert not bool(jnp.any(jnp.diag(adj)))              # no self links
    assert bool(jnp.all(cap > 0.0))                      # safe divisor
    assert bool(jnp.all(jnp.isfinite(cap)))
    # symmetric pathloss => symmetric adjacency (same key both directions)
    np.testing.assert_array_equal(np.asarray(adj), np.asarray(adj).T)


@pytest.mark.parametrize("name", ["two_ray", "free_space"])
def test_deterministic_pathloss_monotone_in_distance(name):
    cfg = dataclasses.replace(SwarmConfig(), channel_model=name)
    fn = get_channel(cfg)
    d = jnp.asarray([[10.0, 100.0, 1_000.0, 10_000.0]])
    pl = np.asarray(fn(KEY, d, cfg))[0]
    assert np.all(np.diff(pl) > 0)


@pytest.mark.parametrize("name", ["log_normal", "log_normal_corr", "rician",
                                  "nakagami"])
def test_stochastic_channel_varies_with_key_but_not_baseline(name):
    cfg = SwarmConfig()
    fn = CHANNEL_MODELS[name]
    d = jnp.full((4, 4), 2_000.0)
    pl1 = np.asarray(fn(jax.random.PRNGKey(1), d, cfg))
    pl2 = np.asarray(fn(jax.random.PRNGKey(2), d, cfg))
    off = ~np.eye(4, dtype=bool)
    assert not np.allclose(pl1[off], pl2[off])           # epoch redraw
    np.testing.assert_array_equal(np.diag(pl1), np.diag(pl2))
    np.testing.assert_allclose(pl1, pl1.T)               # symmetric links


@pytest.mark.parametrize("name", ["rician", "nakagami"])
def test_fading_gain_is_unit_mean_around_log_distance_baseline(name):
    """Small-scale fading redistributes SNR but adds no systematic loss:
    the mean linear power gain 10^((base - PL)/10) over many links is 1."""
    cfg = SwarmConfig()
    from repro.swarm.channel import _log_distance_db
    n = 200
    d = jnp.full((n, n), 2_000.0)
    pl = np.asarray(CHANNEL_MODELS[name](KEY, d, cfg))
    base = float(np.asarray(_log_distance_db(jnp.float32(2_000.0), cfg)))
    g = 10.0 ** ((base - pl) / 10.0)
    off = ~np.eye(n, dtype=bool)
    assert abs(g[off].mean() - 1.0) < 0.05
    assert g[off].std() > 0.05                           # it does fade


def test_correlated_shadowing_follows_gudmundson_decorrelation():
    """log_normal_corr contract: links between distinct endpoint pairs are
    strongly correlated when the endpoints sit within the decorrelation
    distance and (near-)independent far outside it, while every link keeps
    the iid model's marginal N(0, σ²)."""
    import dataclasses as dc
    from repro.swarm.channel import _log_distance_db, pairwise_distance

    # two tight clusters 5 km apart: {0,1} and {2,3}, 10 m inside a cluster
    pos = jnp.asarray([[0.0, 0.0], [10.0, 0.0],
                       [5_000.0, 0.0], [5_010.0, 0.0]], jnp.float32)
    dist = pairwise_distance(pos)
    base = np.asarray(_log_distance_db(dist, SwarmConfig()))
    fn = CHANNEL_MODELS["log_normal_corr"]

    def shadow_samples(corr_m, n_keys=400):
        cfg = dc.replace(SwarmConfig(), shadow_corr_m=corr_m)
        x01, x23 = [], []
        for i in range(n_keys):
            x = np.asarray(fn(jax.random.PRNGKey(i), dist, cfg)) - base
            # links (0,2) and (1,3): no shared endpoint
            x01.append(x[0, 2])
            x23.append(x[1, 3])
        return np.asarray(x01), np.asarray(x23)

    a, b = shadow_samples(corr_m=50_000.0)     # swarm-scale correlation
    corr_near = np.corrcoef(a, b)[0, 1]
    assert corr_near > 0.8, corr_near          # clustered endpoints co-shadow
    a, b = shadow_samples(corr_m=1.0)          # decorrelated regime
    corr_far = np.corrcoef(a, b)[0, 1]
    assert abs(corr_far) < 0.3, corr_far
    # exact marginal: every off-diagonal link keeps std sigma
    assert abs(a.std() - SwarmConfig().shadowing_sigma_db) < 1.0


def test_nakagami_concentrates_with_large_m():
    """m → ∞ approaches the deterministic log-distance baseline."""
    cfg_lo = dataclasses.replace(SwarmConfig(), nakagami_m=1.0)
    cfg_hi = dataclasses.replace(SwarmConfig(), nakagami_m=64.0)
    d = jnp.full((64, 64), 2_000.0)
    fn = CHANNEL_MODELS["nakagami"]
    off = ~np.eye(64, dtype=bool)
    spread_lo = np.asarray(fn(KEY, d, cfg_lo))[off].std()
    spread_hi = np.asarray(fn(KEY, d, cfg_hi))[off].std()
    assert spread_hi < spread_lo / 3.0


def test_levy_flight_bounded_and_speed_capped():
    cfg = dataclasses.replace(SwarmConfig(), mobility_model="levy_flight")
    model = get_mobility(cfg)
    state = model.init(KEY, cfg, 64)
    state, prev = model.step(state, KEY, cfg, jnp.float32(0.0))
    hops = []
    for i in range(1, 31):
        state, pos = model.step(state, jax.random.fold_in(KEY, i), cfg,
                                jnp.float32(i * cfg.decision_period_s))
        assert bool(jnp.all((pos >= 0.0) & (pos <= cfg.area_m)))
        hops.append(np.asarray(jnp.linalg.norm(pos - prev, axis=-1)))
        prev = pos
    hops = np.concatenate(hops)
    cap = cfg.speed_max_mps * cfg.decision_period_s
    assert np.all(hops <= cap + 1e-3)        # physical speed cap holds
    assert np.any(hops > 0)                  # it does move
    # heavy tail: long relocations (> half the cap) are rare but present
    frac_long = float(np.mean(hops > 0.5 * cap))
    assert 0.0 < frac_long < 0.5


# ---------------------------------------------------------------------------
# fault injector
# ---------------------------------------------------------------------------


def test_fault_none_is_identity():
    cfg = SwarmConfig()
    model = get_fault(cfg)
    alive = model.init(KEY, cfg, N)
    assert bool(jnp.all(alive))
    assert bool(jnp.all(model.step(alive, KEY, cfg)))


def test_fault_markov_adjacency_invariants():
    cfg = dataclasses.replace(SwarmConfig(), fault_model="markov",
                              fault_mean_up_s=2.0, fault_mean_down_s=2.0)
    model = get_fault(cfg)
    alive = model.init(KEY, cfg, N)
    full = ~jnp.eye(N, dtype=bool)
    seen_down = False
    for i in range(50):
        alive = model.step(alive, jax.random.fold_in(KEY, i), cfg)
        adj = mask_adjacency(full, alive)
        a = np.asarray(adj)
        al = np.asarray(alive)
        # no edge may touch a down node, in either direction
        assert not np.any(a[~al, :]) and not np.any(a[:, ~al])
        # up-up pairs keep their original links
        np.testing.assert_array_equal(a[np.ix_(al, al)],
                                      np.asarray(full)[np.ix_(al, al)])
        seen_down |= not np.all(al)
    assert seen_down      # symmetric 2 s dwell chain must churn in 50 epochs


def test_churn_preserves_task_conservation():
    """Queued work survives outages: generated = completed + in-system +
    dropped still holds under heavy churn."""
    cfg = dataclasses.replace(SwarmConfig(), sim_time_s=10.0, num_workers=10,
                              fault_model="markov", fault_mean_up_s=3.0,
                              fault_mean_down_s=3.0)
    m = run_many(KEY, cfg, jnp.int32(DISTRIBUTED), 10, 4)
    profile = make_profile(cfg)
    gen = np.asarray(m["generated"])
    done = np.asarray(m["completed"])
    drop = np.asarray(m["dropped"])
    rem_tasks = np.asarray(m["remaining_gflops"]) / profile.total_gflops
    assert np.all(done + drop <= gen + 1e-3)
    assert np.all(gen - done - drop <= rem_tasks + cfg.num_workers + 1)
    # churn slows the swarm down vs the fault-free baseline
    m0 = run_many(KEY, dataclasses.replace(cfg, fault_model="none"),
                  jnp.int32(DISTRIBUTED), 10, 4)
    assert (np.asarray(m["completed"]).mean()
            <= np.asarray(m0["completed"]).mean())


# ---------------------------------------------------------------------------
# scenario sweep smoke: config-only selection through one jitted run_many
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mob,ch,fault", [
    ("random_waypoint", "log_normal", "markov"),
    ("gauss_markov", "free_space", "none"),
])
def test_scenario_selection_is_config_only(mob, ch, fault):
    cfg = dataclasses.replace(SwarmConfig(), sim_time_s=4.0, num_workers=8,
                              mobility_model=mob, channel_model=ch,
                              fault_model=fault)
    for s in range(5):
        m = run_many(KEY, cfg, jnp.int32(s), 8, 2)
        for k, v in m.items():
            assert bool(jnp.all(jnp.isfinite(v))), (s, k)


# ---------------------------------------------------------------------------
# Pallas φ kernel parity (interpret mode) — unit + simulator path
# ---------------------------------------------------------------------------


def _force_interpret(monkeypatch):
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
    # the dispatch mode is read at trace time; drop cached executables so
    # the forced mode actually retraces
    jax.clear_caches()


def test_phi_update_op_matches_phi_update_interpret(monkeypatch):
    _force_interpret(monkeypatch)
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    n = 40
    F = jax.random.uniform(k1, (n,), jnp.float32, 100, 500)
    phi = jax.random.uniform(k2, (n,), jnp.float32, 50, 800)
    adj = jax.random.bernoulli(k3, 0.3, (n, n)) & ~jnp.eye(n, dtype=bool)
    d_tx = jnp.where(adj, jax.random.uniform(k4, (n, n), jnp.float32,
                                             1e-4, 1e-2), 1e30)
    want = phi_update(phi, F, adj, d_tx)
    got = phi_update_op(phi, F, adj, d_tx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    jax.clear_caches()


def test_simulator_phi_kernel_parity_interpret(monkeypatch):
    """Acceptance: the simulator's φ update dispatches through
    kernels/ops.diffusive_phi; interpret-mode Pallas == dense phi_update
    reference through the full run_many path at atol 1e-5."""
    cfg = dataclasses.replace(SwarmConfig(), sim_time_s=4.0, num_workers=10)
    m_ref = run_many(KEY, cfg, jnp.int32(DISTRIBUTED), 10, 2)
    m_ref = {k: np.asarray(v) for k, v in m_ref.items()}
    _force_interpret(monkeypatch)
    m_int = run_many(KEY, cfg, jnp.int32(DISTRIBUTED), 10, 2)
    for k, v in m_int.items():
        np.testing.assert_allclose(np.asarray(v), m_ref[k], atol=1e-5,
                                   rtol=1e-5, err_msg=k)
    jax.clear_caches()
