"""SLO observatory metrics-core tests (DESIGN.md §14.1-§14.3): merge
associativity/commutativity, shard-merge == whole-stream bit-exactness
across vmap/sharded/streaming fill orders, histogram quantiles within one
bucket of exact numpy quantiles (including over decoded TaskRecords),
registry semantics, and the Prometheus render/parse round trip.
"""
import jax
import numpy as np
import pytest

from repro.obs import (DEFAULT_LATENCY_HIST, SLO_QS, Registry, hist,
                       host_class)
from repro.obs.hist import HistSpec
from repro.obs.prom import parse, render
from repro.trace import decode, schema

SPEC = DEFAULT_LATENCY_HIST
RNG = np.random.default_rng(7)


def _sample(n=4096):
    # spans underflow (zeros, <1e-4), the finite grid, and overflow
    x = RNG.lognormal(mean=-2.0, sigma=2.0, size=n)
    x[:5] = 0.0
    x[5:8] = 1e-6
    x[8:10] = 1e5
    return x


# ---------------------------------------------------------------------------
# fill / merge properties
# ---------------------------------------------------------------------------

def test_fill_np_matches_device_fill_bit_exact():
    x = _sample()
    dev = np.asarray(hist.fill(SPEC, hist.empty(SPEC), x), np.int64)
    host = hist.fill_np(SPEC, hist.empty_np(SPEC), x)
    np.testing.assert_array_equal(dev, host)
    assert hist.total(host) == x.size


def test_merge_is_associative_and_commutative():
    a, b, c = (hist.fill_np(SPEC, hist.empty_np(SPEC), _sample(512))
               for _ in range(3))
    np.testing.assert_array_equal(hist.merge(hist.merge(a, b), c),
                                  hist.merge(a, hist.merge(b, c)))
    np.testing.assert_array_equal(hist.merge(a, b), hist.merge(b, a))
    np.testing.assert_array_equal(hist.merge(a, b, c), hist.merge(c, b, a))


def test_shard_merge_equals_whole_across_fill_orders():
    """vmap-batched, per-shard jitted, and streaming-chunk fills all merge
    to the same counts as one whole-stream fill, bit for bit."""
    x = _sample(4096)
    whole = hist.fill_np(SPEC, hist.empty_np(SPEC), x)

    shards = x.reshape(8, -1)
    vmapped = jax.vmap(lambda v: hist.fill(SPEC, hist.empty(SPEC), v))(shards)
    np.testing.assert_array_equal(hist.merge(*np.asarray(vmapped)), whole)

    jfill = jax.jit(lambda v: hist.fill(SPEC, hist.empty(SPEC), v))
    sharded = hist.merge(*(np.asarray(jfill(s)) for s in shards))
    np.testing.assert_array_equal(sharded, whole)

    acc = hist.empty_np(SPEC)       # streaming resume: uneven chunks
    for chunk in (x[:100], x[100:101], x[101:2048], x[2048:]):
        hist.fill_np(SPEC, acc, chunk)
    np.testing.assert_array_equal(acc, whole)


def test_weighted_fill_counts_rows():
    counts = hist.fill_np(SPEC, hist.empty_np(SPEC), [0.5, 0.5, 2.0],
                          weights=[3, 4, 5])
    assert hist.total(counts) == 12


# ---------------------------------------------------------------------------
# quantiles
# ---------------------------------------------------------------------------

def _exact_bucket(spec, v):
    return int(np.searchsorted(hist.edges(spec),
                               np.float32(v), side="right"))


@pytest.mark.parametrize("q", SLO_QS)
def test_quantile_within_one_bucket_of_numpy(q):
    x = RNG.lognormal(mean=-1.0, sigma=1.5, size=20_000)
    counts = hist.fill_np(SPEC, hist.empty_np(SPEC), x)
    hb = hist.quantile_bucket(SPEC, counts, q)
    eb = _exact_bucket(SPEC, np.quantile(x, q))
    assert abs(hb - eb) <= 1
    assert hist.quantile(SPEC, counts, q) >= np.quantile(x, q) * 0.999


def test_quantiles_from_decoded_task_records():
    """The acceptance path: TaskRecord stream → decode → latency_s →
    histogram p50/p99/p999 within one bucket of the exact quantiles."""
    n = 5000
    created = RNG.uniform(0.0, 50.0, size=n)
    lat = RNG.lognormal(mean=-2.5, sigma=1.0, size=n)
    rows = np.stack([schema.pack_np(i, 0, 1, created[i], created[i] + lat[i],
                                    0, 30, 1) for i in range(n)])
    dec = decode(rows)
    counts = hist.fill_np(SPEC, hist.empty_np(SPEC), dec["latency_s"])
    for q in SLO_QS:
        hb = hist.quantile_bucket(SPEC, counts, q)
        eb = _exact_bucket(SPEC, np.quantile(dec["latency_s"], q))
        assert abs(hb - eb) <= 1
    s = hist.summary(SPEC, counts)
    assert s["count"] == n and s["overflow"] == 0
    assert s["p50"] <= s["p99"] <= s["p999"]


def test_quantile_edge_cases():
    assert hist.quantile(SPEC, hist.empty_np(SPEC), 0.5) is None
    over = hist.fill_np(SPEC, hist.empty_np(SPEC), [1e9, 1e9])
    assert np.isinf(hist.quantile(SPEC, over, 0.5))
    s = hist.summary(SPEC, over)
    assert s["p50"] is None and s["overflow"] == 2    # visible, not clamped
    under = hist.fill_np(SPEC, hist.empty_np(SPEC), [0.0])
    assert hist.quantile(SPEC, under, 0.5) == pytest.approx(SPEC.lo)


def test_q_label_grid():
    assert [hist.q_label(q) for q in SLO_QS] == ["p50", "p99", "p999"]


def test_custom_spec_resolution():
    spec = HistSpec(lo=1e-3, hi=1e3, buckets=60)
    assert spec.num_bins == 62
    assert spec.growth == pytest.approx((1e6) ** (1 / 60))
    assert hist.edges(spec).shape == (61,)
    assert np.isinf(hist.upper_edges(spec)[-1])


# ---------------------------------------------------------------------------
# registry + Prometheus round trip
# ---------------------------------------------------------------------------

def _filled_registry():
    reg = Registry()
    reg.counter("repro_test_done_total", "rows done").inc(42)
    reg.gauge("repro_test_depth", "queue depth").set(3.5)
    h = reg.histogram("repro_test_latency_seconds", "latency", spec=SPEC)
    h.observe_many(_sample(256))
    return reg


def test_registry_semantics():
    reg = _filled_registry()
    assert reg["repro_test_done_total"].value == 42
    with pytest.raises(ValueError):
        reg.counter("repro_test_done_total", "x").inc(-1)
    with pytest.raises(TypeError):
        reg.gauge("repro_test_done_total", "wrong kind")
    g = reg.gauge("repro_test_depth", "queue depth")    # get-or-create
    g.inc(0.5)
    assert g.value == pytest.approx(4.0)
    h = reg["repro_test_latency_seconds"]
    assert h.count == 256
    assert h.quantile(0.5) is not None


def test_prometheus_round_trip():
    text = render(_filled_registry())
    out = parse(text)
    assert out["types"]["repro_test_latency_seconds"] == "histogram"
    flat = {name: value for name, labels, value in out["samples"]
            if not labels}
    assert flat["repro_test_done_total"] == 42
    assert flat["repro_test_latency_seconds_count"] == 256
    # cumulative buckets end at the sample count on the +Inf bucket
    inf_bucket = [v for name, labels, v in out["samples"]
                  if name == "repro_test_latency_seconds_bucket"
                  and labels.get("le") == "+Inf"]
    assert inf_bucket and inf_bucket[0] == 256


def test_prometheus_parser_rejects_malformed():
    with pytest.raises(ValueError):
        parse("this is { not prometheus\n")
    good = render(_filled_registry())
    broken = good.replace("repro_test_done_total 42",
                          "repro_test_done_total not-a-number")
    with pytest.raises(ValueError):
        parse(broken)


def test_host_class_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_HOST_CLASS", "ci-linux-large")
    assert host_class() == "ci-linux-large"
    monkeypatch.delenv("REPRO_HOST_CLASS")
    hc = host_class()
    assert hc and "-c" in hc
