"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles
(deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.diffusive_phi import diffusive_phi
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.rmsnorm import rmsnorm

KEY = jax.random.PRNGKey(0)


def tol(dt):
    return dict(rtol=2e-2, atol=2e-2) if dt == jnp.bfloat16 \
        else dict(rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("R,N", [(1, 64), (2, 128), (2, 200), (4, 37)])
def test_diffusive_phi(R, N):
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    F = jax.random.uniform(k1, (R, N), jnp.float32, 100, 500)
    phi = jax.random.uniform(k2, (R, N), jnp.float32, 50, 800)
    adj = jax.random.bernoulli(k3, 0.3, (R, N, N))
    adj = adj & ~jnp.eye(N, dtype=bool)[None]
    dtx = jnp.where(adj, jax.random.uniform(k4, (R, N, N), jnp.float32,
                                            1e-4, 1e-2), -1e30)
    want = ref.diffusive_phi(1.0 / phi, F, dtx)
    got = diffusive_phi(1.0 / phi, F, dtx, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("B,S,Hq,Hkv,hd,causal,win,dt", [
    (2, 128, 4, 2, 64, True, 0, jnp.float32),
    (1, 256, 8, 1, 128, True, 0, jnp.bfloat16),
    (2, 128, 4, 4, 64, False, 0, jnp.float32),
    (1, 256, 4, 2, 64, True, 64, jnp.float32),
    (1, 128, 2, 2, 256, True, 0, jnp.bfloat16),
])
def test_flash_attention(B, S, Hq, Hkv, hd, causal, win, dt):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (B, S, Hq, hd), dt)
    k = jax.random.normal(k2, (B, S, Hkv, hd), dt)
    v = jax.random.normal(k3, (B, S, Hkv, hd), dt)
    want = ref.flash_attention(q, k, v, causal=causal, window=win)
    got = flash_attention(q, k, v, causal=causal, window=win, bq=64, bk=64,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dt))


@pytest.mark.parametrize("B,S,Hq,Hkv,hd,pos,win,dt", [
    (2, 256, 8, 2, 64, 100, 0, jnp.float32),
    (1, 512, 4, 1, 128, 511, 0, jnp.bfloat16),
    (2, 256, 4, 4, 64, 200, 64, jnp.float32),
])
def test_decode_attention(B, S, Hq, Hkv, hd, pos, win, dt):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (B, Hq, hd), dt)
    k = jax.random.normal(k2, (B, S, Hkv, hd), dt)
    v = jax.random.normal(k3, (B, S, Hkv, hd), dt)
    want = ref.decode_attention(q, k, v, pos, window=win)
    got = decode_attention(q, k, v, jnp.int32(pos), window=win, bk=128,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dt))


@pytest.mark.parametrize("B,S,W,bs", [(2, 128, 128, 64), (1, 512, 256, 128),
                                      (3, 64, 128, 64)])
def test_rglru_scan(B, S, W, bs):
    k1, k2 = jax.random.split(KEY)
    a = jax.random.uniform(k1, (B, S, W), jnp.float32, 0.5, 0.999)
    b = jax.random.normal(k2, (B, S, W), jnp.float32)
    want = ref.rglru_scan(a, b)
    got = rglru_scan(a, b, bw=128, bs=bs, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("B,S,D,N,bs", [(2, 64, 128, 16, 32),
                                        (1, 128, 256, 8, 64)])
def test_mamba_scan(B, S, D, N, bs):
    k1, k2, k3 = jax.random.split(KEY, 3)
    a = jax.random.uniform(k1, (B, S, D, N), jnp.float32, 0.5, 0.999)
    b = jax.random.normal(k2, (B, S, D, N), jnp.float32) * 0.1
    C = jax.random.normal(k3, (B, S, N), jnp.float32)
    want = ref.mamba_scan(a, b, C)
    got = mamba_scan(a, b, C, bd=128, bs=bs, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=3e-5)


@pytest.mark.parametrize("shape,dt", [((4, 64, 256), jnp.float32),
                                      ((8, 128), jnp.bfloat16),
                                      ((3, 7, 512), jnp.float32)])
def test_rmsnorm(shape, dt):
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, shape, dt)
    s = jax.random.normal(k2, (shape[-1],), jnp.float32)
    want = ref.rmsnorm(x, s)
    got = rmsnorm(x, s, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dt))


def test_model_ref_consistency_rglru():
    """The model-layer associative scan equals the kernel oracle."""
    from repro.models.rglru import rglru_scan_ref
    k1, k2 = jax.random.split(KEY)
    a = jax.random.uniform(k1, (2, 64, 32), jnp.float32, 0.5, 0.999)
    b = jax.random.normal(k2, (2, 64, 32), jnp.float32)
    h_model, h_last = rglru_scan_ref(a, b)
    h_ref = ref.rglru_scan(a, b)
    np.testing.assert_allclose(np.asarray(h_model), np.asarray(h_ref),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h_ref[:, -1]),
                               rtol=3e-5, atol=3e-5)


def test_model_ref_consistency_mamba():
    """The model-layer chunked scan equals the sequential oracle."""
    from repro.models.mamba import selective_scan_ref
    k1, k2, k3 = jax.random.split(KEY, 3)
    a = jax.random.uniform(k1, (2, 64, 32, 8), jnp.float32, 0.5, 0.999)
    b = jax.random.normal(k2, (2, 64, 32, 8), jnp.float32) * 0.1
    C = jax.random.normal(k3, (2, 64, 8), jnp.float32)
    y_model, _ = selective_scan_ref(a, b, C, chunk=16)
    y_ref = ref.mamba_scan(a, b, C)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_ref),
                               rtol=2e-4, atol=3e-5)
