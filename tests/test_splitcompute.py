"""Split-compute engine: stage composition must equal the full model, the
φ-planner must respect legal split points, and the serve engine must
early-exit under congestion."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.models.common import slice_layers
from repro.models.transformer import embed_in, head_out, run_layers
from repro.splitcompute import (SplitServeEngine, plan_stages, split_points)


def test_stage_composition_equals_full_forward():
    """Running layers [0,k) then [k,L) must reproduce the full forward —
    the correctness property behind every vertical split (paper Fig. 1)."""
    cfg = reduced(get_config("qwen3-1.7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}

    logits_full, _, _ = model.forward(params, batch)

    h, positions = embed_in(params, cfg, batch)
    L = cfg.num_layers
    for (a, b) in [(0, 1), (1, L)]:
        sp = slice_layers(params["layers"], a, b)
        h, _, _ = run_layers(sp, cfg, h, positions, mode="train")
    logits_stages = head_out(params, cfg, h)
    np.testing.assert_allclose(np.asarray(logits_stages, np.float32),
                               np.asarray(logits_full, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_split_points_respect_family_granularity():
    dense = get_config("qwen3-4b")
    assert split_points(dense) == list(range(1, dense.num_layers))
    hyb = get_config("recurrentgemma-9b")
    pts = split_points(hyb)
    assert all(p % len(hyb.hybrid.pattern) == 0 for p in pts)
    assert max(pts) < hyb.num_layers


def test_plan_stages_proportional_to_phi():
    cfg = get_config("qwen3-1.7b")
    F = [100.0, 100.0, 800.0, 100.0]
    plan = plan_stages(cfg, F)
    assert plan.boundaries[0] == 0 and plan.boundaries[-1] == cfg.num_layers
    assert all(b2 > b1 for b1, b2 in zip(plan.boundaries, plan.boundaries[1:]))
    # strongest executor gets the first (and largest) stage
    sizes = np.diff(plan.boundaries)
    assert plan.executors[0] == 2
    assert sizes[0] == sizes.max()


def test_serve_engine_early_exits_under_burst():
    cfg = reduced(get_config("qwen3-1.7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    plan = plan_stages(cfg, [400.0, 420.0])
    eng = SplitServeEngine(cfg, params, plan, tau_med=0.5, tau_high=1.5)
    key = jax.random.PRNGKey(2)
    # burst: submit many requests with no service steps in between
    for r in range(12):
        key, k = jax.random.split(key)
        toks = jax.random.randint(k, (2, 16), 0, cfg.vocab_size)
        eng.submit({"tokens": toks}, 0.0)
        if r < 2:
            eng.step()
    stats = eng.drain()
    assert stats.completed == 12 * 2
    assert stats.exit_counts[1] + stats.exit_counts[2] > 0, \
        "congestion-aware early exit never fired under burst"
