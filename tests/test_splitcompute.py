"""Split-compute engine: stage composition must equal the full model, the
φ-planner must respect legal split points, and the serve engine must
early-exit under congestion."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.models.common import slice_layers
from repro.models.transformer import embed_in, head_out, run_layers
from repro.splitcompute import (SplitServeEngine, plan_stages, split_points)


def test_stage_composition_equals_full_forward():
    """Running layers [0,k) then [k,L) must reproduce the full forward —
    the correctness property behind every vertical split (paper Fig. 1)."""
    cfg = reduced(get_config("qwen3-1.7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}

    logits_full, _, _ = model.forward(params, batch)

    h, positions = embed_in(params, cfg, batch)
    L = cfg.num_layers
    for (a, b) in [(0, 1), (1, L)]:
        sp = slice_layers(params["layers"], a, b)
        h, _, _ = run_layers(sp, cfg, h, positions, mode="train")
    logits_stages = head_out(params, cfg, h)
    np.testing.assert_allclose(np.asarray(logits_stages, np.float32),
                               np.asarray(logits_full, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_split_points_respect_family_granularity():
    dense = get_config("qwen3-4b")
    assert split_points(dense) == list(range(1, dense.num_layers))
    hyb = get_config("recurrentgemma-9b")
    pts = split_points(hyb)
    assert all(p % len(hyb.hybrid.pattern) == 0 for p in pts)
    assert max(pts) < hyb.num_layers


def test_plan_stages_proportional_to_phi():
    cfg = get_config("qwen3-1.7b")
    F = [100.0, 100.0, 800.0, 100.0]
    plan = plan_stages(cfg, F)
    assert plan.boundaries[0] == 0 and plan.boundaries[-1] == cfg.num_layers
    assert all(b2 > b1 for b1, b2 in zip(plan.boundaries, plan.boundaries[1:], strict=False))
    # strongest executor gets the first (and largest) stage
    sizes = np.diff(plan.boundaries)
    assert plan.executors[0] == 2
    assert sizes[0] == sizes.max()


def test_serve_engine_early_exits_under_burst():
    cfg = reduced(get_config("qwen3-1.7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    plan = plan_stages(cfg, [400.0, 420.0])
    eng = SplitServeEngine(cfg, params, plan, tau_med=0.5, tau_high=1.5)
    key = jax.random.PRNGKey(2)
    # burst: submit many requests with no service steps in between
    for r in range(12):
        key, k = jax.random.split(key)
        toks = jax.random.randint(k, (2, 16), 0, cfg.vocab_size)
        eng.submit({"tokens": toks}, 0.0)
        if r < 2:
            eng.step()
    stats = eng.drain()
    assert stats.completed == 12 * 2
    assert stats.exit_counts[1] + stats.exit_counts[2] > 0, \
        "congestion-aware early exit never fired under burst"


# ---------------------------------------------------------------------------
# serve-engine pipeline semantics (regressions for the one-epoch-traversal
# and dropped-results/mixed-clock bugs)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_lm():
    cfg = reduced(get_config("qwen3-1.7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _toks(cfg, seed, batch=2, seq=16):
    return {"tokens": jax.random.randint(jax.random.PRNGKey(seed),
                                         (batch, seq), 0, cfg.vocab_size)}


def test_request_advances_at_most_one_stage_per_epoch(small_lm):
    """Regression: a forwarded request used to land at the head of an empty
    downstream queue and be popped again by the same step() loop, crossing
    the whole pipeline in one epoch."""
    cfg, _, params = small_lm
    plan = plan_stages(cfg, [400.0, 420.0])
    # thresholds far above any queue derivative: no early exit, so the
    # request must traverse every stage
    eng = SplitServeEngine(cfg, params, plan, tau_med=1e9, tau_high=2e9)
    eng.submit(_toks(cfg, 1))
    assert eng.step() == []                  # stage 0 → 1 only, not done
    assert len(eng.queues[1]) == 1
    done = eng.step()                        # stage 1 → head
    assert [rid for rid, _ in done] == [0]
    assert eng.stats.completed == 2


def test_downstream_queue_holds_work_between_epochs(small_lm):
    """Regression companion: each executor serves one request per epoch, so
    a saturated pipeline keeps one request *resident* in every downstream
    queue between epochs.  Before the epoch-snapshot fix the same step()
    loop drained a freshly forwarded request immediately — stage-1 depth
    read 0 at every epoch boundary and downstream congestion was
    structurally invisible."""
    cfg, _, params = small_lm
    plan = plan_stages(cfg, [400.0, 420.0])
    eng = SplitServeEngine(cfg, params, plan, tau_med=1e9, tau_high=2e9)
    depths = []
    for r in range(6):
        eng.submit(_toks(cfg, 10 + r))
        eng.step()
        depths.append(len(eng.queues[1]))
    assert depths[1:] == [1] * 5, \
        f"stage-1 queue empty at epoch boundaries (old semantics): {depths}"
    stats = eng.drain()
    assert stats.completed == 6 * 2


def test_exit_labels_fire_under_bursty_submit_load(small_lm):
    """Labels 1/2 must fire when bursty submissions outpace service —
    the Eq. 14-16 ladder observed through the serving pipeline."""
    cfg, _, params = small_lm
    plan = plan_stages(cfg, [400.0, 420.0])
    eng = SplitServeEngine(cfg, params, plan, tau_med=0.5, tau_high=1.5)
    for r in range(8):      # 2 arrivals per service epoch: queues grow
        eng.submit(_toks(cfg, 10 + r))
        eng.submit(_toks(cfg, 30 + r))
        eng.step()
    stats = eng.drain()
    assert stats.exit_counts[1] + stats.exit_counts[2] > 0, \
        "congestion labels never fired under bursty load"
    assert stats.completed == 16 * 2
    assert sum(stats.exit_counts.values()) == stats.completed


def test_step_returns_and_stashes_logits(small_lm):
    """Regression: step() used to compute completion logits and drop them.
    An uncongested request's logits must match the full forward pass."""
    cfg, model, params = small_lm
    plan = plan_stages(cfg, [400.0, 420.0])
    eng = SplitServeEngine(cfg, params, plan, tau_med=1e9, tau_high=2e9)
    batch = _toks(cfg, 3)
    rid = eng.submit(batch)
    done = []
    for _ in range(eng.n_stages):
        done += eng.step()
    assert [r for r, _ in done] == [rid] and rid in eng.results
    full, _, _ = model.forward(params, batch)
    np.testing.assert_allclose(np.asarray(done[0][1], np.float32),
                               np.asarray(full, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_serve_stats_deterministic_in_caller_clock(small_lm):
    """Latency is measured entirely in the caller's clock domain (the
    internal epoch clock here): no wall-clock reads, so two identical
    schedules produce identical ServeStats."""
    cfg, _, params = small_lm
    plan = plan_stages(cfg, [400.0, 420.0])

    def run():
        eng = SplitServeEngine(cfg, params, plan, tau_med=1e9, tau_high=2e9)
        eng.submit(_toks(cfg, 5))
        for _ in range(4):
            eng.step(dt=0.05)
        return eng.stats

    a, b = run(), run()
    # submitted at clock 0, completes on the 2nd 0.05 s epoch
    assert a.latency_sum == pytest.approx(2 * 0.05 * 2)   # ×batch of 2
    assert (a.completed, a.latency_sum, a.exit_counts) == \
           (b.completed, b.latency_sum, b.exit_counts)

    # an explicit simulated clock works the same way (t_now into step)
    eng = SplitServeEngine(cfg, params, plan, tau_med=1e9, tau_high=2e9)
    eng.submit(_toks(cfg, 6), t_now=100.0)
    eng.step(dt=0.05, t_now=100.2)
    done = eng.step(dt=0.05, t_now=100.4)
    assert len(done) == 1
    assert eng.stats.latency_sum == pytest.approx((100.4 - 100.0) * 2)
