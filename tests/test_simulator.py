"""Swarm simulator invariants + paper-claim checks (integration level)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SwarmConfig
from repro.swarm import (DISTRIBUTED, GREEDY, LOCAL_ONLY, RANDOM,
                         RANDOM_ACYCLIC, make_profile, run_many)

CFG = dataclasses.replace(SwarmConfig(), sim_time_s=20.0, num_workers=15)
KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def results():
    out = {}
    for s in (LOCAL_ONLY, RANDOM, RANDOM_ACYCLIC, GREEDY, DISTRIBUTED):
        out[s] = run_many(KEY, CFG, jnp.int32(s), 15, 6)
    return out


def test_task_conservation(results):
    """generated = completed + remaining-in-system + dropped (approximately:
    remaining is measured in GFLOPs, so convert via the task profile)."""
    profile = make_profile(CFG)
    for m in results.values():
        gen = np.asarray(m["generated"])
        done = np.asarray(m["completed"])
        drop = np.asarray(m["dropped"])
        rem_tasks = np.asarray(m["remaining_gflops"]) / profile.total_gflops
        # remaining GFLOPs undercounts partially-done tasks ⇒ inequality both
        # ways with a 1-task-per-node slack
        assert np.all(done + drop <= gen + 1e-3)
        assert np.all(gen - done - drop <= rem_tasks + CFG.num_workers + 1)


def test_local_only_never_transfers(results):
    assert float(np.max(np.asarray(results[LOCAL_ONLY]["transfers"]))) == 0.0


def test_energy_positive_and_accounted(results):
    for s, m in results.items():
        assert np.all(np.asarray(m["energy_total_j"]) > 0)
        if s == LOCAL_ONLY:
            # no transfers => no tx energy => lowest energy per processed task
            pass
    e_local = np.asarray(results[LOCAL_ONLY]["energy_per_task_j"]).mean()
    e_dist = np.asarray(results[DISTRIBUTED]["energy_per_task_j"]).mean()
    assert e_local <= e_dist + 1e-6   # paper Fig. 4e: LocalOnly cheapest


def test_fairness_in_unit_interval(results):
    for m in results.values():
        j = np.asarray(m["jain_fairness"])
        assert np.all((j > 0) & (j <= 1.0 + 1e-6))


def test_distributed_beats_local_under_load(results):
    """Paper Fig. 4: the diffusive method completes more work with lower
    latency than LocalOnly in the bursty default regime."""
    lat_d = float(np.asarray(results[DISTRIBUTED]["avg_latency_s"]).mean())
    lat_l = float(np.asarray(results[LOCAL_ONLY]["avg_latency_s"]).mean())
    rem_d = float(np.asarray(results[DISTRIBUTED]["remaining_gflops"]).mean())
    rem_l = float(np.asarray(results[LOCAL_ONLY]["remaining_gflops"]).mean())
    assert lat_d < lat_l
    assert rem_d < rem_l


def test_distributed_transfers_bounded(results):
    """One outgoing transfer per node at a time: transfers per node per
    decision epoch <= 1."""
    n_epochs = CFG.sim_time_s / CFG.decision_period_s
    tx = np.asarray(results[DISTRIBUTED]["transfers"])
    assert np.all(tx <= CFG.num_workers * n_epochs)


def test_early_exit_reduces_latency_and_accuracy():
    cfg_ee = dataclasses.replace(CFG, early_exit_enabled=True)
    m_off = run_many(KEY, CFG, jnp.int32(DISTRIBUTED), 15, 6)
    m_on = run_many(KEY, cfg_ee, jnp.int32(DISTRIBUTED), 15, 6)
    assert (np.asarray(m_on["avg_latency_s"]).mean()
            < np.asarray(m_off["avg_latency_s"]).mean())
    assert (np.asarray(m_on["avg_accuracy"]).mean()
            <= np.asarray(m_off["avg_accuracy"]).mean() + 1e-6)
    # with early exit off, completed tasks carry full accuracy
    np.testing.assert_allclose(np.asarray(m_off["avg_accuracy"]), 0.95,
                               atol=1e-3)


def test_channel_monotonicity():
    from repro.swarm.channel import capacity_bps, snr_db, two_ray_pathloss_db
    d = jnp.asarray([100.0, 1_000.0, 5_000.0, 20_000.0])
    pl = two_ray_pathloss_db(d, 100.0, 100.0)
    assert bool(jnp.all(jnp.diff(pl) > 0))          # loss grows with distance
    s = snr_db(d[None], SwarmConfig())
    assert bool(jnp.all(jnp.diff(s[0]) < 0))        # SNR falls
    c = capacity_bps(s, SwarmConfig())
    assert bool(jnp.all(jnp.diff(c[0]) < 0))        # capacity falls


def test_mobility_stays_on_circle():
    from repro.swarm.mobility import init_mobility, positions_at
    cfg = SwarmConfig()
    mob = init_mobility(jax.random.PRNGKey(3), cfg, 10)
    p0 = positions_at(mob, cfg, 0.0)
    p1 = positions_at(mob, cfg, 12.345)
    r0 = jnp.linalg.norm(p0 - mob["center"], axis=-1)
    r1 = jnp.linalg.norm(p1 - mob["center"], axis=-1)
    np.testing.assert_allclose(np.asarray(r0), cfg.movement_radius_m,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(r1), cfg.movement_radius_m,
                               rtol=1e-5)
    # speed check: arc length over dt
    dt = 0.1
    p2 = positions_at(mob, cfg, 12.345 + dt)
    v = jnp.linalg.norm(p2 - p1, axis=-1) / dt
    np.testing.assert_allclose(np.asarray(v), cfg.speed_mps, rtol=1e-3)


# ---------------------------------------------------------------------------
# RNG stream pin (swarmlint R001 audit, DESIGN.md §13)
# ---------------------------------------------------------------------------

# Exact float32 goldens (hex, lossless) for the default scenario after the
# init_state key fix: kf/km/k_fault now come from one split(key, 3) instead
# of split(key) + fold_in(key, 7).  Any change to the key derivations in
# init_state/_epoch — including "harmless" re-splits of the sites baselined
# in analysis_baseline.toml — moves these streams and must be deliberate:
# regenerate the table AND bump the result-store code version in the same
# change, or cached sweep points will silently alias the old streams.
_RNG_PIN = {
    LOCAL_ONLY: {
        "completed": "0x1.a820000000000p+11",
        "generated": "0x1.d340000000000p+11",
        "avg_latency_s": "0x1.1e0d940000000p+0",
        "energy_total_j": "0x1.9790d00000000p+9",
        "jain_fairness": "0x1.53a8000000000p-1",
        "transfers_delivered": "0x0.0p+0",
    },
    GREEDY: {
        "completed": "0x1.a860000000000p+11",
        "generated": "0x1.d340000000000p+11",
        "avg_latency_s": "0x1.1c29900000000p+0",
        "energy_total_j": "0x1.9856320000000p+9",
        "jain_fairness": "0x1.54d7600000000p-1",
        "transfers_delivered": "0x1.3000000000000p+4",
    },
    DISTRIBUTED: {
        "completed": "0x1.b500000000000p+11",
        "generated": "0x1.d340000000000p+11",
        "avg_latency_s": "0x1.003da40000000p+0",
        "energy_total_j": "0x1.b594980000000p+9",
        "jain_fairness": "0x1.5e2bf20000000p-1",
        "transfers_delivered": "0x1.1200000000000p+9",
    },
}


@pytest.mark.parametrize("strategy", sorted(_RNG_PIN))
def test_default_scenario_rng_pin(strategy):
    """Bit-identity golden for the default scenario's RNG streams.

    Referenced by analysis_baseline.toml and DESIGN.md §13.2: the R001
    baseline entries assert their key derivations are *deliberate*; this
    test is what makes that assertion checkable.  A failure here means a
    key derivation (or any traced arithmetic) changed the simulated
    numbers — never "fix" it by regenerating the goldens without also
    retiring the cached store entries (REPRO_CODE_VERSION / code bump).
    """
    from repro.swarm.simulator import run_sim
    m = jax.jit(lambda k: run_sim(k, CFG, jnp.int32(strategy),
                                  CFG.num_workers))(KEY)
    for k, hexval in _RNG_PIN[strategy].items():
        got = float(np.asarray(m[k]))
        assert got.hex() == hexval, (
            f"{k}: {got.hex()} != pinned {hexval} — RNG stream or traced "
            f"arithmetic moved (see DESIGN.md §13.2 before regenerating)")
