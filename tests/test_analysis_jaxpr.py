"""Tier-2 swarmlint (jaxpr rules J001–J005): mutation tests, fingerprint
semantics, SARIF emission, baseline pruning, and the CLI tier contract.

The J rules lint whatever ``targets.py`` traces — the *real* installed
``repro`` package — so the fixture-mini-repo pattern of tier 1 does not
transplant: a fixture tree cannot change what the registry imports.
Mutation tests instead: each rule gets a small local program carrying
exactly the defect (TP) and its closest correct idiom (TN), traced
through the same :func:`trace32_64` / :class:`TracedTarget` path the
registry uses, and fed to the rule function directly.  That proves the
rule *fires* (ISSUE acceptance: in-scan ``jnp.sum`` over N → J001,
``.astype("float64")`` → J002, leaked static arg → J005) independent of
the repo tree being clean.
"""
import importlib.util
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import JAXPR_RULE_IDS, RULE_DOCS, run
from repro.analysis.astutil import Finding
from repro.analysis.baseline import parse_baseline, prune_baseline_text
from repro.analysis.sarif import SARIF_VERSION, to_sarif

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(REPO, "tests", "analysis_fixtures")

try:
    import jax
    import jax.numpy as jnp
    HAVE_JAX = True
except Exception:                                    # pragma: no cover
    HAVE_JAX = False

needs_jax = pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")

if HAVE_JAX:
    from repro.analysis.jaxpr import fingerprint as fpmod
    from repro.analysis.jaxpr.fingerprint import (check_j005, fingerprint_fn,
                                                  group_fingerprints,
                                                  structural_signature,
                                                  sweep_fingerprint_table)
    from repro.analysis.jaxpr.jaxpr_util import trace32_64
    from repro.analysis.jaxpr.rules import (check_j001, check_j002,
                                            check_j003, check_j004)
    from repro.analysis.jaxpr.targets import TARGET_N, Target, TracedTarget


def _traced(fn, args, name="mut", n_axis=None):
    """Trace one local program through the registry's exact path and wrap
    it the way ``trace_targets`` would — the rules' input contract."""
    if n_axis is None:
        n_axis = TARGET_N
    t = Target(name, "sim", lambda: (fn, args), n_axis=n_axis)
    j32, j64, err = trace32_64(fn, *args)
    return {name: TracedTarget(t, j32, j64, err)}


def _cli(*argv, cwd=REPO):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *argv],
        capture_output=True, text=True, cwd=cwd, env=env)


# ---------------------------------------------------------------------------
# J001 — in-scan cross-node float reductions
# ---------------------------------------------------------------------------


@needs_jax
def test_j001_true_positive_in_scan_float_sum():
    """The ISSUE's canonical mutation: a float ``jnp.sum`` collapsing the
    N axis inside a scan body must raise J001."""
    def body(carry, x):                  # x: [N] float32
        s = jnp.sum(x)                   # cross-node collapse, in scan
        return carry + s, s

    def fn(xs):
        return jax.lax.scan(body, jnp.float32(0.0), xs)

    xs = jnp.ones((5, TARGET_N), jnp.float32)
    found = list(check_j001(_traced(fn, (xs,)), REPO))
    assert len(found) == 1
    f = found[0]
    assert f.rule == "J001"
    assert "reduce_sum" in f.message
    assert "mut" in f.message            # names the target it traced via


@needs_jax
def test_j001_true_negative_exact_and_integer_reductions():
    """max (exact in any association order) and integer sums are
    whitelisted, and per-node [N, N] → [N] aggregations keep the axis."""
    def body(carry, x):                  # x: [N, N] float32
        per_node = jnp.sum(x, axis=1)    # keeps an N-sized output axis
        worst = jnp.max(x)               # exact reduction
        hits = jnp.sum((x > 0).astype(jnp.int32))   # integer accumulation
        return carry, (per_node, worst, hits)

    def fn(xs):
        return jax.lax.scan(body, jnp.float32(0.0), xs)

    xs = jnp.ones((3, TARGET_N, TARGET_N), jnp.float32)
    assert list(check_j001(_traced(fn, (xs,)), REPO)) == []


@needs_jax
def test_j001_outside_scan_is_allowed():
    """The same collapse *outside* the scan (summarize-style) is the
    prescribed fix, not a finding."""
    def fn(xs):
        carry, ys = jax.lax.scan(
            lambda c, x: (c + 1, x * 2.0), jnp.int32(0), xs)
        return jnp.sum(ys)               # post-scan reduce: fine

    xs = jnp.ones((4, TARGET_N), jnp.float32)
    assert list(check_j001(_traced(fn, (xs,)), REPO)) == []


@needs_jax
def test_j001_skips_targets_without_n_axis():
    """n_axis=None opts a target out (the executor wrappers)."""
    def body(c, x):
        return c + jnp.sum(x), jnp.sum(x)

    def fn(xs):
        return jax.lax.scan(body, jnp.float32(0.0), xs)

    xs = jnp.ones((5, TARGET_N), jnp.float32)
    traced = _traced(fn, (xs,))
    traced["mut"].n_axis = None
    assert list(check_j001(traced, REPO)) == []


# ---------------------------------------------------------------------------
# J002 — x32/x64 dtype drift
# ---------------------------------------------------------------------------


@needs_jax
@pytest.mark.filterwarnings("ignore::UserWarning")   # the truncation warn
def test_j002_true_positive_astype_float64():
    """The ISSUE's canonical mutation: an ``astype("float64")`` literal
    traces f32 under x32 but f64 under x64 — signature drift."""
    def fn(x):
        return x.astype("float64") * 2.0

    found = list(check_j002(_traced(fn, (jnp.ones(3, jnp.float32),)), REPO))
    assert any("dtype drift" in f.message for f in found)
    assert all(f.rule == "J002" for f in found)


@needs_jax
def test_j002_true_positive_weak_output():
    """A python scalar reaching the outputs is weak-typed — its dtype is
    promotion-context-dependent."""
    def fn(x):
        return jnp.sum(x), 2.0 * 1.5     # second output: weak python float

    found = list(check_j002(_traced(fn, (jnp.ones(3, jnp.float32),)), REPO))
    assert any("weak-typed output" in f.message for f in found)


@needs_jax
def test_j002_true_negative_pinned_dtypes():
    def fn(x):
        return x * jnp.float32(2.0) + jnp.zeros((), jnp.float32)

    assert list(check_j002(_traced(fn, (jnp.ones(3, jnp.float32),)),
                           REPO)) == []


# ---------------------------------------------------------------------------
# J003 — masking-mode gather/scatter must carry an `# oob:` annotation
# ---------------------------------------------------------------------------

_J003_SRC = textwrap.dedent("""\
    import jax.numpy as jnp


    def unannotated(x, idx):
        return x.at[idx].get(mode="clip")


    def annotated(x, idx):
        # oob: clip is deliberate — padded neighbor slots point past N
        return x.at[idx].get(mode="clip")
""")


@needs_jax
def _j003_traced(tmp_path, func_name):
    """Materialize the J003 module under a ``src/repro/`` tree so the
    traced equations anchor to repo-relative files (source_site maps on
    the ``/src/repro/`` marker), then trace one of its functions."""
    pkg = tmp_path / "src" / "repro"
    pkg.mkdir(parents=True, exist_ok=True)
    mod_path = pkg / "j003_mod.py"
    mod_path.write_text(_J003_SRC)
    spec = importlib.util.spec_from_file_location("j003_mod", str(mod_path))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    fn = getattr(mod, func_name)
    args = (jnp.ones(TARGET_N, jnp.float32),
            jnp.array([0, 5, 99], jnp.int32))
    return _traced(fn, args, name=f"j003_{func_name}")


@needs_jax
def test_j003_true_positive_unannotated_clip(tmp_path):
    found = list(check_j003(_j003_traced(tmp_path, "unannotated"),
                            str(tmp_path)))
    assert len(found) == 1
    f = found[0]
    assert f.rule == "J003"
    assert f.file == os.path.join("src", "repro", "j003_mod.py")
    assert f.line > 0                    # anchored to a real source line
    assert "CLIP" in f.message


@needs_jax
def test_j003_true_negative_annotated_clip(tmp_path):
    found = list(check_j003(_j003_traced(tmp_path, "annotated"),
                            str(tmp_path)))
    assert found == []


# ---------------------------------------------------------------------------
# J004 — closure-constant bloat
# ---------------------------------------------------------------------------


@needs_jax
def test_j004_true_positive_big_closed_const():
    big = jnp.zeros((600, 600), jnp.float32)      # 1.44 MB > 1 MiB cap

    def fn(x):
        return x + big[0, 0] + jnp.sum(big)

    found = list(check_j004(_traced(fn, (jnp.ones(3, jnp.float32),)), REPO))
    assert len(found) == 1
    assert found[0].rule == "J004"
    assert "closure-constant bloat" in found[0].message


@needs_jax
def test_j004_true_negative_small_consts():
    small = jnp.zeros((TARGET_N,), jnp.float32)

    def fn(x):
        return x + jnp.sum(small)

    assert list(check_j004(_traced(fn, (jnp.ones(3, jnp.float32),)),
                           REPO)) == []


# ---------------------------------------------------------------------------
# J005 — compile-fingerprint stability
# ---------------------------------------------------------------------------


@needs_jax
def test_fingerprint_abstracts_literal_values():
    """Data differences must vanish: same program shape with different
    literal values shares a fingerprint; a different shape does not."""
    x = jnp.ones(3, jnp.float32)
    fp_a = fingerprint_fn(lambda v: v * 2.0, x)
    fp_b = fingerprint_fn(lambda v: v * 3.5, x)
    fp_c = fingerprint_fn(lambda v: v * 2.0 + 1.0, x)
    assert fp_a == fp_b
    assert fp_a != fp_c


@needs_jax
def test_fingerprint_abstracts_closed_const_values():
    a = jnp.arange(4, dtype=jnp.float32)
    b = jnp.arange(4, dtype=jnp.float32) * 7.0
    fp_a = fingerprint_fn(lambda v: v + a, jnp.ones(4, jnp.float32))
    fp_b = fingerprint_fn(lambda v: v + b, jnp.ones(4, jnp.float32))
    assert fp_a == fp_b


@needs_jax
def test_structural_signature_splits_data_from_structure():
    from repro.configs.base import SwarmConfig
    from repro.fleet.sweep import SweepSpec
    base = SwarmConfig(num_workers=13, sim_time_s=1.0, num_runs=2)
    spec = SweepSpec.build("sig", base, axes={"gamma": (0.01, 0.05)},
                           strategies=(0, 4), num_runs=2)
    sigs = {structural_signature(p) for p in spec.expand()}
    # gamma is data-like and strategy stays traced: all 4 points share
    # one signature (so J005 groups them and compares programs)
    assert len(sigs) == 1
    # a num_runs change is a legitimately different experiment
    spec8 = SweepSpec.build("sig8", base, axes={"gamma": (0.01,)},
                            num_runs=8)
    assert structural_signature(spec8.expand()[0]) not in sigs
    # structural floats (scan trip counts) split the signature too
    import dataclasses
    longer = dataclasses.replace(base, sim_time_s=2.0)
    spec_t = SweepSpec.build("sigt", longer, axes={"gamma": (0.01,)},
                             num_runs=2)
    assert structural_signature(spec_t.expand()[0]) not in sigs


@needs_jax
def test_group_fingerprints_verdicts():
    sig = (("n", 13), ("num_runs", 2))
    rows = [(sig, "a", "fp1"), (sig, "b", "fp1"), (sig, "c", "fp2"),
            ((("n", 26),), "d", "fp3")]
    groups = {len(g["points"]): g for g in group_fingerprints(rows)}
    big, lone = groups[3], groups[1]
    assert not big["stable"] and big["distinct_programs"] == 2
    assert sorted(big["programs"]["fp1"]) == ["a", "b"]
    assert lone["stable"] and lone["distinct_programs"] == 1


@needs_jax
def test_j005_true_negative_data_only_sweep_is_stable():
    """A real data-only sweep over the real simulator: every point must
    trace the same program (this is the invariant CI's fingerprint step
    gates; a failure here means a static arg leaked into ``run_sim``)."""
    from repro.configs.base import SwarmConfig
    from repro.fleet.sweep import SweepSpec
    base = SwarmConfig(num_workers=13, sim_time_s=1.0, num_runs=2)
    spec = SweepSpec.build("tn_gamma", base, axes={"gamma": (0.01, 0.05)},
                           strategies=(4,), num_runs=2)
    table = sweep_fingerprint_table(spec)
    assert table["stable"]
    assert table["distinct_programs"] == 1
    assert table["unstable_groups"] == []
    assert set(table["points"]) == {p.label for p in spec.expand()}


@needs_jax
def test_j005_true_positive_leaked_static_arg(monkeypatch):
    """The ISSUE's canonical mutation: emulate a sweep whose data-like
    axis leaks into program structure (fingerprint depends on gamma) and
    require check_j005 to name the instability.  The leak is injected at
    the point_fingerprint seam — the exact signal a host-side
    ``if gamma > x:`` branch in run_sim would produce."""
    from repro.configs.base import SwarmConfig
    from repro.fleet.sweep import SweepSpec
    base = SwarmConfig(num_workers=13, sim_time_s=1.0, num_runs=2)
    leaky = SweepSpec.build("leaky", base, axes={"gamma": (0.01, 0.05)},
                            strategies=(4,), num_runs=2)
    monkeypatch.setattr(fpmod, "_standin_specs", lambda: [leaky])
    monkeypatch.setattr(fpmod, "point_fingerprint",
                        lambda p: f"leak-{p.cfg.gamma}")
    found = list(check_j005({}, REPO))
    assert len(found) == 1
    f = found[0]
    assert f.rule == "J005"
    assert f.file == "src/repro/fleet/sweep.py"
    assert f.symbol == "sweep:leaky"
    assert "2 distinct programs" in f.message


@needs_jax
def test_sweep_fingerprint_table_caps_points(monkeypatch):
    from repro.configs.base import SwarmConfig
    from repro.fleet.sweep import SweepSpec
    base = SwarmConfig(num_workers=13, sim_time_s=1.0, num_runs=2)
    spec = SweepSpec.build("cap", base,
                           axes={"gamma": (0.01, 0.02, 0.05)}, num_runs=2)
    monkeypatch.setattr(fpmod, "point_fingerprint", lambda p: "fp")
    table = sweep_fingerprint_table(spec, max_points=2)
    assert table["skipped_points"] == 1
    assert len(table["points"]) == 2


# ---------------------------------------------------------------------------
# the shipped tree is clean under the jaxpr tier (CI's --tier all gate)
# ---------------------------------------------------------------------------


@needs_jax
def test_repo_tree_is_clean_under_jaxpr_tier():
    """`--tier jaxpr` over the committed tree: zero findings beyond the
    baseline — the tier-2 half of the CI lint gate, self-applied."""
    findings = run(REPO, tier="jaxpr")
    assert findings == [], "\n".join(
        f"{f.file}:{f.line}: {f.rule} [{f.symbol}] {f.message}"
        for f in findings)


# ---------------------------------------------------------------------------
# SARIF emission (--format sarif, uploaded to code scanning by CI)
# ---------------------------------------------------------------------------


def test_sarif_document_shape():
    findings = [
        Finding("R001", "src/repro/a.py", 12, "f:key", "key reuse"),
        Finding("J002", "src/repro/analysis/jaxpr/targets.py", 0,
                "sim_dense", "dtype drift"),
    ]
    docs = {"R001": RULE_DOCS["R001"], "J002": RULE_DOCS["J002"]}
    doc = to_sarif(findings, docs, "/repo")
    assert doc["version"] == SARIF_VERSION
    (run_,) = doc["runs"]
    assert run_["tool"]["driver"]["name"] == "swarmlint"
    assert [r["id"] for r in run_["tool"]["driver"]["rules"]] == \
        ["J002", "R001"]
    assert run_["originalUriBaseIds"]["SRCROOT"]["uri"] == "file:///repo/"
    r1, r2 = run_["results"]
    assert r1["ruleId"] == "R001"
    loc = r1["locations"][0]["physicalLocation"]
    assert loc["artifactLocation"]["uri"] == "src/repro/a.py"
    assert loc["artifactLocation"]["uriBaseId"] == "SRCROOT"
    assert loc["region"]["startLine"] == 12
    # program-level findings (line 0) pin to SARIF's 1-based minimum
    assert r2["locations"][0]["physicalLocation"]["region"]["startLine"] == 1
    assert "[sim_dense]" in r2["message"]["text"]


def test_sarif_clean_run_still_declares_rules():
    doc = to_sarif([], {rid: RULE_DOCS[rid] for rid in JAXPR_RULE_IDS},
                   "/repo")
    run_ = doc["runs"][0]
    assert run_["results"] == []
    assert len(run_["tool"]["driver"]["rules"]) == len(JAXPR_RULE_IDS)


def test_sarif_cli_emits_valid_json():
    res = _cli("--root", os.path.join(FIXTURES, "r001_tn"),
               "--tier", "ast", "--format", "sarif", "--no-baseline")
    assert res.returncode == 0, res.stderr
    doc = json.loads(res.stdout)
    assert doc["version"] == SARIF_VERSION
    assert doc["runs"][0]["tool"]["driver"]["name"] == "swarmlint"


# ---------------------------------------------------------------------------
# baseline pruning (--prune-baseline)
# ---------------------------------------------------------------------------

_BASELINE_TEXT = """\
# keep this comment
[[allow]]
rule = "R001"
file = "src/repro/live.py"
symbol = "f:key"
reason = "still fires"

[[allow]]
rule = "J001"
file = "src/repro/dead.py"
symbol = "gone"
reason = "the finding was fixed"

[[digest_exempt]]
field = "label"
reason = "presentation only"
"""


def test_prune_baseline_text_drops_only_dead_entries_of_run_rules():
    live = {("R001", "src/repro/live.py", "f:key")}
    new, dropped = prune_baseline_text(_BASELINE_TEXT, live,
                                       ["R001", "J001"])
    assert dropped == [("J001", "src/repro/dead.py", "gone")]
    bl = parse_baseline(new)
    assert bl.allows_ == (("R001", "src/repro/live.py", "f:key"),)
    assert bl.digest_exempt == {"label": "presentation only"}
    assert "# keep this comment" in new


def test_prune_baseline_text_keeps_entries_of_rules_not_run():
    """A dead J001 entry cannot be proven dead by an ast-only run."""
    new, dropped = prune_baseline_text(_BASELINE_TEXT, set(), ["R001"])
    assert dropped == [("R001", "src/repro/live.py", "f:key")]
    bl = parse_baseline(new)
    assert ("J001", "src/repro/dead.py", "gone") in bl.allows_


def test_prune_baseline_cli_roundtrip(tmp_path):
    """`--prune-baseline` rewrites the file in place and reports drops;
    run against a copy of a fixture tree with a synthetic baseline."""
    import shutil
    root = tmp_path / "repo"
    shutil.copytree(os.path.join(FIXTURES, "r001_tn"), root)
    (root / "analysis_baseline.toml").write_text(_BASELINE_TEXT)
    res = _cli("--root", str(root), "--tier", "ast", "--prune-baseline")
    assert res.returncode == 0, res.stderr
    assert "pruned dead baseline entry: R001" in res.stdout
    bl = parse_baseline((root / "analysis_baseline.toml").read_text())
    # the J001 entry survived: its rule did not run under --tier ast
    assert bl.allows_ == (("J001", "src/repro/dead.py", "gone"),)


# ---------------------------------------------------------------------------
# CLI tier selection contract
# ---------------------------------------------------------------------------


def test_cli_rules_infer_their_tier():
    res = _cli("--root", os.path.join(FIXTURES, "r001_tn"),
               "--rules", "R001", "--no-baseline")
    assert res.returncode == 0, res.stderr
    assert "swarmlint[ast]" in res.stdout


def test_cli_rejects_rules_outside_explicit_tier():
    res = _cli("--root", os.path.join(FIXTURES, "r001_tn"),
               "--rules", "J001", "--tier", "ast")
    assert res.returncode == 2
    assert "tier" in res.stderr


def test_cli_rejects_unknown_rules():
    res = _cli("--root", os.path.join(FIXTURES, "r001_tn"),
               "--rules", "J999")
    assert res.returncode == 2
    assert "unknown rules" in res.stderr


def test_cli_list_rules_covers_both_tiers():
    res = _cli("--list-rules")
    assert res.returncode == 0
    for rid in JAXPR_RULE_IDS:
        assert f"{rid}  [jaxpr]" in res.stdout
    assert "R001  [ast]" in res.stdout
