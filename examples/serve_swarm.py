"""End-to-end driver (the paper's kind: distributed inference serving).

A small LM is partitioned at vertical split points by the diffusive
φ-metric over a fleet of heterogeneous executors, then serves batched
requests; a mid-run burst triggers the congestion-aware early exit
(Eqs. 14-16), visibly trading exit depth for latency — the complete paper
mechanism driving real model execution.

    PYTHONPATH=src python examples/serve_swarm.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.splitcompute import SplitServeEngine, plan_stages


def main():
    cfg = reduced(get_config("qwen3-4b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # heterogeneous fleet (paper Table 2: capability ~ N(400, 100) GFLOP/s)
    rng = np.random.default_rng(7)
    F = np.maximum(rng.normal(400, 100, 4), 50.0)
    # link delay per unit workload (s/GFLOP) — the d_tx term of Eq. 10
    d_tx = rng.uniform(1e-4, 1e-3, (4, 4))
    plan = plan_stages(cfg, F, d_tx)
    print("fleet capability (GFLOP/s):", np.round(F, 1).tolist())
    print("aggregated capability φ   :", np.round(plan.phi, 1).tolist())
    print("stage boundaries:", plan.boundaries,
          "→ executors:", plan.executors)

    eng = SplitServeEngine(cfg, params, plan, tau_med=0.5, tau_high=1.5)
    key = jax.random.PRNGKey(1)

    # submit/step both use the engine's internal epoch clock (no t_now), so
    # latency is measured in one clock domain and the run is deterministic
    def submit(n):
        nonlocal key
        for _ in range(n):
            key, k = jax.random.split(key)
            toks = jax.random.randint(k, (4, 32), 0, cfg.vocab_size)
            eng.submit({"tokens": toks})

    # steady phase: requests trickle in, engine keeps up → full-depth exits
    print("\n-- steady phase --")
    for _ in range(8):
        submit(1)
        done = eng.step()
        for rid, logits in done:
            print(f"  request {rid} done: logits {tuple(logits.shape)}")
    steady = dict(eng.stats.exit_counts)

    # burst phase: the event-triggered surge of Fig. 1 → early exits fire
    print("-- burst phase (congestion) --")
    submit(24)
    stats = eng.drain()
    print(f"\nserved {stats.completed} sequences, "
          f"avg latency {stats.avg_latency*1e3:.1f} epoch-ms, "
          f"{len(eng.results)} logits tensors stashed")
    print("exit depth counts  0=full 1=medium 2=high:", stats.exit_counts)
    burst_exits = (stats.exit_counts[1] + stats.exit_counts[2]
                   - steady[1] - steady[2])
    print(f"early exits triggered by the burst: {burst_exits}")
    assert stats.completed > 0 and len(eng.results) == 8 + 24


if __name__ == "__main__":
    main()
