"""Quickstart: build an assigned architecture (reduced), train a few steps,
then prefill + decode — the whole public API in one file.

    PYTHONPATH=src python examples/quickstart.py [--arch qwen3-1.7b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, reduced
from repro.data import DataConfig, batch_at
from repro.launch.step import init_train_state, make_train_step
from repro.models import build_model
from repro.models.common import count_params
from repro.optim import OptConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    print(f"arch={args.arch} family={cfg.family} (reduced for CPU)")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"params: {count_params(params):,}")

    # --- train a few steps on the synthetic pipeline -----------------------
    if cfg.family in ("vlm", "encdec"):
        print("quickstart trains token-LM families; see tests for "
              f"{cfg.family} coverage")
    else:
        step = jax.jit(make_train_step(model, OptConfig(lr=3e-3,
                                                        warmup_steps=5,
                                                        total_steps=200)),
                       donate_argnums=(0,))
        state = init_train_state(model, jax.random.PRNGKey(0))
        dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=8)
        t0 = time.time()
        for s in range(args.steps):
            state, metrics = step(state, batch_at(dcfg, s))
            if s % 5 == 0 or s == args.steps - 1:
                print(f"  step {s:3d} loss {float(metrics['loss']):.4f}")
        print(f"trained {args.steps} steps in {time.time()-t0:.1f}s")
        params = state.params

        # --- decode a continuation (replay prompt, then sample greedily) ---
        prompt = batch_at(dcfg, 999)["tokens"][:2, :16]
        caches = model.init_cache(2, 32)
        logits = None
        for t in range(16):
            logits, caches = model.decode_step(
                params, caches, {"token": prompt[:, t:t + 1],
                                 "pos": jnp.int32(t)})
        out = [int(x) for x in jnp.argmax(logits, -1)]
        for t in range(16, 24):
            nxt = jnp.argmax(logits, -1)[:, None]
            logits, caches = model.decode_step(
                params, caches, {"token": nxt, "pos": jnp.int32(t)})
        print("decoded 8 tokens greedily — public API round trip OK")


if __name__ == "__main__":
    main()
