"""Elastic scaling demo: checkpoint under one device topology, restore under
another, and continue training bit-compatibly (the fleet shrank or grew —
deliverable: elastic scaling + checkpoint/restart).

Runs as a parent process that launches two children with different
simulated device counts (jax fixes the device count at first init):

    PYTHONPATH=src python examples/elastic_rescale.py
"""
import json
import os
import subprocess
import sys
import tempfile

CHILD = r"""
import os, sys, json
n_dev, ckpt, phase = int(sys.argv[1]), sys.argv[2], sys.argv[3]
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.configs import get_config, reduced
from repro.models import build_model
from repro.launch.mesh import shardings
from repro.launch.step import init_train_state, make_train_step, TrainState
from repro.optim import OptConfig, opt_specs
from repro.checkpoint import save, restore, latest_step
from repro.data import DataConfig, batch_at

mesh = jax.make_mesh((n_dev // 2, 2), ("data", "model"))
cfg = reduced(get_config("qwen3-1.7b"))
model = build_model(cfg, mesh=mesh)
opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=20)
dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4)
step_fn = jax.jit(make_train_step(model, opt))

def specs():
    ps = model.specs()
    return TrainState(ps, opt_specs(ps))

if phase == "start":
    state = init_train_state(model, jax.random.PRNGKey(0))
    start = 0
else:
    like = init_train_state(model, jax.random.PRNGKey(0))
    state, man = restore(ckpt, like, mesh=mesh,
                         specs=jax.tree.map(lambda s: s, specs(),
                                            is_leaf=lambda x: isinstance(x, P)))
    start = man["step"]

with mesh:
    sh = shardings(specs(), mesh, state)
    state = jax.device_put(state, sh)
    loss = None
    for s in range(start, start + 10):
        state, metrics = step_fn(state, batch_at(dcfg, s))
        loss = float(metrics["loss"])
save(ckpt, start + 10, jax.device_get(state))
print(json.dumps({"devices": n_dev, "mesh": str(mesh.shape),
                  "from": start, "to": start + 10, "loss": loss}))
"""


def run_child(n_dev, ckpt, phase):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), os.pardir,
                                       "src"))
    r = subprocess.run([sys.executable, "-c", CHILD, str(n_dev), ckpt,
                        phase], capture_output=True, text=True, env=env,
                       timeout=600)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])
    out = json.loads(r.stdout.strip().splitlines()[-1])
    print(f"  {out['devices']} devices, mesh {out['mesh']}: steps "
          f"{out['from']}→{out['to']}, loss {out['loss']:.4f}")
    return out


def main():
    ckpt = tempfile.mkdtemp(prefix="repro_elastic_")
    print("phase 1: train 10 steps on 8 devices (4×2 mesh)")
    a = run_child(8, ckpt, "start")
    print("phase 2: fleet shrinks — resume on 4 devices (2×2 mesh)")
    b = run_child(4, ckpt, "resume")
    print("phase 3: fleet grows — resume on 16 devices (8×2 mesh)")
    c = run_child(16, ckpt, "resume")
    assert b["from"] == 10 and c["from"] == 20
    assert c["loss"] < a["loss"], "loss should keep improving across rescales"
    print("elastic rescale OK: checkpoints re-shard across mesh shapes")


if __name__ == "__main__":
    main()
