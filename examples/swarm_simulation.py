"""Run the paper's UAV-swarm simulation head-to-head: all five offloading
strategies at 30 workers, with and without congestion-aware early exit.

Scenario selection is pure config, and the Monte-Carlo batch executes
through the fleet engine — e.g. random-waypoint mobility over a log-normal
channel with node churn, Monte-Carlo axis sharded over host devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/swarm_simulation.py --num-runs 16 \
        --backend sharded \
        --mobility random_waypoint --channel log_normal --fault markov

``--backend streaming`` caps memory at one swarm state per chunk (the
N >= 1k regime); all backends are bit-identical (DESIGN.md §8).

``--procs N`` goes one level up: the strategy sweep becomes a SweepSpec
dispatched across N worker *processes* through ``repro.fleet.dispatch``
(lease-file work stealing over a shared store, DESIGN.md §9) — same
numbers, point axis parallel.

``--trace out.json`` additionally runs one per-task-telemetry simulation
of the Distributed strategy (``repro.trace``, DESIGN.md §10): prints the
task-level latency CDF / hop / exit-label indices plus the hop-resolved
transfer decomposition, and writes a Chrome-trace/Perfetto timeline with
one slice + flow arrow per *hop* (queue-wait tails on the visited nodes'
tracks) — load it at https://ui.perfetto.dev or chrome://tracing.
``--trace-hops 0`` drops back to task records only (net src→dst arrows).
``--trace-state EVERY`` additionally turns on the per-epoch flight
recorder for that run: prints the φ-convergence summary and adds Perfetto
*counter tracks* (per-UAV φ / queue depth / energy, swarm-level
aggregates) to the same timeline file.
"""
import argparse
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SwarmConfig
from repro.fleet import (BACKENDS, ResultStore, SweepSpec, dispatch,
                         run_batch)
from repro.swarm import STRATEGY_NAMES


def show(tag, m):
    print(f"  {tag:14s} latency={np.mean(m['avg_latency_s']):7.3f}s  "
          f"remaining={np.mean(m['remaining_gflops']):9.1f} GF  "
          f"jain={np.mean(m['jain_fairness']):.3f}  "
          f"E/task={np.mean(m['energy_per_task_j']):.3f} J  "
          f"acc={np.mean(m['avg_accuracy']):.3f}  "
          f"FOM={np.mean(m['fom']):9.1f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--num-runs", "--runs", dest="num_runs", type=int,
                    default=8, help="Monte-Carlo runs per strategy")
    ap.add_argument("--workers", type=int, default=30)
    ap.add_argument("--sim-time", type=float, default=50.0)
    ap.add_argument("--backend", default="vmap", choices=BACKENDS,
                    help="fleet executor backend (bit-identical; sharded "
                         "splits runs over devices, streaming bounds memory)")
    ap.add_argument("--chunk-size", type=int, default=8,
                    help="runs per chunk for --backend streaming")
    ap.add_argument("--procs", type=int, default=1,
                    help="dispatch the strategy sweep across this many "
                         "worker processes (repro.fleet.dispatch)")
    ap.add_argument("--store", default=None,
                    help="shared store root for --procs > 1 "
                         "(default: a temp dir)")
    from repro.swarm import CHANNEL_MODELS, FAULT_MODELS, MOBILITY_MODELS
    ap.add_argument("--mobility", default="circular",
                    choices=sorted(MOBILITY_MODELS))
    ap.add_argument("--channel", default="two_ray",
                    choices=sorted(CHANNEL_MODELS))
    ap.add_argument("--fault", default="none", choices=sorted(FAULT_MODELS))
    ap.add_argument("--trace", default=None, metavar="OUT_JSON",
                    help="run one traced Distributed simulation and write "
                         "a Chrome-trace/Perfetto timeline here")
    ap.add_argument("--trace-capacity", type=int, default=65536,
                    help="TaskRecord slots for --trace (records beyond "
                         "this count as overflow)")
    ap.add_argument("--trace-hops", type=int, default=65536,
                    metavar="CAPACITY",
                    help="HopRecord slots for --trace (one record per "
                         "delivered transfer; 0 disables the hop stream "
                         "and falls back to net src->dst arrows)")
    ap.add_argument("--trace-state", type=int, default=0, metavar="EVERY",
                    help="flight recorder for --trace: sample the swarm "
                         "state every EVERY epochs (0 disables) — prints "
                         "the φ-convergence summary and adds Perfetto "
                         "counter tracks to the timeline")
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    cfg = dataclasses.replace(SwarmConfig(), num_workers=args.workers,
                              sim_time_s=args.sim_time,
                              mobility_model=args.mobility,
                              channel_model=args.channel,
                              fault_model=args.fault)
    print(f"{args.workers} UAVs, {args.sim_time:.0f}s, {args.num_runs} runs "
          f"(backend={args.backend}, {len(jax.devices())} device(s), "
          f"{args.procs} proc(s)), "
          "bursty Markov arrivals (60 ms mean), scenario="
          f"{args.mobility}/{args.channel}/fault:{args.fault}")

    cfg_ee = dataclasses.replace(cfg, early_exit_enabled=True)

    if args.trace:
        from repro.trace import (decode, decode_hops, decode_state,
                                 hop_indices, state_indices, trace_indices,
                                 write_chrome_trace)
        cfg_tr = dataclasses.replace(cfg,
                                     trace_capacity=args.trace_capacity,
                                     trace_hop_capacity=args.trace_hops,
                                     trace_state_every=args.trace_state)
        m = run_batch(key, cfg_tr, jnp.int32(4), args.workers, 1)
        dec = decode(np.asarray(m["trace_records"]),
                     np.asarray(m["trace_overflow"]))
        idx = trace_indices(dec)
        print(f"\nper-task telemetry (Distributed, 1 run, "
              f"capacity {args.trace_capacity}):")
        print(f"  tasks={idx['task_count']} dropped={idx['dropped_count']} "
              f"overflow={idx['trace_overflow']}")
        if idx["task_latency_cdf_s"] is not None:
            cdf = idx["task_latency_cdf_s"]
            print(f"  latency p50={cdf['p50']:.3f}s p95={cdf['p95']:.3f}s "
                  f"p99={cdf['p99']:.3f}s  "
                  f"jain={idx['task_latency_jain']:.3f}")
            print(f"  hops={idx['hop_histogram']} "
                  f"exits={idx['exit_label_histogram']}")
        hdec = None
        if args.trace_hops > 0:
            hdec = decode_hops(np.asarray(m["trace_hops"]),
                               np.asarray(m["trace_hop_overflow"]))
            hix = hop_indices(hdec, tick_s=cfg_tr.tick_s)
            print(f"  hop records={hix['hop_count']} over {hix['link_count']}"
                  f" links, stalled={hix['stalled_hop_count']} "
                  f"overflow={hix['hop_overflow']}")
            if hix["hop_transfer_time_s_quantiles"] is not None:
                ht = hix["hop_transfer_time_s_quantiles"]
                qw = hix["hop_queue_wait_s_quantiles"]
                print(f"  hop time p50={ht['p50']:.3f}s p95={ht['p95']:.3f}s"
                      f"  queue-wait p95={qw['p95']:.3f}s")
        sdec = None
        if args.trace_state > 0:
            sdec = decode_state(np.asarray(m["trace_state"]),
                                np.asarray(m["trace_state_sys"]),
                                np.asarray(m["trace_state_epochs"]))
            six = state_indices(sdec)
            eps = six["phi_epochs_to_eps"]
            print(f"  flight recorder: {six['state_sample_count']} samples "
                  f"(every {args.trace_state}), "
                  f"phi->5% at epoch {eps if eps is not None else 'n/a'}, "
                  f"queue jain final={six['queue_jain_final']}, "
                  f"energy={six['energy_drain_j_curve'][-1]:.1f} J")
        path = write_chrome_trace(args.trace, dec, hdec, cfg_tr.tick_s,
                                  state=sdec)
        print(f"wrote {path} "
              "(open in chrome://tracing or ui.perfetto.dev)")

    if args.procs > 1:
        # two specs — the five plain strategies, then Distributed+EE (a
        # different config) — dispatched over a shared store; workers
        # claim points by lease and steal from dead peers
        store = ResultStore(args.store or
                            tempfile.mkdtemp(prefix="repro_fleet_"))
        spec = SweepSpec.build(
            "swarm_example", cfg, strategies=range(len(STRATEGY_NAMES)),
            num_runs=args.num_runs)
        res = dispatch(spec, store, workers=args.procs,
                       backend=args.backend, chunk_size=args.chunk_size,
                       progress_path=os.path.join(store.root,
                                                  "progress.jsonl"))
        spec_ee = SweepSpec.build("swarm_example_ee", cfg_ee,
                                  strategies=(4,), num_runs=args.num_runs)
        res_ee = dispatch(spec_ee, store, workers=args.procs,
                          backend=args.backend, chunk_size=args.chunk_size)
        print(f"\n(dispatched over {args.procs} processes, "
              f"store={store.root})")
        print("\nno early exit (paper Fig. 4 regime):")
        for pt in spec.expand():
            show(STRATEGY_NAMES[pt.strategy], res[pt.label])
        print("\nDistributed + congestion-aware early exit (Fig. 7):")
        (pt_ee,) = spec_ee.expand()
        show("Distributed+EE", res_ee[pt_ee.label])
        return

    def batch(cfg, s):
        m = run_batch(key, cfg, jnp.int32(s), args.workers, args.num_runs,
                      backend=args.backend, chunk_size=args.chunk_size)
        return {k: np.asarray(v) for k, v in m.items()}

    print("\nno early exit (paper Fig. 4 regime):")
    for s, name in enumerate(STRATEGY_NAMES):
        show(name, batch(cfg, s))

    print("\nDistributed + congestion-aware early exit (Fig. 7):")
    show("Distributed+EE", batch(cfg_ee, 4))


if __name__ == "__main__":
    main()
