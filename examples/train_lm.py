"""Train a small LM for a few hundred steps with the full production stack:
synthetic data pipeline, AdamW + cosine schedule, sharding-aware step
builder, checkpoint/restart driver with an injected failure (the run dies
at step 120 and resumes from the step-100 checkpoint — final state is
identical to an uninterrupted run).

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import shutil
import tempfile
import time

import jax

from repro.configs import get_config, reduced
from repro.data import DataConfig, batch_at
from repro.launch.step import init_train_state, make_train_step
from repro.models import build_model
from repro.models.common import count_params
from repro.optim import OptConfig
from repro.runtime import DriverConfig, run_with_restarts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = reduced(get_config("qwen3-1.7b"))
    model = build_model(cfg)
    print(f"training {cfg.name}: "
          f"{count_params(model.init(jax.random.PRNGKey(0))):,} params, "
          f"{args.steps} steps")

    opt = OptConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)
    train_step = jax.jit(make_train_step(model, opt), donate_argnums=(0,))

    ckpt = tempfile.mkdtemp(prefix="repro_train_")
    losses = []
    t0 = time.time()

    def on_metrics(step, m):
        losses.append(float(m["loss"]))
        if step % 25 == 0:
            print(f"  step {step:4d} loss {losses[-1]:.4f} "
                  f"({time.time()-t0:.0f}s)")

    drv = DriverConfig(ckpt_dir=ckpt, ckpt_every=100, max_steps=args.steps,
                       fail_at_step=min(120, args.steps - 1))
    print("(failure injected at step 120 — the driver restarts from the "
          "step-100 checkpoint)")
    run_with_restarts(
        drv, init_state=lambda: init_train_state(model,
                                                 jax.random.PRNGKey(0)),
        train_step=train_step, batch_fn=lambda s: batch_at(dcfg, s),
        on_metrics=on_metrics)

    first, last = losses[0], sum(losses[-10:]) / 10
    print(f"loss: {first:.3f} → {last:.3f} "
          f"({'improved' if last < first else 'NOT improved'})")
    shutil.rmtree(ckpt, ignore_errors=True)
    assert last < first, "training did not reduce loss"


if __name__ == "__main__":
    main()
