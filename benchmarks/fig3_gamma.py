"""Paper Fig. 3: γ sensitivity — average latency vs outstanding workload."""
from __future__ import annotations

import os

from benchmarks.common import (ART, DEFAULT_RUNS, ci95, fleet_sweep,
                               write_csv)
from repro.configs.base import SwarmConfig
from repro.fleet import SweepSpec
from repro.swarm import DISTRIBUTED


def spec(gammas=(0.002, 0.01, 0.02, 0.05, 0.1, 0.3), n=30,
         runs=DEFAULT_RUNS) -> SweepSpec:
    """The Fig. 3 grid itself — importable without executing it (the
    fingerprint recorder traces these points, benchmarks/fingerprints.py)."""
    return SweepSpec.build("fig3_gamma", SwarmConfig(num_workers=n),
                           axes={"gamma": tuple(gammas)},
                           strategies=(DISTRIBUTED,), num_runs=runs)


def run(gammas=(0.002, 0.01, 0.02, 0.05, 0.1, 0.3), n=30, runs=DEFAULT_RUNS):
    sp = spec(gammas, n, runs)
    res = fleet_sweep(sp)
    if not res:
        return []    # non-zero rank of a multi-host dispatch: worker only
    rows = []
    for pt in sp.expand():
        m, g = res[pt.label], pt.values["gamma"]
        lat, lat_ci = ci95(m["avg_latency_s"])
        rem, rem_ci = ci95(m["remaining_gflops"])
        tx, _ = ci95(m["transfers"])
        rows.append([g, f"{lat:.6g}", f"{lat_ci:.3g}", f"{rem:.6g}",
                     f"{rem_ci:.3g}", f"{tx:.1f}"])
        print(f"γ={g:<6} latency={lat:.4g}s rem={rem:.5g} transfers={tx:.0f}")
    write_csv(os.path.join(ART, "fig3_gamma.csv"),
              "gamma,latency_s,latency_ci,remaining_gflops,remaining_ci,"
              "transfers", rows)
    return rows


if __name__ == "__main__":
    run()
