"""Paper Fig. 3: γ sensitivity — average latency vs outstanding workload."""
from __future__ import annotations

import dataclasses
import os

import jax.numpy as jnp

from benchmarks.common import ART, DEFAULT_RUNS, ci95, timed_sweep, write_csv
from repro.configs.base import SwarmConfig
from repro.swarm import DISTRIBUTED


def run(gammas=(0.002, 0.01, 0.02, 0.05, 0.1, 0.3), n=30, runs=DEFAULT_RUNS):
    rows = []
    for g in gammas:
        cfg = dataclasses.replace(SwarmConfig(num_workers=n), gamma=g)
        m = timed_sweep(cfg, [DISTRIBUTED], n, runs)["Distributed"]
        lat, lat_ci = ci95(m["avg_latency_s"])
        rem, rem_ci = ci95(m["remaining_gflops"])
        tx, _ = ci95(m["transfers"])
        rows.append([g, f"{lat:.6g}", f"{lat_ci:.3g}", f"{rem:.6g}",
                     f"{rem_ci:.3g}", f"{tx:.1f}"])
        print(f"γ={g:<6} latency={lat:.4g}s rem={rem:.5g} transfers={tx:.0f}")
    write_csv(os.path.join(ART, "fig3_gamma.csv"),
              "gamma,latency_s,latency_ci,remaining_gflops,remaining_ci,"
              "transfers", rows)
    return rows


if __name__ == "__main__":
    run()
