"""Paper Fig. 6: latency / remaining GFLOPs / FOM vs mission-area size."""
from __future__ import annotations

import os

from benchmarks.common import (ART, DEFAULT_RUNS, ci95, fleet_sweep,
                               write_csv)
from repro.configs.base import SwarmConfig
from repro.fleet import SweepSpec
from repro.swarm import DISTRIBUTED, LOCAL_ONLY, STRATEGY_NAMES


def run(areas_km=(10, 20, 30, 40), n=30, runs=DEFAULT_RUNS):
    spec = SweepSpec.build(
        "fig6_area", SwarmConfig(num_workers=n),
        axes={"area_km": tuple((a, {"area_m": a * 1000.0})
                               for a in areas_km)},
        strategies=(LOCAL_ONLY, DISTRIBUTED), num_runs=runs)
    res = fleet_sweep(spec)
    if not res:
        return []    # non-zero rank of a multi-host dispatch: worker only
    rows = []
    for pt in spec.expand():
        m, a = res[pt.label], pt.values["area_km"]
        name = STRATEGY_NAMES[pt.strategy]
        lat, lat_ci = ci95(m["avg_latency_s"])
        rem, rem_ci = ci95(m["remaining_gflops"])
        fom, fom_ci = ci95(m["fom"])
        rows.append([a, name, f"{lat:.6g}", f"{lat_ci:.3g}",
                     f"{rem:.6g}", f"{rem_ci:.3g}", f"{fom:.6g}",
                     f"{fom_ci:.3g}"])
        print(f"area={a}km {name:14s} lat={lat:.4g} rem={rem:.5g} "
              f"fom={fom:.5g}")
    write_csv(os.path.join(ART, "fig6_area.csv"),
              "area_km,strategy,latency_s,latency_ci,remaining_gflops,"
              "remaining_ci,fom,fom_ci", rows)
    return rows


if __name__ == "__main__":
    run()
