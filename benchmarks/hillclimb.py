"""§Perf hillclimb driver: re-cost selected cells under config variants
(hypothesis → change → re-lower → re-analyse), tagging each artifact.

    PYTHONPATH=src python -m benchmarks.hillclimb --cell qwen3-moe-30b-a3b:train_4k \
        --variant dots --variant bf16gather --variant dots+bf16gather+losschunk
"""
# ruff: noqa: I001  (deliberate order: dryrun's XLA_FLAGS preamble first)
from __future__ import annotations


# must run through dryrun's XLA_FLAGS preamble
from repro.launch import dryrun  # noqa: E402  (sets device count first)

import argparse        # noqa: E402
import dataclasses     # noqa: E402
import os              # noqa: E402

from repro.configs import get_config  # noqa: E402

VARIANTS = {
    # paper-faithful baseline = untagged artifact from the sweep
    "dots": dict(remat_policy="dots"),
    "noremat": dict(remat_policy="none"),
    "bf16gather": dict(cast_weights_bf16=True),
    "losschunk": dict(loss_chunk=512),
    "attnchunk2k": dict(attn_chunk=2048),
    "nofsdpserve": dict(serve_param_fsdp=False),
    "puredp": dict(pure_dp=True),
}


def variant_cfg(arch: str, spec: str):
    cfg = get_config(arch)
    kw = {}
    for part in spec.split("+"):
        if part == "chunkremat":
            cfg = dataclasses.replace(
                cfg, ssm=dataclasses.replace(cfg.ssm, chunk_remat=True))
        else:
            kw.update(VARIANTS[part])
    return dataclasses.replace(cfg, **kw)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", action="append", required=True,
                    help="arch:shape")
    ap.add_argument("--variant", action="append", required=True,
                    help="'+'-joined keys from VARIANTS")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    out = os.path.join(os.path.normpath(dryrun.ARTIFACT_DIR), "single")
    for cell in args.cell:
        arch, shape = cell.split(":")
        for v in args.variant:
            cfg = variant_cfg(arch, v)
            rec = dryrun.run_cell(arch, shape, "single", out,
                                  force=args.force, cfg_override=cfg,
                                  tag=f"@{v}")
            if rec["status"] == "OK":
                r = rec["roofline"]
                print(f"  {cell}@{v}: dom={r['dominant']} "
                      f"comp={r['compute_s']*1e3:.1f}ms "
                      f"mem={r['memory_s']*1e3:.1f}ms "
                      f"coll={r['collective_s']*1e3:.1f}ms "
                      f"useful={rec['useful_flop_ratio']:.3f}")


if __name__ == "__main__":
    main()
