"""Kernel-path microbenchmarks (CPU ref path; µs/call).  The Pallas kernels
themselves target TPU — interpret-mode timings are not meaningful, so this
times the dispatch path the models actually execute here.

``run_phi_sweep`` additionally sweeps the diffusive-φ reduction across swarm
sizes (jnp reference vs interpret-mode Pallas, which checks the kernel's
lowering at size rather than its speed) and records the rows into
``artifacts/BENCH_fleet.json`` — the seed of the φ wall-clock trajectory the
ROADMAP tracks toward TPU numbers at N ≥ 1k.  ``REPRO_BENCH_FAST=1`` keeps
the sweep to N = 256 (interpret mode is minutes-slow at N = 4096).
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp

from repro.kernels import ref

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def bench(fn, *args, iters=5):
    # one warm-up call (block_until_ready handles tuple outputs as pytrees);
    # interpret-mode Pallas fns re-execute per call, so never call twice here
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    key = jax.random.PRNGKey(0)
    rows = []

    q = jax.random.normal(key, (1, 512, 8, 64), jnp.float32)
    kv = jax.random.normal(key, (1, 512, 2, 64), jnp.float32)
    fa = jax.jit(lambda q, k, v: ref.flash_attention(q, k, v))
    rows.append(("flash_attention_ref_512", bench(fa, q, kv, kv),
                 "B1_S512_H8_kv2_hd64"))

    qd = jax.random.normal(key, (4, 8, 64), jnp.float32)
    kd = jax.random.normal(key, (4, 4096, 2, 64), jnp.float32)
    da = jax.jit(lambda q, k, v: ref.decode_attention(q, k, v, 4095))
    rows.append(("decode_attention_ref_4k", bench(da, qd, kd, kd),
                 "B4_S4096"))

    a = jax.random.uniform(key, (2, 1024, 256), jnp.float32, 0.5, 0.99)
    b = jax.random.normal(key, (2, 1024, 256), jnp.float32)
    rg = jax.jit(ref.rglru_scan)
    rows.append(("rglru_scan_ref_1k", bench(rg, a, b), "B2_S1024_W256"))

    am = jax.random.uniform(key, (1, 256, 512, 16), jnp.float32, 0.5, 0.99)
    bm = jax.random.normal(key, (1, 256, 512, 16), jnp.float32) * 0.1
    Cm = jax.random.normal(key, (1, 256, 16), jnp.float32)
    ms = jax.jit(ref.mamba_scan)
    rows.append(("mamba_scan_ref_256", bench(ms, am, bm, Cm),
                 "B1_S256_D512_N16"))

    F = jax.random.uniform(key, (8, 256), jnp.float32, 100, 500)
    dtx = jnp.where(jax.random.bernoulli(key, 0.3, (8, 256, 256)),
                    1e-3, -1e30)
    dp = jax.jit(ref.diffusive_phi)
    rows.append(("diffusive_phi_ref_256", bench(dp, 1.0 / F, F, dtx),
                 "R8_N256"))

    x = jax.random.normal(key, (4096, 1024), jnp.float32)
    s = jnp.ones((1024,))
    rn = jax.jit(ref.rmsnorm)
    rows.append(("rmsnorm_ref_4k", bench(rn, x, s), "R4096_D1024"))

    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


def _phi_inputs(key, n, runs_axis):
    kF, kA = jax.random.split(jax.random.fold_in(key, n))
    F = jax.random.uniform(kF, (runs_axis, n), jnp.float32, 100, 500)
    dtx = jnp.where(jax.random.bernoulli(kA, 0.3, (runs_axis, n, n)),
                    1e-3, -1e30)
    return 1.0 / F, F, dtx


def run_phi_wallclock(ns=(1024, 4096), runs_axis=1, iters=3,
                      out_json=None):
    """Backend-tagged wall-clock of the φ path the simulator dispatches.

    Times ``kernels.ops.diffusive_phi`` — the entry point ``run_sim``
    executes, i.e. the jnp reference on CPU and the real Pallas kernel on
    TPU — and records ``{n, backend, us_per_call}`` rows into
    ``BENCH_fleet.json`` under ``microbench_diffusive_phi_wallclock``.
    On this container the rows are the CPU seed of the ROADMAP's
    TPU-trajectory item; the same command on a TPU host appends
    directly comparable ``backend="tpu"`` numbers.  Rank-0 guarded: a
    non-zero fleet rank would race the BENCH read-modify-write and record
    an arbitrary host's clock.
    """
    from repro.fleet import worker_env, write_bench_json
    from repro.kernels import ops

    if worker_env().rank != 0:
        return []
    backend = jax.default_backend()
    key = jax.random.PRNGKey(0)
    rows = []
    for n in ns:
        inv_phi, F, dtx = _phi_inputs(key, n, runs_axis)
        us = bench(jax.jit(ops.diffusive_phi), inv_phi, F, dtx, iters=iters)
        rows.append({"n": int(n), "runs_axis": int(runs_axis),
                     "backend": backend, "us_per_call": round(us, 1)})
        print(f"diffusive_phi_dispatch_n{n},{us:.1f},{backend}_R{runs_axis}")
    out_json = out_json or os.path.join(ART, "BENCH_fleet.json")
    write_bench_json(out_json, "microbench_diffusive_phi_wallclock", rows)
    print(f"wrote {out_json} (microbench_diffusive_phi_wallclock, "
          f"{len(rows)} sizes, backend={backend})")
    return rows


def run_phi_sparse_wallclock(ns=(1024, 4096, 16384, 65536), k=16,
                             dense_ns=(1024, 4096), interpret_ns=(256,),
                             iters=3,
                             out_json=None):
    """Sparse neighbor-list φ path at scale (DESIGN.md §11).

    Times the epoch-update pipeline the sparse simulator dispatches —
    spatial-hash neighbor-list build (its own row), then per-edge channel
    + gather-based φ update over the [N, K] lists — and, where the dense
    [N, N] path still fits in memory, the dense pipeline on the same
    positions for a direct crossover row.  One ``kernel_interpret`` row
    per ``interpret_ns`` size checks the sparse Pallas kernel's lowering
    (ref vs interpret parity timing, not a perf number).  Rows land under
    ``microbench_diffusive_phi_sparse`` in ``BENCH_fleet.json``; rank-0
    guarded like the other producers.
    """
    import dataclasses

    from repro.configs.base import SwarmConfig
    from repro.core.diffusive import phi_update_op, phi_update_op_sparse
    from repro.fleet import worker_env, write_bench_json
    from repro.kernels.diffusive_phi import \
        diffusive_phi_sparse as pl_phi_sparse
    from repro.swarm.channel import link_state, link_state_sparse
    from repro.swarm.neighbors import neighbor_lists
    from repro.swarm.tasks import make_profile

    if worker_env().rank != 0:
        return []
    backend = jax.default_backend()
    key = jax.random.PRNGKey(0)
    rows = []
    for n in ns:
        cfg = dataclasses.replace(SwarmConfig(), neighbor_mode="sparse",
                                  neighbor_k=k)
        bpg = make_profile(cfg).bits_per_gflop
        kp, kf = jax.random.split(jax.random.fold_in(key, n))
        pos = jax.random.uniform(kp, (n, 2), jnp.float32, 0.0, cfg.area_m)
        F = jax.random.uniform(kf, (n,), jnp.float32, 100, 500)

        build = jax.jit(lambda p, cfg=cfg: neighbor_lists(p, cfg))
        build_us = bench(build, pos, iters=iters)
        rows.append({"stage": "neighbor_build", "n": int(n), "k": int(k),
                     "backend": backend, "us_per_call": round(build_us, 1)})
        nbr, valid = build(pos)

        @jax.jit
        def sparse_epoch(pos, nbr, valid, phi, F, cfg=cfg, bpg=bpg):
            adj, cap = link_state_sparse(pos, nbr, valid, cfg)
            dtx = jnp.where(adj, bpg / cap, 1e30)
            return phi_update_op_sparse(phi, F, adj, nbr, dtx)

        phi_us = bench(sparse_epoch, pos, nbr, valid, F, F, iters=iters)
        rows.append({"stage": "epoch_sparse", "n": int(n), "k": int(k),
                     "backend": backend, "us_per_call": round(phi_us, 1)})
        print(f"diffusive_phi_sparse_n{n},{build_us:.1f},build_k{k}")
        print(f"diffusive_phi_sparse_n{n},{phi_us:.1f},epoch_k{k}")

        if n in dense_ns:
            @jax.jit
            def dense_epoch(pos, phi, F, cfg=cfg, bpg=bpg):
                adj, cap = link_state(pos, cfg)
                dtx = jnp.where(adj, bpg / cap, 1e30)
                return phi_update_op(phi, F, adj, dtx)

            dense_us = bench(dense_epoch, pos, F, F, iters=iters)
            rows.append({"stage": "epoch_dense", "n": int(n), "k": int(k),
                         "backend": backend,
                         "us_per_call": round(dense_us, 1)})
            print(f"diffusive_phi_sparse_n{n},{dense_us:.1f},dense")

    for n in interpret_ns:
        kk = jax.random.split(jax.random.fold_in(key, 10_000 + n), 5)
        F = jax.random.uniform(kk[0], (1, n), jnp.float32, 100, 500)
        nbr = jax.random.randint(kk[1], (1, n, k), 0, n)
        ok = jax.random.bernoulli(kk[2], 0.6, (1, n, k))
        dtx = jnp.where(ok, 1e-3, -1e30)
        ref_us = bench(jax.jit(ref.diffusive_phi_sparse), 1.0 / F, F, dtx,
                       nbr, iters=iters)
        pal_us = bench(lambda a, b, c, d: pl_phi_sparse(a, b, c, d,
                                                        interpret=True),
                       1.0 / F, F, dtx, nbr, iters=1)
        rows.append({"stage": "kernel_interpret", "n": int(n), "k": int(k),
                     "ref_us": round(ref_us, 1),
                     "pallas_interpret_us": round(pal_us, 1)})
        print(f"diffusive_phi_sparse_kernel_n{n},{ref_us:.1f},ref")
        print(f"diffusive_phi_sparse_kernel_n{n},{pal_us:.1f},"
              f"pallas_interpret")
    out_json = out_json or os.path.join(ART, "BENCH_fleet.json")
    write_bench_json(out_json, "microbench_diffusive_phi_sparse", rows)
    print(f"wrote {out_json} (microbench_diffusive_phi_sparse, "
          f"{len(rows)} rows, backend={backend})")
    return rows


def run_trace_overhead(ns=(1024, 4096), sim_time_s=4.0, queue_slots=8,
                       iters=2,
                       out_json=None):
    """Per-epoch cost of each telemetry stream on the full simulator.

    Times one ``run_sim`` call per variant — tracing off, the task stream,
    task + hop streams, and the flight recorder at stride 1 and 16 — at
    swarm sizes ``ns``, and records ``{n, variant, n_epochs, backend,
    us_per_call, us_per_epoch}`` rows under
    ``microbench_trace_overhead`` in ``BENCH_fleet.json``.  The deltas
    between variants are the streams' marginal cost (the ``off`` row is
    the baseline the zero-cost-when-off claim is judged against).
    Rank-0 guarded like the other BENCH producers.
    """
    import dataclasses

    from repro.configs.base import SwarmConfig
    from repro.fleet import worker_env, write_bench_json
    from repro.swarm import run_sim

    if worker_env().rank != 0:
        return []
    backend = jax.default_backend()
    key = jax.random.PRNGKey(0)
    variants = (
        ("off", {}),
        ("tasks", {"trace_capacity": 4096}),
        ("tasks+hops", {"trace_capacity": 4096,
                        "trace_hop_capacity": 4096}),
        ("state_s1", {"trace_state_every": 1}),
        ("state_s16", {"trace_state_every": 16}),
    )
    rows = []
    for n in ns:
        for name, over in variants:
            cfg = dataclasses.replace(SwarmConfig(),
                                      sim_time_s=float(sim_time_s),
                                      queue_slots=int(queue_slots), **over)
            n_epochs = int(round(cfg.sim_time_s / cfg.decision_period_s))
            fn = jax.jit(lambda k, cfg=cfg, n=n:
                         run_sim(k, cfg, jnp.int32(0), n))
            us = bench(fn, key, iters=iters)
            rows.append({"n": int(n), "variant": name,
                         "n_epochs": n_epochs, "backend": backend,
                         "us_per_call": round(us, 1),
                         "us_per_epoch": round(us / n_epochs, 1)})
            print(f"trace_overhead_n{n},{us:.1f},{name}")
    out_json = out_json or os.path.join(ART, "BENCH_fleet.json")
    write_bench_json(out_json, "microbench_trace_overhead", rows)
    print(f"wrote {out_json} (microbench_trace_overhead, {len(rows)} rows, "
          f"backend={backend})")
    return rows


def run_phi_sweep(ns=(256, 1024, 4096), runs_axis=1, iters=2,
                  out_json=None,
                  wallclock_ns=(1024, 4096)):
    """diffusive_phi at swarm scale: jnp reference vs Pallas interpret mode.

    Returns the recorded rows; also written to ``BENCH_fleet.json`` under
    ``microbench_diffusive_phi``, plus the dispatch-path wall-clock rows
    of :func:`run_phi_wallclock` (``wallclock_ns=()`` skips them).
    """
    from repro.fleet.report import write_bench_json
    from repro.kernels.diffusive_phi import diffusive_phi as pl_phi

    key = jax.random.PRNGKey(0)
    rows = []
    for n in ns:
        inv_phi, F, dtx = _phi_inputs(key, n, runs_axis)
        ref_us = bench(jax.jit(ref.diffusive_phi), inv_phi, F, dtx,
                       iters=iters)
        # interpret=True compiles + emulates the TPU kernel on CPU — a
        # lowering-at-scale check, not a performance number (that needs TPU)
        it = 1 if n >= 4096 else iters
        pal_us = bench(lambda a, b, c: pl_phi(a, b, c, interpret=True),
                       inv_phi, F, dtx, iters=it)
        row = {"n": int(n), "runs_axis": int(runs_axis),
               "ref_us": round(ref_us, 1),
               "pallas_interpret_us": round(pal_us, 1)}
        rows.append(row)
        print(f"diffusive_phi_n{n},{ref_us:.1f},ref_R{runs_axis}")
        print(f"diffusive_phi_n{n},{pal_us:.1f},pallas_interpret_R{runs_axis}")
    out_json = out_json or os.path.join(ART, "BENCH_fleet.json")
    write_bench_json(out_json, "microbench_diffusive_phi", rows)
    print(f"wrote {out_json} (microbench_diffusive_phi, {len(rows)} sizes)")
    if wallclock_ns:
        run_phi_wallclock(ns=wallclock_ns, runs_axis=runs_axis,
                          out_json=out_json)
    return rows


if __name__ == "__main__":
    fast = os.environ.get("REPRO_BENCH_FAST") == "1"
    run()
    run_phi_sweep(ns=(256,) if fast else (256, 1024, 4096))
    if fast:
        run_phi_sparse_wallclock(ns=(256,), k=8, dense_ns=(256,),
                                 interpret_ns=(128,))
        run_trace_overhead(ns=(256,), sim_time_s=1.0, iters=1)
    else:
        run_phi_sparse_wallclock()
        run_trace_overhead()
