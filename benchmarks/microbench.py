"""Kernel-path microbenchmarks (CPU ref path; µs/call).  The Pallas kernels
themselves target TPU — interpret-mode timings are not meaningful, so this
times the dispatch path the models actually execute here."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref


def bench(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    key = jax.random.PRNGKey(0)
    rows = []

    q = jax.random.normal(key, (1, 512, 8, 64), jnp.float32)
    kv = jax.random.normal(key, (1, 512, 2, 64), jnp.float32)
    fa = jax.jit(lambda q, k, v: ref.flash_attention(q, k, v))
    rows.append(("flash_attention_ref_512", bench(fa, q, kv, kv),
                 "B1_S512_H8_kv2_hd64"))

    qd = jax.random.normal(key, (4, 8, 64), jnp.float32)
    kd = jax.random.normal(key, (4, 4096, 2, 64), jnp.float32)
    da = jax.jit(lambda q, k, v: ref.decode_attention(q, k, v, 4095))
    rows.append(("decode_attention_ref_4k", bench(da, qd, kd, kd),
                 "B4_S4096"))

    a = jax.random.uniform(key, (2, 1024, 256), jnp.float32, 0.5, 0.99)
    b = jax.random.normal(key, (2, 1024, 256), jnp.float32)
    rg = jax.jit(ref.rglru_scan)
    rows.append(("rglru_scan_ref_1k", bench(rg, a, b), "B2_S1024_W256"))

    am = jax.random.uniform(key, (1, 256, 512, 16), jnp.float32, 0.5, 0.99)
    bm = jax.random.normal(key, (1, 256, 512, 16), jnp.float32) * 0.1
    Cm = jax.random.normal(key, (1, 256, 16), jnp.float32)
    ms = jax.jit(ref.mamba_scan)
    rows.append(("mamba_scan_ref_256", bench(ms, am, bm, Cm),
                 "B1_S256_D512_N16"))

    F = jax.random.uniform(key, (8, 256), jnp.float32, 100, 500)
    dtx = jnp.where(jax.random.bernoulli(key, 0.3, (8, 256, 256)),
                    1e-3, -1e30)
    dp = jax.jit(ref.diffusive_phi)
    rows.append(("diffusive_phi_ref_256", bench(dp, 1.0 / F, F, dtx),
                 "R8_N256"))

    x = jax.random.normal(key, (4096, 1024), jnp.float32)
    s = jnp.ones((1024,))
    rn = jax.jit(ref.rmsnorm)
    rows.append(("rmsnorm_ref_4k", bench(rn, x, s), "R4096_D1024"))

    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    run()
