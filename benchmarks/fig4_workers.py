"""Paper Fig. 4: all six metrics vs number of workers (10-50), 5 strategies."""
from __future__ import annotations

import dataclasses
import os

from benchmarks.common import (ART, DEFAULT_RUNS, ci95, fleet_sweep,
                               write_csv)
from repro.configs.base import SwarmConfig
from repro.fleet import SweepSpec
from repro.swarm import STRATEGY_NAMES

METRICS = ["avg_latency_s", "remaining_gflops", "avg_transfer_time_s",
           "jain_fairness", "energy_per_task_j", "fom"]


def run(workers=(10, 20, 30, 40, 50), runs=DEFAULT_RUNS, sim_time=None):
    base = SwarmConfig()
    if sim_time:
        base = dataclasses.replace(base, sim_time_s=sim_time)
    spec = SweepSpec.build("fig4_workers", base,
                           axes={"num_workers": tuple(workers)},
                           strategies=tuple(range(5)), num_runs=runs)
    res = fleet_sweep(spec)
    if not res:
        return []    # non-zero rank of a multi-host dispatch: worker only
    rows = []
    for pt in spec.expand():
        m, n = res[pt.label], pt.values["num_workers"]
        name = STRATEGY_NAMES[pt.strategy]
        row = [n, name]
        for k in METRICS:
            mean, half = ci95(m[k])
            row += [f"{mean:.6g}", f"{half:.3g}"]
        rows.append(row)
        print(f"N={n:3d} {name:14s} " + " ".join(
            f"{k.split('_')[0][:4]}={ci95(m[k])[0]:.4g}" for k in METRICS))
    hdr = "workers,strategy," + ",".join(
        f"{k},{k}_ci95" for k in METRICS)
    write_csv(os.path.join(ART, "fig4_workers.csv"), hdr, rows)
    return rows


if __name__ == "__main__":
    run()
