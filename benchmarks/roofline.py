"""§Roofline table: reads the dry-run JSON artifacts and renders the
three-term analysis per (arch × shape) on the single-pod mesh, plus the
multi-pod compile census."""
from __future__ import annotations

import json
import os

from benchmarks.common import ART, write_csv

DRY = os.path.join(ART, "dryrun")


def load(mesh: str):
    d = os.path.join(DRY, mesh)
    recs = []
    if not os.path.isdir(d):
        return recs
    for f in sorted(os.listdir(d)):
        if f.endswith(".json"):
            r = json.load(open(os.path.join(d, f)))
            # §Perf variants are tagged '<arch>__<shape>@<variant>.json'
            if "@" in f:
                r = dict(r, shape=r["shape"] + "@" + f.split("@")[1][:-5])
            recs.append(r)
    return recs


def run():
    rows = []
    for rec in load("single"):
        if rec["status"] == "SKIP":
            rows.append([rec["arch"], rec["shape"], "SKIP", "", "", "", "",
                         "", "", rec["reason"][:60]])
            continue
        if rec["status"] != "OK":
            rows.append([rec["arch"], rec["shape"], "FAIL", "", "", "", "",
                         "", "", rec.get("error", "")[:60]])
            continue
        r = rec["roofline"]
        rows.append([
            rec["arch"], rec["shape"], "OK",
            f"{r['compute_s']:.4g}", f"{r['memory_s']:.4g}",
            f"{r['collective_s']:.4g}", r["dominant"],
            f"{rec['useful_flop_ratio']:.3f}",
            f"{rec['memory'].get('peak_estimate_bytes', 0) / 2**30:.2f}",
            "",
        ])
    write_csv(os.path.join(ART, "roofline.csv"),
              "arch,shape,status,compute_s,memory_s,collective_s,dominant,"
              "useful_flop_ratio,peak_gib_per_dev,note", rows)

    multi = load("multi")
    ok = sum(r["status"] == "OK" for r in multi)
    skip = sum(r["status"] == "SKIP" for r in multi)
    fail = [r for r in multi if r["status"] == "FAIL"]
    print(f"multi-pod: {ok} OK, {skip} SKIP, {len(fail)} FAIL "
          f"of {len(multi)}")
    for r in fail:
        print("  FAIL:", r["arch"], r["shape"], r.get("error", "")[:100])
    return rows


if __name__ == "__main__":
    run()
