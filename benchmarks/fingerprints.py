"""Record the J005 compile-fingerprint tables of the paper's figure
sweeps into BENCH_fleet.json — without executing the sweeps.

``jax.make_jaxpr`` traces a point's whole program but compiles nothing,
so fingerprinting the full Fig. 3 / Fig. 5 grids costs seconds where
running them costs minutes.  The tables land in the ``fingerprints``
BENCH section (the same one ``fleet_sweep`` maintains as a side effect of
real runs, benchmarks/common.py), keyed by sweep name; perf_gate.py reads
them to say *which point started recompiling* when an execute span
regresses (DESIGN.md §15.3).

``--check`` turns instability into exit 1: if any same-structural-
signature group of points traces distinct programs, a config field that
should be traced data has leaked into the compiled program — the exact
failure swarmlint J005 exists to catch — and CI fails the day it lands
rather than the day someone notices the sweep got slow.

Usage::

    PYTHONPATH=src:. python benchmarks/fingerprints.py [--check]
"""
from __future__ import annotations

import argparse
import sys

from benchmarks import fig3_gamma, fig5_rate
from benchmarks.common import BENCH_JSON
from repro.analysis.jaxpr.fingerprint import sweep_fingerprint_table
from repro.fleet import write_bench_json
from repro.fleet.report import load_bench_json


def record(specs=None) -> dict:
    """Trace each spec's points and merge the tables into BENCH_fleet.json
    (per-sweep-name merge: tables from real ``fleet_sweep`` runs and from
    this recorder overwrite each other, never accumulate stale keys)."""
    specs = specs if specs is not None else [fig3_gamma.spec(),
                                             fig5_rate.spec()]
    merged = dict(load_bench_json(BENCH_JSON).get("fingerprints", {}))
    tables = {}
    for sp in specs:
        table = sweep_fingerprint_table(sp)
        merged[sp.name] = table
        tables[sp.name] = table
        print(f"fingerprints: {sp.name}: {len(table['points'])} points, "
              f"{table['distinct_programs']} distinct program(s), "
              f"stable={table['stable']}")
    write_bench_json(BENCH_JSON, "fingerprints", merged)
    return tables


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--check", action="store_true",
                    help="exit 1 when any same-signature point group "
                         "traces distinct programs (J005 instability)")
    args = ap.parse_args(argv)
    tables = record()
    unstable = {name: t for name, t in tables.items() if not t["stable"]}
    if args.check and unstable:
        for name, t in unstable.items():
            for g in t["unstable_groups"]:
                print(f"fingerprints: UNSTABLE {name}: "
                      f"{', '.join(g['points'])} trace "
                      f"{len(g['programs'])} distinct programs",
                      file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
