"""Benchmark entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (kernel microbench) followed by
the figure reproductions (Fig. 3-7) and the roofline table from the dry-run
artifacts.  Env knobs:
  REPRO_FULL_RUNS=1   use the paper's 50 Monte-Carlo runs (default 16)
  REPRO_BENCH_FAST=1  tiny sweep for CI smoke (2 runs)

Flags:
  --workers N   dispatch every fleet sweep across N local worker processes
                (``repro.fleet.dispatch``; results byte-identical to N=1)
  --trace [C]   run every fleet sweep with per-task telemetry
                (``SwarmConfig.trace_capacity = C``, default 65536): each
                sweep's BENCH_fleet.json section gains the task-level
                indices (``task_latency_cdf_s``, hop/exit histograms,
                energy per task) computed from in-scan TaskRecords, and a
                trace-driven figure pass (``fig_trace``) emits the
                Fig. 4a per-task CDF overlay CSV
  --trace-hops [C]  additionally capture the per-hop stream
                (``SwarmConfig.trace_hop_capacity = C``, default 65536):
                BENCH sections gain hop-resolved indices (per-hop
                transfer-time / link-bits quantiles, queue-wait vs
                in-flight decomposition)
  --neighbor-k K  run every fleet sweep on the sparse neighbor-list path
                (``SwarmConfig.neighbor_mode="sparse"``, ``neighbor_k=K``):
                the O(N·k) φ epoch update instead of the dense [N, N] one
  --trace-state [E]  flight recorder: run every fleet sweep with the
                per-epoch swarm-state stream on
                (``SwarmConfig.trace_state_every = E``, default stride 1):
                BENCH sections gain φ-convergence, queue-heatmap and
                energy-drain indices, and a state-driven figure pass
                (``fig_state``) emits the φ-convergence + queue-heatmap
                CSVs; while sweeps run, workers append per-point system
                gauges to progress.jsonl (``--watch`` renders swarm health)
  --watch [p]   don't run benchmarks: follow a progress.jsonl (default
                ``artifacts/progress.jsonl``) and render completed/total,
                points/min, ETA and — when the flight recorder is on —
                the live swarm gauges (mean/max queue depth, φ spread,
                completion rate) for the sweep currently running —
                locally or on any host sharing the progress file.
                ``benchmarks/loadtest.py`` (the open-loop SLO knee sweep,
                DESIGN.md §14) streams its gauges — p50/p99 latency,
                goodput, drop rate — onto the same surface.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

FAST = os.environ.get("REPRO_BENCH_FAST") == "1"


def watch(path: str, interval: float = 2.0) -> None:
    """Render live sweep progress from a shared progress.jsonl."""
    from repro.fleet import progress_summary, read_progress, render_progress
    last = None
    while True:
        s = progress_summary(read_progress(path))
        line = render_progress(s)
        if line != last:
            print(line, flush=True)
            last = line
        if s is not None and s["total"] > 0 and s["completed"] >= s["total"]:
            return
        time.sleep(interval)


def run_benchmarks() -> None:
    from benchmarks import (fig3_gamma, fig4_workers, fig5_rate, fig6_area,
                            fig7_earlyexit, microbench, roofline)
    from repro.fleet import worker_env

    # fleet sweeps coordinate across ranks through the shared store, but
    # the microbench/roofline producers don't — running them on every rank
    # would race the read-modify-write of BENCH_fleet.json and record an
    # arbitrary rank's wall clock; rank 0 owns them
    rank0 = worker_env().rank == 0
    if rank0:
        print("== microbench (name,us_per_call,derived) ==")
        microbench.run()
        print("\n== diffusive_phi at swarm scale (ref vs Pallas interpret)"
              " ==")
        microbench.run_phi_sweep(ns=(256,) if FAST else (256, 1024, 4096))
        print("\n== diffusive_phi sparse neighbor-list path (O(N·k)) ==")
        if FAST:
            microbench.run_phi_sparse_wallclock(
                ns=(256,), k=8, dense_ns=(256,), interpret_ns=(128,))
        else:
            microbench.run_phi_sparse_wallclock()
        print("\n== trace-stream overhead (off / tasks / +hops / +state) ==")
        if FAST:
            microbench.run_trace_overhead(ns=(256,), sim_time_s=1.0,
                                          iters=1)
        else:
            microbench.run_trace_overhead()

    kw = {"runs": 2} if FAST else {}

    print("\n== Fig. 3: gamma sensitivity ==")
    fig3_gamma.run(gammas=(0.02, 0.1) if FAST else
                   (0.002, 0.01, 0.02, 0.05, 0.1, 0.3), **kw)
    print("\n== Fig. 4: workers sweep ==")
    fig4_workers.run(workers=(10, 30) if FAST else (10, 20, 30, 40, 50),
                     **kw)
    print("\n== Fig. 5: arrival rate ==")
    fig5_rate.run(periods_ms=(60, 100) if FAST else (60, 70, 80, 90, 100),
                  **kw)
    print("\n== Fig. 6: mission area ==")
    fig6_area.run(areas_km=(20, 40) if FAST else (10, 20, 30, 40), **kw)
    print("\n== Fig. 7: early exit ==")
    fig7_earlyexit.run(workers=(10, 30) if FAST else (10, 20, 30, 40, 50),
                       **kw)

    print("\n== Scenario sweep (ours): mobility x channel x churn ==")
    from benchmarks import fig_scenarios
    fig_scenarios.run(scenarios=fig_scenarios.SCENARIOS[:3] if FAST
                      else fig_scenarios.SCENARIOS,
                      sim_time=10.0 if FAST else 20.0, **kw)

    if int(os.environ.get("REPRO_FLEET_TRACE", "0")) > 0:
        print("\n== Trace-driven figures: Fig. 4a per-task CDF overlay ==")
        from benchmarks import fig_trace
        fig_trace.run(n=10 if FAST else 30,
                      strategies=(0, 4) if FAST else (0, 1, 2, 3, 4),
                      sim_time=5.0 if FAST else None, **kw)

    if int(os.environ.get("REPRO_FLEET_TRACE_STATE", "0")) > 0:
        print("\n== State-driven figures: φ-convergence + queue heatmap ==")
        from benchmarks import fig_state
        fig_state.run(n=10 if FAST else 30,
                      strategies=(0, 4) if FAST else (0, 1, 2, 3, 4),
                      sim_time=5.0 if FAST else None, **kw)

    if rank0:
        print("\n== Ablation (ours): arrival burstiness ==")
        from benchmarks import ablation_burst
        ablation_burst.run(duties=(0.25, 1.0) if FAST else
                           (0.125, 0.25, 0.5, 1.0), **kw)

        print("\n== Roofline (from dry-run artifacts) ==")
        roofline.run()


def main(argv=None) -> None:
    from benchmarks.common import PROGRESS_JSONL
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--workers", type=int, default=None, metavar="N",
                    help="dispatch fleet sweeps across N local worker "
                         "processes (repro.fleet.dispatch)")
    ap.add_argument("--trace", nargs="?", const=65536, default=None,
                    type=int, metavar="CAPACITY",
                    help="per-task telemetry: run sweeps with "
                         "SwarmConfig.trace_capacity=CAPACITY (default "
                         "65536) so BENCH sections gain task-level CDFs, "
                         "and emit the Fig. 4a overlay CSV (fig_trace)")
    ap.add_argument("--trace-hops", nargs="?", const=65536, default=None,
                    type=int, metavar="CAPACITY",
                    help="per-hop telemetry: SwarmConfig.trace_hop_capacity"
                         "=CAPACITY (default 65536) — BENCH sections gain "
                         "hop-resolved transfer indices")
    ap.add_argument("--neighbor-k", type=int, default=None, metavar="K",
                    help="run every fleet sweep on the sparse neighbor-list "
                         "path (SwarmConfig.neighbor_mode='sparse', "
                         "neighbor_k=K) — the O(N·k) φ epoch update")
    ap.add_argument("--trace-state", nargs="?", const=1, default=None,
                    type=int, metavar="EVERY",
                    help="flight recorder: SwarmConfig.trace_state_every="
                         "EVERY (default stride 1) — BENCH sections gain "
                         "φ-convergence / queue-heatmap / energy-drain "
                         "indices and fig_state emits the state CSVs")
    ap.add_argument("--watch", nargs="?", const=PROGRESS_JSONL, default=None,
                    metavar="PROGRESS_JSONL",
                    help="follow a progress file instead of running "
                         f"benchmarks (default {PROGRESS_JSONL})")
    args = ap.parse_args(argv)

    if args.watch is not None:
        watch(args.watch)
        return
    if args.workers is not None:
        # common.fleet_sweep reads the knob at call time, so setting the
        # env here covers every figure sweep below
        os.environ["REPRO_FLEET_WORKERS"] = str(args.workers)
    if args.trace is not None:
        os.environ["REPRO_FLEET_TRACE"] = str(args.trace)
    if args.trace_hops is not None:
        os.environ["REPRO_FLEET_TRACE_HOPS"] = str(args.trace_hops)
    if args.neighbor_k is not None:
        os.environ["REPRO_FLEET_NEIGHBOR_K"] = str(args.neighbor_k)
    if args.trace_state is not None:
        os.environ["REPRO_FLEET_TRACE_STATE"] = str(args.trace_state)
    run_benchmarks()


if __name__ == "__main__":
    main()
