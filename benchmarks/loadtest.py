"""Open-loop SLO load test for the serve path (DESIGN.md §14.2-§14.3).

Drives the scheduling-faithful :class:`repro.obs.loadgen.
SyntheticServeEngine` with Poisson / MMPP / trace-replay arrivals at a
ladder of rate multipliers around the engine's capacity
(``max_batch / dt`` rows/s — one batch per stage per epoch), producing a
throughput-vs-latency **knee sweep**: per point p50/p99/p999 latency,
goodput, time-to-first-exit, drop rate, queue-saturation gauges and the
compute/queue-wait segment split, merged into ``BENCH_fleet.json`` under
``slo_serve`` and exported as Prometheus exposition text plus Perfetto
counter tracks.  Progress rows stream to the shared ``progress.jsonl``,
so ``benchmarks/run.py --watch`` renders the run live.

A million requests complete on CPU in well under a minute: the synthetic
engine runs the real scheduler (queues, epoch snapshot, congestion EMA,
exit ladder, admission control) with identity stage math and empty
payloads, and arrivals coalesce onto the epoch grid in ≤ ``max_batch``
row batches stamped with their first row's true arrival time.

Examples::

    python benchmarks/loadtest.py --requests 1000000
    python benchmarks/loadtest.py --requests 50000 --processes poisson
    python benchmarks/loadtest.py --replay times.json --processes replay
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

import numpy as np  # noqa: E402

DEFAULT_KNEE = (0.5, 0.8, 0.95, 1.1, 1.4)
GAUGE_EVERY_EPOCHS = 512     # progress-row cadence inside a point


def _arrivals(process: str, rate: float, horizon: float, seed: int,
              replay_path):
    from repro.obs import loadgen
    if process == "poisson":
        return loadgen.poisson_arrivals(rate, horizon, seed=seed)
    if process == "mmpp":
        # dwell-weighted mean equals the target: 6 s low at 0.8·rate,
        # 2 s high at 1.6·rate → (6·0.8 + 2·1.6)/8 = 1.0·rate
        return loadgen.mmpp_arrivals(0.8 * rate, 1.6 * rate, horizon,
                                     mean_lo_s=6.0, mean_hi_s=2.0,
                                     seed=seed)
    if process == "replay":
        if not replay_path:
            raise SystemExit("--processes replay requires --replay PATH")
        with open(replay_path) as f:
            return loadgen.replay_arrivals(json.load(f))
    raise SystemExit(f"unknown arrival process {process!r}")


def run_point(process: str, mult: float, args, progress=None,
              label: str = ""):
    """One knee point: generate arrivals, run the open loop, report."""
    from repro.obs.loadgen import SyntheticServeEngine, run_open_loop
    from repro.obs.slo import slo_indices

    capacity = args.max_batch / args.dt
    rate = mult * capacity
    horizon = args.requests / rate
    seed = args.seed + int(round(1000 * mult))
    times = _arrivals(process, rate, horizon, seed, args.replay)
    if process == "replay" and times.size:
        # the trace sets the offered rate; the multiplier is nominal
        horizon = max(float(times[-1]), args.dt)
        rate = times.size / horizon
    epochs_est = max(int(horizon / args.dt), 1)
    state_every = max(1, epochs_est // 2048)
    eng = SyntheticServeEngine(
        n_stages=args.stages, max_queue=args.max_queue,
        state_every=state_every, max_records=args.max_records)

    def on_epoch(epoch, t, engine):
        if progress is None or epoch % GAUGE_EVERY_EPOCHS:
            return
        st = engine.stats
        lq = st.latency_quantiles()
        progress.emit(
            event="gauges", label=label, sim_t=round(t, 3),
            queue_depth_mean=round(float(np.mean(
                [len(q) for q in engine.queues])), 3),
            queue_depth_max=int(max(len(q) for q in engine.queues)),
            completion_rate=round(
                st.completed / max(st.generated_rows, 1), 4),
            p50_latency_s=lq["p50"], p99_latency_s=lq["p99"],
            goodput_rps=round(st.completed / t, 1) if t > 0 else 0.0,
            drop_rate=round(st.dropped / max(st.generated_rows, 1), 4),
            t=time.time())

    stats = run_open_loop(eng, times, dt=args.dt, max_batch=args.max_batch,
                          on_epoch=on_epoch if progress else None)
    point = slo_indices(stats, horizon_s=float(eng.clock),
                        offered_rows=int(times.size), rate_rps=rate,
                        max_queue=args.max_queue)
    point["rate_multiplier"] = mult
    return point, stats


def main(argv=None) -> None:
    from benchmarks.common import ART, BENCH_JSON, PROGRESS_JSONL
    from repro.fleet import write_bench_json
    from repro.fleet.dispatch import ProgressWriter
    from repro.obs import Registry, host_class
    from repro.obs.prom import parse, render
    from repro.obs.slo import fill_registry, perfetto_counter_events

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--requests", type=int, default=200_000,
                    help="rows offered per knee point (default 200000)")
    ap.add_argument("--processes", default="poisson,mmpp",
                    help="comma list of poisson,mmpp,replay")
    ap.add_argument("--replay", default=None, metavar="PATH",
                    help="JSON array of arrival times (seconds) for the "
                         "replay process")
    ap.add_argument("--knee", default=",".join(map(str, DEFAULT_KNEE)),
                    help="rate multipliers of capacity (max_batch/dt)")
    ap.add_argument("--dt", type=float, default=0.01,
                    help="epoch length, seconds (default 0.01)")
    ap.add_argument("--max-batch", type=int, default=64,
                    help="rows per submitted batch (default 64)")
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--max-queue", type=int, default=512,
                    help="admission-control bound on the entry queue, in "
                         "batches (0 = unbounded)")
    ap.add_argument("--max-records", type=int, default=100_000,
                    help="TaskRecord rows kept per point (counters and "
                         "histograms keep counting past this)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--bench", default=BENCH_JSON)
    ap.add_argument("--prom", default=os.path.join(ART, "slo_serve.prom"))
    ap.add_argument("--perfetto",
                    default=os.path.join(ART, "slo_serve_trace.json"))
    ap.add_argument("--no-artifacts", action="store_true",
                    help="skip BENCH/prom/perfetto writes (smoke runs)")
    args = ap.parse_args(argv)
    if args.max_queue == 0:
        args.max_queue = None

    processes = [p for p in args.processes.split(",") if p]
    multipliers = [float(m) for m in args.knee.split(",") if m]
    capacity = args.max_batch / args.dt
    progress = ProgressWriter(PROGRESS_JSONL)
    progress.emit(event="sweep_start", sweep="slo_loadtest",
                  total=len(processes) * len(multipliers), t=time.time())

    reg = Registry()
    payload = {
        "meta": {
            "host_class": host_class(), "dt_s": args.dt,
            "max_batch_rows": args.max_batch, "stages": args.stages,
            "capacity_rps": capacity, "requests_per_point": args.requests,
            "max_queue": args.max_queue, "seed": args.seed,
            "knee_multipliers": multipliers,
            "mmpp": {"rate_lo": 0.8, "rate_hi": 1.6,
                     "mean_lo_s": 6.0, "mean_hi_s": 2.0},
        },
        "processes": {},
    }
    t_start = time.perf_counter()
    for process in processes:
        points = {}
        knee = []
        ref_stats, ref_mult = None, None
        for mult in multipliers:
            label = f"{process}:x{mult:g}"
            t0 = time.perf_counter()
            point, stats = run_point(process, mult, args,
                                     progress=progress, label=label)
            wall = time.perf_counter() - t0
            points[f"x{mult:g}"] = point
            lq = point["latency_s"]
            knee.append({
                "rate_multiplier": mult,
                "offered_rate_rps": point["offered_rate_rps"],
                "goodput_rps": round(point["goodput_rps"], 1),
                "p50_s": lq["p50"], "p99_s": lq["p99"],
                "p999_s": lq["p999"], "drop_rate": point["drop_rate"],
            })
            progress.emit(event="point", label=label, digest=None,
                          num_runs=1, wall_s=round(wall, 3), cached=False,
                          t=time.time())
            print(f"[loadtest] {label:>16}  offered {point['offered_rows']}"
                  f" rows @ {point['offered_rate_rps']:.0f} rps"
                  f" · goodput {point['goodput_rps']:.0f} rps"
                  f" · p50 {lq['p50']} p99 {lq['p99']} p999 {lq['p999']}"
                  f" · drop {point['drop_rate']:.3f}"
                  f" · {wall:.2f}s wall", flush=True)
            # Prometheus/Perfetto exports use the highest stable point
            # (largest multiplier below capacity; else the first point)
            if mult < 1.0 and (ref_mult is None or mult > ref_mult):
                ref_stats, ref_mult = stats, mult
        if ref_stats is None:
            ref_stats = stats
        payload["processes"][process] = {"points": points, "knee": knee}
        fill_registry(reg, ref_stats, process=process)

    if not args.no_artifacts:
        write_bench_json(args.bench, "slo_serve", payload)
        print(f"[loadtest] wrote slo_serve section -> {args.bench}")
        text = render(reg)
        parse(text)          # round-trip validity before writing
        os.makedirs(os.path.dirname(args.prom) or ".", exist_ok=True)
        with open(args.prom, "w") as f:
            f.write(text)
        print(f"[loadtest] wrote Prometheus exposition -> {args.prom}")
        events = perfetto_counter_events(ref_stats)
        with open(args.perfetto, "w") as f:
            json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
        print(f"[loadtest] wrote Perfetto counters -> {args.perfetto}")
    total = time.perf_counter() - t_start
    print(f"[loadtest] {len(processes) * len(multipliers)} points · "
          f"{args.requests} rows/point · {total:.1f}s total")


if __name__ == "__main__":
    main()
