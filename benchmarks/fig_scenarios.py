"""Scenario robustness sweep (beyond-paper): all five strategies across
mobility × channel × fault profiles, declared as one fleet SweepSpec.

The paper's claim is that the diffusive metric stays robust "when the swarm
grows or the topology shifts rapidly" — this sweep tests exactly that:
random-waypoint / Gauss-Markov / Lévy-flight mobility, free-space /
log-normal / Rician / Nakagami channels and Markov node churn, against the
circular/two-ray baseline.
"""
from __future__ import annotations

import dataclasses
import os

from benchmarks.common import (ART, DEFAULT_RUNS, ci95, fleet_sweep,
                               write_csv)
from repro.configs.base import SwarmConfig
from repro.fleet import SweepSpec
from repro.swarm import STRATEGY_NAMES

METRICS = ["avg_latency_s", "remaining_gflops", "jain_fairness",
           "energy_per_task_j", "fom"]

SCENARIOS = (
    ("baseline", {}),
    ("rwp", {"mobility_model": "random_waypoint"}),
    ("gauss_markov", {"mobility_model": "gauss_markov"}),
    ("levy", {"mobility_model": "levy_flight"}),
    ("shadowed", {"mobility_model": "random_waypoint",
                  "channel_model": "log_normal"}),
    ("free_space", {"channel_model": "free_space"}),
    ("rician", {"channel_model": "rician"}),
    ("nakagami", {"channel_model": "nakagami"}),
    ("churn", {"fault_model": "markov",
               "fault_mean_up_s": 20.0, "fault_mean_down_s": 4.0}),
    ("rwp_churn", {"mobility_model": "random_waypoint",
                   "channel_model": "log_normal", "fault_model": "markov"}),
)


def run(scenarios=SCENARIOS, n=20, runs=DEFAULT_RUNS, sim_time=20.0):
    base = dataclasses.replace(SwarmConfig(), num_workers=n,
                               sim_time_s=sim_time)
    spec = SweepSpec.build(
        "fig_scenarios", base,
        axes={"scenario": tuple((name, dict(ov)) for name, ov in scenarios)},
        strategies=tuple(range(5)), num_runs=runs)
    res = fleet_sweep(spec)
    if not res:
        return []    # non-zero rank of a multi-host dispatch: worker only
    rows = []
    for pt in spec.expand():
        m, name = res[pt.label], pt.values["scenario"]
        strat = STRATEGY_NAMES[pt.strategy]
        row = [name, strat]
        for k in METRICS:
            mean, half = ci95(m[k])
            row += [f"{mean:.6g}", f"{half:.3g}"]
        rows.append(row)
        print(f"{name:12s} {strat:14s} " + " ".join(
            f"{k.split('_')[0][:4]}={ci95(m[k])[0]:.4g}"
            for k in METRICS))
    hdr = "scenario,strategy," + ",".join(f"{k},{k}_ci95" for k in METRICS)
    write_csv(os.path.join(ART, "fig_scenarios.csv"), hdr, rows)
    return rows


if __name__ == "__main__":
    run()
