"""CI perf-regression gate over the ``profile`` section of BENCH_fleet.json.

Every ``fleet_sweep`` records per-point compile/execute wall-clock spans
into ``BENCH_fleet.json["profile"]`` (``benchmarks/common.py``); this
script compares a freshly produced file against a committed baseline and
fails (exit 1) when any point's *execute* span regressed by more than
``--max-ratio``.  Compile spans are reported but never gated — XLA's
compile time is too build-dependent to pin.

Comparisons are deliberately conservative to survive noisy CI hosts:

  * points missing from either file, cache hits (``cached: true`` — a hit
    cost no execute time), and points without an ``execute_s`` span are
    skipped, not failed;
  * baselines below ``--min-seconds`` are skipped — ratios over
    millisecond-scale spans are dominated by scheduler jitter;
  * a missing/empty baseline profile passes with a note, so the gate can
    land before the first baseline is committed.

Usage::

    python benchmarks/perf_gate.py \
        --baseline /tmp/bench_baseline.json \
        --current benchmarks/artifacts/BENCH_fleet.json \
        --max-ratio 2.0
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def load_profile(path: str) -> dict:
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        return json.load(f).get("profile", {})


def compare(baseline: dict, current: dict, max_ratio: float,
            min_seconds: float):
    """(checked, skipped, failures) over matching sweep/point entries."""
    checked, skipped, failures = [], [], []
    for sweep, base_pts in baseline.items():
        cur_pts = current.get(sweep, {})
        for label, b in base_pts.items():
            c = cur_pts.get(label)
            name = f"{sweep}/{label}"
            if c is None:
                skipped.append((name, "missing from current"))
                continue
            be, ce = b.get("execute_s"), c.get("execute_s")
            if b.get("cached") or c.get("cached") or be is None \
                    or ce is None:
                skipped.append((name, "cached or no execute span"))
                continue
            if be < min_seconds:
                skipped.append((name, f"baseline {be:.3f}s < "
                                f"{min_seconds}s floor"))
                continue
            ratio = ce / be
            checked.append((name, be, ce, ratio))
            if ratio > max_ratio:
                failures.append((name, be, ce, ratio))
    return checked, skipped, failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True,
                    help="BENCH_fleet.json with the committed profile")
    ap.add_argument("--current", required=True,
                    help="freshly produced BENCH_fleet.json")
    ap.add_argument("--max-ratio", type=float, default=2.0,
                    help="fail when execute_s regresses past this "
                         "multiple of the baseline (default 2.0)")
    ap.add_argument("--min-seconds", type=float, default=0.2,
                    help="skip baselines shorter than this (default 0.2s "
                         "— sub-200ms ratios are scheduler noise)")
    args = ap.parse_args(argv)

    baseline = load_profile(args.baseline)
    current = load_profile(args.current)
    if not baseline:
        print(f"perf_gate: no profile section in {args.baseline} — "
              "nothing to gate (pass)")
        return 0
    checked, skipped, failures = compare(baseline, current,
                                         args.max_ratio, args.min_seconds)
    for name, be, ce, ratio in checked:
        print(f"perf_gate: {name} execute {be:.3f}s -> {ce:.3f}s "
              f"(x{ratio:.2f})")
    for name, why in skipped:
        print(f"perf_gate: skip {name}: {why}")
    if failures:
        for name, be, ce, ratio in failures:
            print(f"perf_gate: FAIL {name} execute {be:.3f}s -> {ce:.3f}s "
                  f"(x{ratio:.2f} > x{args.max_ratio})", file=sys.stderr)
        return 1
    print(f"perf_gate: ok ({len(checked)} checked, {len(skipped)} skipped, "
          f"max ratio x{args.max_ratio})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
