"""CI perf-regression gate over the ``profile`` section of BENCH_fleet.json.

Every ``fleet_sweep`` records per-point compile/execute wall-clock spans
into ``BENCH_fleet.json["profile"]`` (``benchmarks/common.py``); this
script compares a freshly produced file against a committed baseline and
fails (exit 1) when any point's *execute* span regressed by more than
``--max-ratio``.  Compile spans are reported but never gated — XLA's
compile time is too build-dependent to pin.

Comparisons are deliberately conservative to survive noisy CI hosts:

  * points missing from either file, cache hits (``cached: true`` — a hit
    cost no execute time), and points without an ``execute_s`` span are
    skipped, not failed;
  * baselines below ``--min-seconds`` are skipped — ratios over
    millisecond-scale spans are dominated by scheduler jitter;
  * profile entries carry a ``host_class`` tag (``repro.obs.host_class``:
    OS/ISA/core-count, override ``REPRO_HOST_CLASS``); a regression
    measured on a *different* host class than the baseline's is reported
    as a warning, never a hard failure — only same-class (or untagged,
    treated as same-class) comparisons gate (DESIGN.md §14.5);
  * ``--rel-tol`` (env ``REPRO_PERF_REL_TOL``) adds slack to the ratio
    threshold for known-noisy fleets: fail only past
    ``max_ratio + rel_tol``;
  * a missing/empty baseline profile passes with a note, so the gate can
    land before the first baseline is committed.

When a point fails, the gate also looks up the point's
``latency_segments`` (critical-path attribution, ``trace/critical.py``)
in both files' sweep sections and names the segment whose quantile moved
the most — a regression report says *queue-wait regressed*, not just
"slower" (DESIGN.md §14.5).

When the BENCH files carry ``fingerprints`` sections (the J005
compile-fingerprint tables ``fleet_sweep`` records, DESIGN.md §15.3), the
gate also prints which point's traced program changed against the
baseline and which same-signature groups split — so "slower" comes with
"because this point started recompiling" when that is the cause.
Fingerprint moves are diagnosis, never a failure by themselves.

Usage::

    python benchmarks/perf_gate.py \
        --baseline /tmp/bench_baseline.json \
        --current benchmarks/artifacts/BENCH_fleet.json \
        --max-ratio 2.0 [--rel-tol 0.25]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                "src"))


def load_bench(path: str) -> dict:
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        return json.load(f)


def load_profile(path: str) -> dict:
    return load_bench(path).get("profile", {})


def compare(baseline: dict, current: dict, max_ratio: float,
            min_seconds: float, rel_tol: float = 0.0):
    """(checked, skipped, failures) over matching sweep/point entries.

    Entries whose ``host_class`` tags disagree never fail — an excess
    ratio lands in ``skipped`` with a cross-class warning.  Untagged
    entries (pre-tag baselines) gate as same-class.
    """
    threshold = max_ratio + rel_tol
    checked, skipped, failures = [], [], []
    for sweep, base_pts in baseline.items():
        cur_pts = current.get(sweep, {})
        for label, b in base_pts.items():
            c = cur_pts.get(label)
            name = f"{sweep}/{label}"
            if c is None:
                skipped.append((name, "missing from current"))
                continue
            be, ce = b.get("execute_s"), c.get("execute_s")
            if b.get("cached") or c.get("cached") or be is None \
                    or ce is None:
                skipped.append((name, "cached or no execute span"))
                continue
            if be < min_seconds:
                skipped.append((name, f"baseline {be:.3f}s < "
                                f"{min_seconds}s floor"))
                continue
            ratio = ce / be
            checked.append((name, be, ce, ratio))
            if ratio > threshold:
                bh, ch = b.get("host_class"), c.get("host_class")
                if bh is not None and ch is not None and bh != ch:
                    skipped.append(
                        (name, f"execute x{ratio:.2f} exceeds gate but "
                               f"host classes differ ({bh} vs {ch}) — "
                               "warn only"))
                else:
                    failures.append((name, be, ce, ratio))
    return checked, skipped, failures


def _point_sections(doc: dict, sweep: str, label: str) -> dict:
    return (doc.get(f"sweep:{sweep}", {}).get("points", {})
            .get(label, {}))


def attribute_failure(base_doc: dict, cur_doc: dict, sweep: str,
                      label: str, quantile: str = "p50"):
    """Name the latency segment that moved for one failing point, from
    the ``latency_segments`` payloads both BENCH files carry when the
    sweep ran traced; ``None`` when either side lacks them."""
    bseg = _point_sections(base_doc, sweep, label).get("latency_segments")
    cseg = _point_sections(cur_doc, sweep, label).get("latency_segments")
    if not bseg or not cseg:
        return None
    from repro.trace.critical import attribute
    return attribute(bseg, cseg, quantile)


def fingerprint_notes(base_doc: dict, cur_doc: dict):
    """Compile-fingerprint diagnosis lines (J005, DESIGN.md §15.3).

    ``fleet_sweep`` emits a per-sweep fingerprint table into the
    ``fingerprints`` BENCH section; this names (a) same-structural-
    signature groups that trace distinct programs *within* the current
    file and (b) points whose fingerprint moved against the baseline —
    i.e. exactly which point started recompiling.  Diagnosis only: a
    fingerprint move explains an execute regression, it never gates by
    itself (deliberate program changes legitimately move fingerprints;
    the jaxpr lint tier owns the stability invariant).
    """
    base_fp = base_doc.get("fingerprints", {})
    cur_fp = cur_doc.get("fingerprints", {})
    notes = []
    for sweep, table in sorted(cur_fp.items()):
        if table.get("error"):
            notes.append(f"{sweep}: fingerprint table unavailable "
                         f"({table['error']})")
            continue
        for g in table.get("unstable_groups", []):
            progs = g.get("programs", {})
            notes.append(
                f"{sweep}: {len(g.get('points', []))} structurally "
                f"identical points trace {len(progs)} distinct programs: "
                + "; ".join(f"{fp} <- {', '.join(pts)}"
                            for fp, pts in sorted(progs.items())))
        base_table = base_fp.get(sweep, {})
        base_pts = base_table.get("points", {})
        for label, fp in sorted(table.get("points", {}).items()):
            b = base_pts.get(label)
            if b is None or b == fp:
                continue
            bsig = _group_signature(base_table, label)
            csig = _group_signature(table, label)
            if bsig is not None and csig is not None and bsig != csig:
                # same label, different experiment (e.g. a runs=4 CI
                # smoke vs the committed full grid): a fingerprint
                # difference is expected, not a recompile signal
                notes.append(f"{sweep}/{label}: structural signature "
                             "differs from the baseline's (different "
                             "num_runs / trace knobs) — fingerprint "
                             "not comparable, skipped")
                continue
            notes.append(f"{sweep}/{label}: compile fingerprint "
                         f"{b} -> {fp} — this point recompiles "
                         "vs the committed baseline")
    return notes


def _group_signature(table: dict, label: str):
    for g in table.get("groups", []):
        if label in g.get("points", []):
            return g.get("signature")
    return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True,
                    help="BENCH_fleet.json with the committed profile")
    ap.add_argument("--current", required=True,
                    help="freshly produced BENCH_fleet.json")
    ap.add_argument("--max-ratio", type=float, default=2.0,
                    help="fail when execute_s regresses past this "
                         "multiple of the baseline (default 2.0)")
    ap.add_argument("--min-seconds", type=float, default=0.2,
                    help="skip baselines shorter than this (default 0.2s "
                         "— sub-200ms ratios are scheduler noise)")
    ap.add_argument("--rel-tol", type=float,
                    default=float(os.environ.get("REPRO_PERF_REL_TOL",
                                                 "0.0")),
                    help="extra slack added to --max-ratio (env "
                         "REPRO_PERF_REL_TOL; default 0)")
    args = ap.parse_args(argv)

    base_doc = load_bench(args.baseline)
    cur_doc = load_bench(args.current)
    baseline = base_doc.get("profile", {})
    current = cur_doc.get("profile", {})
    if not baseline:
        print(f"perf_gate: no profile section in {args.baseline} — "
              "nothing to gate (pass)")
        return 0
    checked, skipped, failures = compare(baseline, current,
                                         args.max_ratio, args.min_seconds,
                                         args.rel_tol)
    for note in fingerprint_notes(base_doc, cur_doc):
        print(f"perf_gate: fingerprint: {note}")
    for name, be, ce, ratio in checked:
        print(f"perf_gate: {name} execute {be:.3f}s -> {ce:.3f}s "
              f"(x{ratio:.2f})")
    for name, why in skipped:
        print(f"perf_gate: skip {name}: {why}")
    if failures:
        for name, be, ce, ratio in failures:
            print(f"perf_gate: FAIL {name} execute {be:.3f}s -> {ce:.3f}s "
                  f"(x{ratio:.2f} > x{args.max_ratio + args.rel_tol})",
                  file=sys.stderr)
            sweep, _, label = name.partition("/")
            attr = attribute_failure(base_doc, cur_doc, sweep, label)
            if attr is not None:
                r = ("" if attr["ratio"] is None
                     else f" (x{attr['ratio']:.2f})")
                print(f"perf_gate:   segment attribution: "
                      f"{attr['segment']} p50 {attr['baseline_s']:.4f}s "
                      f"-> {attr['current_s']:.4f}s"
                      f"{r} moved the most", file=sys.stderr)
            else:
                print("perf_gate:   segment attribution unavailable "
                      "(run sweeps with --trace to record "
                      "latency_segments)", file=sys.stderr)
        return 1
    print(f"perf_gate: ok ({len(checked)} checked, {len(skipped)} skipped, "
          f"max ratio x{args.max_ratio}"
          + (f" + rel tol {args.rel_tol}" if args.rel_tol else "") + ")")
    return 0


if __name__ == "__main__":
    sys.exit(main())
