"""Paper Fig. 7: congestion-aware early exit on/off — accuracy, latency,
remaining GFLOPs, fairness, energy, FOM vs workers (Distributed strategy)."""
from __future__ import annotations

import os

from benchmarks.common import (ART, DEFAULT_RUNS, ci95, fleet_sweep,
                               write_csv)
from repro.configs.base import SwarmConfig
from repro.fleet import SweepSpec
from repro.swarm import DISTRIBUTED

METRICS = ["avg_accuracy", "avg_latency_s", "remaining_gflops",
           "jain_fairness", "energy_per_task_j", "fom"]


def run(workers=(10, 20, 30, 40, 50), runs=DEFAULT_RUNS):
    spec = SweepSpec.build(
        "fig7_earlyexit", SwarmConfig(),
        axes={"num_workers": tuple(workers),
              "early_exit": (("off", {"early_exit_enabled": False}),
                             ("on", {"early_exit_enabled": True}))},
        strategies=(DISTRIBUTED,), num_runs=runs)
    res = fleet_sweep(spec)
    if not res:
        return []    # non-zero rank of a multi-host dispatch: worker only
    rows = []
    for pt in spec.expand():
        m = res[pt.label]
        n, ee = pt.values["num_workers"], pt.values["early_exit"]
        row = [n, ee]
        for k in METRICS:
            mean, half = ci95(m[k])
            row += [f"{mean:.6g}", f"{half:.3g}"]
        rows.append(row)
        print(f"N={n:3d} early_exit={ee:3s} " + " ".join(
            f"{k.split('_')[0][:4]}={ci95(m[k])[0]:.4g}" for k in METRICS))
    hdr = "workers,early_exit," + ",".join(f"{k},{k}_ci95" for k in METRICS)
    write_csv(os.path.join(ART, "fig7_earlyexit.csv"), hdr, rows)
    return rows


if __name__ == "__main__":
    run()
