"""Paper Fig. 5: latency / remaining GFLOPs / FOM vs task arrival period
(60→100 ms) at 30 workers."""
from __future__ import annotations

import os

from benchmarks.common import (ART, DEFAULT_RUNS, ci95, fleet_sweep,
                               write_csv)
from repro.configs.base import SwarmConfig
from repro.fleet import SweepSpec
from repro.swarm import STRATEGY_NAMES


def spec(periods_ms=(60, 70, 80, 90, 100), n=30,
         runs=DEFAULT_RUNS) -> SweepSpec:
    """The Fig. 5 grid itself — importable without executing it (the
    fingerprint recorder traces these points, benchmarks/fingerprints.py)."""
    return SweepSpec.build(
        "fig5_rate", SwarmConfig(num_workers=n),
        axes={"period_ms": tuple((p, {"task_period_s": p / 1000.0})
                                 for p in periods_ms)},
        strategies=tuple(range(5)), num_runs=runs)


def run(periods_ms=(60, 70, 80, 90, 100), n=30, runs=DEFAULT_RUNS):
    sp = spec(periods_ms, n, runs)
    res = fleet_sweep(sp)
    if not res:
        return []    # non-zero rank of a multi-host dispatch: worker only
    rows = []
    for pt in sp.expand():
        m, p = res[pt.label], pt.values["period_ms"]
        name = STRATEGY_NAMES[pt.strategy]
        lat, lat_ci = ci95(m["avg_latency_s"])
        rem, rem_ci = ci95(m["remaining_gflops"])
        fom, fom_ci = ci95(m["fom"])
        rows.append([p, name, f"{lat:.6g}", f"{lat_ci:.3g}",
                     f"{rem:.6g}", f"{rem_ci:.3g}", f"{fom:.6g}",
                     f"{fom_ci:.3g}"])
        print(f"period={p}ms {name:14s} lat={lat:.4g} rem={rem:.5g} "
              f"fom={fom:.5g}")
    write_csv(os.path.join(ART, "fig5_rate.csv"),
              "period_ms,strategy,latency_s,latency_ci,remaining_gflops,"
              "remaining_ci,fom,fom_ci", rows)
    return rows


if __name__ == "__main__":
    run()
