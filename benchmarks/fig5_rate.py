"""Paper Fig. 5: latency / remaining GFLOPs / FOM vs task arrival period
(60→100 ms) at 30 workers."""
from __future__ import annotations

import dataclasses
import os

from benchmarks.common import ART, DEFAULT_RUNS, ci95, timed_sweep, write_csv
from repro.configs.base import SwarmConfig


def run(periods_ms=(60, 70, 80, 90, 100), n=30, runs=DEFAULT_RUNS):
    rows = []
    for p in periods_ms:
        cfg = dataclasses.replace(SwarmConfig(num_workers=n),
                                  task_period_s=p / 1000.0)
        res = timed_sweep(cfg, range(5), n, runs)
        for name, m in res.items():
            lat, lat_ci = ci95(m["avg_latency_s"])
            rem, rem_ci = ci95(m["remaining_gflops"])
            fom, fom_ci = ci95(m["fom"])
            rows.append([p, name, f"{lat:.6g}", f"{lat_ci:.3g}",
                         f"{rem:.6g}", f"{rem_ci:.3g}", f"{fom:.6g}",
                         f"{fom_ci:.3g}"])
            print(f"period={p}ms {name:14s} lat={lat:.4g} rem={rem:.5g} "
                  f"fom={fom:.5g}")
    write_csv(os.path.join(ART, "fig5_rate.csv"),
              "period_ms,strategy,latency_s,latency_ci,remaining_gflops,"
              "remaining_ci,fom,fom_ci", rows)
    return rows


if __name__ == "__main__":
    run()
