"""Trace-driven figure exports (DESIGN.md §10.6): Fig. 4a per-task latency
CDF *overlays* and hop/exit histograms, computed from stored in-scan
records instead of run means.

The figure sweeps (fig3-7) report mean ± CI per point; Fig. 4a's actual
artifact is a per-task CDF overlay — one curve per strategy on a shared
axis.  This exporter runs (or cache-hits, through the content-addressed
store) one traced sweep over the strategies and emits:

  * ``fig4a_task_cdf.csv`` — shared CDF-fraction grid in column 0, one
    latency column per strategy: each row is "the p-th per-task latency
    quantile of every strategy", directly plottable as overlaid CDFs;
  * ``fig_trace_hist.csv`` — long-form ``label,kind,bin,count`` rows for
    the task hop histogram, the exit-label histogram and (when the hop
    stream is on) the per-hop boundary-layer histogram — the paper's
    hop/exit decompositions from real samples.

Both files come from record buffers that ride the normal fleet path, so
a cache hit, a resumed sweep or a multi-worker dispatch emit identical
bytes.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from benchmarks.common import ART, DEFAULT_RUNS, fleet_sweep, write_csv
from repro.configs.base import SwarmConfig
from repro.fleet import SweepSpec
from repro.trace import (decode, decode_hops, exit_label_histogram,
                         hop_histogram, int_histogram)

DEFAULT_CAPACITY = 65536
CDF_GRID = tuple(i / 100.0 for i in range(0, 101, 2))   # 51 fractions


def run(n=30, runs=DEFAULT_RUNS, strategies=(0, 1, 2, 3, 4),
        sim_time=None, trace_capacity=None, hop_capacity=None):
    """Traced strategy sweep → Fig. 4a overlay CSV + histogram CSV.

    Capacities default from the ``REPRO_FLEET_TRACE[_HOPS]`` env knobs
    (``run.py --trace [--trace-hops]``), falling back to 65536 for the
    task stream so the exporter works standalone; the hop stream stays
    off unless requested.
    """
    if trace_capacity is None:
        trace_capacity = int(os.environ.get("REPRO_FLEET_TRACE", "0")) \
            or DEFAULT_CAPACITY
    if hop_capacity is None:
        hop_capacity = int(os.environ.get("REPRO_FLEET_TRACE_HOPS", "0"))
    cfg = dataclasses.replace(
        SwarmConfig(), num_workers=n, trace_capacity=trace_capacity,
        trace_hop_capacity=hop_capacity,
        **({"sim_time_s": sim_time} if sim_time else {}))
    spec = SweepSpec.build("fig_trace", cfg, strategies=tuple(strategies),
                           num_runs=runs)
    res = fleet_sweep(spec)
    if not res:
        return []    # non-zero rank of a multi-host dispatch: worker only

    labels, cols, hist_rows = [], [], []
    for pt in spec.expand():
        m = res[pt.label]
        dec = decode(m["trace_records"], m.get("trace_overflow"))
        done = ~dec["is_dropped"]
        lat = np.sort(dec["latency_s"][done])
        labels.append(pt.label.split("strategy=")[-1])
        cols.append([float(np.quantile(lat, q)) if lat.size else ""
                     for q in CDF_GRID])
        for kind, hist in (("task_hops", hop_histogram(dec)),
                           ("exit_label", exit_label_histogram(dec))):
            hist_rows += _hist_rows(labels[-1], kind, hist)
        if "trace_hops" in m:
            hdec = decode_hops(m["trace_hops"],
                               m.get("trace_hop_overflow"))
            hist_rows += _hist_rows(labels[-1], "hop_boundary_layer",
                                    int_histogram(hdec["boundary_layer"]))
        print(f"fig_trace: {pt.label} tasks={int(done.sum())} "
              f"dropped={int(dec['is_dropped'].sum())}"
              + (f" hops={len(hdec['seq'])}" if "trace_hops" in m else ""))

    rows = [[f"{q:.2f}"] + [c[i] for c in cols]
            for i, q in enumerate(CDF_GRID)]
    write_csv(os.path.join(ART, "fig4a_task_cdf.csv"),
              "cdf," + ",".join(labels), rows)
    write_csv(os.path.join(ART, "fig_trace_hist.csv"),
              "strategy,kind,bin,count", hist_rows)
    return rows


def _hist_rows(label, kind, hist):
    """Long-form CSV rows from a string-keyed ``int_histogram`` dict."""
    return [[label, kind, int(b), c]
            for b, c in sorted(hist.items(), key=lambda kv: int(kv[0]))]


if __name__ == "__main__":
    run()
