"""Flight-recorder figure exports (DESIGN.md §12): φ-convergence curves
and queue-depth heatmaps from the per-epoch swarm-state stream.

The figure sweeps (fig3-7) report end-of-mission scalars; the paper's
*dynamics* story — how fast the diffusive metric settles and how queue
load redistributes over the mission — needs the epoch-resolved state
stream.  This exporter runs (or cache-hits, through the content-addressed
store) one state-traced sweep over the strategies and emits:

  * ``fig_state_phi.csv`` — shared epoch grid in column 0, one
    φ-residual column per strategy (run-mean RMS of φ_t − φ_final over
    the sampled nodes): overlaid, the curves are the φ-convergence
    figure, with the ε = 5 % crossing per strategy printed alongside;
  * ``fig_state_queue_heatmap.csv`` — long-form
    ``strategy,epoch,node,depth`` rows of the run-mean queue-depth
    heatmap (epoch-downsampled to ≤ 128 rows by the aggregator).

Both files come from epoch-indexed buffers that ride the normal fleet
path, so a cache hit, a resumed sweep or a multi-worker dispatch emit
identical bytes.
"""
from __future__ import annotations

import dataclasses
import os

from benchmarks.common import ART, DEFAULT_RUNS, fleet_sweep, write_csv
from repro.configs.base import SwarmConfig
from repro.fleet import SweepSpec
from repro.trace import decode_state, state_indices


def run(n=30, runs=DEFAULT_RUNS, strategies=(0, 1, 2, 3, 4),
        sim_time=None, every=None, nodes=None):
    """State-traced strategy sweep → φ-convergence CSV + queue heatmap CSV.

    ``every``/``nodes`` default from the ``REPRO_FLEET_TRACE_STATE[_NODES]``
    env knobs (run.py ``--trace-state``), falling back to stride 1 /
    all nodes so the exporter works standalone.
    """
    if every is None:
        every = int(os.environ.get("REPRO_FLEET_TRACE_STATE", "0")) or 1
    if nodes is None:
        nodes = int(os.environ.get("REPRO_FLEET_TRACE_STATE_NODES", "0"))
    cfg = dataclasses.replace(
        SwarmConfig(), num_workers=n, trace_state_every=every,
        trace_state_nodes=nodes,
        **({"sim_time_s": sim_time} if sim_time else {}))
    spec = SweepSpec.build("fig_state", cfg, strategies=tuple(strategies),
                           num_runs=runs)
    res = fleet_sweep(spec)
    if not res:
        return []    # non-zero rank of a multi-host dispatch: worker only

    labels, curves, heat_rows, epochs = [], [], [], None
    for pt in spec.expand():
        m = res[pt.label]
        sdec = decode_state(m["trace_state"], m.get("trace_state_sys"),
                            m.get("trace_state_epochs"))
        idx = state_indices(sdec)
        label = pt.label.split("strategy=")[-1]
        labels.append(label)
        curves.append(idx["phi_residual_curve"])
        if epochs is None:
            epochs = idx["state_epochs"]
        heat = idx["queue_depth_heatmap"]
        for e, row in zip(idx["queue_depth_heatmap_epochs"], heat, strict=True):
            heat_rows += [[label, int(e), node, d]
                          for node, d in enumerate(row)]
        eps = idx["phi_epochs_to_eps"]
        print(f"fig_state: {pt.label} samples={idx['state_sample_count']} "
              f"phi_eps_epoch={eps if eps is not None else 'n/a'} "
              f"jain_final={idx['queue_jain_final']}")

    rows = [[int(e)] + [c[i] for c in curves]
            for i, e in enumerate(epochs)]
    write_csv(os.path.join(ART, "fig_state_phi.csv"),
              "epoch," + ",".join(labels), rows)
    write_csv(os.path.join(ART, "fig_state_queue_heatmap.csv"),
              "strategy,epoch,node,depth", heat_rows)
    return rows


if __name__ == "__main__":
    run()
