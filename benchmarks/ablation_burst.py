"""Ablation (ours, beyond-paper): burstiness of the arrival process.

The paper calls its arrivals "Markov ... random and bursty".  This ablation
shows *why* that matters: under a memoryless Poisson feed at the same mean
rate (util ≈ 0.5/node) every strategy is equivalent — collaborative
offloading only pays when transient hotspots exist.  duty = on/(on+off);
1.0 ≈ Poisson.
"""
from __future__ import annotations

import dataclasses
import os

from benchmarks.common import ART, DEFAULT_RUNS, ci95, timed_sweep, write_csv
from repro.configs.base import SwarmConfig
from repro.swarm import DISTRIBUTED, LOCAL_ONLY


def run(duties=(0.125, 0.25, 0.5, 1.0), n=30, runs=DEFAULT_RUNS):
    rows = []
    for duty in duties:
        on = 2.0
        off = on * (1.0 - duty) / max(duty, 1e-6)
        cfg = dataclasses.replace(SwarmConfig(num_workers=n),
                                  burst_on_s=on, burst_off_s=max(off, 1e-3))
        res = timed_sweep(cfg, [LOCAL_ONLY, DISTRIBUTED], n, runs)
        lat_l, _ = ci95(res["LocalOnly"]["avg_latency_s"])
        lat_d, _ = ci95(res["Distributed"]["avg_latency_s"])
        fom_l, _ = ci95(res["LocalOnly"]["fom"])
        fom_d, _ = ci95(res["Distributed"]["fom"])
        gain = lat_l / max(lat_d, 1e-9)
        rows.append([duty, f"{lat_l:.5g}", f"{lat_d:.5g}", f"{gain:.3f}",
                     f"{fom_l:.5g}", f"{fom_d:.5g}"])
        print(f"duty={duty:<6} latency local={lat_l:.4g}s dist={lat_d:.4g}s "
              f"(gain {gain:.2f}x)  fom {fom_l:.4g} -> {fom_d:.4g}")
    write_csv(os.path.join(ART, "ablation_burst.csv"),
              "duty,latency_local_s,latency_dist_s,latency_gain,"
              "fom_local,fom_dist", rows)
    return rows


if __name__ == "__main__":
    run()
