"""Shared benchmark utilities: fleet-sweep execution + CI computation + CSV
emission (one file per paper figure, `name,us_per_call,derived` rows for
run.py).

The figure scripts declare :class:`repro.fleet.SweepSpec` grids and execute
them through ``fleet_sweep`` below, which also records each figure's
aggregate indices into ``artifacts/BENCH_fleet.json``.  Env knobs:

  REPRO_FLEET_BACKEND=vmap|sharded|streaming   executor backend (default vmap)
  REPRO_FLEET_CACHE=<dir>   content-addressed result cache: re-runs are free,
                            interrupted streaming sweeps resume per chunk
  REPRO_FLEET_WORKERS=N     dispatch points across N local worker processes
                            (repro.fleet.dispatch; run.py --workers sets it)
  REPRO_FLEET_LEASE_TTL=S   dispatch lease TTL in seconds (default 30; only
                            a *dead* worker's lease expires — live workers
                            heartbeat-renew — so this is the reclaim delay)
  REPRO_FLEET_PROGRESS=<p>  progress.jsonl path (default artifacts/
                            progress.jsonl; run.py --watch renders it)
  REPRO_FLEET_TRACE=C       per-task telemetry: run every sweep with
                            SwarmConfig.trace_capacity = C (run.py --trace
                            sets it), so BENCH_fleet.json sections gain the
                            task-level indices (task_latency_cdf_s, …)
  REPRO_FLEET_TRACE_HOPS=C  per-hop telemetry: SwarmConfig.trace_hop_capacity
                            = C (run.py --trace-hops sets it) — BENCH
                            sections additionally gain the hop-resolved
                            indices (per-hop transfer-time / link-bits
                            quantiles, queue-wait vs in-flight, airtime-J
                            energy attribution)
  REPRO_FLEET_NEIGHBOR_K=K  sparse neighbor-list path: run sweeps with
                            SwarmConfig.neighbor_mode="sparse",
                            neighbor_k=K (run.py --neighbor-k sets it) —
                            the O(N·k) φ epoch update, DESIGN.md §11
  REPRO_FLEET_TRACE_STATE=E        flight recorder: run every sweep with
                                   SwarmConfig.trace_state_every = E
                                   (run.py --trace-state sets it) — BENCH
                                   sections gain φ-convergence curves,
                                   queue-depth heatmaps, energy-drain
                                   trajectories (DESIGN.md §12)
  REPRO_FLEET_TRACE_STATE_NODES=M  node subsample of the state stream
                                   (first M nodes; 0 = all)
  REPRO_FULL_RUNS=1         the paper's 50 Monte-Carlo runs (default 16)
  REPRO_FLEET_FINGERPRINTS=0   skip the J005 compile-fingerprint table
                               (on by default: tracing is compile-free);
                               REPRO_FLEET_FINGERPRINT_MAX caps points

Every ``fleet_sweep`` additionally records each point's compile/execute
wall-clock spans into the ``profile`` section of BENCH_fleet.json, each
entry tagged with its ``host_class`` (``repro.obs.host_class``) so
``benchmarks/perf_gate.py`` only hard-fails same-class comparisons and
downgrades cross-class excesses to warnings (DESIGN.md §14.5).

Multi-host mode: with the ``REPRO_FLEET_*`` rank/world env contract set
(``fleet/dispatch.py``), every figure sweep runs as this rank's worker
against the shared cache; only rank 0 records/returns results.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SwarmConfig
from repro.fleet import (ProgressWriter, ResultStore, SweepSpec,
                         build_report, execute, publish_spec, run_sweep,
                         worker_env, write_bench_json)
from repro.fleet.report import ci95  # noqa: F401  (re-export: fig scripts)
from repro.swarm import STRATEGY_NAMES, run_many

ART = os.path.join(os.path.dirname(__file__), "artifacts")
BENCH_JSON = os.path.join(ART, "BENCH_fleet.json")
PROGRESS_JSONL = os.environ.get("REPRO_FLEET_PROGRESS",
                                os.path.join(ART, "progress.jsonl"))

# paper: 50 runs / 95% CI.  The bench default trades Monte-Carlo count for
# wall time on this 1-core container; REPRO_FULL_RUNS=1 restores 50.
DEFAULT_RUNS = 50 if os.environ.get("REPRO_FULL_RUNS") == "1" else 16
DEFAULT_BACKEND = os.environ.get("REPRO_FLEET_BACKEND", "vmap")


def default_store(required: bool = False) -> Optional[ResultStore]:
    """REPRO_FLEET_CACHE store; dispatch needs one (leases + results live
    there), so ``required`` falls back to ``artifacts/fleet_cache``."""
    root = os.environ.get("REPRO_FLEET_CACHE")
    if not root and required:
        root = os.path.join(ART, "fleet_cache")
    return ResultStore(root) if root else None


def default_workers() -> int:
    return int(os.environ.get("REPRO_FLEET_WORKERS", "1"))


def apply_trace_env(spec: SweepSpec) -> SweepSpec:
    """Fold the ``REPRO_FLEET_TRACE`` / ``REPRO_FLEET_TRACE_HOPS``
    capacities and the ``REPRO_FLEET_NEIGHBOR_K`` sparse-path knob
    (run.py ``--neighbor-k``) into a sweep's base config.

    All three are part of the point identity (they are config fields in
    the digest), so traced/untraced and sparse/dense results never alias
    in the store; with the knobs unset the spec is returned untouched and
    every emitted byte matches the historical build.
    """
    over = {}
    cap = int(os.environ.get("REPRO_FLEET_TRACE", "0"))
    if cap > 0 and spec.base.trace_capacity == 0:
        over["trace_capacity"] = cap
    hop_cap = int(os.environ.get("REPRO_FLEET_TRACE_HOPS", "0"))
    if hop_cap > 0 and spec.base.trace_hop_capacity == 0:
        over["trace_hop_capacity"] = hop_cap
    nk = int(os.environ.get("REPRO_FLEET_NEIGHBOR_K", "0"))
    if nk > 0 and spec.base.neighbor_mode == "dense":
        over["neighbor_mode"] = "sparse"
        over["neighbor_k"] = nk
    se = int(os.environ.get("REPRO_FLEET_TRACE_STATE", "0"))
    if se > 0 and spec.base.trace_state_every == 0:
        over["trace_state_every"] = se
        sn = int(os.environ.get("REPRO_FLEET_TRACE_STATE_NODES", "0"))
        if sn > 0:
            over["trace_state_nodes"] = sn
    if not over:
        return spec
    return dataclasses.replace(
        spec, base=dataclasses.replace(spec.base, **over))


def fleet_sweep(spec: SweepSpec, backend: Optional[str] = None,
                store: Optional[ResultStore] = None,
                record: bool = True,
                workers: Optional[int] = None) -> Dict[str, Dict]:
    """Execute a sweep through the fleet engine: ``{point label: metrics}``.

    Backend/store/workers default from the env knobs above; with ``record``
    the aggregated indices land in ``BENCH_fleet.json`` under
    ``sweep:<spec.name>``.  ``workers > 1`` (or the multi-host env
    contract) routes through ``repro.fleet.dispatch`` — results are
    byte-identical to the single-process path by construction.
    """
    backend = backend or DEFAULT_BACKEND
    workers = default_workers() if workers is None else workers
    spec = apply_trace_env(spec)
    env = worker_env()
    if workers > 1 or env.world > 1:
        from repro.fleet.dispatch import DEFAULT_LEASE_TTL_S
        store = store if store is not None else default_store(required=True)
        publish_spec(spec, store)
        res = run_sweep(spec, store, workers=workers, backend=backend,
                        lease_ttl_s=float(os.environ.get(
                            "REPRO_FLEET_LEASE_TTL", DEFAULT_LEASE_TTL_S)),
                        progress_path=PROGRESS_JSONL)
        if res is None:
            return {}    # non-zero rank: computed its share, nothing to emit
    else:
        store = store if store is not None else default_store()
        res = execute(spec, backend=backend, store=store,
                      progress=ProgressWriter(PROGRESS_JSONL))
    if record:
        write_bench_json(
            BENCH_JSON, f"sweep:{spec.name}",
            build_report(res, meta={"backend": backend,
                                    "num_runs": spec.num_runs},
                         # per point: a sweep axis may override either knob
                         tick_s={pt.label: pt.cfg.tick_s
                                 for pt in spec.expand()},
                         tx_power_dbm={pt.label: pt.cfg.tx_power_dbm
                                       for pt in spec.expand()},
                         # per-point config → latency_segments critical-
                         # path attribution on traced points (§14.4)
                         cfg={pt.label: pt.cfg for pt in spec.expand()}))
        payload = _profile_payload(spec, res, backend)
        if payload:
            # merge per sweep name: profile is the one BENCH section with
            # wall-clock content, accumulated across producers (the perf
            # gate compares it against the committed baseline)
            from repro.fleet.report import load_bench_json
            merged = dict(load_bench_json(BENCH_JSON).get("profile", {}))
            merged[spec.name] = payload
            write_bench_json(BENCH_JSON, "profile", merged)
        fps = _fingerprint_payload(spec)
        if fps:
            from repro.fleet.report import load_bench_json
            merged = dict(load_bench_json(BENCH_JSON).get("fingerprints",
                                                          {}))
            merged[spec.name] = fps
            write_bench_json(BENCH_JSON, "fingerprints", merged)
    return res


def _fingerprint_payload(spec: SweepSpec) -> Dict:
    """J005 compile-fingerprint table of one sweep (DESIGN.md §15.3).

    Tracing is compile-free (``jax.make_jaxpr``, no XLA), so the table is
    cheap next to the sweep itself; still, ``REPRO_FLEET_FINGERPRINTS=0``
    opts out and very large grids are capped (skipped points are counted
    in the payload, never silently dropped).  A tracing failure degrades
    to an ``error`` entry rather than failing the benchmark run: the
    fingerprints section is diagnosis for the perf gate, not a gate on
    producing numbers.
    """
    if os.environ.get("REPRO_FLEET_FINGERPRINTS", "1") == "0":
        return {}
    cap = int(os.environ.get("REPRO_FLEET_FINGERPRINT_MAX", "64"))
    try:
        from repro.analysis.jaxpr.fingerprint import sweep_fingerprint_table
        return sweep_fingerprint_table(spec, max_points=cap)
    except Exception as e:  # diagnosis must not sink the producer
        return {"sweep": spec.name, "error": f"{type(e).__name__}: {e}"}


def _profile_payload(spec: SweepSpec, res: Dict[str, Dict],
                     backend: str) -> Dict:
    """Per-point compile/execute wall-clock spans of one finished sweep.

    The single-process ``execute`` path carries ``_compile_s`` /
    ``_execute_s`` pseudo-metrics in ``res``; a dispatched sweep's results
    come back clean from the store, so the spans are recovered from the
    workers' ``point`` rows in progress.jsonl (last row per label wins —
    that's the worker that actually computed it).  Cache-hit points record
    ``cached: true`` with no spans: a hit cost no compile or execute time,
    and the perf gate skips it.
    """
    from repro.fleet.dispatch import read_progress
    from repro.obs import host_class

    prog: Dict[str, Dict] = {}
    for row in read_progress(PROGRESS_JSONL):
        if row.get("event") == "point" and row.get("label"):
            prog[row["label"]] = row
    payload = {}
    hc = host_class()
    for label, m in res.items():
        entry = {"backend": backend, "cached": True, "host_class": hc,
                 "wall_s": None, "compile_s": None, "execute_s": None}
        if m.get("_execute_s") is not None:
            entry.update(cached=False,
                         wall_s=round(float(m["_wall_s"]), 3),
                         compile_s=round(float(m["_compile_s"]), 3),
                         execute_s=round(float(m["_execute_s"]), 3))
        elif "_wall_s" in m:
            entry["wall_s"] = round(float(m["_wall_s"]), 3)
        elif label in prog:     # dispatched: spans live in progress rows
            row = prog[label]
            entry.update(cached=bool(row.get("cached", False)),
                         wall_s=row.get("wall_s"),
                         compile_s=row.get("compile_s"),
                         execute_s=row.get("execute_s"))
        payload[label] = entry
    return payload


def timed_sweep(cfg: SwarmConfig, strategies: Sequence[int], n: int,
                runs: int, key=None) -> Dict[str, Dict]:
    """Legacy per-config strategy sweep over ``run_many`` (kept for the
    ablation scripts; the figure scripts go through ``fleet_sweep``)."""
    key = jax.random.PRNGKey(0) if key is None else key
    out = {}
    for s in strategies:
        t0 = time.perf_counter()
        m = run_many(key, cfg, jnp.int32(s), n, runs)
        m = {k: np.asarray(v) for k, v in m.items()}
        m["_wall_s"] = time.perf_counter() - t0
        out[STRATEGY_NAMES[s]] = m
    return out


def write_csv(path: str, header: str, rows):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(header + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    print(f"wrote {path} ({len(rows)} rows)")
