"""Shared benchmark utilities: CI computation + CSV emission (one file per
paper figure, `name,us_per_call,derived` rows for run.py)."""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SwarmConfig
from repro.swarm import STRATEGY_NAMES, run_many

ART = os.path.join(os.path.dirname(__file__), "artifacts")

# paper: 50 runs / 95% CI.  The bench default trades Monte-Carlo count for
# wall time on this 1-core container; REPRO_FULL_RUNS=1 restores 50.
DEFAULT_RUNS = 50 if os.environ.get("REPRO_FULL_RUNS") == "1" else 16


def ci95(x: np.ndarray):
    m = x.mean()
    half = 1.96 * x.std(ddof=1) / np.sqrt(len(x)) if len(x) > 1 else 0.0
    return m, half


def timed_sweep(cfg: SwarmConfig, strategies: Sequence[int], n: int,
                runs: int, key=None) -> Dict[str, Dict]:
    key = jax.random.PRNGKey(0) if key is None else key
    out = {}
    for s in strategies:
        t0 = time.perf_counter()
        m = run_many(key, cfg, jnp.int32(s), n, runs)
        m = {k: np.asarray(v) for k, v in m.items()}
        m["_wall_s"] = time.perf_counter() - t0
        out[STRATEGY_NAMES[s]] = m
    return out


def write_csv(path: str, header: str, rows):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(header + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    print(f"wrote {path} ({len(rows)} rows)")
